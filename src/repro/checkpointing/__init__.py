"""Checkpointing: pytree save/restore with sharded device placement.

Weights-on-disk is the largest context element; this module is the staging
format behind ``ContextElement("weights")``.  Storage is a single ``.npz``
(one entry per flattened pytree path) plus a json manifest capturing dtypes
and the tree structure, so restore can place each leaf directly onto its
:class:`NamedSharding` without materialising the full tree on one host.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import jax
import numpy as np

_SEP = "||"


def _flatten(params) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        flat[key] = leaf
    return flat


def save_checkpoint(path: str, params, *, step: int = 0) -> int:
    """Write params; returns total bytes written.

    numpy's npz cannot round-trip ml_dtypes (bfloat16 etc.) — those leaves
    are stored as raw uint views and re-viewed on restore per the manifest.
    """
    os.makedirs(path, exist_ok=True)
    flat = {k: np.asarray(v) for k, v in _flatten(params).items()}
    stored = {}
    for k, v in flat.items():
        if v.dtype.kind not in "fiub" or str(v.dtype) == "bfloat16":
            stored[k] = v.view(np.uint16 if v.dtype.itemsize == 2
                               else np.uint8)
        else:
            stored[k] = v
    np.savez(os.path.join(path, "params.npz"), **stored)
    manifest = {
        "step": step,
        "leaves": {k: {"dtype": str(v.dtype), "shape": list(v.shape)}
                   for k, v in flat.items()},
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return int(sum(v.nbytes for v in flat.values()))


def restore_checkpoint(path: str, like, *, shardings=None):
    """Restore into the structure of ``like`` (a params pytree or its spec).

    ``shardings`` (optional pytree matching ``like``) places each leaf via
    ``jax.device_put`` directly onto its NamedSharding — host memory never
    holds more than one leaf beyond the mmap'd npz.
    """
    data = np.load(os.path.join(path, "params.npz"), mmap_mode="r")
    with open(os.path.join(path, "manifest.json")) as f:
        leaves_meta = json.load(f)["leaves"]
    flat_like = _flatten(like)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    out = {}
    for key, leaf in flat_like.items():
        arr = data[key]
        saved_dtype = leaves_meta[key]["dtype"]
        if str(arr.dtype) != saved_dtype:      # stored as a raw uint view
            import ml_dtypes
            arr = np.asarray(arr).view(np.dtype(
                getattr(ml_dtypes, saved_dtype)))
        if list(arr.shape) != list(np.shape(leaf)):
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {np.shape(leaf)}")
        tgt_dtype = getattr(leaf, "dtype", arr.dtype)
        arr = arr.astype(tgt_dtype)
        sh = flat_shard.get(key)
        out[key] = jax.device_put(arr, sh) if sh is not None else \
            jax.numpy.asarray(arr)
    # rebuild the tree
    leaves_order = [
        _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        for path, _ in jax.tree_util.tree_flatten_with_path(like)[0]]
    treedef = jax.tree_util.tree_structure(like)
    return jax.tree_util.tree_unflatten(treedef,
                                        [out[k] for k in leaves_order])


def checkpoint_step(path: str) -> Optional[int]:
    m = os.path.join(path, "manifest.json")
    if not os.path.exists(m):
        return None
    with open(m) as f:
        return json.load(f)["step"]
