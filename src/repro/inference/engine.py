"""Batched inference engine: jitted prefill + decode loop, KV cache managed.

The engine is the computational payload the context-management layer hosts:
``params`` (device-resident weights), the jitted ``prefill``/``decode_step``
executables, and the tokenizer together form the *pervasive context*; an
:class:`InferenceEngine` instance is exactly what a library process keeps
alive between tasks.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import model as M


@dataclass
class GenerationResult:
    tokens: np.ndarray            # (B, n_new)
    n_prefill: int
    n_new: int


class InferenceEngine:
    def __init__(self, cfg, params, *, max_len: int = 256):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        # the compiled executables are part of the context (DESIGN.md §2)
        self._prefill = jax.jit(
            functools.partial(M.prefill, cfg, max_len=max_len))
        self._decode = jax.jit(functools.partial(M.decode_step, cfg))

    # ------------------------------------------------------------------
    def generate(self, batch: Dict[str, Any], *, max_new: int = 16,
                 temperature: float = 0.0,
                 seed: int = 0) -> GenerationResult:
        """Greedy (or sampled) continuation of ``batch['tokens']``."""
        tokens = jnp.asarray(batch["tokens"])
        B, S = tokens.shape
        assert S + max_new <= self.max_len, (S, max_new, self.max_len)
        logits, cache = self._prefill(self.params, batch)
        key = jax.random.PRNGKey(seed)
        out: List[jnp.ndarray] = []
        tok = self._select(logits[:, -1], temperature, key)
        out.append(tok)
        for i in range(max_new - 1):
            logits, cache = self._decode(self.params, cache, tok[:, None])
            key = jax.random.fold_in(key, i)
            tok = self._select(logits[:, -1], temperature, key)
            out.append(tok)
        return GenerationResult(np.asarray(jnp.stack(out, axis=1)), S,
                                max_new)

    @staticmethod
    def _select(logits, temperature: float, key) -> jnp.ndarray:
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / temperature,
                                      axis=-1).astype(jnp.int32)

    # ------------------------------------------------------------------
    def warmup(self, batch: Dict[str, Any]) -> None:
        """Force compilation (the xla_executable context element)."""
        self.generate(batch, max_new=2)
