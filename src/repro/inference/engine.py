"""Batched inference engine: jitted prefill + decode loop, KV cache managed.

The engine is the computational payload the context-management layer hosts:
``params`` (device-resident weights), the jitted ``prefill``/``decode_step``
executables, and the tokenizer together form the *pervasive context*; an
:class:`InferenceEngine` instance is exactly what a library process keeps
alive between tasks.

The decode loop never round-trips logits to the host: the greedy path is a
single ``lax.scan`` over the whole budget (one dispatch per ``generate``),
and the sampled path fuses token selection into the jitted step (one small
int32 transfer per token instead of a materialised (B, V) logits array) —
so the engine baseline the slot-pool streaming decoder is measured against
is compute-bound, not dispatch-bound.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models import model as M


@dataclass
class GenerationResult:
    tokens: np.ndarray            # (B, n_new)
    n_prefill: int
    n_new: int


class InferenceEngine:
    def __init__(self, cfg, params, *, max_len: int = 256):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        # the compiled executables are part of the context (DESIGN.md §2)
        self._prefill = jax.jit(
            functools.partial(M.prefill, cfg, max_len=max_len))
        self._decode_sample = jax.jit(self._decode_sample_impl)
        self._greedy_loops: Dict[int, Any] = {}   # n_steps -> compiled scan

    # ------------------------------------------------------------------
    def generate(self, batch: Dict[str, Any], *, max_new: int = 16,
                 temperature: float = 0.0,
                 seed: int = 0) -> GenerationResult:
        """Greedy (or sampled) continuation of ``batch['tokens']``."""
        tokens = jnp.asarray(batch["tokens"])
        B, S = tokens.shape
        assert S + max_new <= self.max_len, (S, max_new, self.max_len)
        logits, cache = self._prefill(self.params, batch)
        key = jax.random.PRNGKey(seed)
        if temperature <= 0.0:
            tok0 = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            out = self._greedy_loop(max_new - 1)(self.params, cache, tok0)
            toks = jnp.concatenate([tok0[:, None], out], axis=1)
            return GenerationResult(np.asarray(toks), S, max_new)
        tok = self._select(logits[:, -1], temperature, key)
        out = [tok]
        for i in range(max_new - 1):
            tok, cache = self._decode_sample(
                self.params, cache, tok, jax.random.fold_in(key, i),
                jnp.float32(temperature))
            out.append(tok)
        return GenerationResult(np.asarray(jnp.stack(out, axis=1)), S,
                                max_new)

    def _decode_sample_impl(self, params, cache, tok, key, temperature
                            ) -> Tuple[jnp.ndarray, Any]:
        """One decode step with sampling FUSED: only the (B,) int32 token
        leaves the device, never the (B, V) logits."""
        logits, cache = M.decode_step(self.cfg, params, cache, tok[:, None])
        nxt = jax.random.categorical(key, logits[:, -1] / temperature,
                                     axis=-1).astype(jnp.int32)
        return nxt, cache

    def _greedy_loop(self, n_steps: int):
        """Whole greedy continuation as ONE jitted ``lax.scan`` dispatch."""
        fn = self._greedy_loops.get(n_steps)
        if fn is None:
            cfg = self.cfg

            def loop(params, cache, tok0):
                def body(carry, _):
                    cache, tok = carry
                    logits, cache = M.decode_step(cfg, params, cache,
                                                  tok[:, None])
                    nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
                    return (cache, nxt), nxt

                (_, _), toks = jax.lax.scan(body, (cache, tok0), None,
                                            length=n_steps)
                return toks.T                      # (B, n_steps)

            fn = self._greedy_loops[n_steps] = jax.jit(loop)
        return fn

    @staticmethod
    def _select(logits, temperature: float, key) -> jnp.ndarray:
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / temperature,
                                      axis=-1).astype(jnp.int32)

    # ------------------------------------------------------------------
    def warmup(self, batch: Dict[str, Any]) -> None:
        """Force compilation (the xla_executable context element)."""
        self.generate(batch, max_new=2)
