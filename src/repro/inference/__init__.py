"""Inference: batched engine + the Prompt-for-Fact application."""
from .engine import GenerationResult, InferenceEngine
from .pff import (MAX_NEW, PROMPT_LEN, build_context_recipe, infer_claims,
                  sweep_accuracy)

__all__ = ["GenerationResult", "InferenceEngine", "MAX_NEW", "PROMPT_LEN",
           "build_context_recipe", "infer_claims", "sweep_accuracy"]
