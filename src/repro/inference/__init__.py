"""Inference: batched engine, streaming decoder + the PfF application."""
from .engine import GenerationResult, InferenceEngine
from .pff import (MAX_NEW, PROMPT_LEN, build_context_recipe, infer_claims,
                  sweep_accuracy)
from .streaming import (SlotPool, StreamingDecoder, make_pff_step_fn,
                        stream_verdict)

__all__ = ["GenerationResult", "InferenceEngine", "MAX_NEW", "PROMPT_LEN",
           "SlotPool", "StreamingDecoder", "build_context_recipe",
           "infer_claims", "make_pff_step_fn", "stream_verdict",
           "sweep_accuracy"]
