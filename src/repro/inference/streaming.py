"""Live continuous-batching decoder: re-formed padded batches per step.

The LIVE leg of the request-stream redesign.  A library's dynamic batch
changes membership between decode steps, so the device batch cannot be a
fixed (B, S) array compiled once per task.  :class:`StreamingDecoder`
keeps per-request token state on the host and, at EVERY step, re-forms
the padded JAX batch for the current membership:

* batch dim padded up to the next power of two;
* sequence dim padded up to the next multiple of 8;

so however requests churn, the number of distinct compiled shapes — and
hence XLA recompiles — is O(log max_batch · max_len / 8), not O(steps).

Decoding runs through the model's full-forward path (prompt + generated
so far each step) with per-row logit gather at each request's own last
position; causal attention makes right-padding inert, so the streamed
greedy tokens are exactly what a per-request full-forward loop produces
(asserted in tests/test_streaming_live.py).
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Sequence

import jax
import numpy as np

from ..data.prompts import parse_verdict
from ..data.tokenizer import PAD
from ..models import model as M
from .pff import PROMPT_LEN


def _next_pow2(n: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return b


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


class StreamingDecoder:
    """Greedy decoder over a membership-changing request batch."""

    def __init__(self, cfg, params, tokenizer, template, *,
                 prompt_len: int = PROMPT_LEN):
        self.cfg = cfg
        self.params = params
        self.tokenizer = tokenizer
        self.template = template
        self.prompt_len = prompt_len
        self._tokens: Dict[int, List[int]] = {}   # rid -> prompt+generated
        self._prompt_end: Dict[int, int] = {}
        self._fwd = jax.jit(
            lambda p, toks: M.forward(cfg, p, {"tokens": toks}))
        self._shapes: set = set()                 # compile-shape audit

    # -- membership -----------------------------------------------------
    def ensure(self, rid: int, claim) -> None:
        """Admit ``rid``: tokenize its prompt (idempotent)."""
        if rid in self._tokens:
            return
        ids = self.tokenizer.encode(
            self.template.render(claim))[:self.prompt_len]
        self._tokens[rid] = list(ids)
        self._prompt_end[rid] = len(ids)

    def finish(self, rid: int) -> List[int]:
        """Release ``rid``'s state; returns its generated token ids."""
        toks = self._tokens.pop(rid, [])
        end = self._prompt_end.pop(rid, len(toks))
        return toks[end:]

    # -- the step -------------------------------------------------------
    def step(self, rids: Sequence[int]) -> Dict[int, int]:
        """One greedy decode step for the CURRENT membership.

        Re-forms the padded (B, S) batch — B/S bucketed — runs the full
        forward, gathers each row's logits at its own last position, and
        appends the argmax token.  Returns {rid: new_token}."""
        rids = list(rids)
        if not rids:
            return {}
        seqs = [self._tokens[r] for r in rids]
        lens = [len(s) for s in seqs]
        B = _next_pow2(len(rids))
        S = _round_up(max(lens), 8)
        arr = np.full((B, S), PAD, dtype=np.int32)
        for i, s in enumerate(seqs):
            arr[i, :len(s)] = s
        self._shapes.add((B, S))
        logits = np.asarray(self._fwd(self.params, arr))
        out: Dict[int, int] = {}
        for i, rid in enumerate(rids):
            nxt = int(np.argmax(logits[i, lens[i] - 1]))
            self._tokens[rid].append(nxt)
            out[rid] = nxt
        return out

    @property
    def shape_buckets(self) -> int:
        """Distinct (B, S) buckets seen — an upper bound on recompiles."""
        return len(self._shapes)


def make_pff_step_fn(prompt_len: int = PROMPT_LEN):
    """Step function for :class:`~repro.cluster.LiveExecutor.step_fns`.

    Lazily builds a :class:`StreamingDecoder` inside the library's
    payloads (it belongs to the hosted context: it dies with a spill and
    is rebuilt on re-materialisation) and advances the current members by
    one token.  Request payloads are the claims to verify."""
    def step_fn(payloads, members):
        dec = payloads.get("_stream_decoder")
        if dec is None:
            engine = payloads["xla_executable"]
            ci = payloads["context_inputs"]
            dec = StreamingDecoder(engine.cfg, engine.params,
                                   ci["tokenizer"], ci["template"],
                                   prompt_len=prompt_len)
            payloads["_stream_decoder"] = dec
        for r in members:
            dec.ensure(r.request_id, r.payload)
        out = dec.step([r.request_id for r in members])
        for r in members:
            if r.steps_done + 1 >= r.n_units:    # last step: free state
                dec.finish(r.request_id)
        return out
    return step_fn


def stream_verdict(tokenizer, step_tokens: Iterable[int]) -> str:
    """Decode one request's accumulated step outputs into a verdict."""
    return parse_verdict(tokenizer.decode(list(step_tokens)))
