"""Live continuous-batching decoder: a persistent slot pool of KV state.

The LIVE leg of the request-stream redesign.  A library's dynamic batch
changes membership between decode steps, so the device batch cannot be a
fixed (B, S) array compiled once per task.  :class:`StreamingDecoder`
keeps the decode state RESIDENT on the device instead: a
:class:`SlotPool` of ``capacity`` rows of KV cache that requests bind to
on admission and free on completion.

* **admit** — a new request's prompt runs through a prompt-only prefill
  that scatters its K/V + position into the shared cache at its slot,
  without touching live rows;
* **step** — ONE cached ``M.decode_step`` over all slots advances every
  active row by one token at O(1) FLOPs/token (each row embeds/RoPEs at
  its own position, ring-writes at its own slot, masks at its own
  length via the vector-``n_valid`` decode-attention kernel);
* **finish** — the slot returns to the free list; its stale K/V is
  either fully overwritten by the next tenant's admission prefill
  (contiguous) or unmapped from the page table (paged), so reuse never
  leaks context across requests.

Paged KV layout (``paged=True``, the default where
``M.supports_paging``)
----------------------------------------------------------------------
The contiguous per-slot ring (B, max_len, K, hd) is replaced by
PHYSICAL PAGE POOLS of shape (L, n_pages, page_size, K, hd) shared by
every row, addressed through a per-row PAGE TABLE:

* ``cache["table"]`` is (B, max_pages) int32.  Row ``b``'s logical ring
  slot ``s`` (s = pos % T, T = max_pages * page_size) lives at physical
  coordinates ``(table[b, s // page_size], s % page_size)``.  Entry 0 is
  the UNMAPPED sentinel: physical page 0 is reserved as the trash page
  — never allocated, never attended (it always sits past ``n_valid``),
  and the landing zone for masked lock-step writes.
* :class:`PagePool` owns the physical pages host-side with REFCOUNTS.
  ``alloc`` → refcount 1; admission of a request whose prompt prefix is
  already resident increfs the shared pages instead of recomputing
  them; ``finish`` decrefs every mapped page and frees at zero.
* :class:`PrefixIndex` maps EXACT token tuples (no hashing collisions:
  the key is the tuple itself) of whole-page prompt prefixes to the
  page chain holding them.  On admission the longest indexed prefix —
  capped at ``(prompt_len - 1) // page_size`` pages so the tail is
  never empty and the first-token logits still come from this
  request's own prefill — is mapped by reference (refcount++, ZERO
  prefill FLOPs, ZERO new KV bytes) and only the unshared tail runs
  through ``M.prefill_into_pages``.  Index entries are purged when
  their page is freed or overwritten in place (ring wrap), so a hit
  can never alias stale bytes.
* Copy-on-write: decode writes land in the page holding slot
  ``pos % T``.  Before each step ``_ensure_writable`` allocates a fresh
  page when that entry is unmapped, and COPIES the page (then decrefs
  the original) when its refcount is > 1 — a tenant wrapping its ring
  into a shared prefix page never corrupts the other holders.

Compiled-shape accounting: the decode step compiles once per pool
capacity (capacities grow by doubling) with paging on or off — the page
table is a cache VALUE, not a shape — and prefill once per (admission
batch bucket, tail-length bucket).  Per-slot cache bytes are MEASURED
after the first admission (``measured_slot_bytes``; for the paged
layout this is the worst case ``max_pages * page_bytes`` a row can pin)
and fed back into ``ContextRecipe.decode_slot_bytes`` by the live
executor when sizing slot budgets.

Over-length prompts are never silently truncated any more: with
``strict_prompts=True`` admission raises; otherwise the prompt is
clipped and the request's ``truncated`` flag (surfaced through
``RequestRecord``) records it.

The pre-slot full-forward path (prompt + generated prefix re-run
through ``M.forward`` every step; right-padding inert under causal
attention) survives as ``slot_cached=False`` — the token-exactness
reference both cached paths are asserted against in
tests/test_streaming_live.py and tests/test_paged_kv.py.
"""
from __future__ import annotations

import functools
from collections import OrderedDict
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

import jax
import numpy as np

from ..data.prompts import parse_verdict
from ..data.tokenizer import PAD
from ..models import model as M
from .pff import PROMPT_LEN


def _next_pow2(n: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return b


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


class SlotPool:
    """Fixed-capacity allocator binding request ids to cache rows."""

    def __init__(self, capacity: int = 0):
        self.capacity = capacity
        self.slot_of: Dict[int, int] = {}
        self._free: List[int] = list(range(capacity - 1, -1, -1))

    def bind(self, rid: int) -> int:
        slot = self._free.pop()
        self.slot_of[rid] = slot
        return slot

    def release(self, rid: int) -> Optional[int]:
        slot = self.slot_of.pop(rid, None)
        if slot is not None:
            self._free.append(slot)
        return slot

    def grow(self, capacity: int) -> None:
        assert capacity >= self.capacity
        self._free[:0] = range(capacity - 1, self.capacity - 1, -1)
        self.capacity = capacity

    @property
    def free(self) -> int:
        return len(self._free)

    def __len__(self) -> int:
        return len(self.slot_of)


class PagePool:
    """Refcounted allocator over the physical KV pages.

    Page 0 is the reserved TRASH page: it is never handed out, doubles
    as the unmapped page-table sentinel, and absorbs masked lock-step
    writes.  Refcounts are host-side only — the device sees pages purely
    through the table.

    PREFIX RETENTION (``retained_cap`` > 0): a page whose refcount hits
    zero is PARKED in an LRU of at most ``retained_cap`` pages instead
    of freed — its bytes stay valid device-side and its prefix-index
    entries survive, so shared-prefix reuse works across GAPS in time,
    not just overlap.  ``incref`` revives a parked page (an index hit on
    a retained prefix); parked pages are reclaimed only under pressure:
    LRU-first when ``alloc`` finds the free list empty, or when the park
    itself overflows the cap.  Reclaiming fires ``on_evict_retained``
    (the decoder wires it to ``PrefixIndex.forget_page``) — index
    entries purge on ACTUAL free, never on park.  ``retained_cap=0``
    (default) frees at zero exactly as before."""

    TRASH = 0

    def __init__(self, n_pages: int, retained_cap: int = 0):
        assert n_pages >= 1
        self.n_pages = n_pages
        self.retained_cap = retained_cap
        self.on_evict_retained = None     # callback(page) on actual free
        self._ref: Dict[int, int] = {}
        self._retained: "OrderedDict[int, None]" = OrderedDict()
        self._free: List[int] = list(range(n_pages - 1, 0, -1))

    def _reclaim_lru(self) -> int:
        """Actually free the least-recently-parked page."""
        page, _ = self._retained.popitem(last=False)
        if self.on_evict_retained is not None:
            self.on_evict_retained(page)
        return page

    def alloc(self) -> int:
        if not self._free and self._retained:
            page = self._reclaim_lru()    # allocation pressure: evict LRU
        else:
            page = self._free.pop()
        self._ref[page] = 1
        return page

    def incref(self, page: int) -> None:
        assert page != self.TRASH
        if page in self._retained:        # prefix hit on a parked page
            del self._retained[page]
            self._ref[page] = 1
            return
        assert page in self._ref
        self._ref[page] += 1

    def decref(self, page: int) -> bool:
        """Drop one reference; returns True when the page was freed
        (a parked page is NOT freed — its bytes remain valid)."""
        assert page != self.TRASH
        assert self._ref.get(page, 0) > 0, \
            f"decref of unreferenced page {page} (double free)"
        self._ref[page] -= 1
        if self._ref[page] == 0:
            del self._ref[page]
            if self.retained_cap > 0:
                self._retained[page] = None
                while len(self._retained) > self.retained_cap:
                    self._free.append(self._reclaim_lru())
                return False
            self._free.append(page)
            return True
        return False

    def refcount(self, page: int) -> int:
        return self._ref.get(page, 0)

    def grow(self, n_pages: int) -> None:
        assert n_pages >= self.n_pages
        self._free[:0] = range(n_pages - 1, self.n_pages - 1, -1)
        self.n_pages = n_pages

    @property
    def free(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return len(self._ref)

    @property
    def retained_count(self) -> int:
        return len(self._retained)


class PrefixIndex:
    """Exact-match index from whole-page prompt prefixes to page chains.

    Keys are the literal token TUPLES of the first ``j * page_size``
    prompt tokens (j = 1..n_full_pages) — exact equality, so a hit can
    never be a hash collision.  Values are the physical page chains
    holding those tokens.  ``forget_page`` removes every entry whose
    chain references a page (called when the page is freed or about to
    be overwritten in place), keeping the index free of stale bytes."""

    def __init__(self):
        self._chains: Dict[Tuple[int, ...], Tuple[int, ...]] = {}
        self._keys_of: Dict[int, Set[Tuple[int, ...]]] = {}

    def insert(self, tokens: Sequence[int], page_size: int,
               pages: Sequence[int]) -> None:
        """Register every whole-page prefix of ``tokens`` (first wins)."""
        n_full = min(len(tokens) // page_size, len(pages))
        for j in range(1, n_full + 1):
            key = tuple(tokens[:j * page_size])
            if key in self._chains:
                continue
            chain = tuple(int(p) for p in pages[:j])
            self._chains[key] = chain
            for p in chain:
                self._keys_of.setdefault(p, set()).add(key)

    def lookup(self, tokens: Sequence[int], page_size: int,
               max_pages: int) -> List[int]:
        """Longest indexed whole-page prefix of ``tokens``, at most
        ``max_pages`` pages (callers cap at (len-1)//page_size so the
        unshared tail is never empty)."""
        best: Tuple[int, ...] = ()
        for j in range(1, max_pages + 1):
            chain = self._chains.get(tuple(tokens[:j * page_size]))
            if chain is None:
                break                    # prefixes are registered in chains
            best = chain
        return list(best)

    def forget_page(self, page: int) -> None:
        for key in self._keys_of.pop(page, ()):
            chain = self._chains.pop(key, ())
            for p in chain:
                if p != page and p in self._keys_of:
                    self._keys_of[p].discard(key)

    def __len__(self) -> int:
        return len(self._chains)


class StreamingDecoder:
    """Greedy decoder over a membership-changing request batch.

    ``slot_cached=True`` (default): persistent slot-pool decode, O(1) per
    token.  ``slot_cached=False``: the full-forward reference path, O(S)
    per token.  Both produce identical greedy tokens while sequences stay
    within ``max_len`` (asserted in tests under membership churn).

    ``paged=None`` turns the paged KV layout on automatically where the
    model family supports it (see module docstring); ``paged=False``
    forces the contiguous per-slot rings; ``paged=True`` on an
    unsupported family raises.

    ``b_max`` pre-sizes the pool (typically the library's slot budget, so
    the decode step compiles exactly once); it is a sizing hint, not a
    hard cap — if the scheduler ever admits beyond it the pool doubles
    rather than dropping in-flight requests.
    """

    def __init__(self, cfg, params, tokenizer, template, *,
                 prompt_len: int = PROMPT_LEN, slot_cached: bool = True,
                 max_len: Optional[int] = None, b_max: Optional[int] = None,
                 paged: Optional[bool] = None, page_size: int = 64,
                 strict_prompts: bool = False, retain_bytes: int = 0):
        self.cfg = cfg
        self.params = params
        self.tokenizer = tokenizer
        self.template = template
        self.prompt_len = prompt_len
        self.slot_cached = slot_cached
        self.max_len = max_len or prompt_len + 64
        self.strict_prompts = strict_prompts
        if paged is None:
            paged = slot_cached and M.supports_paging(cfg)
        elif paged and not M.supports_paging(cfg):
            raise ValueError(
                f"paged KV cache unsupported for {cfg.name}: "
                "recurrent/MLA/cross-attn/int8/windowed caches keep the "
                "contiguous layout")
        self.paged = bool(paged and slot_cached)
        self.page_size = page_size
        self.max_pages = -(-self.max_len // page_size)
        self.pages: Optional[PagePool] = None
        self.prefix = PrefixIndex()
        self._table: Optional[np.ndarray] = None  # host page table mirror
        self._table_dirty = False
        self._tokens: Dict[int, List[int]] = {}   # rid -> prompt+generated
        self._prompt_end: Dict[int, int] = {}
        self.truncated: Dict[int, bool] = {}      # rid -> prompt was clipped
        self._fwd = jax.jit(
            lambda p, toks: M.forward(cfg, p, {"tokens": toks}))
        self._decode = jax.jit(functools.partial(M.decode_step, cfg))
        self._prefill_slots = jax.jit(functools.partial(
            M.prefill_into_slots, cfg, max_len=self.max_len))
        self._prefill_pages = jax.jit(functools.partial(
            M.prefill_into_pages, cfg))
        self._copy_page = jax.jit(lambda stages, dst, src: jax.tree_util.
                                  tree_map(lambda x: x.at[:, dst].
                                           set(x[:, src]), stages))
        self._shapes: set = set()                 # compile-shape audit
        self.pool = SlotPool(b_max or 0)
        self._cache = None                        # device cache pytree
        self.measured_slot_bytes = 0              # real per-slot footprint
        self.prefill_tokens_total = 0             # admission cost counter
        self.shared_tokens_total = 0              # prefix tokens reused
        # prefix-page retention budget (bytes of refcount-zero pages to
        # park, see PagePool); 0 = free-at-zero, the pre-retention path
        self.retain_bytes = retain_bytes
        # rid -> host-side KV snapshot (preemption suspend/resume)
        self._suspended: Dict[int, dict] = {}
        self.kv_suspend_bytes_total = 0           # spill-path byte meters
        self.kv_resume_bytes_total = 0
        # snapshots received from ANOTHER decoder (KV_SHIP): their restore
        # bytes are a handoff landing, not a preemption resume, and must
        # not pollute the spill/resume parity meters
        self._adopted: set = set()
        self.kv_adopt_bytes_total = 0
        self.kv_ckpt_bytes_total = 0              # non-destructive exports

    # -- membership -----------------------------------------------------
    def ensure(self, rid: int, claim) -> None:
        """Admit ``rid``: tokenize its prompt (idempotent)."""
        if rid in self._tokens:
            return
        ids = list(self.tokenizer.encode(self.template.render(claim)))
        self.ensure_tokens(rid, ids, limit=self.prompt_len)

    def ensure_tokens(self, rid: int, token_ids: List[int], *,
                      limit: Optional[int] = None) -> None:
        """Admit ``rid`` with pre-tokenized prompt ids (idempotent).

        Prompts longer than ``limit`` (default: the ``max_len`` ring)
        RAISE under ``strict_prompts``; otherwise they are clipped and
        the request's ``truncated`` flag records it — never a silent
        drop."""
        if rid in self._tokens:
            return
        cap = min(limit or self.max_len, self.max_len)
        if len(token_ids) > cap:
            if self.strict_prompts:
                raise ValueError(
                    f"prompt for request {rid} is {len(token_ids)} tokens "
                    f"but the decoder caps prompts at {cap} "
                    f"(prompt_len={self.prompt_len}, max_len={self.max_len})")
            self.truncated[rid] = True
        else:
            self.truncated[rid] = False
        self._tokens[rid] = list(token_ids)[:cap]
        self._prompt_end[rid] = len(self._tokens[rid])

    def active_rids(self) -> List[int]:
        """Requests currently holding decoder state."""
        return list(self._tokens.keys())

    def finish(self, rid: int) -> List[int]:
        """Release ``rid``'s state (slot + page references); returns its
        generated token ids.  Contiguous: the freed slot's stale K/V is
        inert until the next tenant's admission prefill overwrites the
        row.  Paged: every mapped page is decref'd (freed pages purge
        their prefix-index entries) and the table row reset to trash."""
        slot = self.pool.release(rid)
        if slot is not None and self.paged and self._table is not None:
            for p in self._table[slot]:
                p = int(p)
                if p != PagePool.TRASH and self.pages.decref(p):
                    self.prefix.forget_page(p)
            self._table[slot] = PagePool.TRASH
            self._table_dirty = True
        toks = self._tokens.pop(rid, [])
        end = self._prompt_end.pop(rid, len(toks))
        self.truncated.pop(rid, None)
        return toks[end:]

    # -- preemption: KV suspend / resume --------------------------------
    def has_suspended(self, rid: int) -> bool:
        return rid in self._suspended

    def suspend(self, rid: int) -> int:
        """Spill ``rid``'s decode state HOST-side and release its device
        footprint (slot + pages), so an interactive request can take the
        slot.  The snapshot — token buffer, per-row position, and the
        row's K/V bytes — lives in ``_suspended`` until :meth:`resume`
        restores it bit-exactly, WITHOUT re-prefill.  Returns the
        snapshot's KV byte size (0 if ``rid`` holds no slot)."""
        slot = self.pool.slot_of.get(rid)
        if slot is None or rid not in self._tokens or self._cache is None:
            return 0
        snap: dict = {
            "tokens": list(self._tokens[rid]),
            "prompt_end": self._prompt_end[rid],
            "truncated": self.truncated.get(rid, False),
            "pos": int(np.asarray(self._cache["pos"])[slot]),
        }
        if self.paged:
            mapped = [(pi, int(p)) for pi, p in enumerate(self._table[slot])
                      if int(p) != PagePool.TRASH]
            idx = np.asarray([p for _pi, p in mapped], np.int32)
            host = jax.tree_util.tree_map(
                lambda x: np.asarray(x[:, idx]), self._cache["stages"])
            snap["page_idx"] = [pi for pi, _p in mapped]
            snap["kv"] = host
            for _pi, p in mapped:
                if self.pages.decref(p):
                    self.prefix.forget_page(p)
            self._table[slot] = PagePool.TRASH
            self._table_dirty = True
        else:
            snap["kv"] = jax.tree_util.tree_map(
                lambda x: np.asarray(x[:, slot]), self._cache["stages"])
        nbytes = int(sum(x.nbytes
                         for x in jax.tree_util.tree_leaves(snap["kv"])))
        self.pool.release(rid)
        del self._tokens[rid]
        del self._prompt_end[rid]
        self.truncated.pop(rid, None)
        self._suspended[rid] = snap
        self.kv_suspend_bytes_total += nbytes
        return nbytes

    def resume(self, rid: int) -> int:
        """Re-admit a suspended ``rid`` from its host snapshot: bind a
        slot, scatter the saved K/V back (paged: onto freshly allocated
        pages), restore the row position — NO prefill runs.  Greedy
        decode then continues bit-exactly where it stopped.  Returns the
        restored KV byte size."""
        snap = self._suspended.pop(rid)
        if self.pool.free == 0:
            self._grow(len(self.pool.slot_of) + 1)
        elif self._cache is None:
            self._cache = self._fresh_cache(self.pool.capacity)
        slot = self.pool.bind(rid)
        self._tokens[rid] = snap["tokens"]
        self._prompt_end[rid] = snap["prompt_end"]
        self.truncated[rid] = snap["truncated"]
        if self.paged:
            pages = [self.pages.alloc() for _ in snap["page_idx"]]
            self._table[slot] = PagePool.TRASH
            for pi, p in zip(snap["page_idx"], pages):
                self._table[slot, pi] = p
            self._table_dirty = True
            idx = np.asarray(pages, np.int32)
            self._cache["stages"] = jax.tree_util.tree_map(
                lambda big, small: big.at[:, idx].set(small),
                self._cache["stages"], snap["kv"])
            self._sync_table()
        else:
            self._cache["stages"] = jax.tree_util.tree_map(
                lambda big, small: big.at[:, slot].set(small),
                self._cache["stages"], snap["kv"])
        self._cache["pos"] = self._cache["pos"].at[slot].set(snap["pos"])
        nbytes = int(sum(x.nbytes
                         for x in jax.tree_util.tree_leaves(snap["kv"])))
        if rid in self._adopted:
            self._adopted.discard(rid)
            self.kv_adopt_bytes_total += nbytes
        else:
            self.kv_resume_bytes_total += nbytes
        return nbytes

    # -- disaggregation: KV_SHIP export / adopt -------------------------
    def export_suspended(self, rid: int) -> Optional[dict]:
        """Hand ``rid``'s host-side snapshot to the caller (the KV_SHIP
        path): ownership leaves this decoder entirely — the destination
        decoder takes it via :meth:`adopt`.  Returns None when ``rid``
        holds no suspended state here (e.g. the library was spilled and
        the snapshot died with it)."""
        self._adopted.discard(rid)
        return self._suspended.pop(rid, None)

    def adopt(self, rid: int, snap: dict) -> int:
        """Receive a snapshot shipped from another decoder's
        :meth:`export_suspended`.  It parks in ``_suspended`` exactly
        like a local suspend, so the next step's ``has_suspended`` path
        restores it WITHOUT re-prefill — decode continues bit-exactly
        from the prefill worker's state.  Restore bytes are accounted to
        ``kv_adopt_bytes_total`` (a handoff, not a preemption resume).
        Both decoders must use the same KV layout (same recipe, so same
        paged/contiguous choice and ``max_len``).  Returns the
        snapshot's KV byte size."""
        self._suspended[rid] = snap
        self._adopted.add(rid)
        return int(sum(x.nbytes
                       for x in jax.tree_util.tree_leaves(snap["kv"])))

    # -- crash safety: non-destructive KV checkpoint export -------------
    def checkpoint(self, rid: int) -> Optional[dict]:
        """Export a COPY of ``rid``'s current decode state (the KV_CKPT
        path): the same host-side snapshot :meth:`suspend` builds, but
        the request keeps decoding here — its slot, page mappings and
        refcounts are untouched.  A checkpoint host parks the copy via
        :meth:`adopt`; if this worker later dies, decode resumes
        token-exactly from the snapshot there, losing only the steps
        generated since the export.  Returns None when ``rid`` holds no
        bound slot (nothing to snapshot)."""
        slot = self.pool.slot_of.get(rid)
        if slot is None or rid not in self._tokens or self._cache is None:
            return None
        snap: dict = {
            "tokens": list(self._tokens[rid]),
            "prompt_end": self._prompt_end[rid],
            "truncated": self.truncated.get(rid, False),
            "pos": int(np.asarray(self._cache["pos"])[slot]),
        }
        if self.paged:
            mapped = [(pi, int(p)) for pi, p in enumerate(self._table[slot])
                      if int(p) != PagePool.TRASH]
            idx = np.asarray([p for _pi, p in mapped], np.int32)
            snap["page_idx"] = [pi for pi, _p in mapped]
            snap["kv"] = jax.tree_util.tree_map(
                lambda x: np.asarray(x[:, idx]), self._cache["stages"])
        else:
            snap["kv"] = jax.tree_util.tree_map(
                lambda x: np.asarray(x[:, slot]), self._cache["stages"])
        self.kv_ckpt_bytes_total += int(sum(
            x.nbytes for x in jax.tree_util.tree_leaves(snap["kv"])))
        return snap

    # -- the step -------------------------------------------------------
    def step(self, rids: Sequence[int]) -> Dict[int, int]:
        """One greedy decode step for the CURRENT membership.

        Slot mode: one cached ``decode_step`` over the pool advances the
        rows already bound; newly seen rids are admitted via prefill
        (their first token comes from the prefill logits).  Full mode:
        re-form the padded (B, S) batch and run the full forward.
        Returns {rid: new_token}."""
        rids = list(rids)
        if not rids:
            return {}
        if not self.slot_cached:
            return self._step_full(rids)
        active = [r for r in rids if r in self.pool.slot_of]
        fresh = [r for r in rids if r not in self.pool.slot_of]
        out: Dict[int, int] = {}
        if len(fresh) > self.pool.free:
            self._grow(len(self.pool.slot_of) + len(fresh))
        elif fresh and self._cache is None:       # b_max pre-sized the pool
            self._cache = self._fresh_cache(self.pool.capacity)
        if active:
            out.update(self._decode_active(active))
        if fresh:
            out.update(self._admit(fresh))
        return out

    def _fresh_cache(self, cap: int):
        """Device cache for ``cap`` rows (+ host paging structures)."""
        if not self.paged:
            return M.cache_init(self.cfg, cap, self.max_len)
        n_pages = 1 + cap * self.max_pages        # +1: the trash page
        if self.pages is None:
            self.pages = PagePool(n_pages)
            # retained pages purge their index entries on ACTUAL free
            self.pages.on_evict_retained = self.prefix.forget_page
        self._table = np.zeros((cap, self.max_pages), np.int32)
        self._table_dirty = False                 # fresh device table is 0 too
        return M.paged_cache_init(self.cfg, cap, n_pages, self.page_size,
                                  self.max_pages)

    def _sync_table(self) -> None:
        if self.paged and self._table_dirty:
            self._cache["table"] = jax.numpy.asarray(self._table)
            self._table_dirty = False

    @property
    def page_bytes(self) -> int:
        """Per-page KV bytes across all layers (0 until first admit)."""
        if not self.paged or self._cache is None or self.pages is None:
            return 0
        total = sum(x.nbytes
                    for x in jax.tree_util.tree_leaves(self._cache["stages"]))
        return int(total // self.pages.n_pages)

    @property
    def kv_bytes_in_use(self) -> int:
        """Bytes actually pinned by live requests (paged: mapped pages
        count ONCE however many rows share them)."""
        if self.paged:
            return self.pages.in_use * self.page_bytes if self.pages else 0
        return self.measured_slot_bytes * len(self.pool)

    # -- paged page lifecycle -------------------------------------------
    def _bind_pages(self, rid: int) -> int:
        """Map ``rid``'s prompt onto pages: the longest indexed prefix by
        reference (refcount++), fresh pages for the rest.  Registers the
        prompt's own whole pages in the index (they are filled by this
        very admission's prefill call) and returns the shared base —
        the number of prompt tokens that will NOT be prefilled."""
        toks = self._tokens[rid]
        P = self.page_size
        n_needed = max(1, -(-len(toks) // P))
        shared = self.prefix.lookup(toks, P, (len(toks) - 1) // P)
        for p in shared:
            self.pages.incref(p)
        pages = list(shared)
        while len(pages) < n_needed:
            pages.append(self.pages.alloc())
        slot = self.pool.slot_of[rid]
        self._table[slot, :len(pages)] = pages
        self._table[slot, len(pages):] = PagePool.TRASH
        self._table_dirty = True
        self.prefix.insert(toks, P, pages)        # whole pages only
        self.shared_tokens_total += len(shared) * P
        return len(shared) * P

    def _ensure_writable(self, rid: int) -> None:
        """Guarantee the page receiving this step's decode write is
        exclusively owned.  Unmapped (ring entered a new page) → alloc;
        shared (ring WRAPPED into a refcounted prefix page) → copy-on-
        write; exclusively owned but indexed → purge the index entry
        (the in-place write is about to change the page's bytes)."""
        T = self.max_pages * self.page_size
        pos = len(self._tokens[rid]) - 1          # slot this token writes
        pi = (pos % T) // self.page_size
        slot = self.pool.slot_of[rid]
        page = int(self._table[slot, pi])
        if page == PagePool.TRASH:
            self._table[slot, pi] = self.pages.alloc()
            self._table_dirty = True
        elif self.pages.refcount(page) > 1:
            fresh = self.pages.alloc()
            self._cache["stages"] = self._copy_page(
                self._cache["stages"], np.int32(fresh), np.int32(page))
            if self.pages.decref(page):
                self.prefix.forget_page(page)
            self._table[slot, pi] = fresh
            self._table_dirty = True
        else:
            self.prefix.forget_page(page)

    # -- device steps ---------------------------------------------------
    def _decode_active(self, active: List[int]) -> Dict[int, int]:
        B = self.pool.capacity
        toks = np.full((B, 1), PAD, dtype=np.int32)
        mask = np.zeros((B,), dtype=bool)
        for r in active:
            if self.paged:
                self._ensure_writable(r)
            s = self.pool.slot_of[r]
            toks[s, 0] = self._tokens[r][-1]
            mask[s] = True
        self._sync_table()
        self._shapes.add(("decode", B))
        logits, self._cache = self._decode(self.params, self._cache, toks,
                                           mask)
        logits = np.asarray(logits)
        out: Dict[int, int] = {}
        for r in active:
            nxt = int(np.argmax(logits[self.pool.slot_of[r], -1]))
            self._tokens[r].append(nxt)
            out[r] = nxt
        return out

    def _admit(self, fresh: List[int]) -> Dict[int, int]:
        """Prefill for newly admitted rows.  The admission batch is
        bucketed (rows → pow2, tokens → multiple of 8); padding rows
        DUPLICATE row 0 (same tokens, same slot/pages), so the duplicate
        scatter writes identical bytes and live rows stay untouched.
        Paged: only each row's unshared TAIL is prefilled."""
        slots = [self.pool.bind(r) for r in fresh]
        if self.paged:
            bases = [self._bind_pages(r) for r in fresh]
            seqs = [self._tokens[r][b:] for r, b in zip(fresh, bases)]
        else:
            bases = [0] * len(fresh)
            seqs = [self._tokens[r] for r in fresh]
        S = min(_round_up(max(len(s) for s in seqs), 8), self.max_len)
        lens = [min(len(s), S) for s in seqs]     # exactness holds ≤ max_len
        Bn = _next_pow2(len(fresh))
        arr = np.full((Bn, S), PAD, dtype=np.int32)
        for i, s in enumerate(seqs):
            arr[i, :lens[i]] = s[:lens[i]]
        arr[len(fresh):] = arr[0]
        pad = Bn - len(fresh)
        slot_arr = np.asarray(slots + [slots[0]] * pad, np.int32)
        len_arr = np.asarray(lens + [lens[0]] * pad, np.int32)
        self.prefill_tokens_total += sum(lens)
        self._shapes.add(("prefill", Bn, S, self.pool.capacity))
        if self.paged:
            base_arr = np.asarray(bases + [bases[0]] * pad, np.int32)
            self._sync_table()
            logits, self._cache = self._prefill_pages(
                self.params, {"tokens": arr}, self._cache, slot_arr,
                base_arr, len_arr)
        else:
            logits, self._cache = self._prefill_slots(
                self.params, {"tokens": arr}, self._cache, slot_arr, len_arr)
        if not self.measured_slot_bytes:
            if self.paged:
                self.measured_slot_bytes = self.page_bytes * self.max_pages
                if self.retain_bytes and self.page_bytes:
                    # byte budget -> page count, now that pages have a size
                    self.pages.retained_cap = max(
                        1, self.retain_bytes // self.page_bytes)
            else:
                total = sum(x.nbytes
                            for x in jax.tree_util.tree_leaves(self._cache))
                self.measured_slot_bytes = int(total // self.pool.capacity)
        logits = np.asarray(logits)
        out: Dict[int, int] = {}
        for i, r in enumerate(fresh):
            nxt = int(np.argmax(logits[i, 0]))
            self._tokens[r].append(nxt)
            out[r] = nxt
        return out

    def _grow(self, needed: int) -> None:
        """Capacity to the next power of two ≥ ``needed``; live state is
        copied across GENERICALLY — every leaf of the old cache pytree is
        prefix-sliced into the freshly initialised one (and cache keys
        the initialiser doesn't know about are carried verbatim), so
        growth is invisible to in-flight requests whatever the layout."""
        cap = max(self.pool.capacity, 1)
        while cap < needed:
            cap *= 2
        if cap == self.pool.capacity:
            return
        old_cap = self.pool.capacity
        old_cache = self._cache
        old_table = self._table
        new_cache = self._fresh_cache(cap)
        if old_cache is not None:
            def copy_prefix(big, small):
                if big.shape == small.shape:
                    return small
                idx = tuple(slice(0, n) for n in small.shape)
                return big.at[idx].set(small)
            merged = {}
            for key, val in new_cache.items():
                if key in old_cache:
                    merged[key] = jax.tree_util.tree_map(
                        copy_prefix, val, old_cache[key])
                else:
                    merged[key] = val
            for key, val in old_cache.items():    # keys init doesn't know
                merged.setdefault(key, val)
            new_cache = merged
        self._cache = new_cache
        if self.paged:
            self.pages.grow(1 + cap * self.max_pages)
            if old_table is not None:
                self._table[:old_cap] = old_table
            self._table_dirty = True
        self.pool.grow(cap)
        self.measured_slot_bytes = 0              # re-measure at new B
        if self.paged:
            self._sync_table()

    def _step_full(self, rids: List[int]) -> Dict[int, int]:
        """Reference path: full forward over prompt+generated each step."""
        seqs = [self._tokens[r] for r in rids]
        lens = [len(s) for s in seqs]
        B = _next_pow2(len(rids))
        S = _round_up(max(lens), 8)
        arr = np.full((B, S), PAD, dtype=np.int32)
        for i, s in enumerate(seqs):
            arr[i, :len(s)] = s
        self._shapes.add(("full", B, S))
        logits = np.asarray(self._fwd(self.params, arr))
        out: Dict[int, int] = {}
        for i, rid in enumerate(rids):
            nxt = int(np.argmax(logits[i, lens[i] - 1]))
            self._tokens[rid].append(nxt)
            out[rid] = nxt
        return out

    @property
    def shape_buckets(self) -> int:
        """Distinct compiled shapes seen — an upper bound on recompiles.
        O(1) in decode steps for the slot path (decode compiles once per
        pool capacity; prefill once per admission bucket)."""
        return len(self._shapes)


def make_pff_step_fn(prompt_len: int = PROMPT_LEN, *,
                     slot_cached: bool = True,
                     max_len: Optional[int] = None,
                     paged: Optional[bool] = None):
    """Step function for :class:`~repro.cluster.LiveExecutor.step_fns`.

    Lazily builds a :class:`StreamingDecoder` inside the library's
    payloads (it belongs to the hosted context: it dies with a spill and
    is rebuilt on re-materialisation) and advances the current members by
    one token.  Request payloads are the claims to verify.

    Requests the scheduler pulled OUT of the batch mid-flight (requeued
    on preemption / migrated to another replica) are detected by their
    absence from ``members`` and their decoder state — slot, pages,
    token buffers — is freed immediately; previously these rows leaked
    until the decoder was torn down.

    The returned function carries a ``prefill`` attribute — the
    disaggregation entry the live executor uses to run a request's
    PREFILL phase without joining a stream (see
    :meth:`repro.cluster.LiveExecutor._run_prefill`)."""
    def _decoder(payloads) -> StreamingDecoder:
        dec = payloads.get("_stream_decoder")
        if dec is None:
            engine = payloads["xla_executable"]
            ci = payloads["context_inputs"]
            dec = StreamingDecoder(engine.cfg, engine.params,
                                   ci["tokenizer"], ci["template"],
                                   prompt_len=prompt_len,
                                   slot_cached=slot_cached, max_len=max_len,
                                   paged=paged)
            payloads["_stream_decoder"] = dec
        # shipped-in KV snapshots parked before this decoder existed (or
        # between steps): take ownership so has_suspended resumes them
        inbox = payloads.pop("_kv_inbox", None)
        if inbox:
            for rid, snap in inbox.items():
                dec.adopt(rid, snap)
        return dec

    def step_fn(payloads, members):
        dec = _decoder(payloads)
        present = {r.request_id for r in members}
        for rid in dec.active_rids():
            if rid not in present:                # requeued away mid-batch
                dec.finish(rid)
        for r in members:
            # a preempted member coming back: restore its KV snapshot
            # in place of the admission prefill (suspend removed it from
            # active_rids, so the cleanup above never touches it)
            if dec.has_suspended(r.request_id):
                dec.resume(r.request_id)
        for r in members:
            dec.ensure(r.request_id, r.payload)
            if dec.truncated.get(r.request_id):
                r.truncated = True
        out = dec.step([r.request_id for r in members])
        for r in members:
            if r.steps_done + 1 >= r.n_units:    # last step: free state
                dec.finish(r.request_id)
        return out

    def prefill(payloads, request) -> Tuple[int, List[int]]:
        """Run ``request``'s PREFILL phase: admit it, emit the first
        ``prompt_units`` tokens exactly as the colocated steps would,
        then suspend the row — the host snapshot IS the shippable KV.
        Returns ``(snapshot_nbytes, tokens)``; the DECODE phase resumes
        from the snapshot (same worker or shipped) and continues the
        token stream bit-exactly."""
        dec = _decoder(payloads)
        rid = request.request_id
        dec.ensure(rid, request.payload)
        if dec.truncated.get(rid):
            request.truncated = True
        toks: List[int] = []
        for _ in range(max(int(request.prompt_units), 1)):
            toks.append(dec.step([rid])[rid])
        return dec.suspend(rid), toks

    step_fn.prefill = prefill
    return step_fn


def stream_verdict(tokenizer, step_tokens: Iterable[int]) -> str:
    """Decode one request's accumulated step outputs into a verdict."""
    return parse_verdict(tokenizer.decode(list(step_tokens)))
