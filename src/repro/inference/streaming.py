"""Live continuous-batching decoder: a persistent slot pool of KV state.

The LIVE leg of the request-stream redesign.  A library's dynamic batch
changes membership between decode steps, so the device batch cannot be a
fixed (B, S) array compiled once per task.  :class:`StreamingDecoder`
keeps the decode state RESIDENT on the device instead: a
:class:`SlotPool` of ``capacity`` rows of KV cache (ring length
``max_len``) that requests bind to on admission and free on completion.

* **admit** — a new request's prompt runs through a prompt-only prefill
  (``M.prefill_into_slots``) that scatters its K/V + position into the
  shared cache at its slot, without touching live rows;
* **step** — ONE cached ``M.decode_step`` over all slots advances every
  active row by one token at O(1) FLOPs/token (each row embeds/RoPEs at
  its own position, ring-writes at its own slot, masks at its own
  length via the vector-``n_valid`` decode-attention kernel);
* **finish** — the slot returns to the free list; its stale K/V is fully
  overwritten by the next tenant's admission prefill, so reuse never
  leaks context across requests.

Compiled-shape accounting: the decode step compiles once per pool
capacity (capacities grow by doubling), prefill once per (admission
batch bucket, prompt-length bucket) — O(log) shapes total, and crucially
O(1) in the number of decode steps, where the previous full-forward
re-run was O(S) FLOPs per token.  Per-slot cache bytes are MEASURED
after the first admission (``measured_slot_bytes``) and fed back into
``ContextRecipe.decode_slot_bytes`` by the live executor, replacing the
``KV_BYTES_PER_PARAM`` analytic guess when sizing slot budgets.

The pre-slot full-forward path (prompt + generated prefix re-run through
``M.forward`` every step; right-padding inert under causal attention)
survives as ``slot_cached=False`` — the token-exactness reference the
slot path is asserted against in tests/test_streaming_live.py.
"""
from __future__ import annotations

import functools
from typing import Dict, Iterable, List, Optional, Sequence

import jax
import numpy as np

from ..data.prompts import parse_verdict
from ..data.tokenizer import PAD
from ..models import model as M
from .pff import PROMPT_LEN


def _next_pow2(n: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return b


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


class SlotPool:
    """Fixed-capacity allocator binding request ids to cache rows."""

    def __init__(self, capacity: int = 0):
        self.capacity = capacity
        self.slot_of: Dict[int, int] = {}
        self._free: List[int] = list(range(capacity - 1, -1, -1))

    def bind(self, rid: int) -> int:
        slot = self._free.pop()
        self.slot_of[rid] = slot
        return slot

    def release(self, rid: int) -> Optional[int]:
        slot = self.slot_of.pop(rid, None)
        if slot is not None:
            self._free.append(slot)
        return slot

    def grow(self, capacity: int) -> None:
        assert capacity >= self.capacity
        self._free[:0] = range(capacity - 1, self.capacity - 1, -1)
        self.capacity = capacity

    @property
    def free(self) -> int:
        return len(self._free)

    def __len__(self) -> int:
        return len(self.slot_of)


class StreamingDecoder:
    """Greedy decoder over a membership-changing request batch.

    ``slot_cached=True`` (default): persistent slot-pool decode, O(1) per
    token.  ``slot_cached=False``: the full-forward reference path, O(S)
    per token.  Both produce identical greedy tokens while sequences stay
    within ``max_len`` (asserted in tests under membership churn).

    ``b_max`` pre-sizes the pool (typically the library's slot budget, so
    the decode step compiles exactly once); it is a sizing hint, not a
    hard cap — if the scheduler ever admits beyond it the pool doubles
    rather than dropping in-flight requests.
    """

    def __init__(self, cfg, params, tokenizer, template, *,
                 prompt_len: int = PROMPT_LEN, slot_cached: bool = True,
                 max_len: Optional[int] = None, b_max: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.tokenizer = tokenizer
        self.template = template
        self.prompt_len = prompt_len
        self.slot_cached = slot_cached
        self.max_len = max_len or prompt_len + 64
        self._tokens: Dict[int, List[int]] = {}   # rid -> prompt+generated
        self._prompt_end: Dict[int, int] = {}
        self._fwd = jax.jit(
            lambda p, toks: M.forward(cfg, p, {"tokens": toks}))
        self._decode = jax.jit(functools.partial(M.decode_step, cfg))
        self._prefill_slots = jax.jit(functools.partial(
            M.prefill_into_slots, cfg, max_len=self.max_len))
        self._shapes: set = set()                 # compile-shape audit
        self.pool = SlotPool(b_max or 0)
        self._cache = None                        # device cache pytree
        self.measured_slot_bytes = 0              # real per-slot footprint

    # -- membership -----------------------------------------------------
    def ensure(self, rid: int, claim) -> None:
        """Admit ``rid``: tokenize its prompt (idempotent)."""
        if rid in self._tokens:
            return
        ids = self.tokenizer.encode(
            self.template.render(claim))[:self.prompt_len]
        self.ensure_tokens(rid, list(ids))

    def ensure_tokens(self, rid: int, token_ids: List[int]) -> None:
        """Admit ``rid`` with pre-tokenized prompt ids (idempotent)."""
        if rid in self._tokens:
            return
        self._tokens[rid] = list(token_ids)
        self._prompt_end[rid] = len(token_ids)

    def finish(self, rid: int) -> List[int]:
        """Release ``rid``'s state (and its slot); returns its generated
        token ids.  The freed slot's stale K/V is inert: the next tenant's
        admission prefill overwrites the whole cache row."""
        self.pool.release(rid)
        toks = self._tokens.pop(rid, [])
        end = self._prompt_end.pop(rid, len(toks))
        return toks[end:]

    # -- the step -------------------------------------------------------
    def step(self, rids: Sequence[int]) -> Dict[int, int]:
        """One greedy decode step for the CURRENT membership.

        Slot mode: one cached ``decode_step`` over the pool advances the
        rows already bound; newly seen rids are admitted via
        ``prefill_into_slots`` (their first token comes from the prefill
        logits).  Full mode: re-form the padded (B, S) batch and run the
        full forward.  Returns {rid: new_token}."""
        rids = list(rids)
        if not rids:
            return {}
        if not self.slot_cached:
            return self._step_full(rids)
        active = [r for r in rids if r in self.pool.slot_of]
        fresh = [r for r in rids if r not in self.pool.slot_of]
        out: Dict[int, int] = {}
        if len(fresh) > self.pool.free:
            self._grow(len(self.pool.slot_of) + len(fresh))
        elif fresh and self._cache is None:       # b_max pre-sized the pool
            self._cache = M.cache_init(self.cfg, self.pool.capacity,
                                       self.max_len)
        if active:
            out.update(self._decode_active(active))
        if fresh:
            out.update(self._admit(fresh))
        return out

    def _decode_active(self, active: List[int]) -> Dict[int, int]:
        B = self.pool.capacity
        toks = np.full((B, 1), PAD, dtype=np.int32)
        mask = np.zeros((B,), dtype=bool)
        for r in active:
            s = self.pool.slot_of[r]
            toks[s, 0] = self._tokens[r][-1]
            mask[s] = True
        self._shapes.add(("decode", B))
        logits, self._cache = self._decode(self.params, self._cache, toks,
                                           mask)
        logits = np.asarray(logits)
        out: Dict[int, int] = {}
        for r in active:
            nxt = int(np.argmax(logits[self.pool.slot_of[r], -1]))
            self._tokens[r].append(nxt)
            out[r] = nxt
        return out

    def _admit(self, fresh: List[int]) -> Dict[int, int]:
        """Prefill-into-slots for newly admitted rows.  The admission batch
        is bucketed (rows → pow2, prompt → multiple of 8); padding rows
        DUPLICATE row 0 (same tokens, same slot), so the duplicate scatter
        writes identical bytes and live rows stay untouched."""
        slots = [self.pool.bind(r) for r in fresh]
        seqs = [self._tokens[r] for r in fresh]
        S = min(_round_up(max(len(s) for s in seqs), 8), self.max_len)
        lens = [min(len(s), S) for s in seqs]     # exactness holds ≤ max_len
        Bn = _next_pow2(len(fresh))
        arr = np.full((Bn, S), PAD, dtype=np.int32)
        for i, s in enumerate(seqs):
            arr[i, :lens[i]] = s[:lens[i]]
        arr[len(fresh):] = arr[0]
        pad = [slots[0]] * (Bn - len(fresh))
        slot_arr = np.asarray(slots + pad, np.int32)
        len_arr = np.asarray(lens + [lens[0]] * (Bn - len(fresh)), np.int32)
        self._shapes.add(("prefill", Bn, S, self.pool.capacity))
        logits, self._cache = self._prefill_slots(
            self.params, {"tokens": arr}, self._cache, slot_arr, len_arr)
        if not self.measured_slot_bytes:
            total = sum(x.nbytes
                        for x in jax.tree_util.tree_leaves(self._cache))
            self.measured_slot_bytes = int(total // self.pool.capacity)
        logits = np.asarray(logits)
        out: Dict[int, int] = {}
        for i, r in enumerate(fresh):
            nxt = int(np.argmax(logits[i, 0]))
            self._tokens[r].append(nxt)
            out[r] = nxt
        return out

    def _grow(self, needed: int) -> None:
        """Capacity to the next power of two ≥ ``needed``; live rows are
        copied across, so growth is invisible to in-flight requests."""
        cap = max(self.pool.capacity, 1)
        while cap < needed:
            cap *= 2
        if cap == self.pool.capacity:
            return
        new_cache = M.cache_init(self.cfg, cap, self.max_len)
        if self._cache is not None:
            old = self.pool.capacity
            new_cache = {
                "stages": jax.tree_util.tree_map(
                    lambda big, small: big.at[:, :old].set(small),
                    new_cache["stages"], self._cache["stages"]),
                "pos": new_cache["pos"].at[:old].set(self._cache["pos"]),
            }
        self._cache = new_cache
        self.pool.grow(cap)
        self.measured_slot_bytes = 0              # re-measure at new B

    def _step_full(self, rids: List[int]) -> Dict[int, int]:
        """Reference path: full forward over prompt+generated each step."""
        seqs = [self._tokens[r] for r in rids]
        lens = [len(s) for s in seqs]
        B = _next_pow2(len(rids))
        S = _round_up(max(lens), 8)
        arr = np.full((B, S), PAD, dtype=np.int32)
        for i, s in enumerate(seqs):
            arr[i, :len(s)] = s
        self._shapes.add(("full", B, S))
        logits = np.asarray(self._fwd(self.params, arr))
        out: Dict[int, int] = {}
        for i, rid in enumerate(rids):
            nxt = int(np.argmax(logits[i, lens[i] - 1]))
            self._tokens[rid].append(nxt)
            out[rid] = nxt
        return out

    @property
    def shape_buckets(self) -> int:
        """Distinct compiled shapes seen — an upper bound on recompiles.
        O(1) in decode steps for the slot path (decode compiles once per
        pool capacity; prefill once per admission bucket)."""
        return len(self._shapes)


def make_pff_step_fn(prompt_len: int = PROMPT_LEN, *,
                     slot_cached: bool = True,
                     max_len: Optional[int] = None):
    """Step function for :class:`~repro.cluster.LiveExecutor.step_fns`.

    Lazily builds a :class:`StreamingDecoder` inside the library's
    payloads (it belongs to the hosted context: it dies with a spill and
    is rebuilt on re-materialisation) and advances the current members by
    one token.  Request payloads are the claims to verify."""
    def step_fn(payloads, members):
        dec = payloads.get("_stream_decoder")
        if dec is None:
            engine = payloads["xla_executable"]
            ci = payloads["context_inputs"]
            dec = StreamingDecoder(engine.cfg, engine.params,
                                   ci["tokenizer"], ci["template"],
                                   prompt_len=prompt_len,
                                   slot_cached=slot_cached, max_len=max_len)
            payloads["_stream_decoder"] = dec
        for r in members:
            dec.ensure(r.request_id, r.payload)
        out = dec.step([r.request_id for r in members])
        for r in members:
            if r.steps_done + 1 >= r.n_units:    # last step: free state
                dec.finish(r.request_id)
        return out
    return step_fn


def stream_verdict(tokenizer, step_tokens: Iterable[int]) -> str:
    """Decode one request's accumulated step outputs into a verdict."""
    return parse_verdict(tokenizer.decode(list(step_tokens)))
