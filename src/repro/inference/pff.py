"""Prompt-for-Fact: the paper's evaluation application (§6.1).

PfF takes (LLM, prompt template) and returns fact-verification accuracy
over a claim set.  This module provides:

* :func:`build_context_loaders` — the *context code* of Fig 3's
  ``load_model``: loaders that materialise tokenizer, params, engine and
  the compiled executables, keyed to real :class:`ContextElement`s so the
  LIVE executor exercises the context lifecycle for real;
* :func:`infer_claims` — the bound function of Fig 3's ``infer_model``:
  runs inside the library's address space against the hosted context;
* :func:`sweep_accuracy` — the aggregated (LLM, template) score.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Sequence

import jax
import numpy as np

from ..configs import ModelConfig
from ..core import ContextElement, ContextRecipe, model_context_recipe
from ..data.claims import Claim
from ..data.prompts import TEMPLATES, accuracy, parse_verdict
from ..data.tokenizer import ByteTokenizer
from ..models import model as M
from .engine import InferenceEngine

PROMPT_LEN = 96
MAX_NEW = 8


def build_context_recipe(cfg: ModelConfig, template_name: str,
                         *, max_len: int = PROMPT_LEN + MAX_NEW,
                         seed: int = 0) -> ContextRecipe:
    """A live recipe whose loaders really materialise the PfF context."""
    sized = model_context_recipe(cfg, include_compile=True,
                                 shapes_key=f"len{max_len}",
                                 deps_bytes=64_000_000, activation_s=0.0)
    tok = ByteTokenizer(cfg.vocab_size)

    state: Dict[str, Any] = {}

    def load_deps():
        import jax as _jax              # noqa: F401  (the import IS the work)
        import numpy as _np             # noqa: F401
        return {"jax": _jax.__version__}

    def load_weights():
        params = M.init_params(cfg, jax.random.PRNGKey(seed))
        state["params"] = params
        return params

    def load_context_inputs():
        return {"tokenizer": tok, "template": TEMPLATES[template_name]}

    def load_executable():
        engine = InferenceEngine(cfg, state["params"], max_len=max_len)
        warm = {"tokens": np.ones((1, 8), np.int32)}
        engine.warmup(warm)
        return engine

    loaders = {"deps": load_deps, "weights": load_weights,
               "context_inputs": load_context_inputs,
               "xla_executable": load_executable,
               "code": lambda: infer_claims}
    elements = tuple(dataclasses.replace(e, loader=loaders[e.name])
                     for e in sized.elements)
    return dataclasses.replace(sized, elements=elements)


def infer_claims(payloads: Dict[str, Any],
                 claims: Sequence[Claim]) -> List[str]:
    """The task body (Fig 3 ``infer_model``): executed inside the library."""
    engine: InferenceEngine = payloads["xla_executable"]
    ci = payloads["context_inputs"]
    tok: ByteTokenizer = ci["tokenizer"]
    template = ci["template"]
    prompts = [template.render(c) for c in claims]
    batch = {"tokens": tok.encode_batch(prompts, PROMPT_LEN)}
    res = engine.generate(batch, max_new=MAX_NEW)
    return [parse_verdict(tok.decode(row)) for row in res.tokens]


def sweep_accuracy(cfg: ModelConfig, template_name: str,
                   claims: Sequence[Claim], *, batch: int = 8,
                   seed: int = 0) -> float:
    """Single-process reference sweep (what pv0 computes)."""
    recipe = build_context_recipe(cfg, template_name, seed=seed)
    payloads = {e.name: e.loader() for e in recipe.elements}
    preds: List[str] = []
    for i in range(0, len(claims), batch):
        preds.extend(infer_claims(payloads, claims[i:i + batch]))
    return accuracy(preds, claims)
