"""The factory: reconciles the worker pool against an availability trace.

Paper §5.1: "The pool of resources is maintained by the TaskVine factory,
a daemon-like process that monitors the current resource pool and adjusts
it based on a given resource policy and the current load of the cluster."

In the sim, cluster load is exogenous (a :mod:`traces` trace of target
worker counts); the factory submits or evicts pilot jobs to track it.
Joins draw devices from a supply iterator (heterogeneous, Table-1
proportioned); evictions pick victims by ``evict_priority`` (pv5 drains
A10s first) — the *scheduler* then requeues any unfinished request.

The DEFAULT eviction priority is spill-aware: it consults the context
registry and prefers reclaiming workers whose resident recipes are
replicated (READY) elsewhere, so a drain costs re-staging only when no
other copy survives.  Pass ``evict_priority=`` to override (higher value
= evicted first).
"""
from __future__ import annotations

import itertools
from typing import Callable, Iterable, Iterator, List, Optional

from ..core import (HostState, LinkBudget, WarmPoolPolicy, WorkerShape,
                    PAPER_WORKER_SHAPE)
from .events import EventLoop
from .executors import SimExecutor
from .hardware import DeviceModel, cluster_sample, paper_20gpu_pool
from .scheduler import Scheduler
from .traces import Trace
from .worker import Worker


def spill_aware_evict_priority(view) -> Callable[[Worker], tuple]:
    """Registry-consulting eviction priority (ROADMAP: spill-aware).

    A PURE function of a :class:`~repro.core.ClusterView` — anything
    exposing a read-only ``registry`` works, so pre-plane callers that
    pass the scheduler itself keep working.

    A worker's score is the minimum number of OTHER ready replicas over
    the recipes it currently hosts READY — the worker holding the last
    warm copy of some context scores 0 and is reclaimed last; a worker
    hosting nothing (or only recipes replicated elsewhere) goes first.
    Ties break toward the newest joiner (the seed policy).
    """
    reg = view.registry

    def priority(w: Worker) -> tuple:
        hosted = [k for k in w.libraries
                  if reg.state(k, w.worker_id) is HostState.READY]
        if not hosted:
            return (float("inf"), w.joined_s)
        score = min(len(reg.ready_workers(k)) - 1 for k in hosted)
        return (score, w.joined_s)
    return priority


class Factory:
    def __init__(self, scheduler: Scheduler, executor: SimExecutor,
                 device_supply: Iterable[DeviceModel],
                 *, workers_per_zone: int = 8,
                 worker_shape: Optional[WorkerShape] = None,
                 evict_priority: Optional[Callable[[Worker], float]] = None):
        self.sched = scheduler
        self.ex = executor
        self.loop: EventLoop = executor.loop
        self._supply: Iterator[DeviceModel] = itertools.cycle(device_supply)
        self._zone_counter = itertools.count()
        self.workers_per_zone = workers_per_zone
        self.worker_shape = worker_shape or PAPER_WORKER_SHAPE
        # higher priority value = evicted first; None resolves to the
        # spill-aware default over a fresh ClusterView at eviction time
        # (reclaim workers whose contexts are replicated elsewhere)
        self.evict_priority = evict_priority

    def _next_zone(self) -> str:
        return f"z{next(self._zone_counter) // self.workers_per_zone}"

    # ------------------------------------------------------------------
    def reconcile(self, target: int) -> None:
        now = self.loop.now
        cur = len(self.sched.workers)
        if target > cur:
            for _ in range(target - cur):
                w = Worker(next(self._supply), zone=self._next_zone(),
                           shape=self.worker_shape)
                self.sched.add_worker(w, now)
            if getattr(self.ex, "prestage_enabled", False):
                for key in self.sched.registry.recipes:
                    self.ex.prestage(key)
            self.ex.pump()
        elif target < cur:
            prio = self.evict_priority or \
                spill_aware_evict_priority(self.sched.view(now))
            victims = sorted(self.sched.workers.values(),
                             key=prio, reverse=True)
            for w in victims[:cur - target]:
                self.sched.on_evict(w.worker_id, now)
            self.ex.pump()

    def apply_trace(self, trace: Trace) -> None:
        for t, n in trace:
            self.loop.at(t, lambda n=n: self.reconcile(n))


# ---------------------------------------------------------------------------
# Convenience: assemble the whole sim for one experiment
# ---------------------------------------------------------------------------

def make_sim(devices: Optional[List[DeviceModel]] = None,
             trace: Optional[Trace] = None,
             *, evict_priority=None, workers_per_zone: int = 8,
             worker_shape: Optional[WorkerShape] = None,
             backfill: bool = True, aging_bound=8,
             warm_pool: Optional[WarmPoolPolicy] = None,
             link_budget: Optional[LinkBudget] = None,
             prestage: bool = False, disaggregate: bool = False):
    """Returns (scheduler, executor, factory) wired together."""
    sched = Scheduler(backfill=backfill, aging_bound=aging_bound,
                      link_budget=link_budget, disaggregate=disaggregate)
    ex = SimExecutor(sched, prestage=prestage, warm_pool=warm_pool)
    devices = devices if devices is not None else paper_20gpu_pool()
    fac = Factory(sched, ex, devices, workers_per_zone=workers_per_zone,
                  worker_shape=worker_shape, evict_priority=evict_priority)
    if trace:
        fac.apply_trace(trace)
    return sched, ex, fac


def opportunistic_supply(n: int = 256, seed: int = 0):
    return cluster_sample(n, seed=seed)
