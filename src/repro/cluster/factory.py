"""The factory: reconciles the worker pool against supply and demand.

Paper §5.1: "The pool of resources is maintained by the TaskVine factory,
a daemon-like process that monitors the current resource pool and adjusts
it based on a given resource policy and the current load of the cluster."

Two modes:

* **Trace-following** (the original): cluster load is exogenous (a
  :mod:`traces` trace of target worker counts); the factory submits or
  evicts pilot jobs to track it exactly.

* **Demand-driven** (``Factory(policy=ElasticPolicy(...))``): the trace
  becomes an availability CEILING, and the factory sizes the pool from
  the scheduler's demand forecast (``ClusterView.forecast_rate``) via
  the policy's hysteresis/cooldown contract — acquiring ahead of bursts
  and releasing when the forecast decays, never exceeding what the
  cluster offers.  The policy re-decides on a periodic tick AND on every
  executor pump (cooldowns keep that cheap), and
  :meth:`Factory.restrict` lets fault injectors model reclaimed
  capacity that must not be instantly re-acquired.

Joins draw devices from a supply iterator (heterogeneous, Table-1
proportioned); evictions and elastic releases pick victims by
``evict_priority`` (pv5 drains A10s first) — the *scheduler* then
requeues any unfinished request.

The DEFAULT eviction priority is spill-aware: it consults the context
registry and prefers reclaiming workers whose resident recipes are
replicated (READY) elsewhere, so a drain (or an elastic release) costs
re-staging only when no other copy survives — the last warm copy of a
context is reclaimed last.  Pass ``evict_priority=`` to override (higher
value = evicted first).
"""
from __future__ import annotations

import itertools
from typing import Callable, Dict, Iterable, Iterator, List, Optional

from ..core import (HostState, LinkBudget, WarmPoolPolicy, WorkerShape,
                    PAPER_WORKER_SHAPE)
from .events import EventLoop
from .executors import SimExecutor
from .forecast import ElasticPolicy
from .hardware import DeviceModel, cluster_sample, paper_20gpu_pool
from .scheduler import Scheduler
from .traces import Trace
from .worker import Worker


def spill_aware_evict_priority(view) -> Callable[[Worker], tuple]:
    """Registry-consulting eviction priority (ROADMAP: spill-aware).

    A PURE function of a :class:`~repro.core.ClusterView` — anything
    exposing a read-only ``registry`` works, so pre-plane callers that
    pass the scheduler itself keep working.

    A worker's score is the minimum number of OTHER ready replicas over
    the recipes it currently hosts READY — the worker holding the last
    warm copy of some context scores 0 and is reclaimed last; a worker
    hosting nothing (or only recipes replicated elsewhere) goes first.
    Ties break toward the newest joiner (the seed policy).
    """
    reg = view.registry

    def priority(w: Worker) -> tuple:
        hosted = [k for k in w.libraries
                  if reg.state(k, w.worker_id) is HostState.READY]
        if not hosted:
            return (float("inf"), w.joined_s)
        score = min(len(reg.ready_workers(k)) - 1 for k in hosted)
        return (score, w.joined_s)
    return priority


class Factory:
    def __init__(self, scheduler: Scheduler, executor: SimExecutor,
                 device_supply: Iterable[DeviceModel],
                 *, workers_per_zone: int = 8,
                 worker_shape: Optional[WorkerShape] = None,
                 evict_priority: Optional[Callable[[Worker], float]] = None,
                 policy: Optional[ElasticPolicy] = None,
                 tick_s: float = 15.0):
        self.sched = scheduler
        self.ex = executor
        self.loop: EventLoop = executor.loop
        mix = list(device_supply)
        self._mix: List[DeviceModel] = mix
        self._supply: Iterator[DeviceModel] = itertools.cycle(mix)
        self._zone_counter = itertools.count()
        self.workers_per_zone = workers_per_zone
        self.worker_shape = worker_shape or PAPER_WORKER_SHAPE
        # higher priority value = evicted first; None resolves to the
        # spill-aware default over a fresh ClusterView at eviction time
        # (reclaim workers whose contexts are replicated elsewhere)
        self.evict_priority = evict_priority
        # -- demand-driven mode -------------------------------------------
        self.policy = policy
        self.tick_s = tick_s
        if policy is not None and not list(policy.supply):
            policy.supply = mix         # capacity model sees our mix
        self.target = 0                 # last decided pool target
        self._ceiling: Optional[int] = None   # trace availability cap
        self._restrictions: List[List[float]] = []  # [until_s, n_lost]
        self.scale_log: List[tuple] = []      # (t, from_n, to_n)
        # worker_id -> acquire-decision time; pool_summary() joins this
        # with plane.first_ready_s for the acquire -> warm lead time
        self.acquire_log: Dict[str, float] = {}
        self._stepping = False
        self._ticking = False

    def _next_zone(self) -> str:
        return f"z{next(self._zone_counter) // self.workers_per_zone}"

    # ------------------------------------------------------------------
    def reconcile(self, target: int) -> None:
        now = self.loop.now
        cur = len(self.sched.workers)
        if target > cur:
            for _ in range(target - cur):
                w = Worker(next(self._supply), zone=self._next_zone(),
                           shape=self.worker_shape)
                self.sched.add_worker(w, now)
                self.acquire_log[w.worker_id] = now
            if getattr(self.ex, "prestage_enabled", False):
                for key in self.sched.registry.recipes:
                    self.ex.prestage(key)
            self.ex.pump()
        elif target < cur:
            prio = self.evict_priority or \
                spill_aware_evict_priority(self.sched.view(now))
            victims = sorted(self.sched.workers.values(),
                             key=prio, reverse=True)
            for w in victims[:cur - target]:
                self.sched.on_evict(w.worker_id, now)
            self.ex.pump()

    def apply_trace(self, trace: Trace) -> None:
        """Trace-following mode tracks the trace exactly; demand-driven
        mode treats each trace point as the availability ceiling and
        lets the policy decide the pool size under it."""
        if self.policy is None:
            for t, n in trace:
                self.loop.at(t, lambda n=n: self.reconcile(n))
            return
        for t, n in trace:
            self.loop.at(t, lambda n=n: self.set_ceiling(n))
        self.start()

    # -- demand-driven mode --------------------------------------------
    def set_ceiling(self, n: int) -> None:
        """Availability changed: re-decide immediately (a ceiling drop
        is an exogenous revocation the policy obeys without cooldown)."""
        self._ceiling = n
        self.step()

    def restrict(self, n: int, until_s: float) -> None:
        """Temporarily lower the effective ceiling by ``n`` workers
        (until ``until_s``): a churn storm reclaimed capacity the
        factory must not instantly re-acquire."""
        self._restrictions.append([until_s, float(n)])
        self.step()
        # re-expand the moment the restriction lapses
        self.loop.at(until_s, self.step)

    def effective_ceiling(self, now: float) -> float:
        base = float("inf") if self._ceiling is None else self._ceiling
        self._restrictions = [r for r in self._restrictions
                              if r[0] > now]
        return max(0.0, base - sum(r[1] for r in self._restrictions))

    def step(self) -> None:
        """One policy decision: read the view, clamp to the ceiling,
        reconcile if the policy moved the target.  Re-entrant-safe —
        reconcile pumps the executor, which calls back into step()."""
        if self.policy is None or self._stepping:
            return
        self._stepping = True
        try:
            now = self.loop.now
            view = self.sched.view(now)
            cap = self.effective_ceiling(now)
            cur = len(self.sched.workers)
            tgt = self.policy.decide(view, cur, cap, now)
            self.target = tgt
            if tgt != cur:
                self.scale_log.append((now, cur, tgt))
                self.reconcile(tgt)
        finally:
            self._stepping = False

    def start(self) -> None:
        """Begin demand-driven reconciliation: decide now, re-decide on
        every executor pump, and keep a periodic tick alive so the pool
        shrinks even when no events fire (e.g. demand simply stopped)."""
        if self.policy is None:
            return
        self.ex.supply_hook = self.step
        self.loop.at(self.loop.now, self.step)
        if self._ticking:
            return
        self._ticking = True

        def tick():
            self.step()
            if self.sched.done and self.sched.submitted > 0:
                self._ticking = False   # run drained: stop re-arming
                return
            self.loop.after(self.tick_s, tick)
        self.loop.after(self.tick_s, tick)


# ---------------------------------------------------------------------------
# Convenience: assemble the whole sim for one experiment
# ---------------------------------------------------------------------------

def make_sim(devices: Optional[List[DeviceModel]] = None,
             trace: Optional[Trace] = None,
             *, evict_priority=None, workers_per_zone: int = 8,
             worker_shape: Optional[WorkerShape] = None,
             backfill: bool = True, aging_bound=8,
             warm_pool: Optional[WarmPoolPolicy] = None,
             link_budget: Optional[LinkBudget] = None,
             prestage: bool = False, disaggregate: bool = False,
             policy: Optional[ElasticPolicy] = None,
             tick_s: float = 15.0, ckpt_every_steps: Optional[int] = None,
             retry_seed: int = 0):
    """Returns (scheduler, executor, factory) wired together."""
    sched = Scheduler(backfill=backfill, aging_bound=aging_bound,
                      link_budget=link_budget, disaggregate=disaggregate)
    sched.ckpt_every_steps = ckpt_every_steps
    ex = SimExecutor(sched, prestage=prestage, warm_pool=warm_pool,
                     retry_seed=retry_seed)
    devices = devices if devices is not None else paper_20gpu_pool()
    fac = Factory(sched, ex, devices, workers_per_zone=workers_per_zone,
                  worker_shape=worker_shape, evict_priority=evict_priority,
                  policy=policy, tick_s=tick_s)
    if trace:
        fac.apply_trace(trace)
    elif policy is not None:
        fac.start()
    return sched, ex, fac


def opportunistic_supply(n: int = 256, seed: int = 0):
    return cluster_sample(n, seed=seed)
