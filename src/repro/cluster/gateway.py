"""The serving gateway: SLO classes, bounded queues, deadline semantics.

The scheduler's lanes are FIFO with a starvation bound — fine for a pool
that serves ONE throughput-oriented application, but the moment traffic
is mixed nothing distinguishes an interactive request (a user is
waiting) from a batch sweep (nobody is).  The gateway is the admission
edge that makes the distinction explicit, modeled on the rusets gateway
contract (bounded queues, queue-vs-reject, timeout-to-503) and Aladdin's
SLO-aware placement (arXiv 2405.06856):

* every :class:`~repro.cluster.scheduler.Request` carries an SLO class —
  ``INTERACTIVE`` (has a deadline) or ``BATCH`` (best-effort);
* each (recipe, class) pair gets a BOUNDED queue of fresh admissions
  with an explicit overflow policy: ``"reject"`` turns the request away
  at the edge with a terminal ``REJECTED`` record (the 429/503 path),
  ``"queue"`` parks it in a gateway-side overflow buffer that refills
  the scheduler lane as it drains — the lane itself never exceeds the
  bound;
* a queued interactive request whose deadline passes is TIMED OUT — a
  terminal ``TIMED_OUT`` record, never silently served late.  Deadlines
  bound QUEUE time: once a request is admitted to a worker it runs to
  completion (the decode itself is the service being paid for);
* re-admissions bypass the bound: a request requeued by preemption or
  worker eviction already consumed admission budget at the edge — the
  bound is front-door admission control, not an in-flight cap.

Preemption (the scheduler side, see ``Scheduler.route``): when an
interactive head's deadline is at risk and no warm slot is free, a
BATCH member of a live dynamic batch is suspended — its KV state spills
host-side through the decoder's suspend/resume pair — and the
interactive request takes its slot.  The victim re-enters its lane
``PREEMPTED`` and later resumes from the spilled cache on the same
worker without re-prefill.

Terminal outcomes are mutually exclusive by construction:
:meth:`Scheduler.record_terminal` asserts a request is finalized at
most once, and ``REJECTED``/``TIMED_OUT``/``"done"`` are the only
terminal states (a preempted request is NOT terminal — it completes
``"done"`` with ``preemptions > 0`` on its record).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from enum import Enum
from typing import Deque, Dict, List, Optional, Tuple

from .scheduler import Request, Scheduler

# terminal outcomes on RequestRecord.outcome
DONE = "done"
REJECTED = "rejected"
TIMED_OUT = "timed_out"


class SLOClass(str, Enum):
    """Request service classes the gateway distinguishes."""
    INTERACTIVE = "interactive"
    BATCH = "batch"


INTERACTIVE = SLOClass.INTERACTIVE.value
BATCH = SLOClass.BATCH.value


@dataclass
class ClassPolicy:
    """Admission policy for one SLO class.

    ``max_queue`` bounds FRESH queued requests per recipe lane (``None``
    = unbounded); ``overflow`` picks what happens past the bound:
    ``"reject"`` (terminal REJECTED) or ``"queue"`` (park in the
    gateway's overflow buffer; the lane never exceeds the bound).
    ``deadline_s`` is the default RELATIVE deadline stamped on requests
    that arrive without one (``None`` = no deadline — the batch class);
    ``preempt_slack_s`` is how close to its deadline a queued
    interactive request must be before the router may preempt a batch
    slot for it."""
    max_queue: Optional[int] = None
    overflow: str = "queue"                 # "queue" | "reject"
    deadline_s: Optional[float] = None
    preempt_slack_s: float = 5.0

    def __post_init__(self):
        if self.overflow not in ("queue", "reject"):
            raise ValueError(f"overflow must be 'queue' or 'reject', "
                             f"got {self.overflow!r}")


class Gateway:
    """Admission edge between :class:`Application` and :class:`Scheduler`.

    Installs itself as ``scheduler.gateway``; :meth:`Scheduler.ingress`
    then routes every submission through :meth:`submit`, and
    ``Scheduler.route`` calls :meth:`expire` each dispatch round so a
    deadline can never be crossed silently."""

    def __init__(self, sched: Scheduler, *,
                 interactive: Optional[ClassPolicy] = None,
                 batch: Optional[ClassPolicy] = None):
        self.sched = sched
        self.policies: Dict[str, ClassPolicy] = {
            INTERACTIVE: interactive or ClassPolicy(
                max_queue=64, overflow="reject", deadline_s=60.0),
            BATCH: batch or ClassPolicy(max_queue=None, overflow="queue"),
        }
        # (recipe_key, slo) -> parked fresh requests awaiting lane room
        self._overflow: Dict[Tuple[str, str], Deque[Request]] = {}
        self.rejected: Dict[str, int] = {INTERACTIVE: 0, BATCH: 0}
        self.timed_out: Dict[str, int] = {INTERACTIVE: 0, BATCH: 0}
        self.admitted: Dict[str, int] = {INTERACTIVE: 0, BATCH: 0}
        sched.gateway = self

    # -- admission accounting -------------------------------------------
    @staticmethod
    def _is_fresh(req: Request) -> bool:
        """Fresh = never dispatched; re-admissions bypass the bound."""
        return req.attempts == 0 and req.preemptions == 0 \
            and req.steps_done == 0

    def queued_fresh(self, key: str, slo: str) -> int:
        lane = self.sched.lanes.get(key)
        if not lane:
            return 0
        return sum(1 for r in lane if r.slo == slo and self._is_fresh(r))

    def queue_depth(self, key: str, slo: str) -> int:
        """Lane depth + overflow for (recipe, class)."""
        lane = self.sched.lanes.get(key) or ()
        return sum(1 for r in lane if r.slo == slo) + \
            len(self._overflow.get((key, slo), ()))

    @property
    def pending_overflow(self) -> int:
        return sum(len(q) for q in self._overflow.values())

    # -- the front door --------------------------------------------------
    def submit(self, req: Request) -> Request:
        """Admit, park, or reject one request at the edge."""
        pol = self.policies.get(req.slo)
        if pol is None:
            raise ValueError(f"unknown SLO class {req.slo!r}")
        now = self.sched.clock()
        if req.slo == INTERACTIVE and req.deadline_s is None \
                and pol.deadline_s is not None:
            req.deadline_s = max(req.arrival_s, now) + pol.deadline_s
        if pol.max_queue is not None and self._is_fresh(req) and \
                self.queued_fresh(req.recipe_key, req.slo) >= pol.max_queue:
            if pol.overflow == "reject":
                self.rejected[req.slo] += 1
                self.sched.record_terminal(req, REJECTED, now)
                return req
            self._overflow.setdefault((req.recipe_key, req.slo),
                                      deque()).append(req)
            return req
        self.admitted[req.slo] += 1
        self.sched.submit(req)
        return req

    def _refill(self, key: str, slo: str) -> None:
        pol = self.policies[slo]
        q = self._overflow.get((key, slo))
        while q and (pol.max_queue is None
                     or self.queued_fresh(key, slo) < pol.max_queue):
            req = q.popleft()
            self.admitted[slo] += 1
            self.sched.submit(req)
        if q is not None and not q:
            del self._overflow[(key, slo)]

    def on_dispatched(self, req: Request) -> None:
        """A lane head left its queue; refill from overflow."""
        self._refill(req.recipe_key, req.slo)

    # -- deadline semantics ----------------------------------------------
    @staticmethod
    def _expirable(r: Request) -> bool:
        """Only requests whose service has NOT begun can time out at the
        edge.  A request with banked progress (a DECODE-phase requeue
        carrying its prefill KV, or a preempted member awaiting resume)
        is mid-service: dropping it would waste the work already done
        and strand its snapshot — it keeps its queue slot instead."""
        return r.deadline_s is not None and r.steps_done == 0

    def expire(self, now: float) -> List[Request]:
        """Time out every QUEUED request whose deadline has passed —
        lane and overflow alike — so nothing is ever served late.
        Returns the expired requests."""
        expired: List[Request] = []
        for key, lane in self.sched.lanes.items():
            dead = [r for r in lane
                    if self._expirable(r) and r.deadline_s < now]
            for r in dead:
                lane.remove(r)
                expired.append(r)
        for (key, slo), q in list(self._overflow.items()):
            dead = [r for r in q
                    if self._expirable(r) and r.deadline_s < now]
            for r in dead:
                q.remove(r)
                expired.append(r)
        for r in expired:
            self.timed_out[r.slo] += 1
            self.sched.record_terminal(r, TIMED_OUT, now)
        if expired:
            for key in {r.recipe_key for r in expired}:
                for slo in (INTERACTIVE, BATCH):
                    self._refill(key, slo)
        return expired

    def next_deadline(self) -> Optional[float]:
        """Earliest deadline among queued EXPIRABLE requests (lane or
        overflow) — the same set :meth:`expire` can act on, so a
        deadline timer armed on this value always makes progress."""
        ds = [r.deadline_s for lane in self.sched.lanes.values()
              for r in lane if self._expirable(r)]
        ds += [r.deadline_s for q in self._overflow.values()
               for r in q if self._expirable(r)]
        return min(ds) if ds else None

    # -- observability ----------------------------------------------------
    def saturation(self) -> Dict[str, float]:
        """Active decode slots vs pool slot capacity, plus queue depths
        and the terminal counters — the backpressure dashboard."""
        sched = self.sched
        active = {INTERACTIVE: 0, BATCH: 0}
        for req, _wid in sched.running.values():
            active[req.slo] = active.get(req.slo, 0) + 1
        capacity = 0
        for w in sched.workers.values():
            for key in w.open_streams:
                lib = w.libraries.get(key)
                if lib is None:
                    continue
                req = next(iter(lib.batch.values()), None)
                ap = req.active_params if req is not None else 0.0
                capacity += w.slot_budget(key, ap)
        queued = {slo: sum(self.queue_depth(key, slo)
                           for key in set(sched.lanes)
                           | {k for k, _ in self._overflow})
                  for slo in (INTERACTIVE, BATCH)}
        return {
            "active_interactive": active[INTERACTIVE],
            "active_batch": active[BATCH],
            "slot_capacity": capacity,
            "saturation": (sum(active.values()) / capacity
                           if capacity else 0.0),
            "queued_interactive": queued[INTERACTIVE],
            "queued_batch": queued[BATCH],
            "rejected": sum(self.rejected.values()),
            "timed_out": sum(self.timed_out.values()),
            "preemptions": sched.preemptions,
        }


def format_gateway(gw: Gateway) -> str:
    s = gw.saturation()
    return (f"[gateway] active {s['active_interactive']:.0f}i/"
            f"{s['active_batch']:.0f}b of {s['slot_capacity']:.0f} slots "
            f"({100 * s['saturation']:.0f}%) | queued "
            f"{s['queued_interactive']:.0f}i/{s['queued_batch']:.0f}b | "
            f"rejected {s['rejected']:.0f}  timed-out {s['timed_out']:.0f}  "
            f"preemptions {s['preemptions']:.0f}")
