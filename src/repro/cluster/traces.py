"""Opportunistic-availability traces (paper §6 scenarios).

A trace is a sorted list of ``(t_seconds, target_worker_count)`` pairs the
factory reconciles against.  Three families, mirroring the evaluation:

* ``constant``          — the controlled 20-GPU pool (pv0-pv4);
* ``drain``             — pv5: 15 min stable, then -1 GPU/min to zero;
* ``diurnal``           — pv6: availability follows the cluster's daily
                          load curve, noisy, time-of-day dependent.

Beyond the smooth availability families, :class:`Storm` /
:func:`storm_schedule` describe CORRELATED eviction storms — N workers
reclaimed in one window, typically zone-correlated (a rack or power
domain going away takes its neighbours together).  A trace shapes the
*ceiling* the factory may acquire under; a storm schedule names discrete
loss events the :class:`~repro.cluster.forecast.ChurnInjector` fires
through the scheduler's eviction path.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass
from typing import List, Tuple

Trace = List[Tuple[float, int]]


@dataclass(frozen=True)
class Storm:
    """One correlated eviction event: ``n_workers`` lost at ``t_s``.

    ``zone_correlated`` drains a population-weighted seed zone first
    (spilling into neighbours only when it runs dry); ``revoke_staging``
    prefers victims that are mid-staging — the worst case for the
    context plane, which must refund their in-flight ops."""
    t_s: float
    n_workers: int
    zone_correlated: bool = True
    revoke_staging: bool = False


def storm_schedule(first_s: float, every_s: float, n_storms: int,
                   n_workers: int, *, zone_correlated: bool = True,
                   revoke_staging: bool = False) -> List[Storm]:
    """A regular train of ``n_storms`` identical storms."""
    return [Storm(first_s + i * every_s, n_workers,
                  zone_correlated=zone_correlated,
                  revoke_staging=revoke_staging)
            for i in range(n_storms)]


# fault kinds a Fault event may carry (see docs/failure-model.md):
#   revoke   — advance-notice clean eviction (the Storm path)
#   crash    — silent crash-stop; only the FailureDetector's lease
#              expiry notices, bounding detection latency by lease_s
#   hang     — worker stays leased but decode stops making progress;
#              the detector's step watchdog converts it to an eviction
#   transfer — one in-flight context-plane transfer sourced from the
#              victim fails; exercises abort-refund-retry with backoff
FAULT_KINDS = ("revoke", "crash", "hang", "transfer")


@dataclass(frozen=True)
class Fault:
    """One scheduled fault event: ``n_workers`` hit by ``kind`` at
    ``t_s``.  Victim selection reuses the Storm machinery (zone
    correlation, staging preference) so crash storms stress the same
    correlated-loss paths clean revocations do."""
    t_s: float
    kind: str
    n_workers: int = 1
    zone_correlated: bool = True
    revoke_staging: bool = False

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")


def fault_schedule(first_s: float, every_s: float, n_faults: int,
                   kind: str, n_workers: int = 1, *,
                   zone_correlated: bool = True) -> List[Fault]:
    """A regular train of ``n_faults`` identical fault events."""
    return [Fault(first_s + i * every_s, kind, n_workers,
                  zone_correlated=zone_correlated)
            for i in range(n_faults)]


def constant(n: int) -> Trace:
    return [(0.0, n)]


def drain(n: int = 20, stable_s: float = 900.0,
          rate_per_s: float = 1 / 60.0) -> Trace:
    """pv5: stable for ``stable_s``, then reclaim 1 worker per minute."""
    out: Trace = [(0.0, n)]
    for i in range(1, n + 1):
        out.append((stable_s + i / rate_per_s, n - i))
    return out


# Hourly availability fractions of the ~186 opportunistically reachable
# GPUs, shaped like the paper's Fig 4/7 narrative: mornings busy, early
# afternoon freest, overnight jobs soak the cluster.
_DIURNAL_FRAC = {
    0: 0.12, 1: 0.10, 2: 0.09, 3: 0.08, 4: 0.08, 5: 0.10,
    6: 0.12, 7: 0.15, 8: 0.18, 9: 0.20, 10: 0.24, 11: 0.28,
    12: 0.30, 13: 0.33, 14: 0.34, 15: 0.30, 16: 0.26, 17: 0.22,
    18: 0.20, 19: 0.18, 20: 0.16, 21: 0.14, 22: 0.12, 23: 0.06,
}


def diurnal(start_hour: int, *, max_gpus: int = 186,
            duration_s: float = 14_400.0, step_s: float = 120.0,
            noise: float = 0.15, seed: int = 0) -> Trace:
    """pv6: noisy availability around the cluster's daily load curve."""
    rng = random.Random(seed * 1009 + start_hour)
    out: Trace = []
    t = 0.0
    while t <= duration_s:
        hour = (start_hour + t / 3600.0) % 24
        h0, h1 = int(hour) % 24, (int(hour) + 1) % 24
        frac = _DIURNAL_FRAC[h0] + (hour - int(hour)) * (
            _DIURNAL_FRAC[h1] - _DIURNAL_FRAC[h0])
        jitter = 1.0 + noise * (2 * rng.random() - 1.0)
        out.append((t, max(1, int(max_gpus * frac * jitter))))
        t += step_s
    return out


def quiet_day(*, max_gpus: int = 186, duration_s: float = 3_600.0,
              step_s: float = 120.0, seed: int = 7) -> Trace:
    """pv6 (different, less busy day): ~85 % of the pool reachable."""
    rng = random.Random(seed)
    out: Trace = []
    t = 0.0
    while t <= duration_s:
        frac = 0.85 + 0.1 * math.sin(t / 600.0) * rng.random()
        out.append((t, max(1, int(max_gpus * min(frac, 1.0)))))
        t += step_s
    return out
