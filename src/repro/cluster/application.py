"""The application front-end: request streams over the scheduler.

The paper's thesis is that throughput-oriented LLM *applications* are
streams of many small inferences.  :class:`Application` is the surface
such an application programs against: it registers context recipes and
feeds per-request work (prompt units + a decode-step budget + an arrival
time) into the scheduler's per-recipe lanes, where the routing layer can
continuously admit requests into already-decoding batches on warm
workers.

Two submission styles:

* :meth:`submit` — one request, now (live serving: call it as traffic
  arrives; the wall clock is the arrival time);
* :meth:`submit_stream` — a whole arrival schedule for the DES backend:
  each spec is submitted as a loop event at its ``arrival_s`` and the
  executor is pumped, so the sim sees the same open-loop arrival process
  a live front-end would.

The old whole-batch API (``scheduler.submit_sweep``) survives as a
deprecated shim that expands into *exclusive* requests.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, List, Optional

from ..core import ContextMode, ContextRecipe, PERVASIVE
from .hardware import REF_ACTIVE_PARAMS
from .observability import class_latency_summary, latency_summary
from .scheduler import Request, RequestRecord, Scheduler


class Application:
    """A request-stream application bound to one scheduler."""

    def __init__(self, scheduler: Scheduler, *,
                 default_mode: ContextMode = PERVASIVE):
        self.sched = scheduler
        self.default_mode = default_mode
        self.requests: List[Request] = []
        self.active_params: Dict[str, float] = {}

    # -- contexts -------------------------------------------------------
    def register(self, recipe: ContextRecipe, *,
                 active_params: float = REF_ACTIVE_PARAMS) -> str:
        key = self.sched.register_context(recipe)
        self.active_params[key] = active_params
        return key

    # -- submission -----------------------------------------------------
    def make_request(self, recipe_key: str, *, decode_steps: int = 1,
                     prompt_units: int = 0, payload: Any = None,
                     arrival_s: float = 0.0,
                     mode: Optional[ContextMode] = None,
                     active_params: Optional[float] = None,
                     exclusive: bool = False,
                     slo: str = "batch",
                     deadline_s: Optional[float] = None) -> Request:
        """Build (but do not submit) one request.

        ``exclusive=True`` produces a run-to-completion request that
        admits no co-members — ONLY useful as the benchmark baseline the
        continuous-batching path is measured against.  ``slo`` picks the
        gateway service class (``"interactive"`` or ``"batch"``);
        ``deadline_s`` is an ABSOLUTE queue deadline (interactive
        requests without one get the gateway policy's default)."""
        req = Request(
            recipe_key, decode_steps=decode_steps,
            prompt_units=prompt_units, payload=payload,
            arrival_s=arrival_s, mode=mode or self.default_mode,
            exclusive=exclusive, slo=slo, deadline_s=deadline_s,
            active_params=(active_params if active_params is not None
                           else self.active_params.get(recipe_key,
                                                       REF_ACTIVE_PARAMS)))
        self.requests.append(req)
        return req

    def submit(self, recipe_key: str, **kw) -> Request:
        """Submit one request immediately (live-serving arrival).

        Goes through :meth:`Scheduler.ingress`, so an installed gateway
        applies its admission policy (bound / reject / deadline stamp)."""
        req = self.make_request(recipe_key, **kw)
        self.sched.ingress(req)
        return req

    def submit_stream(self, executor, specs: Iterable[Dict[str, Any]]
                      ) -> List[Request]:
        """Replay an arrival schedule through a :class:`SimExecutor`.

        Each spec is the kwargs of :meth:`make_request` plus a required
        ``recipe_key``; the request enters its lane at ``arrival_s`` on
        the executor's event loop and the dispatch loop is pumped, so
        admissions happen at arrival time, not at run start."""
        out = []
        for spec in specs:
            spec = dict(spec)
            key = spec.pop("recipe_key")
            req = self.make_request(key, **spec)
            out.append(req)

            def arrive(req=req):
                executor.pending_arrivals -= 1
                self.sched.ingress(req)
                executor.pump()

            executor.pending_arrivals += 1
            executor.loop.at(req.arrival_s, arrive)
        return out

    # -- results --------------------------------------------------------
    def records(self) -> List[RequestRecord]:
        """Completion records for THIS application's requests."""
        ids = {r.request_id for r in self.requests}
        return [rec for rec in self.sched.records if rec.request_id in ids]

    def latency_summary(self) -> Dict[str, float]:
        """Queue-wait / time-to-first-step / end-to-end distributions."""
        return latency_summary(self.records())

    def class_latency_summary(self) -> Dict[str, Dict[str, float]]:
        """Latency distributions split by SLO class."""
        return class_latency_summary(self.records())
