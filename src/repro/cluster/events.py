"""Minimal discrete-event engine for the cluster simulator.

A classic calendar-queue DES: a heap of (time, seq, callback).  The same
scheduler/registry/transfer/cache code runs under this engine (SimExecutor)
and under wall-clock time (LiveExecutor); only task execution time differs
(DESIGN.md §3, dual execution backend).

:meth:`EventLoop.at` / :meth:`~EventLoop.after` return a :class:`Timer`
handle; cancelling it is O(1) (the heap entry is skipped when popped).
Stream batch runners rely on this: every membership change of a dynamic
batch invalidates the previously scheduled step boundary.
"""
from __future__ import annotations

import heapq
import itertools
from typing import Callable, List, Optional, Tuple


class Timer:
    """Cancellable handle for one scheduled callback."""
    __slots__ = ("t", "cancelled")

    def __init__(self, t: float):
        self.t = t
        self.cancelled = False

    def cancel(self) -> None:
        self.cancelled = True


class EventLoop:
    def __init__(self):
        self._heap: List[Tuple[float, int, Timer,
                               Callable[[], None]]] = []
        self._seq = itertools.count()
        self._now = 0.0

    @property
    def now(self) -> float:
        return self._now

    def at(self, t: float, fn: Callable[[], None]) -> Timer:
        if t < self._now:
            raise ValueError(f"scheduling into the past: {t} < {self._now}")
        timer = Timer(t)
        heapq.heappush(self._heap, (t, next(self._seq), timer, fn))
        return timer

    def after(self, delay: float, fn: Callable[[], None]) -> Timer:
        return self.at(self._now + max(delay, 0.0), fn)

    def step(self) -> bool:
        while self._heap:
            t, _, timer, fn = heapq.heappop(self._heap)
            if timer.cancelled:
                continue
            self._now = t
            fn()
            return True
        return False

    def _next_live(self) -> Optional[float]:
        while self._heap and self._heap[0][2].cancelled:
            heapq.heappop(self._heap)
        return self._heap[0][0] if self._heap else None

    def run(self, *, until: Optional[float] = None,
            stop: Optional[Callable[[], bool]] = None,
            max_events: int = 50_000_000) -> float:
        """Run until the heap drains, ``until`` time passes, or ``stop()``."""
        n = 0
        while True:
            if stop is not None and stop():
                break
            t = self._next_live()
            if t is None:
                break
            if until is not None and t > until:
                self._now = until
                break
            if not self.step():
                break
            n += 1
            if n >= max_events:
                raise RuntimeError(f"event budget exceeded ({max_events})")
        return self._now
