"""Minimal discrete-event engine for the cluster simulator.

A classic calendar-queue DES: a heap of (time, seq, callback).  The same
scheduler/registry/transfer/cache code runs under this engine (SimExecutor)
and under wall-clock time (LiveExecutor); only task execution time differs
(DESIGN.md §3, dual execution backend).
"""
from __future__ import annotations

import heapq
import itertools
from typing import Callable, List, Optional, Tuple


class EventLoop:
    def __init__(self):
        self._heap: List[Tuple[float, int, Callable[[], None]]] = []
        self._seq = itertools.count()
        self._now = 0.0

    @property
    def now(self) -> float:
        return self._now

    def at(self, t: float, fn: Callable[[], None]) -> None:
        if t < self._now:
            raise ValueError(f"scheduling into the past: {t} < {self._now}")
        heapq.heappush(self._heap, (t, next(self._seq), fn))

    def after(self, delay: float, fn: Callable[[], None]) -> None:
        self.at(self._now + max(delay, 0.0), fn)

    def step(self) -> bool:
        if not self._heap:
            return False
        t, _, fn = heapq.heappop(self._heap)
        self._now = t
        fn()
        return True

    def run(self, *, until: Optional[float] = None,
            stop: Optional[Callable[[], bool]] = None,
            max_events: int = 50_000_000) -> float:
        """Run until the heap drains, ``until`` time passes, or ``stop()``."""
        n = 0
        while self._heap:
            if stop is not None and stop():
                break
            t = self._heap[0][0]
            if until is not None and t > until:
                self._now = until
                break
            if not self.step():
                break
            n += 1
            if n >= max_events:
                raise RuntimeError(f"event budget exceeded ({max_events})")
        return self._now
