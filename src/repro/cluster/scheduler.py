"""The manager: TaskVine-style scheduler with context-aware routing.

The :class:`Scheduler` is *time-free*: it owns the ready lanes, the worker
pool, the context registry, and all placement decisions, but never looks at
a clock.  The executors (sim: discrete-event; live: wall clock) pump
:meth:`route` and feed back :meth:`on_complete` / :meth:`on_evict`, so the
paper's management layer — the contribution under test — is byte-for-byte
identical in both backends.

Routing policy (paper §5.1/§5.3.2, plus context-aware backfill):
  * tasks run 1-per-worker (work stealing across heterogeneous devices);
  * the ready queue is split into per-recipe LANES; :meth:`route` scans the
    lane heads in global FIFO order and may *backfill* past a blocked head
    (no idle worker can host its recipe) to any routable deeper pair, so
    one unplaceable recipe never stalls the whole pool;
  * warm placements (library READY) are matched before any cold placement;
  * anti-starvation: a head that has been passed over ``aging_bound`` times
    reserves the workers able to host it — younger tasks may no longer
    backfill onto those until the aged head is placed;
  * cold placement prefers a worker holding a SPILLED local copy (promotion
    from local disk — no fetch), then the fastest capable idle device,
    fetching from an in-zone ready peer when one exists (spanning-tree
    distribution emerges from many such decisions);
  * an evicted worker's running task is requeued at its lane head and its
    registry residencies are dropped (no grace period).

``backfill=False`` restores the seed single-FIFO head-only policy (used as
the baseline in benchmarks/bench_fig6_busy_cluster.py's mixed scenario).
"""
from __future__ import annotations

import itertools
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional, Tuple

from ..core import (ContextRegistry, ContextRecipe, ContextMode, PERVASIVE,
                    Peer, pick_sources)
from .hardware import ClusterSpec, PAPER_CLUSTER, REF_ACTIVE_PARAMS
from .worker import Worker

_task_ids = itertools.count()


@dataclass
class Task:
    recipe_key: str
    n_inferences: int
    mode: ContextMode = PERVASIVE
    active_params: float = REF_ACTIVE_PARAMS
    payload: Any = None               # live mode: callable args
    task_id: int = field(default_factory=lambda: next(_task_ids))
    attempts: int = 0
    skipped: int = 0                  # dispatches that backfilled past us


@dataclass
class Assignment:
    task: Task
    worker: Worker
    warm: bool                        # library READY on this worker
    peer_source: Optional[str]        # ready peer to fetch from (cold only)
    cross_zone: bool = False
    local_restage: bool = False       # cold, but promoted from local disk


@dataclass
class TaskRecord:
    task_id: int
    worker_id: str
    device: str
    t_start: float
    t_end: float
    exec_s: float                     # on-worker execution (incl. staging)
    n_inferences: int
    warm: bool
    attempts: int


class Scheduler:
    def __init__(self, cluster: ClusterSpec = PAPER_CLUSTER, *,
                 backfill: bool = True, aging_bound: int = 8):
        self.cluster = cluster
        self.backfill = backfill
        self.aging_bound = aging_bound
        self.registry = ContextRegistry()
        # per-recipe FIFO lanes; global order recovered via task_id
        self.lanes: "OrderedDict[str, Deque[Task]]" = OrderedDict()
        self.workers: Dict[str, Worker] = {}
        self.running: Dict[int, Tuple[Task, str]] = {}
        # -- metrics -------------------------------------------------
        self.records: List[TaskRecord] = []
        self.progress_events: List[Tuple[float, int]] = [(0.0, 0)]
        self.worker_events: List[Tuple[float, int]] = [(0.0, 0)]
        self.completed_inferences = 0
        self.evicted_tasks = 0
        self.evicted_inferences = 0
        self.backfills = 0            # dispatches that jumped a blocked head
        self.spilled_libraries = 0
        self.submitted = 0

    # ------------------------------------------------------------------
    # registration
    # ------------------------------------------------------------------
    def register_context(self, recipe: ContextRecipe) -> str:
        return self.registry.register(recipe)

    def submit(self, task: Task) -> None:
        self.lanes.setdefault(task.recipe_key, deque()).append(task)
        self.submitted += 1

    def submit_sweep(self, recipe_key: str, n_total: int, batch: int,
                     mode: ContextMode = PERVASIVE,
                     active_params: float = REF_ACTIVE_PARAMS) -> int:
        """Split ``n_total`` inferences into batch-sized tasks (the PfF app)."""
        n_tasks = 0
        left = n_total
        while left > 0:
            b = min(batch, left)
            self.submit(Task(recipe_key, b, mode, active_params))
            left -= b
            n_tasks += 1
        return n_tasks

    @property
    def queue(self) -> List[Task]:
        """All queued tasks in global FIFO (submission) order."""
        return sorted((t for lane in self.lanes.values() for t in lane),
                      key=lambda t: t.task_id)

    def _requeue(self, task: Task) -> None:
        self.lanes.setdefault(task.recipe_key, deque()).appendleft(task)

    # ------------------------------------------------------------------
    # pool membership (driven by the factory / eviction processes)
    # ------------------------------------------------------------------
    def add_worker(self, worker: Worker, now: float = 0.0) -> None:
        worker.joined_s = now
        self.workers[worker.worker_id] = worker
        self.worker_events.append((now, len(self.workers)))

    def on_evict(self, worker_id: str, now: float = 0.0) -> List[Task]:
        """Worker reclaimed with no grace period. Returns requeued tasks.

        Also covers eviction mid-staging/mid-spill: the in-flight task goes
        back to its lane head and the worker's residencies (READY, STAGING
        and SPILLED alike) vanish from the registry, so no later routing
        decision can count on the lost copies.
        """
        worker = self.workers.pop(worker_id, None)
        if worker is None:
            return []
        self.worker_events.append((now, len(self.workers)))
        self.registry.drop_worker(worker_id)
        requeued = []
        for tid, (task, wid) in list(self.running.items()):
            if wid == worker_id:
                del self.running[tid]
                task.attempts += 1
                self.evicted_tasks += 1
                self.evicted_inferences += task.n_inferences
                self._requeue(task)             # retry first (paper: requeue)
                requeued.append(task)
        return requeued

    # ------------------------------------------------------------------
    # routing
    # ------------------------------------------------------------------
    def _idle_workers(self) -> List[Worker]:
        return [w for w in self.workers.values() if w.idle]

    def _heads(self) -> List[Task]:
        heads = [lane[0] for lane in self.lanes.values() if lane]
        heads.sort(key=lambda t: t.task_id)
        return heads

    def _usable_by(self, task: Task, w: Worker) -> bool:
        return w.has_ready(task.recipe_key) or \
            w.can_host(self.registry.recipes[task.recipe_key])

    def route(self) -> Optional[Assignment]:
        """Match a routable (lane head, idle worker) pair, warm-first.

        Scans lane heads oldest-first; with ``backfill`` enabled a blocked
        head is skipped rather than stalling the pool.  The oldest head
        that has been passed over ``aging_bound`` times reserves every
        worker able to host it."""
        heads = self._heads()
        if not heads:
            return None
        idle = self._idle_workers()
        if not idle:
            return None
        if not self.backfill:
            heads = heads[:1]           # seed policy: head-of-line only
        starved = heads[0] if heads[0].skipped >= self.aging_bound else None

        def allowed(task: Task, w: Worker) -> bool:
            if starved is None or task is starved:
                return True
            return not self._usable_by(starved, w)

        # pass 1: warm placements (library READY on an idle worker)
        for task in heads:
            key = task.recipe_key
            ready = self.registry.ready_workers(key)
            warm = [w for w in idle if w.worker_id in ready
                    and w.has_ready(key) and allowed(task, w)]
            if warm:
                # fastest warm device first (work stealing does the rest)
                w = min(warm, key=lambda w: w.device.infer_s)
                return self._dispatch(task, w, warm=True)
        # pass 2: cold placements (stage onto any capable idle worker)
        for task in heads:
            recipe = self.registry.recipes[task.recipe_key]
            cands = [w for w in idle
                     if w.can_host(recipe) and allowed(task, w)]
            if not cands:
                continue
            spilled = self.registry.spilled_workers(task.recipe_key)
            # prefer promotion from a local spilled copy, then fastest
            w = min(cands, key=lambda w: (w.worker_id not in spilled,
                                          w.device.infer_s))
            return self._dispatch(task, w, warm=False)
        return None

    def _dispatch(self, task: Task, w: Worker, *, warm: bool) -> Assignment:
        lane = self.lanes[task.recipe_key]
        assert lane and lane[0] is task
        lane.popleft()
        # age every older head this dispatch jumped past
        jumped = False
        for other in self._heads():
            if other.task_id < task.task_id:
                other.skipped += 1
                jumped = True
        if jumped:
            self.backfills += 1
        self.running[task.task_id] = (task, w.worker_id)
        if warm:
            return Assignment(task, w, warm=True, peer_source=None)
        recipe = self.registry.recipes[task.recipe_key]
        if w.has_local(recipe):
            # spilled (or disk-cached) copy: promote locally, no fetch
            return Assignment(task, w, warm=False, peer_source=None,
                              local_restage=True)
        src, cross = self._pick_peer(task.recipe_key, w)
        return Assignment(task, w, warm=False, peer_source=src,
                          cross_zone=cross)

    def _pick_peer(self, key: str, dst: Worker) -> Tuple[Optional[str], bool]:
        ready = self.registry.ready_workers(key) - {dst.worker_id}
        if not ready:
            return None, False
        peers = [Peer(wid, self.workers[wid].zone) for wid in ready
                 if wid in self.workers]
        if not peers:
            return None, False
        chosen = pick_sources(peers, dst.zone, max_sources=1)[0]
        return chosen.worker_id, chosen.zone != dst.zone

    # ------------------------------------------------------------------
    # completion bookkeeping (executors call these)
    # ------------------------------------------------------------------
    def on_start(self, assignment: Assignment) -> None:
        w, task = assignment.worker, assignment.task
        w.running += 1
        w.running_by_recipe[task.recipe_key] = \
            w.running_by_recipe.get(task.recipe_key, 0) + 1
        w.touch(task.recipe_key)
        if not assignment.warm:
            recipe = self.registry.recipes[task.recipe_key]
            for key in w.make_room(recipe):     # spill, don't drop
                self.registry.mark_spilled(key, w.worker_id)
                self.spilled_libraries += 1
            w.staging = True
            self.registry.mark_staging(task.recipe_key, w.worker_id)

    def on_staged(self, assignment: Assignment) -> None:
        w = assignment.worker
        w.staging = False
        self.registry.mark_ready(assignment.task.recipe_key, w.worker_id)

    def on_complete(self, assignment: Assignment, t_start: float,
                    t_end: float) -> None:
        task, w = assignment.task, assignment.worker
        if task.task_id not in self.running:
            return                          # stale (worker evicted mid-run)
        del self.running[task.task_id]
        w.running -= 1
        n = w.running_by_recipe.get(task.recipe_key, 0)
        w.running_by_recipe[task.recipe_key] = max(0, n - 1)
        w.tasks_done += 1
        w.inferences_done += task.n_inferences
        self.completed_inferences += task.n_inferences
        self.progress_events.append((t_end, self.completed_inferences))
        self.records.append(TaskRecord(
            task.task_id, w.worker_id, w.device.name, t_start, t_end,
            t_end - t_start, task.n_inferences, assignment.warm,
            task.attempts))

    # ------------------------------------------------------------------
    @property
    def done(self) -> bool:
        return not any(self.lanes.values()) and not self.running

    def makespan(self) -> float:
        return max((r.t_end for r in self.records), default=0.0)

    def avg_connected_workers(self) -> float:
        """Time-weighted mean worker count over the run."""
        ev = sorted(self.worker_events)
        end = self.makespan() or (ev[-1][0] if ev else 0.0)
        if end <= 0:
            return float(ev[-1][1]) if ev else 0.0
        area, prev_t, prev_n = 0.0, 0.0, 0
        for t, n in ev:
            t = min(t, end)
            area += prev_n * (t - prev_t)
            prev_t, prev_n = t, n
        area += prev_n * (end - prev_t)
        return area / end
