"""The manager: TaskVine-style scheduler with context-aware routing.

The :class:`Scheduler` is *time-free*: it owns the ready queue, the worker
pool, the context registry, and all placement decisions, but never looks at
a clock.  The executors (sim: discrete-event; live: wall clock) pump
:meth:`route` and feed back :meth:`on_complete` / :meth:`on_evict`, so the
paper's management layer — the contribution under test — is byte-for-byte
identical in both backends.

Routing policy (paper §5.1/§5.3.2):
  * tasks run 1-per-worker (work stealing across heterogeneous devices);
  * a task prefers a worker whose library for its context is READY;
  * otherwise it takes any idle cold worker and stages the context there,
    fetching from an in-zone ready peer when one exists (spanning-tree
    distribution emerges from many such decisions);
  * an evicted worker's running task is requeued at the queue head and its
    registry residencies are dropped (no grace period).
"""
from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional, Tuple

from ..core import (ContextRegistry, ContextRecipe, ContextMode, PERVASIVE,
                    Peer, pick_sources)
from .hardware import ClusterSpec, PAPER_CLUSTER, REF_ACTIVE_PARAMS
from .worker import Worker

_task_ids = itertools.count()


@dataclass
class Task:
    recipe_key: str
    n_inferences: int
    mode: ContextMode = PERVASIVE
    active_params: float = REF_ACTIVE_PARAMS
    payload: Any = None               # live mode: callable args
    task_id: int = field(default_factory=lambda: next(_task_ids))
    attempts: int = 0


@dataclass
class Assignment:
    task: Task
    worker: Worker
    warm: bool                        # library READY on this worker
    peer_source: Optional[str]        # ready peer to fetch from (cold only)
    cross_zone: bool = False


@dataclass
class TaskRecord:
    task_id: int
    worker_id: str
    device: str
    t_start: float
    t_end: float
    exec_s: float                     # on-worker execution (incl. staging)
    n_inferences: int
    warm: bool
    attempts: int


class Scheduler:
    def __init__(self, cluster: ClusterSpec = PAPER_CLUSTER):
        self.cluster = cluster
        self.registry = ContextRegistry()
        self.queue: Deque[Task] = deque()
        self.workers: Dict[str, Worker] = {}
        self.running: Dict[int, Tuple[Task, str]] = {}
        # -- metrics -------------------------------------------------
        self.records: List[TaskRecord] = []
        self.progress_events: List[Tuple[float, int]] = [(0.0, 0)]
        self.worker_events: List[Tuple[float, int]] = [(0.0, 0)]
        self.completed_inferences = 0
        self.evicted_tasks = 0
        self.evicted_inferences = 0
        self.submitted = 0

    # ------------------------------------------------------------------
    # registration
    # ------------------------------------------------------------------
    def register_context(self, recipe: ContextRecipe) -> str:
        return self.registry.register(recipe)

    def submit(self, task: Task) -> None:
        self.queue.append(task)
        self.submitted += 1

    def submit_sweep(self, recipe_key: str, n_total: int, batch: int,
                     mode: ContextMode = PERVASIVE,
                     active_params: float = REF_ACTIVE_PARAMS) -> int:
        """Split ``n_total`` inferences into batch-sized tasks (the PfF app)."""
        n_tasks = 0
        left = n_total
        while left > 0:
            b = min(batch, left)
            self.submit(Task(recipe_key, b, mode, active_params))
            left -= b
            n_tasks += 1
        return n_tasks

    # ------------------------------------------------------------------
    # pool membership (driven by the factory / eviction processes)
    # ------------------------------------------------------------------
    def add_worker(self, worker: Worker, now: float = 0.0) -> None:
        worker.joined_s = now
        self.workers[worker.worker_id] = worker
        self.worker_events.append((now, len(self.workers)))

    def on_evict(self, worker_id: str, now: float = 0.0) -> List[Task]:
        """Worker reclaimed with no grace period. Returns requeued tasks."""
        worker = self.workers.pop(worker_id, None)
        if worker is None:
            return []
        self.worker_events.append((now, len(self.workers)))
        self.registry.drop_worker(worker_id)
        requeued = []
        for tid, (task, wid) in list(self.running.items()):
            if wid == worker_id:
                del self.running[tid]
                task.attempts += 1
                self.evicted_tasks += 1
                self.evicted_inferences += task.n_inferences
                self.queue.appendleft(task)     # retry first (paper: requeue)
                requeued.append(task)
        return requeued

    # ------------------------------------------------------------------
    # routing
    # ------------------------------------------------------------------
    def _idle_workers(self) -> List[Worker]:
        return [w for w in self.workers.values() if w.idle]

    def route(self) -> Optional[Assignment]:
        """Match the head-most routable task with the best idle worker."""
        if not self.queue:
            return None
        idle = self._idle_workers()
        if not idle:
            return None
        task = self.queue[0]
        key = task.recipe_key
        ready = self.registry.ready_workers(key)
        warm = [w for w in idle if w.worker_id in ready
                and w.has_ready(key)]
        if warm:
            # fastest warm device first (work stealing does the rest)
            w = min(warm, key=lambda w: w.device.infer_s)
            self.queue.popleft()
            self.running[task.task_id] = (task, w.worker_id)
            return Assignment(task, w, warm=True, peer_source=None)
        # cold placement: any idle worker; prefer the fastest device
        w = min(idle, key=lambda w: w.device.infer_s)
        src, cross = self._pick_peer(key, w)
        self.queue.popleft()
        self.running[task.task_id] = (task, w.worker_id)
        return Assignment(task, w, warm=False, peer_source=src,
                          cross_zone=cross)

    def _pick_peer(self, key: str, dst: Worker) -> Tuple[Optional[str], bool]:
        ready = self.registry.ready_workers(key) - {dst.worker_id}
        if not ready:
            return None, False
        peers = [Peer(wid, self.workers[wid].zone) for wid in ready
                 if wid in self.workers]
        if not peers:
            return None, False
        chosen = pick_sources(peers, dst.zone, max_sources=1)[0]
        return chosen.worker_id, chosen.zone != dst.zone

    # ------------------------------------------------------------------
    # completion bookkeeping (executors call these)
    # ------------------------------------------------------------------
    def on_start(self, assignment: Assignment) -> None:
        w = assignment.worker
        w.running += 1
        if not assignment.warm:
            w.staging = True
            self.registry.mark_staging(assignment.task.recipe_key,
                                       w.worker_id)

    def on_staged(self, assignment: Assignment) -> None:
        w = assignment.worker
        w.staging = False
        self.registry.mark_ready(assignment.task.recipe_key, w.worker_id)

    def on_complete(self, assignment: Assignment, t_start: float,
                    t_end: float) -> None:
        task, w = assignment.task, assignment.worker
        if task.task_id not in self.running:
            return                          # stale (worker evicted mid-run)
        del self.running[task.task_id]
        w.running -= 1
        w.tasks_done += 1
        w.inferences_done += task.n_inferences
        self.completed_inferences += task.n_inferences
        self.progress_events.append((t_end, self.completed_inferences))
        self.records.append(TaskRecord(
            task.task_id, w.worker_id, w.device.name, t_start, t_end,
            t_end - t_start, task.n_inferences, assignment.warm,
            task.attempts))

    # ------------------------------------------------------------------
    @property
    def done(self) -> bool:
        return not self.queue and not self.running

    def makespan(self) -> float:
        return max((r.t_end for r in self.records), default=0.0)

    def avg_connected_workers(self) -> float:
        """Time-weighted mean worker count over the run."""
        ev = sorted(self.worker_events)
        end = self.makespan() or (ev[-1][0] if ev else 0.0)
        if end <= 0:
            return float(ev[-1][1]) if ev else 0.0
        area, prev_t, prev_n = 0.0, 0.0, 0
        for t, n in ev:
            t = min(t, end)
            area += prev_n * (t - prev_t)
            prev_t, prev_n = t, n
        area += prev_n * (end - prev_t)
        return area / end
