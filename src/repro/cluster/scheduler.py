"""The manager: request-stream scheduler with context-aware routing.

The submission surface is REQUEST-level: an application hands the
scheduler a stream of :class:`Request`\\ s (prompt units + a decode-step
budget + an arrival time) rather than opaque run-to-completion batches.
Resident libraries expose an admission interface
(:meth:`~repro.core.Library.admit` / ``step`` / ``drain``), so a request
can join a batch that is ALREADY DECODING on a warm worker — token-level
continuous batching — instead of waiting for the whole batch ahead of it
to finish.  The deprecated batch API (:func:`Task`, :meth:`submit_sweep`)
still works: a task is simply an *exclusive* request that occupies its
worker run-to-completion, which is also the baseline the benchmarks
compare against.

The :class:`Scheduler` stays *time-free* for placement ordering: it owns
the ready lanes, the worker pool, and all placement decisions, but never
orders events by a clock.  The executors (sim: discrete-event; live: wall
clock) pump :meth:`route` and feed back :meth:`on_complete` /
:meth:`on_evict`, so the paper's management layer — the contribution
under test — is byte-for-byte identical in both backends.  (The one
clock consumer is the context plane's sliding LINK-BUDGET window; the
executors install their time source on :attr:`Scheduler.clock`.)

Context operations are no longer hand-rolled here: cold placements
compile an :class:`~repro.core.Acquire` intent through the
:class:`~repro.core.ContextPlane` (see :mod:`repro.core.plane`), which
prices the staging bytes per zone and owns every registry write.

Routing policy (paper §5.1/§5.3.2, plus context-aware backfill and
continuous admission):
  * the ready queue is split into per-recipe LANES; :meth:`route` scans
    the lane heads in global FIFO order and may *backfill* past a blocked
    head (nowhere to place its recipe) to any routable deeper pair, so
    one unplaceable recipe never stalls the whole pool;
  * warm placements come first: an idle worker with the library READY,
    else — for stream requests — ADMISSION into a live dynamic batch with
    free slots (slot budgets derive from the hardware catalog via
    :meth:`Library.slot_budget`);
  * anti-starvation: a head that has been passed over ``aging_bound``
    times reserves the workers able to host it — younger requests may no
    longer backfill (or be admitted) onto those until the aged head is
    placed.  ``aging_bound="auto"`` derives the bound per recipe from
    observed warm/cold service-time ratios (see
    :func:`repro.core.derive_aging_bound`); the static ``int`` path is
    unchanged;
  * cold placement prefers a worker holding a SPILLED local copy
    (promotion from local disk — no fetch), then the fastest capable idle
    device, fetching from an in-zone ready peer when one exists;
  * an evicted worker requeues ONLY its unfinished requests at their lane
    heads (members that already left the batch keep their records) and
    its registry residencies are dropped (no grace period).

``backfill=False`` restores the seed single-FIFO head-only policy (used
as the baseline in benchmarks/bench_fig6_busy_cluster.py).
"""
from __future__ import annotations

import itertools
import math
import warnings
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple, Union

from ..core import (AGING_BOUND_DEFAULT, Acquire, ClusterView, ContextPlane,
                    ContextRecipe, ContextMode, LinkBudget, PERVASIVE,
                    PlacementPlan, PlanOp, OpKind, derive_aging_bound)
from .forecast import DemandForecaster
from .hardware import ClusterSpec, PAPER_CLUSTER, REF_ACTIVE_PARAMS
from .worker import Worker

_request_ids = itertools.count()

# time constant of the per-recipe arrival-rate EWMA the warm-pool policy
# reads (ClusterView.arrival_rate); ~the horizon of a staging decision
ARRIVAL_EWMA_TAU_S = 30.0

# disaggregation phase tags (see docs/disaggregation.md).  ``None`` on
# Request.phase means colocated legacy execution — prefill and decode
# priced and placed together, exactly the pre-disaggregation behaviour.
PREFILL = "prefill"
DECODE = "decode"


@dataclass
class Request:
    """One unit of application work: a prompt plus a decode-step budget.

    ``prompt_units`` (prefill) and ``decode_steps`` are both charged as
    work units; a request completes after ``n_units`` steps of whatever
    dynamic batch hosts it.  ``exclusive=True`` marks a deprecated
    run-to-completion batch task: it occupies a whole worker and admits
    no co-members (the pre-redesign behaviour, kept as baseline).
    """
    recipe_key: str
    decode_steps: int = 1
    prompt_units: int = 0
    mode: ContextMode = PERVASIVE
    active_params: float = REF_ACTIVE_PARAMS
    payload: Any = None               # live mode: prompt / callable args
    arrival_s: float = 0.0
    exclusive: bool = False
    request_id: int = field(default_factory=lambda: next(_request_ids))
    attempts: int = 0
    skipped: int = 0                  # dispatches that backfilled past us
    steps_done: int = 0
    t_first_step: Optional[float] = None
    truncated: bool = False           # prompt clipped at admission (live)
    # -- SLO class (see repro.cluster.gateway) -------------------------
    slo: str = "batch"                # "interactive" | "batch"
    deadline_s: Optional[float] = None  # ABSOLUTE queue deadline (503 past)
    preemptions: int = 0              # times a batch slot was taken from us
    suspended: bool = False           # KV snapshot parked, awaiting resume
    suspended_on: Optional[str] = None  # worker holding the snapshot
    # -- prefill/decode disaggregation (see docs/disaggregation.md) ----
    phase: Optional[str] = None       # None = colocated; PREFILL | DECODE
    prefill_worker: Optional[str] = None  # worker holding the prefill KV
    kv_nbytes: int = 0                # KV snapshot size (priced/measured)
    prefill_s: float = 0.0            # accumulated PREFILL phase seconds
    ship_s: float = 0.0               # accumulated KV handoff seconds
    cold_started: bool = False        # any phase paid a cold start
    # -- crash safety (see docs/failure-model.md) ----------------------
    ckpt_worker: Optional[str] = None  # host of the last landed checkpoint
    ckpt_steps: int = 0               # steps_done the checkpoint captured
    ckpt_nbytes: int = 0              # checkpoint snapshot size

    @property
    def n_units(self) -> int:
        """Total work units (prefill + decode) this request needs."""
        return self.prompt_units + self.decode_steps

    # -- deprecated Task-era aliases ------------------------------------
    @property
    def n_inferences(self) -> int:
        return self.n_units

    @property
    def task_id(self) -> int:
        return self.request_id


def Task(recipe_key: str, n_inferences: int,
         mode: ContextMode = PERVASIVE,
         active_params: float = REF_ACTIVE_PARAMS,
         payload: Any = None, **kw) -> Request:
    """DEPRECATED: a run-to-completion batch of ``n_inferences``.

    Kept so pre-redesign callers and benchmarks still run; new code
    should submit :class:`Request`\\ s (or use
    :class:`~repro.cluster.Application`) so the scheduler sees the
    request stream and can continuously admit into in-flight batches.
    """
    warnings.warn("Task(...) is deprecated; submit Request objects "
                  "(see repro.cluster.Application)", DeprecationWarning,
                  stacklevel=2)
    return Request(recipe_key, decode_steps=n_inferences, mode=mode,
                   active_params=active_params, payload=payload,
                   exclusive=True, **kw)


@dataclass
class Assignment:
    request: Request
    worker: Worker
    warm: bool                        # no staging charged to this request
    peer_source: Optional[str]        # ready peer to fetch from (cold only)
    cross_zone: bool = False
    local_restage: bool = False       # cold, but promoted from local disk
    join: bool = False                # admitted into an in-flight batch
    t_dispatch: float = 0.0           # set by the executor at dispatch
    # cold placements carry the context plane's compiled Acquire plan;
    # peer_source/cross_zone/local_restage above are derived views of it
    plan: Optional[PlacementPlan] = None
    moved_bytes: int = 0              # measured fetch bytes (sim executor)
    # deadline-driven preemption: the BATCH member this dispatch evicts
    # from its slot (executor suspends its KV), and whether this
    # dispatch RESUMES a previously suspended request from its snapshot
    preempt: Optional[Request] = None
    resumed: bool = False
    # disaggregation: the committed KV_SHIP op moving the prefill KV to
    # this worker (None = same-worker fast path or colocated request)
    kv_ship: Optional[PlanOp] = None

    @property
    def task(self) -> Request:        # deprecated alias
        return self.request


@dataclass
class RequestRecord:
    """Per-request completion record (replaces the per-task TaskRecord).

    ``queue_wait_s`` and ``ttfs_s`` are the latency views the batch API
    could not express: how long the request sat in its lane, and how long
    until its first decode step completed.
    """
    request_id: int
    worker_id: str
    device: str
    t_arrival: float
    t_start: float                    # dispatch (admission) time
    t_first_step: float
    t_end: float
    n_units: int
    warm: bool
    attempts: int
    exclusive: bool = True
    joined: bool = False              # admitted into an in-flight batch
    truncated: bool = False           # prompt was clipped, output partial
    outcome: str = "done"             # "done" | "rejected" | "timed_out"
    slo: str = "batch"                # SLO class the request carried
    preemptions: int = 0              # slot preemptions suffered en route
    # -- per-phase latency breakdown (disaggregated requests) ----------
    prefill_s: float = 0.0            # PREFILL phase on-worker seconds
    ship_s: float = 0.0               # KV handoff (SHIPPING) seconds

    @property
    def exec_s(self) -> float:        # on-worker time (incl. staging)
        return self.t_end - self.t_start

    @property
    def decode_s(self) -> float:
        """DECODE phase seconds: the final dispatch's on-worker time
        minus the KV handoff it waited on.  Colocated requests report
        their whole ``exec_s`` here (prefill_s/ship_s are zero)."""
        return max(0.0, self.exec_s - self.ship_s)

    @property
    def queue_wait_s(self) -> float:
        return self.t_start - self.t_arrival

    @property
    def ttfs_s(self) -> float:
        """Time to first (completed) decode step, from arrival."""
        return self.t_first_step - self.t_arrival

    # -- deprecated Task-era aliases ------------------------------------
    @property
    def n_inferences(self) -> int:
        return self.n_units

    @property
    def task_id(self) -> int:
        return self.request_id


TaskRecord = RequestRecord            # deprecated alias


class Scheduler:
    def __init__(self, cluster: ClusterSpec = PAPER_CLUSTER, *,
                 backfill: bool = True,
                 aging_bound: Union[int, str] = AGING_BOUND_DEFAULT,
                 link_budget: Optional[LinkBudget] = None,
                 disaggregate: bool = False):
        self.cluster = cluster
        self.backfill = backfill
        # phase-split execution: requests with both prompt and decode
        # work run PREFILL and DECODE as separately routed phases, the
        # KV handoff travelling as a KV_SHIP context-plane op
        self.disaggregate = disaggregate
        if aging_bound != "auto" and not isinstance(aging_bound, int):
            raise ValueError(f"aging_bound must be an int or 'auto', "
                             f"got {aging_bound!r}")
        self.aging_bound = aging_bound
        # the context plane owns ALL registry writes; `registry` stays a
        # public READ alias (the globally consistent residency view)
        self.plane = ContextPlane(budget=link_budget)
        self.registry = self.plane.registry
        # placement ordering never reads a clock, but the plane's budget
        # window does; executors install their time source here
        self.clock: Callable[[], float] = lambda: 0.0
        # per-recipe FIFO lanes; global order recovered via request_id
        self.lanes: "OrderedDict[str, Deque[Request]]" = OrderedDict()
        # upper bound on suspended requests queued in lanes: bumped on
        # requeue, re-counted exactly whenever _heads() scans.  May go
        # stale HIGH (a suspended head dispatched or voided) — never
        # low — so a zero is trusted as the no-suspensions fast path
        self._suspended_queued = 0
        self.workers: Dict[str, Worker] = {}
        self.running: Dict[int, Tuple[Request, str]] = {}
        # -- metrics -------------------------------------------------
        self.records: List[RequestRecord] = []
        self.progress_events: List[Tuple[float, int]] = [(0.0, 0)]
        self.worker_events: List[Tuple[float, int]] = [(0.0, 0)]
        self.completed_inferences = 0
        self.evicted_tasks = 0
        self.evicted_inferences = 0
        self.backfills = 0            # dispatches that jumped a blocked head
        self.admissions = 0           # requests joined into live batches
        self.spilled_libraries = 0
        self.submitted = 0
        self.preemptions = 0          # batch slots taken for interactive
        self.kv_ships = 0             # KV handoffs committed to the plane
        self.local_decodes = 0        # same-worker fast-path decodes
        self.prefills_done = 0        # PREFILL phases completed
        # -- crash safety (docs/failure-model.md) --------------------
        # decode-step checkpoint cadence: every N settled steps a batch
        # member exports its KV snapshot to a host in another failure
        # zone as an OpKind.KV_CKPT plane op (None disables)
        self.ckpt_every_steps: Optional[int] = None
        self.kv_ckpts = 0             # checkpoints committed to the plane
        self.kv_ckpts_deferred = 0    # cadence boundaries the budget pushed
        self.ckpt_resumes = 0         # crash victims resumed from a ckpt
        # failure classes funneled through on_evict: (t, worker_id, cause)
        self.failure_log: List[Tuple[float, str, str]] = []
        self.evictions_by_cause: Dict[str, int] = {}
        # the serving gateway installs itself here (repro.cluster.gateway);
        # ingress() then routes submissions through its admission edge
        self.gateway = None
        self._terminal_ids: set = set()   # mutual-exclusion guard
        # per-recipe observed service times: [warm_sum, warm_n, cold_sum,
        # cold_n] — feeds aging_bound="auto"
        self._service: Dict[str, List[float]] = {}
        # per-recipe arrival EWMA: [last_arrival_s, rate_per_s]
        self._arrivals: Dict[str, List[float]] = {}
        # per-recipe PREEMPTION EWMA, same shape: spill storms are a
        # demand signal the arrival rate cannot see — the warm-pool
        # policy reads it via ClusterView.preempt_rate
        self._preempts: Dict[str, List[float]] = {}
        # windowed-rate forecast (trend + burst detection) fed on every
        # submission; view() publishes it as ClusterView.forecast_rate
        self.forecaster = DemandForecaster()
        # per-recipe mean request shape: [n, prompt_sum, decode_sum] —
        # converts forecast req/s into per-phase unit rates
        self._req_units: Dict[str, List[float]] = {}
        # supply-side observability: joins/evictions per device class
        self.pool_joins: Dict[str, int] = {}
        self.pool_evictions: Dict[str, int] = {}
        # zone of every worker EVER seen: a voided snapshot is metered
        # (kv_lost) after its holder already left self.workers, so the
        # holder's zone must outlive the membership entry
        self._zone_of: Dict[str, str] = {}
        # the plane stamps first-READY ("warm") times with this clock
        self.plane.clock = lambda: self.clock()

    # ------------------------------------------------------------------
    # registration / submission
    # ------------------------------------------------------------------
    def register_context(self, recipe: ContextRecipe) -> str:
        return self.plane.register(recipe)

    def view(self, now: Optional[float] = None) -> ClusterView:
        """Read-only snapshot for the context plane / pure policies."""
        t = self.clock() if now is None else now
        demand: Dict[str, int] = {}
        backlog: Dict[str, float] = {}
        for key, lane in self.lanes.items():
            demand[key] = demand.get(key, 0) + len(lane)
            for req in lane:
                backlog[key] = backlog.get(key, 0.0) \
                    + max(req.n_units - req.steps_done, 0)
        for req, _wid in self.running.values():
            key = req.recipe_key
            demand[key] = demand.get(key, 0) + 1
            backlog[key] = backlog.get(key, 0.0) \
                + max(req.n_units - req.steps_done, 0)
        return ClusterView(
            workers=self.workers, registry=self.registry, demand=demand,
            arrival_rate=self._decayed(self._arrivals, t),
            preempt_rate=self._decayed(self._preempts, t),
            forecast_rate=self.forecaster.snapshot(t),
            backlog_units=backlog,
            request_units={k: (m[1] / m[0], m[2] / m[0])
                           for k, m in self._req_units.items() if m[0]},
            now=t)

    @staticmethod
    def _decayed(table: Dict[str, List[float]], t: float
                 ) -> Dict[str, float]:
        """EWMA snapshots decayed to ``t``.  ``_note_event`` only updates
        a rate AT event times, so a recipe that stops arriving would keep
        its last (high) rate forever; reading through this decay means
        policies never act on frozen demand.  Pure — the stored state is
        untouched, so the next event's ``alpha`` blend is unchanged."""
        out: Dict[str, float] = {}
        for k, st in table.items():
            dt = max(t - st[0], 0.0)
            out[k] = st[1] * math.exp(-dt / ARRIVAL_EWMA_TAU_S)
        return out

    @staticmethod
    def _note_event(table: Dict[str, List[float]], key: str,
                    t: float) -> None:
        st = table.get(key)
        if st is None:
            table[key] = [t, 0.0]
            return
        dt = max(t - st[0], 1e-3)       # bursts at one instant: floor dt
        alpha = 1.0 - math.exp(-dt / ARRIVAL_EWMA_TAU_S)
        st[1] += alpha * (1.0 / dt - st[1])
        st[0] = t

    def _note_arrival(self, key: str, t: float) -> None:
        self._note_event(self._arrivals, key, t)
        self.forecaster.note(key, t)

    def ingress(self, request: Request) -> Request:
        """The front door: route through the serving gateway when one is
        installed (SLO admission control), else straight into a lane."""
        if self.gateway is not None:
            self.gateway.submit(request)
        else:
            self.submit(request)
        return request

    @staticmethod
    def _interactive_block_end(lane: "Deque[Request]") -> int:
        """Index just past the leading run of interactive requests.

        Class priority is an insertion discipline, not a separate queue:
        interactive requests always form a prefix of their lane, FIFO
        within the class, so lane heads stay the dispatch interface."""
        i = 0
        while i < len(lane) and lane[i].slo == "interactive":
            i += 1
        return i

    def submit(self, request: Request) -> None:
        if not request.exclusive and not request.mode.state_resident:
            # a dynamic batch presupposes the model staying resident
            # between steps; partial/naive modes tear the context down
            # per task and only make sense as run-to-completion baselines
            raise ValueError(
                "continuous batching requires a state-resident context "
                f"mode, got {request.mode.name!r}; submit partial/naive "
                "work as exclusive=True run-to-completion requests")
        if (self.disaggregate and request.phase is None
                and request.prompt_units > 0 and request.decode_steps > 0
                and request.mode.state_resident):
            # phase-split candidate: prefill routes first, decode follows
            # once the KV exists (same worker or shipped)
            request.phase = PREFILL
        lane = self.lanes.setdefault(request.recipe_key, deque())
        if request.slo == "interactive":
            lane.insert(self._interactive_block_end(lane), request)
        else:
            lane.append(request)
        self.submitted += 1
        m = self._req_units.setdefault(request.recipe_key,
                                       [0.0, 0.0, 0.0])
        m[0] += 1
        m[1] += request.prompt_units
        m[2] += request.decode_steps
        self._note_arrival(request.recipe_key, request.arrival_s)

    def record_terminal(self, request: Request, outcome: str,
                        now: float) -> None:
        """Finalize a request at the admission edge (never dispatched):
        ``rejected`` at the bound or ``timed_out`` past its deadline.
        Terminal outcomes are mutually exclusive — a request is finalized
        at most once, ever."""
        rid = request.request_id
        assert rid not in self._terminal_ids, \
            f"request {rid} finalized twice ({outcome})"
        assert rid not in self.running, \
            f"request {rid} is running; cannot finalize {outcome}"
        self._terminal_ids.add(rid)
        self.records.append(RequestRecord(
            rid, "", "", request.arrival_s, now, now, now,
            request.n_units, False, request.attempts,
            request.exclusive, False, request.truncated,
            outcome=outcome, slo=request.slo,
            preemptions=request.preemptions))

    def submit_sweep(self, recipe_key: str, n_total: int, batch: int,
                     mode: ContextMode = PERVASIVE,
                     active_params: float = REF_ACTIVE_PARAMS) -> int:
        """DEPRECATED: split ``n_total`` inferences into batch-sized
        run-to-completion tasks (the pre-request-stream PfF shape).

        Each chunk expands to one *exclusive* :class:`Request`; prefer
        :class:`~repro.cluster.Application` request streams, which let
        libraries admit work into in-flight batches.
        """
        warnings.warn("submit_sweep() is deprecated; submit Request "
                      "streams (see repro.cluster.Application)",
                      DeprecationWarning, stacklevel=2)
        n_tasks = 0
        left = n_total
        while left > 0:
            b = min(batch, left)
            self.submit(Request(recipe_key, decode_steps=b, mode=mode,
                                active_params=active_params,
                                exclusive=True))
            left -= b
            n_tasks += 1
        return n_tasks

    @property
    def queue(self) -> List[Request]:
        """All queued requests in global FIFO (submission) order."""
        return sorted((r for lane in self.lanes.values() for r in lane),
                      key=lambda r: r.request_id)

    def _requeue(self, request: Request) -> None:
        """Front-of-class requeue: interactive at the very head, batch at
        the head of the batch section (behind queued interactive work) —
        preserving the interactive-prefix lane invariant."""
        lane = self.lanes.setdefault(request.recipe_key, deque())
        if request.suspended:
            self._suspended_queued += 1
        if request.slo == "interactive":
            lane.appendleft(request)
        else:
            lane.insert(self._interactive_block_end(lane), request)

    # ------------------------------------------------------------------
    # pool membership (driven by the factory / eviction processes)
    # ------------------------------------------------------------------
    def add_worker(self, worker: Worker, now: float = 0.0) -> None:
        worker.joined_s = now
        self.workers[worker.worker_id] = worker
        self._zone_of[worker.worker_id] = worker.zone
        self.worker_events.append((now, len(self.workers)))
        cls = worker.device.name
        self.pool_joins[cls] = self.pool_joins.get(cls, 0) + 1

    def _live_ckpt_holder(self, req: Request) -> Optional[Worker]:
        """The worker holding ``req``'s last landed checkpoint, if it is
        still pooled with the recipe warm — i.e. the snapshot is
        adoptable right now."""
        if req.ckpt_worker is None or req.exclusive:
            return None
        w = self.workers.get(req.ckpt_worker)
        if w is None or not w.has_ready(req.recipe_key):
            return None
        return w

    def on_evict(self, worker_id: str, now: float = 0.0,
                 cause: str = "revoke") -> List[Request]:
        """Worker reclaimed with no grace period. Returns requeued requests.

        ``cause`` records the failure class that funneled here — "revoke"
        (advance-notice reclamation, the default), "crash" (silent death
        the FailureDetector noticed on lease expiry) or "hang" (the
        decode-progress watchdog fired).  IDEMPOTENT: a double eviction
        of the same worker (a ChurnInjector storm racing an elastic
        release or a factory drain) is a no-op — no double-requeue, no
        double-refund, no double-counted metrics.

        Only UNFINISHED requests are requeued (members that already left
        the dynamic batch keep their completion records); an exclusive
        task loses its whole batch, a stream member only its progress —
        and a stream member with a LIVE CHECKPOINT on a surviving worker
        loses only the steps since that checkpoint: it re-enters its
        lane suspended on the checkpoint holder and resumes from the
        snapshot there (see docs/failure-model.md).  Covers eviction
        mid-staging/mid-batch: residencies (READY, STAGING and SPILLED
        alike) vanish from the registry, so no later routing decision
        can count on the lost copies.
        """
        worker = self.workers.pop(worker_id, None)
        if worker is None:
            return []
        self.worker_events.append((now, len(self.workers)))
        cls = worker.device.name
        self.pool_evictions[cls] = self.pool_evictions.get(cls, 0) + 1
        self.failure_log.append((now, worker_id, cause))
        self.evictions_by_cause[cause] = \
            self.evictions_by_cause.get(cause, 0) + 1
        # the plane refunds the worker's in-flight staging ops and leaves
        # LOST tombstones it later turns into re-replication intents
        self.plane.drop_worker(worker_id, now)
        victims = sorted((req for req, wid in self.running.values()
                          if wid == worker_id),
                         key=lambda r: r.request_id, reverse=True)
        for req in victims:
            del self.running[req.request_id]
            req.attempts += 1
            self.evicted_tasks += 1
            holder = self._live_ckpt_holder(req)
            if holder is not None:
                # crash-safe resume: only the decode since the last
                # checkpoint is wasted; the request parks suspended on
                # the checkpoint holder and adopts the snapshot there
                self.evicted_inferences += max(
                    0, req.steps_done - req.ckpt_steps)
                req.steps_done = req.ckpt_steps
                req.t_first_step = None
                req.suspended = True
                req.suspended_on = holder.worker_id
                req.kv_nbytes = req.ckpt_nbytes
                if req.phase == DECODE:
                    req.prefill_worker = holder.worker_id
                self.ckpt_resumes += 1
                self._requeue(req)
                continue
            self.evicted_inferences += (req.n_units if req.exclusive
                                        else req.steps_done)
            req.steps_done = 0        # decode state died with the worker
            req.t_first_step = None
            if req.phase == DECODE:
                # the shipped/local KV died with the worker: back to the
                # PREFILL phase from scratch
                req.phase = PREFILL
                req.prefill_worker = None
                req.kv_nbytes = 0
            self._requeue(req)        # retry first (paper: requeue)
        return victims[::-1]

    # ------------------------------------------------------------------
    # routing
    # ------------------------------------------------------------------
    def _idle_workers(self) -> List[Worker]:
        return [w for w in self.workers.values() if w.idle]

    def _heads(self) -> List[Request]:
        """Routable lane heads.  A lane contributes its head, and — when
        suspended requests are queued (preemption victims, checkpoint
        resumes) — one candidate per DISTINCT snapshot holder plus the
        first non-suspended request.  A suspended request can only run
        where its snapshot lives; without the extra candidates a
        suspended head whose holder is momentarily full would stall the
        whole lane (fresh work AND victims pinned to other holders).

        The full-lane scan only runs while suspensions are queued
        (`_suspended_queued` upper bound, re-counted exactly here);
        otherwise heads are the lane fronts — O(#lanes), which matters
        because _dispatch ages heads on EVERY dispatch."""
        if self._suspended_queued == 0:
            heads = [lane[0] for lane in self.lanes.values() if lane]
            heads.sort(key=lambda r: r.request_id)
            return heads
        heads: List[Request] = []
        suspended = 0
        for lane in self.lanes.values():
            if not lane:
                continue
            holders: set = set()
            fresh = False
            for r in lane:
                if r.suspended:
                    suspended += 1
                    if r.suspended_on not in holders:
                        holders.add(r.suspended_on)
                        heads.append(r)
                elif not fresh:
                    fresh = True
                    heads.append(r)
        self._suspended_queued = suspended
        heads.sort(key=lambda r: r.request_id)
        return heads

    def _usable_by(self, req: Request, w: Worker) -> bool:
        """Could ``w`` (eventually) serve ``req``?  The reservation
        predicate: capacity-only (`could_host`), because a stream worker
        that keeps admitting is never idle yet must still be reservable
        for an aged head it could serve once its batch drains.  A
        suspended request is usable ONLY by its snapshot holder — a
        starved suspended head must reserve that one worker's slots,
        not idle the rest of the pool."""
        if req.suspended:
            return w.worker_id == req.suspended_on
        if not req.exclusive and \
                w.stream_slots_free(req.recipe_key, req.active_params) > 0:
            return True
        return w.has_ready(req.recipe_key) or \
            w.could_host(self.registry.recipes[req.recipe_key])

    def aging_bound_for(self, recipe_key: str) -> int:
        """Effective skip bound for a lane head of ``recipe_key``.

        Static ``int`` bounds pass through; ``"auto"`` derives the bound
        from this recipe's observed warm/cold service-time ratio (a skip
        costs at most one warm service; a cold placement costs a full
        cold start) and falls back to the default until both sides have
        been observed."""
        if self.aging_bound != "auto":
            return self.aging_bound
        st = self._service.get(recipe_key)
        if not st or not st[1] or not st[3]:
            return AGING_BOUND_DEFAULT
        return derive_aging_bound(st[0] / st[1], st[2] / st[3])

    def route(self) -> Optional[Assignment]:
        """Match a routable (lane head, worker) pair, warm-first.

        Scans lane heads oldest-first; with ``backfill`` enabled a blocked
        head is skipped rather than stalling the pool.  The oldest head
        that has been passed over its aging bound reserves every worker
        able to host it.  Stream requests have a third placement beyond
        warm-idle and cold: ADMISSION into a live batch with free slots,
        which needs no idle worker at all.  With a gateway installed the
        round starts by expiring overdue queued requests (TIMED_OUT) and
        may end with DEADLINE-DRIVEN PREEMPTION: an interactive head
        within ``preempt_slack_s`` of its deadline, with no warm slot
        free, suspends a batch member of a live dynamic batch (the
        executor spills its KV) and takes the slot."""
        now = self.clock()
        if self.gateway is not None:
            self.gateway.expire(now)
        # a suspended request whose snapshot died (worker evicted, or the
        # library spilled — payloads cleared) restarts from scratch; a
        # decode-phase request whose prefill KV holder died re-prefills.
        # Either way the voided snapshot is METERED on the plane as
        # kv_lost in the dead holder's zone — a crash destroyed bytes the
        # spill/ship meters recorded as saved.  Only suspended or
        # DECODE-phase entries can need voiding, so the lane scan is
        # skipped entirely when neither can exist
        if self._suspended_queued > 0 or self.disaggregate:
            for lane in self.lanes.values():
                for r in lane:
                    if r.suspended:
                        w = self.workers.get(r.suspended_on)
                        if w is None or not w.has_ready(r.recipe_key):
                            if r.kv_nbytes > 0:
                                self.plane.record_kv_lost(
                                    r.recipe_key,
                                    self._zone_of.get(r.suspended_on, "z0"),
                                    r.kv_nbytes)
                            r.suspended = False
                            r.suspended_on = None
                            r.steps_done = 0
                            r.t_first_step = None
                            r.kv_nbytes = 0
                            if r.phase == DECODE:
                                r.phase = PREFILL
                                r.prefill_worker = None
                    elif r.phase == DECODE:
                        w = self.workers.get(r.prefill_worker)
                        if w is None or not w.has_ready(r.recipe_key):
                            if r.kv_nbytes > 0:
                                self.plane.record_kv_lost(
                                    r.recipe_key,
                                    self._zone_of.get(r.prefill_worker, "z0"),
                                    r.kv_nbytes)
                            r.phase = PREFILL
                            r.prefill_worker = None
                            r.kv_nbytes = 0
                            r.steps_done = 0
                            r.t_first_step = None
        heads = self._heads()
        if not heads:
            return None
        if not self.backfill:
            heads = heads[:1]           # seed policy: head-of-line only
        starved = (heads[0] if heads[0].skipped >=
                   self.aging_bound_for(heads[0].recipe_key) else None)

        def allowed(req: Request, w: Worker) -> bool:
            if starved is None or req is starved:
                return True
            return not self._usable_by(starved, w)

        idle = self._idle_workers()

        def foundable(req: Request, w: Worker) -> bool:
            # a stream request must JOIN a worker's open batch for its
            # recipe, never found a second one on the same library
            return req.exclusive or req.recipe_key not in w.open_streams

        # pass 1: warm placements — idle READY worker, else admission
        # into an in-flight dynamic batch with free slots
        for req in heads:
            key = req.recipe_key
            ready = self.registry.ready_workers(key)
            warm = [w for w in idle if w.worker_id in ready
                    and w.has_ready(key) and foundable(req, w)
                    and allowed(req, w)]
            if req.suspended:
                # affinity: the KV snapshot lives on suspended_on — only
                # a placement there resumes without re-prefill
                warm = [w for w in warm if w.worker_id == req.suspended_on]
            if req.phase == PREFILL:
                # prefill is FLOP-bound: route to the compute-richest
                # warm worker (the cold pass below may still stage one)
                if warm:
                    w = min(warm, key=lambda w: w.device.prefill_time(
                        req.active_params, 1))
                    return self._dispatch(req, w, warm=True)
                continue
            if req.phase == DECODE and not req.suspended:
                a = self._route_decode(req, idle, allowed, foundable, now)
                if a is not None:
                    return a
                # no decode slot anywhere: the interactive preemption
                # path below still applies to a decode-phase head
                if (self.gateway is not None and req.slo == "interactive"
                        and req.deadline_s is not None):
                    pol = self.gateway.policies.get("interactive")
                    if pol is not None and \
                            req.deadline_s - now <= pol.preempt_slack_s:
                        a = self._try_preempt(req)
                        if a is not None:
                            return a
                continue
            if warm:
                # fastest warm device first (work stealing does the rest)
                w = min(warm, key=lambda w: w.device.infer_s)
                return self._dispatch(req, w, warm=True)
            if req.exclusive:
                continue
            joinable = [w for w in self.workers.values()
                        if w.stream_slots_free(key, req.active_params) > 0
                        and allowed(req, w)]
            if req.suspended:
                joinable = [w for w in joinable
                            if w.worker_id == req.suspended_on]
            if joinable:
                # founding a NEW batch on an idle worker beats joining
                # when the lane backlog overflows the open batches' free
                # slots (more capacity is needed anyway); otherwise join
                # — admission is free, staging is not.
                recipe = self.registry.recipes[key]
                backlog = len(self.lanes[key])
                free = sum(w.stream_slots_free(key, req.active_params)
                           for w in joinable)
                # a suspended request can ONLY run where its snapshot
                # lives — "found elsewhere instead" is never an option
                # for it, so the backlog heuristic must not defer it
                can_found = not req.suspended and backlog > free and any(
                    w.can_host(recipe) and foundable(req, w)
                    and allowed(req, w) for w in idle)
                if not can_found:
                    w = min(joinable, key=lambda w: (
                        w.device.infer_s,
                        -w.stream_slots_free(key, req.active_params)))
                    return self._dispatch(req, w, warm=True, join=True)
            # no free slot anywhere: an interactive head inside its
            # preemption slack takes a batch member's slot instead of
            # missing its deadline (the victim's KV spills + resumes)
            if (self.gateway is not None and req.slo == "interactive"
                    and req.deadline_s is not None):
                pol = self.gateway.policies.get("interactive")
                if pol is not None and \
                        req.deadline_s - now <= pol.preempt_slack_s:
                    a = self._try_preempt(req)
                    if a is not None:
                        return a
        # pass 2: cold placements (stage onto any capable idle worker)
        for req in heads:
            if req.suspended:
                continue              # wait for the affinity slot instead
            if req.phase == DECODE:
                continue              # decode only lands on warm workers
            recipe = self.registry.recipes[req.recipe_key]
            cands = [w for w in idle
                     if w.can_host(recipe) and foundable(req, w)
                     and allowed(req, w)]
            if not cands:
                continue
            spilled = self.registry.spilled_workers(req.recipe_key)
            # prefer promotion from a local spilled copy, then fastest
            # on the axis the request's phase is bound by
            if req.phase == PREFILL:
                w = min(cands, key=lambda w: (
                    w.worker_id not in spilled,
                    w.device.prefill_time(req.active_params, 1)))
            else:
                w = min(cands, key=lambda w: (w.worker_id not in spilled,
                                              w.device.infer_s))
            return self._dispatch(req, w, warm=False)
        return None

    # ------------------------------------------------------------------
    # disaggregation: decode placement with the ship-vs-local decision
    # ------------------------------------------------------------------
    def _ship_cost_s(self, req: Request, w: Worker) -> float:
        """Seconds the KV handoff to ``w`` would take over the peer link
        class connecting it to the prefill worker (0 for the same-worker
        fast path)."""
        src = self.workers.get(req.prefill_worker)
        if src is None or src.worker_id == w.worker_id \
                or req.kv_nbytes <= 0:
            return 0.0
        bw = (self.cluster.peer_bw_local if src.zone == w.zone
              else self.cluster.peer_bw_cross)
        return req.kv_nbytes / bw

    def _ship_op_for(self, req: Request, w: Worker) -> Optional[PlanOp]:
        """The KV_SHIP plan op moving ``req``'s prefill KV to ``w``, or
        None when no ship is needed (same worker, resumed snapshot)."""
        if req.phase != DECODE or req.suspended:
            return None
        src = self.workers.get(req.prefill_worker)
        if src is None or src.worker_id == w.worker_id:
            return None
        return self.plane.kv_ship_op(
            req.recipe_key, src.worker_id, w.worker_id, req.kv_nbytes,
            src_zone=src.zone, dst_zone=w.zone)

    def _route_decode(self, req: Request, idle: List[Worker], allowed,
                      foundable, now: float) -> Optional[Assignment]:
        """Place a DECODE-phase request on a memory-side slot.

        Candidates are open dynamic batches with free slots (join — no
        idle worker needed) and warm idle workers (found a new stream;
        exclusive decode occupies the worker instead).  Each candidate is
        scored by the plane's cost model: estimated remaining decode time
        at the batch size it would see, PLUS the KV handoff seconds over
        the peer link from the prefill worker — the same-worker fast path
        scores a zero ship and wins whenever shipping would lose.  A ship
        the LinkBudget window cannot absorb is deferred to the local fast
        path when one exists; when decoding locally is impossible the
        ship is demand-critical and committed anyway (charged like a
        demand Acquire, never dropped)."""
        key, ap = req.recipe_key, req.active_params
        cands: List[Tuple[Worker, bool]] = []
        if not req.exclusive:
            for w in self.workers.values():
                if w.stream_slots_free(key, ap) > 0 and allowed(req, w):
                    cands.append((w, True))
        ready = self.registry.ready_workers(key)
        for w in idle:
            if w.worker_id in ready and w.has_ready(key) \
                    and foundable(req, w) and allowed(req, w):
                cands.append((w, False))
        if not cands:
            return None

        def score(cand: Tuple[Worker, bool]) -> Tuple[float, float]:
            w, join = cand
            batch = 1
            if join:
                lib = w.libraries.get(key)
                batch = (len(lib.batch) if lib is not None else 0) + 1
            est = req.decode_steps * w.device.step_time(ap, batch)
            ship = self._ship_cost_s(req, w)
            return (ship + est, ship)   # tie: prefer the local fast path

        w, join = min(cands, key=score)
        ship_op = self._ship_op_for(req, w)
        if ship_op is not None and \
                not self.plane.ship_admits(ship_op, now):
            local = [c for c in cands
                     if c[0].worker_id == req.prefill_worker]
            if local:
                # budget window full: defer to the same-worker fast path
                w, join = min(local, key=score)
                ship_op = None
            # else: demand-critical ship — committed despite the window
        if ship_op is None and w.worker_id == req.prefill_worker:
            self.local_decodes += 1
        return self._dispatch(req, w, warm=True, join=join,
                              kv_ship=ship_op)

    def _try_preempt(self, req: Request) -> Optional[Assignment]:
        """Pick and suspend a batch victim so ``req`` can take its slot.

        The victim is the settled BATCH member with the most remaining
        work (tie: youngest) across workers with an open stream for the
        recipe; members still joining (mid-prefill) are never preempted.
        Returns the join Assignment for ``req``, or None if no live
        batch holds a preemptible member."""
        key = req.recipe_key
        best = None                   # (units_left, request_id, v, w, lib)
        for w in self.workers.values():
            if key not in w.open_streams:
                continue
            if req.suspended and w.worker_id != req.suspended_on:
                continue
            lib = w.libraries.get(key)
            if lib is None:
                continue
            for v in lib.batch.values():
                if v.slo != "batch" or v.exclusive \
                        or v.request_id in lib.joining:
                    continue
                cand = (v.n_units - v.steps_done, v.request_id, v, w, lib)
                if best is None or cand[:2] > best[:2]:
                    best = cand
        if best is None:
            return None
        _, _, victim, w, lib = best
        self._preempt(victim, w, lib)
        return self._dispatch(req, w, warm=True, join=True, preempt=victim,
                              kv_ship=self._ship_op_for(req, w))

    def _preempt(self, victim: Request, w: Worker, lib) -> None:
        """Suspend ``victim`` out of its dynamic batch: it keeps its
        decode progress (``steps_done``) and re-enters its lane with a
        worker affinity; the EXECUTOR spills its KV through
        ``StreamingDecoder.suspend`` when it sees ``Assignment.preempt``."""
        vid = victim.request_id
        lib.batch.pop(vid, None)
        lib.joining.discard(vid)
        self.running.pop(vid, None)
        n = w.running_by_recipe.get(victim.recipe_key, 0)
        w.running_by_recipe[victim.recipe_key] = max(0, n - 1)
        victim.suspended = True
        victim.suspended_on = w.worker_id
        if victim.kv_nbytes <= 0:
            # price the parked snapshot (the same per-slot estimate the
            # spill meters use) so a holder death can meter what it
            # destroyed; live mode overwrites with the measured size
            victim.kv_nbytes = self.registry.recipes[
                victim.recipe_key].decode_slot_bytes(victim.active_params)
        victim.preemptions += 1
        self.preemptions += 1
        self._note_event(self._preempts, victim.recipe_key, self.clock())
        self._requeue(victim)

    def _dispatch(self, req: Request, w: Worker, *, warm: bool,
                  join: bool = False,
                  preempt: Optional[Request] = None,
                  kv_ship: Optional[PlanOp] = None) -> Assignment:
        lane = self.lanes[req.recipe_key]
        assert lane and req in lane
        if lane[0] is req:
            lane.popleft()
        else:
            # a non-front head (see _heads): a suspended request pinned
            # to a different holder, or fresh work jumping a blocked
            # suspended prefix — removal preserves lane order
            lane.remove(req)
        # age every older head this dispatch jumped past
        jumped = False
        for other in self._heads():
            if other.request_id < req.request_id:
                other.skipped += 1
                jumped = True
        if jumped:
            self.backfills += 1
        self.running[req.request_id] = (req, w.worker_id)
        resumed = False
        if req.suspended:
            # re-admission onto the snapshot's worker: resume in place
            resumed = True
            req.suspended = False
            req.suspended_on = None
        if self.gateway is not None:
            self.gateway.on_dispatched(req)
        if join:
            self.admissions += 1
            return Assignment(req, w, warm=True, peer_source=None,
                              join=True, preempt=preempt, resumed=resumed,
                              kv_ship=kv_ship)
        if warm:
            return Assignment(req, w, warm=True, peer_source=None,
                              resumed=resumed, kv_ship=kv_ship)
        if req.phase is not None:
            req.cold_started = True     # this request paid a cold start
        if not req.mode.deps_cached and not req.mode.weights_cached:
            # naive mode manages no context: nothing for the plane to plan
            return Assignment(req, w, warm=False, peer_source=None)
        # demand-critical placement: compile an Acquire intent.  The plane
        # prices the staging bytes, picks the peer source (in-zone first)
        # and previews the spills; Acquire is charged to the zone meters
        # but never deferred — a routed request must not starve behind a
        # byte budget (only proactive Replicate intents defer).
        plan = self.plane.compile([Acquire(req.recipe_key, w.worker_id)],
                                  self.view())
        op = plan.acquire_op()
        if op.kind is OpKind.PROMOTE:
            # spilled (or disk-cached) copy: promote locally, no fetch
            return Assignment(req, w, warm=False, peer_source=None,
                              local_restage=True, plan=plan)
        return Assignment(req, w, warm=False, peer_source=op.src_worker,
                          cross_zone=op.cross_zone, plan=plan)

    def _pick_peer(self, key: str, dst: Worker) -> Tuple[Optional[str], bool]:
        """DEPRECATED shim: peer-source choice now lives in the context
        plane's Acquire compilation (kept one PR for external callers)."""
        src = self.plane._pick_source(key, dst, self.view())
        if src is None:
            return None, False
        return src.worker_id, src.zone != dst.zone

    # ------------------------------------------------------------------
    # progress bookkeeping (executors call these)
    # ------------------------------------------------------------------
    def on_start(self, assignment: Assignment) -> None:
        w, req = assignment.worker, assignment.request
        key = req.recipe_key
        w.running_by_recipe[key] = w.running_by_recipe.get(key, 0) + 1
        w.touch(key)
        if assignment.kv_ship is not None:
            # the KV handoff is committed with the dispatch: budget and
            # planned meters charged, op in flight until the executor
            # reports it landed (kv_ship_completed) or dead (aborted)
            self.kv_ships += 1
            self.plane.commit_kv_ship(req.request_id, assignment.kv_ship,
                                      now=assignment.t_dispatch)
        if assignment.join:
            # admission into the live batch; no staging, no new slot
            lib = w.libraries[key]
            lib.admit(req, w.slot_budget(key, req.active_params))
            return
        w.running += 1
        recipe = self.registry.recipes[key]
        if not req.exclusive and req.phase != PREFILL:
            # founding member of a new stream batch on this worker
            # (a PREFILL dispatch occupies the worker like an exclusive
            # task — its product is the KV snapshot, not a stream)
            lib = w.library_for(recipe)
            lib.admit(req, w.slot_budget(key, req.active_params))
            w.open_streams.add(key)
        if not assignment.warm:
            for k in w.make_room(recipe):       # spill, don't drop
                self.plane.note_spilled(k, w.worker_id)
                self.spilled_libraries += 1
            w.staging = True
            if assignment.plan is not None:
                # charge the plan's priced bytes to the zone meters and
                # the budget window, then open the staging op
                self.plane.commit(assignment.plan,
                                  now=assignment.t_dispatch)
                self.plane.op_started(assignment.plan.acquire_op())
            else:
                self.plane.note_staging(key, w.worker_id)

    def on_staged(self, assignment: Assignment) -> None:
        w = assignment.worker
        w.staging = False
        op = (assignment.plan.acquire_op() if assignment.plan is not None
              else None)
        if op is not None:
            self.plane.op_completed(op, moved_bytes=assignment.moved_bytes
                                    if assignment.moved_bytes else None)
        else:
            self.plane.note_ready(assignment.request.recipe_key,
                                  w.worker_id)

    def on_prefill_done(self, assignment: Assignment, t_start: float,
                        t_end: float, kv_nbytes: int) -> None:
        """The PREFILL phase finished: bank the phase latency, park the
        KV snapshot with the worker, flip the request to DECODE and
        requeue it at the front of its class (mid-flight work must not
        wait behind fresh arrivals).  NOT terminal — the request
        completes through :meth:`on_complete` after its decode phase."""
        req, w = assignment.request, assignment.worker
        cur = self.running.get(req.request_id)
        if cur is None or cur[1] != w.worker_id:
            return                    # stale: worker evicted mid-prefill
        del self.running[req.request_id]
        key = req.recipe_key
        n = w.running_by_recipe.get(key, 0)
        w.running_by_recipe[key] = max(0, n - 1)
        w.running -= 1
        req.prefill_s += t_end - t_start
        req.steps_done = req.prompt_units   # prompt units are banked in
        req.phase = DECODE                  # the KV; only decode remains
        req.prefill_worker = w.worker_id
        req.kv_nbytes = int(kv_nbytes)
        self.prefills_done += 1
        self._requeue(req)

    def abort_prefill(self, assignment: Assignment) -> None:
        """The executor found no phase-capable backend for a PREFILL
        dispatch (e.g. a live recipe whose step function cannot prefill
        without stepping): undo the dispatch and requeue the request for
        COLOCATED execution — the phase tag is cleared so it routes like
        a pre-disaggregation request from here on."""
        req, w = assignment.request, assignment.worker
        cur = self.running.get(req.request_id)
        if cur is None or cur[1] != w.worker_id:
            return
        del self.running[req.request_id]
        n = w.running_by_recipe.get(req.recipe_key, 0)
        w.running_by_recipe[req.recipe_key] = max(0, n - 1)
        w.running -= 1
        req.phase = None
        self._requeue(req)

    def on_complete(self, assignment: Assignment, t_start: float,
                    t_end: float,
                    t_first_step: Optional[float] = None) -> None:
        req, w = assignment.request, assignment.worker
        cur = self.running.get(req.request_id)
        if cur is None or cur[1] != w.worker_id:
            # stale: worker evicted mid-run — and possibly the request
            # already re-dispatched elsewhere, which this event must not
            # complete on the dead worker's behalf
            return
        del self.running[req.request_id]
        key = req.recipe_key
        n = w.running_by_recipe.get(key, 0)
        w.running_by_recipe[key] = max(0, n - 1)
        if req.exclusive:
            w.running -= 1                  # stream slots close via
        w.tasks_done += 1                   # close_stream when the batch
        w.inferences_done += req.n_units    # itself empties
        self.completed_inferences += req.n_units
        self.progress_events.append((t_end, self.completed_inferences))
        st = self._service.setdefault(key, [0.0, 0, 0.0, 0])
        # phase-split requests experienced BOTH phases: the service time
        # feeding the aging bound covers the whole request (prefill on
        # its worker + handoff + decode here), and the warm/cold label
        # follows whether ANY phase paid a cold start — otherwise the
        # derived bound would treat every disaggregated request as a
        # cheap warm decode and starve cold placements of their weight
        warm_eff = assignment.warm and not req.cold_started
        i = 0 if warm_eff else 2
        st[i] += (t_end - t_start) + req.prefill_s
        st[i + 1] += 1
        if t_first_step is None:
            t_first_step = req.t_first_step
        self._terminal_ids.add(req.request_id)
        self.records.append(RequestRecord(
            req.request_id, w.worker_id, w.device.name, req.arrival_s,
            t_start, t_end if t_first_step is None else t_first_step,
            t_end, req.n_units, warm_eff, req.attempts,
            req.exclusive, assignment.join, req.truncated,
            outcome="done", slo=req.slo, preemptions=req.preemptions,
            prefill_s=req.prefill_s, ship_s=req.ship_s))

    def close_stream(self, worker_id: str, recipe_key: str) -> None:
        """The dynamic batch for ``recipe_key`` on ``worker_id`` emptied;
        release its concurrency slot (executors call this)."""
        w = self.workers.get(worker_id)
        if w is None:
            return
        if recipe_key in w.open_streams:
            w.open_streams.discard(recipe_key)
            w.running = max(0, w.running - 1)

    # ------------------------------------------------------------------
    @property
    def done(self) -> bool:
        return (not any(self.lanes.values()) and not self.running
                and (self.gateway is None
                     or not self.gateway.pending_overflow))

    def makespan(self) -> float:
        return max((r.t_end for r in self.records), default=0.0)

    def avg_connected_workers(self) -> float:
        """Time-weighted mean worker count over the run."""
        ev = sorted(self.worker_events)
        end = self.makespan() or (ev[-1][0] if ev else 0.0)
        if end <= 0:
            return float(ev[-1][1]) if ev else 0.0
        area, prev_t, prev_n = 0.0, 0.0, 0
        for t, n in ev:
            t = min(t, end)
            area += prev_n * (t - prev_t)
            prev_t, prev_n = t, n
        area += prev_n * (end - prev_t)
        return area / end
