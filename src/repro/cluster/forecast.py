"""Forecast-aware elastic supply (ROADMAP: forecast-aware elastic pool).

The arrival EWMA (PR 3) is a *reactive* demand signal: it rises only
after requests arrive and says nothing about where the rate is heading.
SageServe (PAPERS.md) shows forecast-driven auto-scaling is what turns an
opportunistic pool from reactive thrash into real savings, so this module
promotes the EWMA into a proper supply-side subsystem with three parts:

* :class:`DemandForecaster` — per-recipe windowed rate history with trend
  extrapolation and burst detection.  A rate jump >= ``burst_factor`` x
  the trailing window flags a burst and PINS the forecast at the burst
  rate for ``burst_hold_s`` (bursts end abruptly; capacity should not).
  The scheduler feeds it on every submission and publishes its snapshot
  on :class:`~repro.core.ClusterView` as ``forecast_rate``, next to
  ``arrival_rate`` / ``preempt_rate``.

* :class:`ElasticPolicy` — converts the forecast plus per-phase service
  rates (:func:`~repro.cluster.hardware.pool_rate` with ``phase=``) into
  a target worker count, with a multiplicative hysteresis band and
  acquire/release cooldowns so the pool never thrashes on a noisy
  signal.  ``Factory(policy=ElasticPolicy(...))`` reconciles against
  this target *within* the availability trace's ceiling instead of
  blindly tracking the trace.

* :class:`ChurnInjector` — fault injection over :mod:`traces`:
  correlated eviction storms (N workers lost in one window,
  zone-correlated victims, optional revoke-during-staging) driven
  through the scheduler's ``on_evict`` -> the plane's ``drop_worker`` /
  ``recovery_intents`` path, so resilience benches can treat storms as a
  first-class scenario rather than a tail case.

See docs/elastic-pool.md for the forecast model and the
hysteresis/cooldown contract.
"""
from __future__ import annotations

import math
import random
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Sequence, Tuple

from .hardware import DeviceModel, REF_ACTIVE_PARAMS, pool_rate
from .traces import Storm


# ---------------------------------------------------------------------------
# DemandForecaster — windowed rates + trend + burst detection
# ---------------------------------------------------------------------------

class DemandForecaster:
    """Per-recipe arrival-rate forecast from a windowed event history.

    Events land in fixed ``window_s`` buckets (at most ``n_windows``
    retained).  The forecast for a recipe is::

        max(0, trend line over the completed windows, evaluated
               ``horizon_s`` ahead)                      # extrapolation
        .. raised to the current partial window's rate   # fast rise
        .. raised to the pinned burst rate while a burst holds

    Burst detection compares the current window's instantaneous rate to
    the trailing completed-window mean: a jump >= ``burst_factor`` x
    (with at least ``min_burst_events`` events, so one early arrival in
    a fresh window cannot trip it) pins the forecast at the observed
    burst rate for ``burst_hold_s`` seconds.  Re-detections while a
    burst holds extend the hold and can raise — never lower — the pin.

    Windows with no arrivals count as zero-rate samples, so a recipe
    that stops arriving sees its trailing mean AND trend decay to zero
    within ``n_windows`` windows (no frozen demand — the same contract
    the decayed EWMA satisfies).
    """

    def __init__(self, *, window_s: float = 10.0, n_windows: int = 12,
                 burst_factor: float = 3.0, burst_hold_s: float = 120.0,
                 horizon_s: float = 60.0, min_burst_events: int = 4):
        if window_s <= 0 or n_windows < 2:
            raise ValueError("need window_s > 0 and n_windows >= 2")
        self.window_s = window_s
        self.n_windows = n_windows
        self.burst_factor = burst_factor
        self.burst_hold_s = burst_hold_s
        self.horizon_s = horizon_s
        self.min_burst_events = min_burst_events
        # key -> deque of [window_start_s, event_count]
        self._hist: Dict[str, Deque[List[float]]] = {}
        # key -> [hold_until_s, pinned_rate]
        self._burst: Dict[str, List[float]] = {}
        self.bursts_detected = 0

    # -- ingestion ---------------------------------------------------------
    def note(self, key: str, t: float) -> None:
        start = math.floor(t / self.window_s) * self.window_s
        buckets = self._hist.setdefault(key, deque())
        if buckets and buckets[-1][0] == start:
            buckets[-1][1] += 1
        else:
            buckets.append([start, 1.0])
            while len(buckets) > self.n_windows:
                buckets.popleft()
        self._detect_burst(key, t)

    # -- series reconstruction --------------------------------------------
    def _series(self, key: str, now: float) -> List[float]:
        """Rates of the last ``n_windows`` COMPLETED windows (oldest
        first), zeros filled for windows with no arrivals."""
        buckets = self._hist.get(key)
        if not buckets:
            return []
        cur_start = math.floor(now / self.window_s) * self.window_s
        by_start = {b[0]: b[1] for b in buckets}
        first = buckets[0][0]
        out: List[float] = []
        for i in range(self.n_windows, 0, -1):
            start = cur_start - i * self.window_s
            if start < first:
                continue                # before we saw this recipe at all
            out.append(by_start.get(start, 0.0) / self.window_s)
        return out

    def _current_rate(self, key: str, now: float) -> float:
        """Instantaneous rate of the current (partial) window.  The
        elapsed span is floored at a quarter window so the first events
        of a fresh window cannot fake an arbitrarily high rate."""
        buckets = self._hist.get(key)
        if not buckets:
            return 0.0
        cur_start = math.floor(now / self.window_s) * self.window_s
        if buckets[-1][0] != cur_start:
            return 0.0
        elapsed = max(now - cur_start, self.window_s * 0.25)
        return buckets[-1][1] / elapsed

    def trailing_rate(self, key: str, now: float) -> float:
        """Mean rate over the completed trailing windows (0 if none)."""
        series = self._series(key, now)
        if not series:
            return 0.0
        return sum(series) / len(series)

    # -- burst detection ---------------------------------------------------
    def _detect_burst(self, key: str, now: float) -> None:
        buckets = self._hist[key]
        cur_start = math.floor(now / self.window_s) * self.window_s
        if buckets[-1][0] != cur_start \
                or buckets[-1][1] < self.min_burst_events:
            return
        cur = self._current_rate(key, now)
        trailing = self.trailing_rate(key, now)
        floor_rate = self.min_burst_events / self.window_s
        if cur < self.burst_factor * max(trailing, floor_rate / 2):
            return
        pin = self._burst.get(key)
        if pin is None or now >= pin[0]:
            self.bursts_detected += 1
            self._burst[key] = [now + self.burst_hold_s, cur]
        else:                           # extend + maybe raise the pin
            pin[0] = now + self.burst_hold_s
            pin[1] = max(pin[1], cur)

    def burst_active(self, key: str, now: float) -> bool:
        pin = self._burst.get(key)
        return pin is not None and now < pin[0]

    # -- the forecast ------------------------------------------------------
    def forecast(self, key: str, now: float) -> float:
        """Expected arrival rate (req/s) ``horizon_s`` from ``now``."""
        series = self._series(key, now)
        est = 0.0
        if len(series) >= 2:
            n = len(series)
            # least-squares trend over the window series, extrapolated
            # horizon_s past the newest completed window's center
            xbar = (n - 1) / 2.0
            ybar = sum(series) / n
            sxx = sum((i - xbar) ** 2 for i in range(n))
            sxy = sum((i - xbar) * (series[i] - ybar) for i in range(n))
            slope = sxy / sxx if sxx else 0.0
            x_future = (n - 1) + self.horizon_s / self.window_s
            est = ybar + slope * (x_future - xbar)
        elif series:
            est = series[0]
        # a rising partial window beats a trend that has not seen it yet
        est = max(est, self._current_rate(key, now))
        pin = self._burst.get(key)
        if pin is not None and now < pin[0]:
            est = max(est, pin[1])
        return max(0.0, est)

    def snapshot(self, now: float) -> Dict[str, float]:
        """Per-recipe forecast map — what ``ClusterView.forecast_rate``
        publishes."""
        return {key: self.forecast(key, now) for key in self._hist}


# ---------------------------------------------------------------------------
# ElasticPolicy — forecast + per-phase service rates -> pool target
# ---------------------------------------------------------------------------

@dataclass
class ElasticPolicy:
    """Demand-driven worker-count targets with hysteresis + cooldowns.

    ``decide`` is the factory's contract: given a view, the current pool
    size and the availability ceiling, return the pool size to reconcile
    to.  Guarantees (the hypothesis property tests assert these at every
    DES event):

    * the returned target is never negative and never exceeds the
      ceiling (availability is exogenous — a ceiling below the current
      pool size forces an immediate shed, bypassing hysteresis);
    * voluntary scaling happens only OUTSIDE the multiplicative
      hysteresis band ``[cur*(1-hysteresis), cur*(1+hysteresis)]``, and
      never within a cooldown of the previous scale action (one shared
      clock for both directions, so an acquire is followed by at least
      ``release_cooldown_s`` of calm — no acquire->release flip-flop on
      a boundary-oscillating rate).

    Demand is converted to capacity per phase: forecast arrivals times
    the recipe's mean prompt/decode units give required prefill and
    decode unit rates, queued backlog is amortised over ``drain_s``, and
    the per-worker denominators come from ``pool_rate(phase=)`` averaged
    over the supply mix — so a compute-poor mix needs more workers for
    the same prefill demand.  ``signal="ewma"`` swaps the forecast for
    the decayed arrival EWMA: the reactive baseline bench_elastic
    compares against.
    """
    supply: Sequence[DeviceModel] = ()
    signal: str = "forecast"            # "forecast" | "ewma" (baseline)
    active_params: float = REF_ACTIVE_PARAMS
    drain_s: float = 60.0               # drain queued backlog this fast
    slack: float = 1.2                  # capacity headroom over demand
    hysteresis: float = 0.25            # +/- dead band around current size
    acquire_cooldown_s: float = 20.0
    release_cooldown_s: float = 120.0
    min_workers: int = 1                # floor while any demand exists
    max_workers: Optional[int] = None
    _last_scale_s: float = field(default=float("-inf"), repr=False)

    def __post_init__(self):
        if self.signal not in ("forecast", "ewma"):
            raise ValueError(f"unknown signal {self.signal!r}")

    # -- demand -> required unit rates ------------------------------------
    def demand_rates(self, view) -> Tuple[float, float]:
        """Required (prefill_units/s, decode_units/s) for this view."""
        rates = (view.forecast_rate if self.signal == "forecast"
                 else view.arrival_rate)
        prefill = decode = 0.0
        for key in set(rates) | set(view.backlog_units):
            r = rates.get(key, 0.0)
            prompt_mean, decode_mean = view.request_units.get(
                key, (0.0, 1.0))
            prefill += r * prompt_mean
            decode += r * decode_mean
            backlog = view.backlog_units.get(key, 0.0)
            if backlog > 0:
                # split the queued units between phases in the recipe's
                # observed prompt/decode proportions
                total_mean = prompt_mean + decode_mean
                pfrac = prompt_mean / total_mean if total_mean else 0.0
                prefill += backlog * pfrac / self.drain_s
                decode += backlog * (1.0 - pfrac) / self.drain_s
        return prefill, decode

    def target_workers(self, view) -> int:
        """Raw (pre-hysteresis) worker count covering both phase axes."""
        mix = list(self.supply)
        if not mix:
            raise ValueError("ElasticPolicy needs a device supply mix "
                             "(Factory installs its own at construction)")
        prefill_need, decode_need = self.demand_rates(view)
        per_prefill = pool_rate(mix, self.active_params,
                                phase="prefill") / len(mix)
        per_decode = pool_rate(mix, self.active_params,
                               phase="decode") / len(mix)
        need = 0.0
        if prefill_need > 0 and per_prefill > 0:
            need = max(need, self.slack * prefill_need / per_prefill)
        if decode_need > 0 and per_decode > 0:
            need = max(need, self.slack * decode_need / per_decode)
        return int(math.ceil(need))

    # -- the scaling decision ---------------------------------------------
    def decide(self, view, current: int, ceiling: float,
               now: float) -> int:
        cap = ceiling if self.max_workers is None \
            else min(ceiling, self.max_workers)
        cap = max(cap, 0)
        raw = self.target_workers(view)
        has_demand = raw > 0 or any(
            n > 0 for n in view.demand.values())
        floor_n = self.min_workers if has_demand else 0
        want = max(min(raw, cap), min(floor_n, cap))
        want = int(want)
        if current > cap:
            # exogenous revocation: the trace says these workers are
            # gone.  Obey immediately; no band, no cooldown.
            self._last_scale_s = now
            return int(cap)
        if want > current:
            band_hi = max(current + 1,
                          math.ceil(current * (1.0 + self.hysteresis)))
            if current > 0 and want < band_hi:
                return current          # inside the dead band
            if now - self._last_scale_s < self.acquire_cooldown_s:
                return current
            self._last_scale_s = now
            return want
        if want < current:
            band_lo = min(current - 1,
                          math.floor(current * (1.0 - self.hysteresis)))
            if want > band_lo:
                return current          # inside the dead band
            if now - self._last_scale_s < self.release_cooldown_s:
                return current
            self._last_scale_s = now
            return want
        return current


# ---------------------------------------------------------------------------
# ChurnInjector — correlated eviction storms over a running sim
# ---------------------------------------------------------------------------

class ChurnInjector:
    """Drives :class:`~repro.cluster.traces.Storm` schedules through the
    scheduler's eviction path.

    Victim selection per storm: workers currently STAGING go first when
    ``revoke_staging`` is set (the worst case — the pool loses copies it
    already paid transfer bytes for); with ``zone_correlated`` a seed
    zone is drawn weighted by population and drained first, spilling
    into the next-largest zones only when the seed zone runs dry (a rack
    or power-domain reclamation takes neighbours together, not a uniform
    sample).  Every kill goes through ``Scheduler.on_evict`` — requeue,
    ``plane.drop_worker`` refunds + LOST tombstones, later
    ``recovery_intents`` — exactly like a real reclamation.

    With a ``factory`` attached, each storm also registers a temporary
    capacity restriction (``suppress_s`` seconds): the resources were
    *reclaimed*, so an elastic factory must not instantly re-acquire
    what the cluster just took back.
    """

    def __init__(self, executor, storms: Sequence[Storm], *,
                 factory=None, seed: int = 0, suppress_s: float = 0.0):
        self.ex = executor
        self.sched = executor.sched
        self.storms = sorted(storms, key=lambda s: s.t_s)
        self.factory = factory
        self.suppress_s = suppress_s
        self.rng = random.Random(seed)
        self.storm_log: List[Tuple[float, int]] = []   # (t, n_killed)
        self.killed = 0
        self._armed = False

    def arm(self) -> None:
        """Schedule every storm on the executor's event loop."""
        assert not self._armed, "ChurnInjector.arm() called twice"
        self._armed = True
        for s in self.storms:
            self.ex.loop.at(s.t_s, lambda s=s: self._fire(s))

    def _pick_victims(self, storm: Storm) -> List:
        workers = list(self.sched.workers.values())
        if not workers:
            return []
        n = min(storm.n_workers, len(workers))
        ordered: List = []
        chosen: set = set()
        if storm.revoke_staging:
            staging = [w for w in workers if w.staging]
            self.rng.shuffle(staging)
            ordered.extend(staging)
            chosen.update(w.worker_id for w in staging)
        rest = [w for w in workers if w.worker_id not in chosen]
        if storm.zone_correlated and rest:
            by_zone: Dict[str, List] = {}
            for w in rest:
                by_zone.setdefault(w.zone, []).append(w)
            zones = sorted(by_zone)
            seed_zone = self.rng.choices(
                zones, weights=[len(by_zone[z]) for z in zones])[0]
            # drain the seed zone first, then spill by population
            spill = sorted((z for z in zones if z != seed_zone),
                           key=lambda z: (-len(by_zone[z]), z))
            for z in [seed_zone] + spill:
                members = by_zone[z]
                self.rng.shuffle(members)
                ordered.extend(members)
        else:
            self.rng.shuffle(rest)
            ordered.extend(rest)
        return ordered[:n]

    def _fire(self, storm: Storm) -> None:
        now = self.ex.loop.now
        victims = self._pick_victims(storm)
        for w in victims:
            # storms are clean advance-notice revocations; silent crash /
            # hang faults route through repro.cluster.faults instead
            self.sched.on_evict(w.worker_id, now, cause="revoke")
        self.killed += len(victims)
        self.storm_log.append((now, len(victims)))
        if self.factory is not None and self.suppress_s > 0 and victims:
            self.factory.restrict(len(victims),
                                  until_s=now + self.suppress_s)
        self.ex.pump()
