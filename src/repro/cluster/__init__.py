"""Cluster runtime: DES engine, hardware catalog, workers, the
request-stream scheduler + application front-end, factory, availability
traces, and the dual (sim/live) executors.

MIGRATION (context-plane API): direct ``ContextRegistry`` mutation from
cluster code is gone — every residency write now flows through the
:class:`repro.core.ContextPlane` (``scheduler.plane``), driven by
declarative intents compiled against a read-only
:class:`repro.core.ClusterView` (``scheduler.view()``).  Old entry points
map as follows (direct-mutation shims survive this one PR, then go):

=====================================================  =====================
old direct call                                        context-plane intent
=====================================================  =====================
``registry.mark_staging(key, wid)`` (cold dispatch)    ``Acquire(key, wid)``
    + hand-picked ``Scheduler._pick_peer``             compiled by the plane
``WarmPoolPolicy.plan(sched)`` -> ``_stage_replica``   ``WarmPoolPolicy.intents(view)``
                                                       -> ``Replicate(key, n)``
``registry.mark_spilled`` / manual teardown            ``Release(key, wid)``
``registry.drop_worker(wid)`` (silent delete)          ``plane.drop_worker`` —
                                                       LOST tombstones +
                                                       ``recovery_intents``
=====================================================  =====================

Compiled plans are priced in per-zone bytes over the link classes
``transfer.py`` distinguishes and checked against a sliding
:class:`repro.core.LinkBudget` window (``Scheduler(link_budget=...)``);
proactive replication that would blow a zone's window is deferred, never
dropped.  Both executors run the same plan ops; per-zone byte counters
surface in run summaries via :func:`zone_byte_summary` /
:func:`format_zone_bytes`.
"""
from .events import EventLoop, Timer
from .hardware import (DECODE_FIXED_FRAC, GPU_CATALOG, TPU_CATALOG,
                       PAPER_CLUSTER, ClusterSpec, DeviceModel,
                       cluster_sample, paper_20gpu_pool, pool_rate,
                       REF_ACTIVE_PARAMS)
from .worker import Worker
from .scheduler import (Assignment, DECODE, PREFILL, Request,
                        RequestRecord, Scheduler, Task, TaskRecord)
from .gateway import (BATCH, ClassPolicy, Gateway, INTERACTIVE, REJECTED,
                      SLOClass, TIMED_OUT, format_gateway)
from .executors import LiveExecutor, SimExecutor
from .application import Application
from .factory import (Factory, make_sim, opportunistic_supply,
                      spill_aware_evict_priority)
from .forecast import ChurnInjector, DemandForecaster, ElasticPolicy
from .observability import (ProgressMonitor, Snapshot,
                            class_latency_summary, format_class_latency,
                            format_latency, format_pool, format_snapshot,
                            format_zone_bytes, latency_summary, percentile,
                            pool_summary, zone_byte_summary)
from .traces import (FAULT_KINDS, Fault, Storm, fault_schedule,
                     storm_schedule)
from .faults import FailureDetector, FaultInjector
from . import traces

__all__ = [
    "Application", "Assignment", "BATCH", "ChurnInjector", "ClassPolicy",
    "ClusterSpec",
    "DECODE", "DECODE_FIXED_FRAC", "DemandForecaster", "DeviceModel",
    "ElasticPolicy", "EventLoop", "FAULT_KINDS", "Factory",
    "FailureDetector", "Fault", "FaultInjector", "PREFILL",
    "fault_schedule",
    "GPU_CATALOG", "Gateway", "INTERACTIVE", "LiveExecutor",
    "PAPER_CLUSTER", "REF_ACTIVE_PARAMS", "REJECTED", "Request",
    "RequestRecord", "SLOClass", "Scheduler", "SimExecutor", "Storm",
    "TIMED_OUT", "TPU_CATALOG", "Task", "TaskRecord",
    "Timer", "Worker", "cluster_sample", "format_gateway", "make_sim",
    "opportunistic_supply", "paper_20gpu_pool", "pool_rate",
    "spill_aware_evict_priority", "storm_schedule", "traces",
    "ProgressMonitor", "Snapshot", "class_latency_summary",
    "format_class_latency", "format_latency", "format_pool",
    "format_snapshot", "format_zone_bytes", "latency_summary",
    "percentile", "pool_summary", "zone_byte_summary",
]
