"""Cluster runtime: DES engine, hardware catalog, workers, the
request-stream scheduler + application front-end, factory, availability
traces, and the dual (sim/live) executors."""
from .events import EventLoop, Timer
from .hardware import (DECODE_FIXED_FRAC, GPU_CATALOG, TPU_CATALOG,
                       PAPER_CLUSTER, ClusterSpec, DeviceModel,
                       cluster_sample, paper_20gpu_pool, pool_rate,
                       REF_ACTIVE_PARAMS)
from .worker import Worker
from .scheduler import (Assignment, Request, RequestRecord, Scheduler,
                        Task, TaskRecord)
from .executors import LiveExecutor, SimExecutor
from .application import Application
from .factory import (Factory, make_sim, opportunistic_supply,
                      spill_aware_evict_priority)
from .observability import (ProgressMonitor, Snapshot, format_latency,
                            format_snapshot, latency_summary, percentile)
from . import traces

__all__ = [
    "Application", "Assignment", "ClusterSpec", "DECODE_FIXED_FRAC",
    "DeviceModel", "EventLoop", "Factory", "GPU_CATALOG", "LiveExecutor",
    "PAPER_CLUSTER", "REF_ACTIVE_PARAMS", "Request", "RequestRecord",
    "Scheduler", "SimExecutor", "TPU_CATALOG", "Task", "TaskRecord",
    "Timer", "Worker", "cluster_sample", "make_sim",
    "opportunistic_supply", "paper_20gpu_pool", "pool_rate",
    "spill_aware_evict_priority", "traces",
    "ProgressMonitor", "Snapshot", "format_latency", "format_snapshot",
    "latency_summary", "percentile",
]
