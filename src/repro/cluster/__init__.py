"""Cluster runtime: DES engine, hardware catalog, workers, scheduler,
factory, availability traces, and the dual (sim/live) executors."""
from .events import EventLoop
from .hardware import (GPU_CATALOG, TPU_CATALOG, PAPER_CLUSTER, ClusterSpec,
                       DeviceModel, cluster_sample, paper_20gpu_pool,
                       pool_rate, REF_ACTIVE_PARAMS)
from .worker import Worker
from .scheduler import Assignment, Scheduler, Task, TaskRecord
from .executors import LiveExecutor, SimExecutor
from .factory import Factory, make_sim, opportunistic_supply
from .observability import ProgressMonitor, Snapshot, format_snapshot
from . import traces

__all__ = [
    "Assignment", "ClusterSpec", "DeviceModel", "EventLoop", "Factory",
    "GPU_CATALOG", "LiveExecutor", "PAPER_CLUSTER", "REF_ACTIVE_PARAMS",
    "Scheduler", "SimExecutor", "TPU_CATALOG", "Task", "TaskRecord",
    "Worker", "cluster_sample", "make_sim", "opportunistic_supply",
    "paper_20gpu_pool", "pool_rate", "traces",
    "ProgressMonitor", "Snapshot", "format_snapshot",
]
