"""Hardware catalog: the paper's Table 1 GPU mix + TPU-fleet analogues.

Calibration (documented derivations — all from the paper's own numbers):

* ``infer_s`` is seconds per inference of the paper's workload (SmolLM2-1.7B
  fact-verification prompt) on each device.  Anchors:
    - pv0: 150 k inferences on one dedicated A10 in 40.9 ks
      → infer_s(A10) = 0.27 s.
    - pv4_100 (pervasive, batch 100, 10×A10 + 10×TITAN X Pascal) = 2.9 ks
      → pool rate 51.7 inf/s → infer_s(TITAN X Pascal) ≈ 0.675 s.
  Other models are scaled by their published LLM inference throughput
  relative to these two anchors.
* ``disk_bw`` / ``h2d_bw`` set the *partial-context* warm overhead
  (weights deserialise + host→device each task):
    - pv3_1 (batch 1, partial) = 141.1 ks over 150 k tasks
      → mean per-task overhead ≈ 15-25 s depending on device
      → A10: 7.4 GB host bytes / 500 MB/s + 3.7 GB / 8 GB/s ≈ 15.7 s.
* ``internet_bw`` reproduces pv1 (naive): every task re-downloads the
  3.7 GB model → per-task ≈ 80-105 s → 45 MB/s effective.
* shared filesystem: Panasas ActiveStor-16, 84 Gb/s aggregate read
  → 10.5 GB/s cluster-wide, ~1 GB/s per-stream cap.

Scaling to other architectures: per-inference time scales with active
parameter bytes (decode is memory-bound), ``infer_s(cfg) ∝ n_active``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

REF_ACTIVE_PARAMS = 1.71e9          # SmolLM2-1.7B (the calibration anchor)

# Decode is memory-bound: streaming the weights through the memory system
# dominates one step, and that cost is paid once per step REGARDLESS of how
# many sequences decode together.  DECODE_FIXED_FRAC is the weight-streaming
# share of a batch-1 step; the remaining (1 - frac) is the per-sequence
# marginal cost (KV reads, sampling).  step_time(ap, 1) == infer_time(ap)
# by construction, so the calibrated batch-task numbers are unchanged; a
# full dynamic batch approaches a 1/DECODE_FIXED_FRAC ≈ 4x per-request
# throughput gain — the headroom continuous admission harvests.  The live
# slot-pool decoder (inference/streaming.py) realises the same shape: one
# cached decode_step per batch whose cost is independent of each row's
# prefix length, so sim and live step-time curves agree
# (benchmarks/bench_live_decode.py).
DECODE_FIXED_FRAC = 0.75

# Prefill is the OTHER phase: a long prompt is one big matmul, so its cost
# is bounded by the device's matrix-engine FLOPs, not its memory system.
# The two phases rank devices very differently — an H100 decodes ~8x
# faster than a TITAN X (Pascal) but prefills ~90x faster — and that
# spread is exactly what prefill/decode disaggregation harvests on a
# heterogeneous pool (arXiv 2504.15303).  One "prompt unit" is the anchor
# workload's prompt chunk (~256 tokens); a causal-LM forward costs
# ~2 * active_params FLOPs per token, discounted by an achievable
# utilisation (MFU) typical of un-tuned prefill kernels.
PREFILL_TOKENS_PER_UNIT = 256
PREFILL_MFU = 0.4


@dataclass(frozen=True)
class DeviceModel:
    name: str
    year: int
    count: int                      # population in the cluster (Table 1)
    infer_s: float                  # s/inference of the anchor workload
    mem_gb: int
    disk_bw: float                  # local SSD read, bytes/s
    h2d_bw: float                   # host->device, bytes/s
    compile_base_s: float = 0.0     # jit/compile cost (TPU analogue)
    tflops: float = 0.0             # matmul TFLOPs (prefill-relevant path)

    def infer_time(self, active_params: float) -> float:
        return self.infer_s * (active_params / REF_ACTIVE_PARAMS)

    def step_time(self, active_params: float, batch: int = 1) -> float:
        """Seconds for ONE decode step of a size-``batch`` dynamic batch."""
        b = max(int(batch), 1)
        return self.infer_time(active_params) * (
            DECODE_FIXED_FRAC + (1.0 - DECODE_FIXED_FRAC) * b)

    def prefill_time(self, active_params: float, units: int = 1) -> float:
        """FLOP-bound seconds to prefill ``units`` prompt units.

        Devices without a catalogued ``tflops`` fall back to the balanced
        assumption the pre-disaggregation model made — one prompt unit
        costs one batch-1 inference — so legacy catalogs keep their
        calibrated totals."""
        u = max(int(units), 1)
        if self.tflops <= 0:
            return u * self.infer_time(active_params)
        flops = 2.0 * active_params * PREFILL_TOKENS_PER_UNIT
        return u * flops / (self.tflops * 1e12 * PREFILL_MFU)

    def compile_s(self, recipe) -> float:
        return self.compile_base_s


# --- Table 1: the 8 major GPU models (75 % of the 567-GPU cluster) --------
# ``tflops`` is the half-precision matrix-engine throughput (tensor cores
# where the architecture has them, FP32 shader throughput for Pascal/
# Maxwell which do not) — the prefill-relevant axis.  Note the spread:
# decode speed (1/infer_s) varies ~10x across the pool while matmul
# throughput varies ~150x.
GPU_CATALOG: Dict[str, DeviceModel] = {m.name: m for m in [
    DeviceModel("NVIDIA Quadro RTX 6000", 2018, 106, 0.34, 24, 450e6, 6e9,
                tflops=65.0),
    DeviceModel("NVIDIA A10", 2021, 78, 0.27, 24, 500e6, 8e9, tflops=125.0),
    DeviceModel("NVIDIA TITAN X (Pascal)", 2016, 69, 0.675, 12, 300e6, 4e9,
                tflops=11.0),
    DeviceModel("NVIDIA GeForce GTX 1080 Ti", 2017, 63, 0.60, 11, 300e6, 4e9,
                tflops=11.3),
    DeviceModel("NVIDIA RTX 6000 Ada Generation", 2022, 36, 0.16, 48, 900e6,
                12e9, tflops=360.0),
    DeviceModel("NVIDIA GeForce GTX TITAN X", 2015, 34, 0.85, 12, 250e6, 3e9,
                tflops=6.6),
    DeviceModel("NVIDIA A40", 2020, 26, 0.22, 48, 700e6, 8e9, tflops=150.0),
    DeviceModel("NVIDIA H100 80GB HBM3", 2023, 15, 0.08, 80, 2e9, 26e9,
                tflops=990.0),
]}

# --- TPU analogues (fleet mode; compile cost is first-class context) ------
TPU_CATALOG: Dict[str, DeviceModel] = {m.name: m for m in [
    DeviceModel("TPU v4", 2021, 64, 0.24, 32, 800e6, 12e9, compile_base_s=45,
                tflops=275.0),
    DeviceModel("TPU v5e", 2023, 256, 0.30, 16, 800e6, 12e9,
                compile_base_s=35, tflops=197.0),
    DeviceModel("TPU v5p", 2023, 64, 0.12, 95, 1.2e9, 20e9, compile_base_s=50,
                tflops=459.0),
    DeviceModel("TPU v6e", 2024, 128, 0.10, 32, 1.2e9, 20e9,
                compile_base_s=40, tflops=918.0),
]}


@dataclass(frozen=True)
class ClusterSpec:
    """Cluster-level constants shared by all workers."""
    shared_fs_bw: float = 10.5e9        # Panasas aggregate read bytes/s
    shared_fs_stream_bw: float = 1.0e9  # per-stream cap
    internet_bw: float = 45e6           # per-stream model-hub download
    peer_bw_local: float = 12.5e9       # worker<->worker, same zone
    peer_bw_cross: float = 3.0e9        # cross-zone (DCN analogue)
    manager_dispatch_s: float = 0.02    # scheduler RTT + arg/result staging


PAPER_CLUSTER = ClusterSpec()


def paper_20gpu_pool() -> List[DeviceModel]:
    """The controlled pool: 10× A10 + 10× TITAN X (Pascal)."""
    a10 = GPU_CATALOG["NVIDIA A10"]
    titan = GPU_CATALOG["NVIDIA TITAN X (Pascal)"]
    return [a10] * 10 + [titan] * 10


# How often each model is *idle* and thus opportunistically reachable:
# new/fast devices are almost always claimed by static allocations, old
# ones sit free — availability anti-correlates with desirability.  These
# factors are calibrated so pv6's effective pool rate lands near the
# paper's 150 k / 783 s ≈ 191 inf/s at ~157 connected workers.
IDLE_PROPENSITY: Dict[str, float] = {
    "NVIDIA Quadro RTX 6000": 1.0,
    "NVIDIA A10": 0.5,
    "NVIDIA TITAN X (Pascal)": 2.2,
    "NVIDIA GeForce GTX 1080 Ti": 2.2,
    "NVIDIA RTX 6000 Ada Generation": 0.15,
    "NVIDIA GeForce GTX TITAN X": 2.5,
    "NVIDIA A40": 0.35,
    "NVIDIA H100 80GB HBM3": 0.05,
}


def cluster_sample(n: int, seed: int = 0,
                   catalog: Optional[Dict[str, DeviceModel]] = None,
                   weighted_by_idleness: bool = True) -> List[DeviceModel]:
    """Sample ``n`` devices ∝ Table-1 population × idle propensity."""
    cat = list((catalog or GPU_CATALOG).values())

    def w(m: DeviceModel) -> float:
        f = IDLE_PROPENSITY.get(m.name, 1.0) if weighted_by_idleness else 1.0
        return m.count * f

    total = sum(w(m) for m in cat)
    out: List[DeviceModel] = []
    # deterministic largest-remainder apportionment, then rotate by seed
    quotas = [(m, n * w(m) / total) for m in cat]
    base = [(m, int(q)) for m, q in quotas]
    out = [m for m, k in base for _ in range(k)]
    rem = sorted(quotas, key=lambda mq: mq[1] - int(mq[1]), reverse=True)
    i = 0
    while len(out) < n:
        out.append(rem[i % len(rem)][0])
        i += 1
    k = seed % max(len(out), 1)
    return out[k:] + out[:k]


def pool_rate(devices: List[DeviceModel],
              active_params: float = REF_ACTIVE_PARAMS,
              phase: Optional[str] = None) -> float:
    """Aggregate units/s of a pool (work-stealing steady state).

    ``phase`` selects the capacity axis.  ``None`` keeps the legacy
    whole-request model (one colocated inference per device at a time).
    Under disaggregation a worker runs the two phases on DIFFERENT
    engines — prefill occupies the matrix units while decode streams
    weights through HBM — so a worker busy prefilling still contributes
    its decode capacity to the pool and vice versa; phase-specific
    estimates therefore count every device, not just the "free" ones:

    * ``"prefill"``: prompt units/s, FLOP-bound (``prefill_time``);
    * ``"decode"``: batch-1 decode steps/s, HBM-bound (``step_time``).
    """
    if phase is None:
        return sum(1.0 / d.infer_time(active_params) for d in devices)
    if phase == "prefill":
        return sum(1.0 / d.prefill_time(active_params, 1) for d in devices)
    if phase == "decode":
        return sum(1.0 / d.step_time(active_params, 1) for d in devices)
    raise ValueError(f"unknown phase {phase!r}")
