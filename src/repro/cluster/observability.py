"""Observability: progress/throughput reporting (paper Challenge #2).

"Availability of opportunistic resources is generally unpredictable ...
This can only be alleviated by observability tools that transparently
inform users of the current rate of throughput and the overall progress."

The :class:`ProgressMonitor` turns a scheduler's event streams into the
rate/progress/ETA view Parsl+TaskVine give their users; it works for both
executors since it only reads scheduler state.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from .scheduler import Scheduler


@dataclass
class Snapshot:
    t: float
    completed: int
    submitted_inferences: int
    workers: int
    rate_inf_s: float            # over the trailing window
    eta_s: Optional[float]
    warm_fraction: float         # of completed tasks so far
    evicted_inferences: int


class ProgressMonitor:
    def __init__(self, sched: Scheduler, *, window_s: float = 60.0):
        self.sched = sched
        self.window_s = window_s
        self.snapshots: List[Snapshot] = []

    def _total_submitted_inferences(self) -> int:
        done = self.sched.completed_inferences
        queued = sum(t.n_inferences for t in self.sched.queue)
        running = sum(t.n_inferences for t, _ in self.sched.running.values())
        return done + queued + running

    def snapshot(self, now: float) -> Snapshot:
        s = self.sched
        prog = s.progress_events
        # trailing-window rate
        lo = now - self.window_s
        done_now = prog[-1][1] if prog else 0
        done_lo = 0
        for t, n in reversed(prog):
            if t <= lo:
                done_lo = n
                break
        rate = (done_now - done_lo) / max(min(now, self.window_s),
                                          self.window_s * 1e-3)
        total = self._total_submitted_inferences()
        remaining = total - done_now
        eta = remaining / rate if rate > 0 else None
        n_tasks = max(len(s.records), 1)
        snap = Snapshot(
            t=now, completed=done_now, submitted_inferences=total,
            workers=len(s.workers), rate_inf_s=rate, eta_s=eta,
            warm_fraction=sum(r.warm for r in s.records) / n_tasks,
            evicted_inferences=s.evicted_inferences)
        self.snapshots.append(snap)
        return snap

    def attach(self, loop, *, every_s: float = 60.0,
               printer=None) -> None:
        """Sample on a cadence inside a DES loop (sim executor)."""
        def tick():
            snap = self.snapshot(loop.now)
            if printer:
                printer(format_snapshot(snap))
            if not self.sched.done:
                loop.after(every_s, tick)
        loop.after(every_s, tick)


def format_snapshot(s: Snapshot) -> str:
    pct = 100.0 * s.completed / max(s.submitted_inferences, 1)
    eta = f"{s.eta_s:,.0f}s" if s.eta_s is not None else "—"
    return (f"[{s.t:8.0f}s] {s.completed:>8,}/{s.submitted_inferences:,} "
            f"({pct:5.1f}%)  {s.workers:>3} workers  "
            f"{s.rate_inf_s:7.1f} inf/s  eta {eta}  "
            f"warm {100*s.warm_fraction:.0f}%  "
            f"evicted {s.evicted_inferences:,}")
