"""Observability: progress/throughput AND latency reporting.

Paper Challenge #2: "Availability of opportunistic resources is generally
unpredictable ... This can only be alleviated by observability tools that
transparently inform users of the current rate of throughput and the
overall progress."

The :class:`ProgressMonitor` turns a scheduler's event streams into the
rate/progress/ETA view Parsl+TaskVine give their users; it works for both
executors since it only reads scheduler state.

With the request-stream API the records are PER-REQUEST, so latency is
first-class: :func:`latency_summary` reports queue-wait, time-to-first-
step and end-to-end distributions (p50/p95/mean) — what a makespan-only
view of run-to-completion batches could never show.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from ..core.plane import METER_FIELDS
from .scheduler import RequestRecord, Scheduler


def percentile(xs: Sequence[float], p: float) -> float:
    """Linear-interpolated percentile (p in [0, 100]) of ``xs``."""
    if not xs:
        return float("nan")
    ys = sorted(xs)
    if len(ys) == 1:
        return ys[0]
    rank = (p / 100.0) * (len(ys) - 1)
    lo = int(math.floor(rank))
    hi = min(lo + 1, len(ys) - 1)
    frac = rank - lo
    return ys[lo] * (1.0 - frac) + ys[hi] * frac


def latency_summary(records: Sequence[RequestRecord]) -> Dict[str, float]:
    """Per-request latency distributions over completion ``records``.

    Keys: ``n``, per-outcome counts (``n_done`` / ``n_rejected`` /
    ``n_timed_out`` / ``n_preempted``), and
    ``{queue_wait,ttfs,e2e}_{p50,p95,mean}_s``.  ``e2e`` is arrival →
    completion; works identically for sim and live records.

    OUTCOME-AWARE: the percentile series cover only requests SERVED
    normally.  A rejected or timed-out record never decoded (its
    ``t_end`` is the refusal instant — including it would fake
    suspiciously good latency), and a preempted request's e2e includes
    its suspension gap (including it would smear the batch class's tail
    into the served distribution); both are counted, not averaged."""
    out: Dict[str, float] = {"n": float(len(records))}
    n_done = n_rej = n_to = n_pre = 0
    served = []
    for r in records:
        outcome = getattr(r, "outcome", "done")
        if outcome == "rejected":
            n_rej += 1
        elif outcome == "timed_out":
            n_to += 1
        else:
            n_done += 1
            if getattr(r, "preemptions", 0) > 0:
                n_pre += 1
            else:
                served.append(r)
    out["n_done"] = float(n_done)
    out["n_rejected"] = float(n_rej)
    out["n_timed_out"] = float(n_to)
    out["n_preempted"] = float(n_pre)
    series = {
        "queue_wait": [r.queue_wait_s for r in served],
        "ttfs": [r.ttfs_s for r in served],
        "e2e": [r.t_end - r.t_arrival for r in served],
    }
    # per-phase breakdown (disaggregated runs): a TTFS regression is
    # attributable to its phase only if the phases are reported apart.
    # Keys appear only when some served request actually phase-split.
    phased = [r for r in served if getattr(r, "prefill_s", 0.0) > 0]
    if phased:
        series["prefill"] = [r.prefill_s for r in phased]
        series["ship"] = [r.ship_s for r in phased]
        series["decode"] = [r.decode_s for r in phased]
        out["n_phased"] = float(len(phased))
        out["n_shipped"] = float(sum(1 for r in phased if r.ship_s > 0))
    for name, xs in series.items():
        out[f"{name}_p50_s"] = percentile(xs, 50)
        out[f"{name}_p95_s"] = percentile(xs, 95)
        out[f"{name}_mean_s"] = (sum(xs) / len(xs)) if xs else float("nan")
    return out


def class_latency_summary(records: Sequence[RequestRecord]
                          ) -> Dict[str, Dict[str, float]]:
    """:func:`latency_summary` split by SLO class (``slo`` on the
    record) — the per-class percentile view the gateway contract
    exports."""
    by_class: Dict[str, List[RequestRecord]] = {}
    for r in records:
        by_class.setdefault(getattr(r, "slo", "batch"), []).append(r)
    return {slo: latency_summary(rs)
            for slo, rs in sorted(by_class.items())}


def format_latency(summary: Dict[str, float], label: str = "") -> str:
    extras = ""
    dropped = (summary.get("n_rejected", 0) + summary.get("n_timed_out", 0)
               + summary.get("n_preempted", 0))
    if dropped:
        extras = (f" | done {summary['n_done']:.0f} "
                  f"rej {summary['n_rejected']:.0f} "
                  f"t/o {summary['n_timed_out']:.0f} "
                  f"pre {summary['n_preempted']:.0f}")
    phases = ""
    if "prefill_p50_s" in summary:
        phases = (f"\n  [phases] n={summary['n_phased']:.0f} "
                  f"({summary['n_shipped']:.0f} shipped)  "
                  f"prefill p50 {summary['prefill_p50_s']:.2f}s "
                  f"p95 {summary['prefill_p95_s']:.2f}s | "
                  f"ship p50 {summary['ship_p50_s']*1e3:.1f}ms "
                  f"p95 {summary['ship_p95_s']*1e3:.1f}ms | "
                  f"decode p50 {summary['decode_p50_s']:.2f}s "
                  f"p95 {summary['decode_p95_s']:.2f}s")
    return (f"[latency{' ' + label if label else ''}] n={summary['n']:.0f}  "
            f"queue p50 {summary['queue_wait_p50_s']:.2f}s "
            f"p95 {summary['queue_wait_p95_s']:.2f}s | "
            f"ttfs p50 {summary['ttfs_p50_s']:.2f}s "
            f"p95 {summary['ttfs_p95_s']:.2f}s | "
            f"e2e p50 {summary['e2e_p50_s']:.2f}s "
            f"p95 {summary['e2e_p95_s']:.2f}s" + extras + phases)


def format_class_latency(summaries: Dict[str, Dict[str, float]]) -> str:
    return "\n".join(format_latency(s, label=slo)
                     for slo, s in summaries.items())


def pool_summary(sched: Scheduler, factory=None) -> Dict[str, object]:
    """Supply-side counters: pool size, per-device-class join/eviction
    totals, acquire -> warm lead time, and (with an elastic factory)
    target-vs-actual + the availability ceiling.

    Lead time pairs the factory's acquire-decision stamps with the
    plane's first-READY stamps: how long after the factory asked for a
    worker did that worker first hold a warm context — the latency every
    *proactive* scaling decision has to beat.  Works factory-less too
    (``serve.py`` adds workers directly): the lead-time and target rows
    are simply absent.
    """
    out: Dict[str, object] = {
        "n_workers": len(sched.workers),
        "joins": dict(sched.pool_joins),
        "evictions": dict(sched.pool_evictions),
        "by_class": {},
    }
    by_class: Dict[str, int] = {}
    for w in sched.workers.values():
        by_class[w.device.name] = by_class.get(w.device.name, 0) + 1
    out["by_class"] = by_class
    if factory is not None:
        if factory.policy is not None:
            out["target"] = factory.target
            cap = factory.effective_ceiling(sched.clock())
            out["ceiling"] = None if math.isinf(cap) else int(cap)
            out["scale_events"] = len(factory.scale_log)
        leads = []
        warm = sched.plane.first_ready_s
        for wid, t0 in factory.acquire_log.items():
            t_warm = warm.get(wid)
            if t_warm is not None and t_warm >= t0:
                leads.append(t_warm - t0)
        out["n_acquired"] = len(factory.acquire_log)
        out["n_warmed"] = len(leads)
        if leads:
            out["acquire_lead_p50_s"] = percentile(leads, 50)
            out["acquire_lead_p95_s"] = percentile(leads, 95)
            out["acquire_lead_mean_s"] = sum(leads) / len(leads)
    return out


def format_pool(summary: Dict[str, object], label: str = "") -> str:
    """One block: headline pool state, then a line per device class."""
    head = (f"[pool{' ' + label if label else ''}] "
            f"{summary['n_workers']} worker(s)")
    if "target" in summary:
        ceil = summary.get("ceiling")
        head += (f" | target {summary['target']}"
                 f" / ceiling {'∞' if ceil is None else ceil}"
                 f" | {summary['scale_events']} scale event(s)")
    joins: Dict[str, int] = summary["joins"]          # type: ignore
    evictions: Dict[str, int] = summary["evictions"]  # type: ignore
    head += (f" | joins {sum(joins.values())} "
             f"evictions {sum(evictions.values())}")
    if "acquire_lead_p50_s" in summary:
        head += (f" | acquire→warm p50 {summary['acquire_lead_p50_s']:.1f}s "
                 f"p95 {summary['acquire_lead_p95_s']:.1f}s "
                 f"({summary['n_warmed']}/{summary['n_acquired']} warmed)")
    lines = [head]
    by_class: Dict[str, int] = summary["by_class"]    # type: ignore
    for cls in sorted(set(joins) | set(evictions) | set(by_class)):
        lines.append(f"  {cls}: {by_class.get(cls, 0)} up / "
                     f"{joins.get(cls, 0)} joined / "
                     f"{evictions.get(cls, 0)} evicted")
    return "\n".join(lines)


def zone_byte_summary(plane) -> Dict[str, Dict[str, float]]:
    """Per-zone context-transfer bytes from the plane's MOVED meters,
    plus the plan/executed delta and deferral counters — the run-summary
    view of the cross-zone budget's cost model."""
    out: Dict[str, Dict[str, float]] = {}
    planned = plane.planned.as_dict()
    moved = plane.moved.as_dict()
    empty = {f: 0 for f in METER_FIELDS}
    shipped = getattr(plane, "kv_shipped", {}) or {}
    ckpt = getattr(plane, "kv_ckpt", {}) or {}
    lost = getattr(plane, "kv_lost", {}) or {}
    for zone in sorted(set(planned) | set(moved) | set(shipped)
                       | set(ckpt) | set(lost)):
        row = dict(empty, **moved.get(zone, {}))
        row["planned_minus_moved"] = sum(
            planned.get(zone, {}).get(f, 0) - row[f] for f in empty)
        # phase-attributable slice of the link bytes above: KV handoffs
        # that LANDED in this zone (already included in in_local/in_cross)
        row["kv_shipped"] = shipped.get(zone, 0)
        # crash-safety slices: checkpoint snapshots that LANDED here, and
        # parked/suspended KV voided because its holder died
        row["kv_ckpt"] = ckpt.get(zone, 0)
        row["kv_lost"] = lost.get(zone, 0)
        out[zone] = row
    return out


def format_zone_bytes(plane, label: str = "") -> str:
    """One line per zone: in/out GB by link class, then plane counters."""
    gb = 1e9
    lines = [f"[zones{' ' + label if label else ''}] "
             f"ops {plane.ops_completed}/{plane.ops_committed} completed, "
             f"{plane.deferred_intents} budget deferral event(s) — each "
             f"round a replica waits counts once"]
    for zone, row in zone_byte_summary(plane).items():
        lines.append(
            f"  {zone}: in {row['in_local']/gb:.1f} GB local / "
            f"{row['in_cross']/gb:.1f} GB cross / "
            f"{row['in_fs']/gb:.1f} GB fs | out "
            f"{row['out_local']/gb:.1f} GB local / "
            f"{row['out_cross']/gb:.1f} GB cross"
            + (f" | plan-exec delta {row['planned_minus_moved']/gb:.2f} GB"
               if row["planned_minus_moved"] else ""))
    kv = plane.kv_summary() if hasattr(plane, "kv_summary") else None
    if kv and (kv["spill_events"] or kv["resume_events"]):
        lines.append(
            f"  kv preemption: spilled {kv['spilled_bytes']/gb:.2f} GB "
            f"({kv['spill_events']} spill(s)) | resumed "
            f"{kv['resumed_bytes']/gb:.2f} GB ({kv['resume_events']} "
            f"resume(s))")
    if kv and kv.get("ship_events"):
        lines.append(
            f"  kv disaggregation: shipped {kv['shipped_bytes']/gb:.2f} GB "
            f"({kv['ship_events']} handoff(s))")
    if kv and kv.get("ckpt_events"):
        lines.append(
            f"  kv crash safety: checkpointed {kv['ckpt_bytes']/gb:.2f} GB "
            f"({kv['ckpt_events']} snapshot(s))")
    if kv and kv.get("lost_events"):
        lines.append(
            f"  kv lost: {kv['lost_bytes']/gb:.2f} GB voided with dead "
            f"holders ({kv['lost_events']} snapshot(s))")
    return "\n".join(lines)


@dataclass
class Snapshot:
    t: float
    completed: int
    submitted_inferences: int
    workers: int
    rate_inf_s: float            # over the trailing window
    eta_s: Optional[float]
    warm_fraction: float         # of completed tasks so far
    evicted_inferences: int


class ProgressMonitor:
    def __init__(self, sched: Scheduler, *, window_s: float = 60.0):
        self.sched = sched
        self.window_s = window_s
        self.snapshots: List[Snapshot] = []

    def _total_submitted_inferences(self) -> int:
        done = self.sched.completed_inferences
        queued = sum(t.n_inferences for t in self.sched.queue)
        running = sum(t.n_inferences for t, _ in self.sched.running.values())
        return done + queued + running

    def snapshot(self, now: float) -> Snapshot:
        s = self.sched
        prog = s.progress_events
        # trailing-window rate
        lo = now - self.window_s
        done_now = prog[-1][1] if prog else 0
        done_lo = 0
        for t, n in reversed(prog):
            if t <= lo:
                done_lo = n
                break
        rate = (done_now - done_lo) / max(min(now, self.window_s),
                                          self.window_s * 1e-3)
        total = self._total_submitted_inferences()
        remaining = total - done_now
        eta = remaining / rate if rate > 0 else None
        n_tasks = max(len(s.records), 1)
        snap = Snapshot(
            t=now, completed=done_now, submitted_inferences=total,
            workers=len(s.workers), rate_inf_s=rate, eta_s=eta,
            warm_fraction=sum(r.warm for r in s.records) / n_tasks,
            evicted_inferences=s.evicted_inferences)
        self.snapshots.append(snap)
        return snap

    def attach(self, loop, *, every_s: float = 60.0,
               printer=None) -> None:
        """Sample on a cadence inside a DES loop (sim executor)."""
        def tick():
            snap = self.snapshot(loop.now)
            if printer:
                printer(format_snapshot(snap))
            if not self.sched.done:
                loop.after(every_s, tick)
        loop.after(every_s, tick)


def format_snapshot(s: Snapshot) -> str:
    pct = 100.0 * s.completed / max(s.submitted_inferences, 1)
    eta = f"{s.eta_s:,.0f}s" if s.eta_s is not None else "—"
    return (f"[{s.t:8.0f}s] {s.completed:>8,}/{s.submitted_inferences:,} "
            f"({pct:5.1f}%)  {s.workers:>3} workers  "
            f"{s.rate_inf_s:7.1f} inf/s  eta {eta}  "
            f"warm {100*s.warm_fraction:.0f}%  "
            f"evicted {s.evicted_inferences:,}")
