"""Failure detection and deterministic fault injection.

The scheduler's eviction path (``Scheduler.on_evict``) was built for
REVOKE — the cluster gives advance notice and the worker leaves cleanly.
Opportunistic pools also fail silently: a node crash-stops (kernel
panic, preempted VM, yanked power) or hangs (driver wedge, NIC brownout)
with the process alive but decode frozen.  Neither announces itself, so
both need a *detector* that converts silence into an eviction within a
bounded window (docs/failure-model.md):

* **CRASH** — the worker's heartbeat lease (renewed every ``lease_s``
  since it joined) stops being renewed; the manager notices at the
  first missed expiry, so detection latency is bounded by ``lease_s``.
* **HANG / STRAGGLER** — the lease stays alive (the pilot process still
  heartbeats) but the decode-step watchdog sees no step progress for
  ``watchdog_s``; only then is the worker declared failed.

Both funnel into the SAME ``on_evict`` path as a revocation — requeue,
``plane.drop_worker`` refunds, recovery intents — with the failure
class recorded in ``Scheduler.failure_log`` / ``evictions_by_cause``.

:class:`FaultInjector` grows :class:`~repro.cluster.forecast.
ChurnInjector` into a deterministic fault-schedule driver: seeded,
reproducible :class:`~repro.cluster.traces.Fault` events firing crash /
hang / clean-revoke / transfer-failure faults against a running sim.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

from .forecast import ChurnInjector
from .traces import Fault


class FailureDetector:
    """Lease-based crash detection plus a decode-progress watchdog.

    The sim models a crashed/hung worker by setting
    ``Worker.frozen_s`` — the executor stops crediting progress past
    that instant, but the SCHEDULER keeps routing to the worker until
    this detector notices (exactly the realism the paper's opportunistic
    setting demands: you cannot avoid dispatching to a node you do not
    yet know is dead).

    * :meth:`crash` freezes the worker and schedules the eviction at the
      worker's next lease expiry — leases renew every ``lease_s``
      seconds from ``joined_s``, so latency is in ``(0, lease_s]``.
    * :meth:`hang` freezes the worker but keeps its lease alive; a
      watchdog fires after ``watchdog_s`` and evicts only if no decode
      step landed since the fault (a slow-but-alive worker survives).

    ``detection_log`` records ``(worker_id, cause, t_fault, t_detect)``
    for every conversion — tests assert the latency bound from it.
    """

    def __init__(self, executor, *, lease_s: float = 30.0,
                 watchdog_s: Optional[float] = None):
        if lease_s <= 0:
            raise ValueError("lease_s must be positive")
        self.ex = executor
        self.sched = executor.sched
        self.lease_s = lease_s
        self.watchdog_s = watchdog_s if watchdog_s is not None \
            else 2.0 * lease_s
        self.detection_log: List[Tuple[str, str, float, float]] = []

    # -- lease clock ----------------------------------------------------
    def lease_expiry(self, worker, now: float) -> float:
        """The first lease expiry AFTER ``now``: the earliest instant a
        silent death at ``now`` becomes observable."""
        since = max(0.0, now - worker.joined_s)
        last_renewal = worker.joined_s + math.floor(
            since / self.lease_s) * self.lease_s
        return last_renewal + self.lease_s

    # -- fault entry points ---------------------------------------------
    def crash(self, worker_id: str, now: Optional[float] = None) -> None:
        """Silent crash-stop: freeze the worker NOW; the eviction lands
        at its next lease expiry (detection latency <= lease_s)."""
        now = self.ex.loop.now if now is None else now
        w = self.sched.workers.get(worker_id)
        if w is None or w.frozen_s is not None:
            return
        # settle the worker's stream runs up to the crash instant FIRST:
        # progress (and checkpoint exports) before the crash really
        # happened, however lazily the sim was going to materialise them
        self._settle_runs(worker_id, now)
        w.frozen_s = now
        t_detect = self.lease_expiry(w, now)

        def expire():
            if self.sched.workers.get(worker_id) is not w:
                return              # revoked/detected through another path
            self.detection_log.append(
                (worker_id, "crash", now, self.ex.loop.now))
            self.sched.on_evict(worker_id, self.ex.loop.now, cause="crash")
            self.ex.pump()

        self.ex.loop.at(max(t_detect, self.ex.loop.now), expire)

    def hang(self, worker_id: str, now: Optional[float] = None) -> None:
        """Hang/straggler: the worker stops stepping but its lease stays
        renewed.  The step watchdog evicts after ``watchdog_s`` with no
        progress; a worker that stepped in the window is left alone."""
        now = self.ex.loop.now if now is None else now
        w = self.sched.workers.get(worker_id)
        if w is None or w.frozen_s is not None:
            return
        # settle the worker's stream runs up to NOW so the progress
        # probe is not confused by lazily un-settled past boundaries
        self._settle_runs(worker_id, now)
        w.frozen_s = now
        probe = self._progress(w)

        def watchdog():
            if self.sched.workers.get(worker_id) is not w:
                return
            if self._progress(w) != probe:
                return              # stepped since the fault: not hung
            self.detection_log.append(
                (worker_id, "hang", now, self.ex.loop.now))
            self.sched.on_evict(worker_id, self.ex.loop.now, cause="hang")
            self.ex.pump()

        self.ex.loop.after(self.watchdog_s, watchdog)

    def _settle_runs(self, worker_id: str, now: float) -> None:
        for (wid, _key), run in list(getattr(self.ex, "_streams",
                                             {}).items()):
            if wid == worker_id and run.alive():
                run.settle(now)

    def _progress(self, w) -> Tuple[int, int]:
        """A monotone progress fingerprint: completions plus the decode
        steps of every resident batch member."""
        steps = sum(r.steps_done for lib in w.libraries.values()
                    for r in lib.batch.values())
        return (w.inferences_done, steps)


class FaultInjector(ChurnInjector):
    """Deterministic fault-schedule driver over a running sim.

    Extends :class:`ChurnInjector` (which fires clean REVOKE storms)
    with the full :data:`~repro.cluster.traces.FAULT_KINDS` taxonomy.
    Victim selection reuses the parent's seeded storm machinery — zone
    correlation and staging preference behave identically — so a crash
    storm stresses the same correlated-loss paths a revocation storm
    does, differing ONLY in how the loss becomes observable:

    * ``revoke``   — immediate ``on_evict(cause="revoke")`` (parent path);
    * ``crash``    — ``detector.crash``: silent freeze, lease-expiry evict;
    * ``hang``     — ``detector.hang``: frozen but leased, watchdog evict;
    * ``transfer`` — up to ``n_workers`` in-flight sourced acquires are
      marked failed via ``executor.fail_transfer`` (abort-refund-retry
      with backoff at their completion instant).

    Same seed + same schedule => byte-identical victim sequence.
    """

    def __init__(self, executor, faults: Sequence[Fault], *,
                 detector: Optional[FailureDetector] = None,
                 factory=None, seed: int = 0, suppress_s: float = 0.0):
        super().__init__(executor, faults, factory=factory, seed=seed,
                         suppress_s=suppress_s)
        if detector is None and any(f.kind in ("crash", "hang")
                                    for f in faults):
            raise ValueError(
                "crash/hang faults need a FailureDetector "
                "(silent failures are only observable through one)")
        self.detector = detector
        self.fault_log: List[Tuple[float, str, int]] = []  # (t, kind, n)

    def _fire(self, fault: Fault) -> None:
        now = self.ex.loop.now
        if fault.kind == "transfer":
            n = self._fail_transfers(fault.n_workers)
            self.fault_log.append((now, "transfer", n))
            return
        victims = self._pick_victims(fault)
        for w in victims:
            if fault.kind == "revoke":
                self.sched.on_evict(w.worker_id, now, cause="revoke")
            elif fault.kind == "crash":
                self.detector.crash(w.worker_id, now)
            else:                           # hang
                self.detector.hang(w.worker_id, now)
        self.killed += len(victims)
        self.fault_log.append((now, fault.kind, len(victims)))
        self.storm_log.append((now, len(victims)))
        if self.factory is not None and self.suppress_s > 0 and victims:
            self.factory.restrict(len(victims),
                                  until_s=now + self.suppress_s)
        self.ex.pump()

    def _fail_transfers(self, n: int) -> int:
        """Mark up to ``n`` in-flight sourced transfers as failed (a
        FETCH from the shared fs has no peer source to die, so only
        peer-sourced ops are eligible)."""
        plane = self.sched.plane
        eligible = sorted(
            (key_wid for key_wid, op in plane._inflight.items()
             if op.src_worker is not None),
            key=lambda kw: (kw[1], kw[0]))
        hit = 0
        for key, wid in eligible:
            if hit >= n:
                break
            if (key, wid) in self.ex._failed_transfers:
                continue
            self.ex.fail_transfer(key, wid)
            hit += 1
        return hit
