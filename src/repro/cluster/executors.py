"""Request-execution backends: discrete-event simulation and live JAX.

Both executors drive the SAME :class:`~repro.cluster.scheduler.Scheduler`
(routing, registry, cache, policies).  Only the source of time differs:

* :class:`SimExecutor` — durations from the calibrated hardware catalog
  (paper-scale runs: 150 k inferences, 186 GPUs).  Stream batches advance
  with a per-step event model: each step of a size-B dynamic batch costs
  ``device.step_time(active_params, B)``, membership changes between
  steps, and a batch fast-forwards in O(membership changes) events rather
  than O(steps);
* :class:`LiveExecutor` — really materialises contexts (device_put, jit)
  and runs forward passes on this container's device, measuring wall
  time.  Stream batches are advanced one decode step at a time through a
  per-recipe ``step_fn``; the decode state lives in a persistent device
  slot pool (see :mod:`repro.inference.streaming`) so membership churn
  costs one admission prefill per joiner — never a re-prefill of rows
  already in flight — and each step is O(1) in prefix length.

Deprecated exclusive tasks (``Task`` / ``submit_sweep``) keep the
pre-redesign run-to-completion path in both backends, which is also the
benchmark baseline continuous admission is measured against.
"""
from __future__ import annotations

import random
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from ..core import (ContextMode, NAIVE, OpKind, PARTIAL, PERVASIVE,
                    PlacementPlan, PlanOp, Tier, WarmPoolPolicy)
from .events import EventLoop
from .hardware import ClusterSpec
from .scheduler import Assignment, PREFILL, Scheduler
from .worker import Worker

_EPS = 1e-9


def _kv_nbytes(tree) -> int:
    """Byte size of a host-side KV snapshot pytree (no jax dependency —
    the sim backend must stay importable without an accelerator stack)."""
    if hasattr(tree, "nbytes"):
        return int(tree.nbytes)
    if isinstance(tree, dict):
        return sum(_kv_nbytes(v) for v in tree.values())
    if isinstance(tree, (list, tuple)):
        return sum(_kv_nbytes(v) for v in tree)
    return 0


class _PlanOpExecution:
    """The ONE plan-op execution path both executors share.

    The context plane compiles intents into :class:`PlacementPlan` ops;
    this mixin walks the ops, makes worker-side room (authoritative
    spills), and feeds the op lifecycle back to the plane.  Only
    :meth:`_materialize_op` differs per backend — the sim charges the
    calibrated staging cost on the event loop, live mode really runs the
    loaders — which is exactly the dual-backend discipline the scheduler
    already follows.
    """

    def execute_plan(self, plan: PlacementPlan) -> None:
        plane = self.sched.plane
        for op in plan.ops:
            if op.kind in (OpKind.FETCH, OpKind.PEER_COPY, OpKind.PROMOTE):
                self._execute_acquire_op(op)
            elif op.kind is OpKind.SPILL:
                # both a Release compilation's demotion and an acquire
                # op's preview; executing the preview up front is what
                # make_room would do anyway (and make_room still backstops
                # any spill the preview missed)
                self._execute_spill_op(op)
            elif op.kind is OpKind.EVICT:
                plane.note_released(op.recipe_key, op.worker_id)

    def _execute_spill_op(self, op: PlanOp) -> None:
        sched = self.sched
        w = sched.workers.get(op.worker_id)
        if w is None:
            return
        lib = w.libraries.get(op.recipe_key)
        if lib is None or not lib.ready \
                or w.running_by_recipe.get(op.recipe_key, 0) > 0:
            return                      # gone, already spilled, or busy
        lib.spill()
        sched.plane.note_spilled(op.recipe_key, op.worker_id)
        sched.spilled_libraries += 1

    def _execute_acquire_op(self, op: PlanOp) -> None:
        sched = self.sched
        plane = sched.plane
        w = sched.workers.get(op.worker_id)
        if w is None or not w.idle or w.has_ready(op.recipe_key):
            plane.op_aborted(op)        # pool moved under the plan
            return
        recipe = plane.registry.recipes[op.recipe_key]
        for k in w.make_room(recipe):
            plane.note_spilled(k, w.worker_id)
            sched.spilled_libraries += 1
        w.staging = True
        plane.op_started(op)
        self._materialize_op(op, w, recipe)

    def _materialize_op(self, op: PlanOp, w: Worker, recipe,
                        attempt: int = 0) -> None:
        raise NotImplementedError


class _StreamRun:
    """Sim-side driver for ONE library's dynamic batch on one worker.

    Keeps the step clock: ``t_boundary`` is the last step boundary,
    ``step_s`` the current per-step cost (a function of batch size).
    Progress is settled lazily — the runner schedules a single event at
    the next *interesting* boundary (earliest member completion, or the
    first boundary after an admission) and bulk-advances whole segments
    of stable membership, so a 256-step request with no churn costs one
    event, not 256.
    """

    def __init__(self, ex: "SimExecutor", a: Assignment):
        self.ex = ex
        self.w = a.worker
        self.key = a.request.recipe_key
        self.lib = a.worker.libraries[self.key]
        self.active_params = a.request.active_params
        self.assign: Dict[int, Assignment] = {a.request.request_id: a}
        self.join_t: Dict[int, float] = {}   # admission wall time per rid
        self.t_boundary = 0.0
        self.step_s = 0.0
        self.begun = False
        self._timer = None
        # steps_done at each member's last checkpoint ATTEMPT (landed or
        # budget-deferred) — the cadence counter for ckpt_every_steps
        self._ckpt_mark: Dict[int, int] = {}

    # -- lifecycle ------------------------------------------------------
    def alive(self) -> bool:
        """False once the worker was evicted or this run was replaced;
        also lazily unregisters a dead run (eviction never notifies the
        executor, so the stale entry would otherwise leak)."""
        sched = self.ex.sched
        ok = (sched.workers.get(self.w.worker_id) is self.w and
              self.ex._streams.get((self.w.worker_id, self.key)) is self)
        if not ok and self.ex._streams.get(
                (self.w.worker_id, self.key)) is self:
            del self.ex._streams[(self.w.worker_id, self.key)]
        return ok

    def admit(self, a: Assignment) -> None:
        """A request joined (scheduler already put it in ``lib.batch``);
        it starts stepping at the first boundary at/after NOW — never at
        an earlier, lazily settled one."""
        if not self.alive():
            return                      # worker evicted mid-dispatch
        rid = a.request.request_id
        self.assign[rid] = a
        self.join_t[rid] = self.ex.loop.now
        if self.begun:
            self.settle(self.ex.loop.now)
            self.schedule()

    def begin(self) -> None:
        """Staging done (or warm): the batch starts decoding now."""
        if not self.alive():
            return
        self.begun = True
        self.t_boundary = self.ex.loop.now
        self.lib.activate()
        self.join_t.clear()
        self._reprice()
        self.schedule()

    def _reprice(self) -> None:
        # price by the members actually decoding (joiners waiting for
        # their boundary don't occupy the step yet)
        self.step_s = self.w.device.step_time(
            self.active_params, max(self.lib.stepping, 1))

    # -- event plumbing -------------------------------------------------
    def schedule(self) -> None:
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None
        if self.w.frozen_s is not None:
            return              # crashed/hung: no future step completes;
                                # the FailureDetector's eviction requeues
        if not self.lib.batch:
            self.close()
            return
        if self.lib.stepping == 0:
            # everyone left is a joiner (preemption can suspend the last
            # settled member): there is no running step whose boundary a
            # due joiner could wait for — activate the due ones NOW, and
            # if none are due yet the in-flight admit() will reschedule.
            due = self._due_joiners(self.ex.loop.now)
            if not due:
                return
            self.t_boundary = self.ex.loop.now
            self.lib.activate(due)
            for rid in due:
                self.join_t.pop(rid, None)
            self._reprice()
        if self.lib.joining:
            t_next = self.t_boundary + self.step_s
        else:
            min_rem = min(r.n_units - r.steps_done
                          for r in self.lib.batch.values())
            t_next = self.t_boundary + min_rem * self.step_s
        self._timer = self.ex.loop.at(max(t_next, self.ex.loop.now),
                                      self._fire)

    def _due_joiners(self, boundary: float) -> list:
        """Joining members whose admission happened at/before
        ``boundary`` — the only ones allowed to activate there.  A
        member the scheduler admitted but whose dispatch the manager has
        not finished (admit() not yet called) is never due."""
        return [rid for rid in self.lib.joining
                if self.join_t.get(rid, float("inf")) <= boundary + _EPS]

    def _fire(self) -> None:
        self._timer = None
        if not self.alive():
            return
        self.settle(self.ex.loop.now)
        self.schedule()
        self.ex.pump()

    def close(self) -> None:
        self.ex._streams.pop((self.w.worker_id, self.key), None)
        self.ex.sched.close_stream(self.w.worker_id, self.key)

    # -- the step clock -------------------------------------------------
    def settle(self, t: float) -> None:
        """Advance the batch to time ``t``: whole segments of stable
        membership at once, completing members and absorbing DUE joiners
        at the boundaries in between.  A joiner is due only at
        boundaries at/after its admission time — lazily settled PAST
        boundaries must never retro-activate it (it would be credited
        with steps it never ran).

        With ``Scheduler.ckpt_every_steps`` set, segments are ALSO
        clamped at each member's next checkpoint-cadence boundary, where
        the member's KV snapshot is exported to another failure zone as
        a KV_CKPT plane op — keeping the event count O(membership
        changes + checkpoints), never O(steps).  A frozen (crashed or
        hung) worker settles only up to the instant it died: a dead GPU
        completes nothing, however late the detector notices."""
        fz = self.w.frozen_s
        if fz is not None:
            t = min(t, fz)
        every = self.ex.sched.ckpt_every_steps
        while self.lib.stepping > 0 and self.step_s > 0:
            span = (t - self.t_boundary) + _EPS
            if span < self.step_s:
                break
            k = int(span / self.step_s)
            min_rem = min(r.n_units - r.steps_done
                          for rid, r in self.lib.batch.items()
                          if rid not in self.lib.joining)
            if self._due_joiners(self.t_boundary + self.step_s):
                k = 1                 # membership changes next boundary
            k = max(1, min(k, min_rem))
            if every:
                to_ckpt = min(
                    every - (r.steps_done - self._ckpt_mark.setdefault(
                        rid, r.steps_done))
                    for rid, r in self.lib.batch.items()
                    if rid not in self.lib.joining)
                k = max(1, min(k, to_ckpt))
            stepping = [r for rid, r in self.lib.batch.items()
                        if rid not in self.lib.joining]
            t_seg0 = self.t_boundary
            self.t_boundary = t_seg0 + k * self.step_s
            for _ in range(k - 1):    # quiet steps: nobody can finish
                self.lib.step()
            finished = self.lib.step()
            for r in stepping:
                if r.t_first_step is None:
                    r.t_first_step = t_seg0 + self.step_s
            for r in finished:
                self._ckpt_mark.pop(r.request_id, None)
                # a finished request needs no checkpoint: refund any
                # still-in-flight one so drained runs meter to parity
                self.ex.sched.plane.kv_ckpt_aborted(r.request_id,
                                                    self.t_boundary)
                a = self.assign.pop(r.request_id, None)
                if a is not None:
                    self.ex.sched.on_complete(a, a.t_dispatch,
                                              self.t_boundary,
                                              t_first_step=r.t_first_step)
            if every:
                for rid, r in list(self.lib.batch.items()):
                    if rid in self.lib.joining:
                        continue
                    mark = self._ckpt_mark.setdefault(rid, r.steps_done)
                    if r.steps_done - mark >= every:
                        self._ckpt_mark[rid] = r.steps_done
                        self.ex._fire_ckpt(self, r, self.t_boundary)
            due = self._due_joiners(self.t_boundary)
            if due:                   # joiners enter at this boundary
                self.lib.activate(due)
                for rid in due:
                    self.join_t.pop(rid, None)
            self._reprice()


class SimExecutor(_PlanOpExecution):
    """Discrete-event executor with the calibrated cluster time model.

    ``prestage=True`` enables proactive spanning-tree context distribution
    (paper §5.3.1): when workers join and a context already has ready
    hosts, the scheduler plans a fanout-capped tree over the joiners and
    stages them immediately, instead of lazily on first task dispatch.

    ``warm_pool`` plugs in a :class:`~repro.core.WarmPoolPolicy`: after
    each dispatch round, hot recipes are replicated onto leftover idle
    capable workers ahead of demand, so the stream's next requests route
    warm.
    """

    def __init__(self, scheduler: Scheduler, loop: Optional[EventLoop] = None,
                 *, prestage: bool = False, fanout_cap: int = 3,
                 warm_pool: Optional[WarmPoolPolicy] = None,
                 retry_seed: int = 0):
        self.sched = scheduler
        self.loop = loop or EventLoop()
        scheduler.clock = lambda: self.loop.now
        self.cluster: ClusterSpec = scheduler.cluster
        self.prestage_enabled = prestage
        self.fanout_cap = fanout_cap
        self.warm_pool = warm_pool
        self._manager_free = 0.0
        self._fs_streams = 0
        self._peer_streams: Dict[str, int] = {}   # outbound per source
        self._streams: Dict[Tuple[str, str], _StreamRun] = {}
        # transfer retry-with-backoff (docs/failure-model.md): an acquire
        # op whose SOURCE died (or a FaultInjector transfer fault hit) is
        # aborted-refunded and retried against an alternate source under
        # capped exponential backoff with seeded jitter
        self.retry_base_s = 0.5
        self.retry_cap_s = 30.0
        self.retry_jitter = 0.25
        self._retry_rng = random.Random(retry_seed)
        self._failed_transfers: set = set()   # (recipe_key, dst_worker)
        self.transfer_retries = 0
        self._ckpt_rr = 0               # round-robin ckpt-host cursor
        self._budget_retry = None       # pending deferred-replication timer
        self._prestage_retry = None     # deferred prestage-edge timer
        self._prestage_pending: set = set()   # recipes with deferred edges
        self._deadline_timer = None     # next gateway deadline expiry
        # arrivals scheduled on the loop but not yet submitted
        # (Application.submit_stream); keeps run() from stopping early
        self.pending_arrivals = 0
        # demand-driven supply: an elastic Factory installs its step()
        # here so the pool re-sizes on every pump, not just on its tick
        self.supply_hook: Optional[Callable[[], None]] = None

    # -- proactive spanning-tree distribution (§5.3.1) ---------------------
    def prestage(self, recipe_key: str) -> int:
        """Stage ``recipe_key`` onto every context-less idle worker via a
        topology-aware spanning tree. Returns the number of targets.

        BUDGET-AWARE: each cross-zone tree edge is admission-checked
        against the plane's :class:`LinkBudget` as a ``PEER_COPY`` op, so
        operators capping DCN bytes cap the bulk distribution too — not
        just the warm pool's share.  A deferred edge re-emits next round
        exactly like a deferred ``Replicate``: its subtree is skipped
        (children cannot source from a copy that never landed), the
        deferral is counted, and a half-window timer re-runs prestage for
        the recipe once the budget window can have slid."""
        from ..core import Peer, plan_spanning_tree
        reg = self.sched.registry
        recipe = reg.recipes[recipe_key]
        ready = reg.ready_workers(recipe_key)
        if not ready:
            return 0
        have = reg.workers_with(recipe_key)
        c = self.cluster
        mk = lambda w: Peer(w.worker_id, w.zone, bw_local=c.peer_bw_local,
                            bw_cross=c.peer_bw_cross)
        sources = [mk(self.sched.workers[wid]) for wid in ready
                   if wid in self.sched.workers]
        targets = [mk(w) for w in self.sched.workers.values()
                   if w.worker_id not in have and w.idle
                   and w.can_host(recipe)]
        if not targets or not sources:
            return 0
        plane = self.sched.plane
        plan = plan_spanning_tree(recipe.transfer_bytes, sources, targets,
                                  fanout_cap=self.fanout_cap,
                                  t0=self.loop.now)
        zones = {w.worker_id: w.zone for w in self.sched.workers.values()}
        dead: set = set()               # dsts whose edge the budget deferred
        deferred = 0
        for edge in plan.edges:
            w = self.sched.workers.get(edge.dst)
            if w is None:
                continue
            if edge.src in dead:
                # parent edge deferred: this copy has no source yet; the
                # retry round re-plans the tree from what actually landed
                dead.add(edge.dst)
                deferred += 1
                continue
            op = PlanOp(OpKind.PEER_COPY, recipe_key, edge.dst,
                        nbytes=recipe.transfer_bytes, src_worker=edge.src,
                        src_zone=zones.get(edge.src, w.zone),
                        dst_zone=w.zone)
            if not plane.budget.admits(op, self.loop.now):
                dead.add(edge.dst)
                deferred += 1
                continue
            plane.budget.charge(op, self.loop.now)
            w.staging = True
            plane.note_staging(recipe_key, edge.dst)

            def arrive(wid=edge.dst, src=edge.src):
                w = self.sched.workers.get(wid)
                if w is None or w.frozen_s is not None:
                    return                      # evicted while in flight
                for k in w.make_room(recipe):
                    plane.note_spilled(k, wid)
                    self.sched.spilled_libraries += 1
                lib = w.library_for(recipe)
                cost = lib.materialize_cost(w.device, already_local=False,
                                            fetch_bw=float("inf"))
                # the tree edge's bytes landed: meter them per zone pair
                plane.record_transfer(recipe_key, zones.get(src, w.zone),
                                      w.zone, cost.fetch_bytes)

                def ready_cb(wid=wid):
                    w = self.sched.workers.get(wid)
                    if w is None or w.frozen_s is not None:
                        return
                    w.staging = False
                    plane.note_ready(recipe_key, wid)
                    self.pump()

                self.loop.after(cost.total_s, ready_cb)

            self.loop.at(edge.end_s, arrive)
        if deferred:
            plane.deferred_intents += deferred
            self._prestage_pending.add(recipe_key)
            if self._prestage_retry is None:
                def retry():
                    self._prestage_retry = None
                    pending, self._prestage_pending = \
                        self._prestage_pending, set()
                    for key in sorted(pending):
                        if key in self.sched.registry.recipes:
                            self.prestage(key)
                    self.pump()
                self._prestage_retry = self.loop.after(
                    plane.budget.window_s / 2, retry)
        return len(targets) - deferred

    # -- warm-pool replication (demand-driven, beyond prestage) ------------
    def _apply_warm_pool(self) -> int:
        """Compile Replicate intents (recovery + policy) through the
        context plane and execute the budget-admitted ops.  Intents the
        budget window deferred are retried — not dropped — once the
        window can have slid, even if no other event re-pumps first."""
        if self.warm_pool is None:
            return 0
        plane = self.sched.plane
        view = self.sched.view(now=self.loop.now)
        intents = list(plane.recovery_intents(view))
        intents += self.warm_pool.intents(view)
        if not intents:
            return 0
        plan = plane.compile(intents, view)
        plane.commit(plan, now=view.now)
        self.execute_plan(plan)
        if any(d.retriable for d in plan.deferred) \
                and self._budget_retry is None:
            def retry():
                self._budget_retry = None
                self.pump()
            self._budget_retry = self.loop.after(
                plane.budget.window_s / 2, retry)
        return len(plan.acquire_ops())

    # -- shared plan-op path: the sim's staging-time backend ---------------
    def _materialize_op(self, op, w: Worker, recipe,
                        attempt: int = 0) -> None:
        lib = w.library_for(recipe)
        if op.kind is OpKind.PROMOTE:
            fetch_bw = None                     # promotion only, no fetch
        elif op.kind is OpKind.PEER_COPY:
            base = (self.cluster.peer_bw_cross if op.cross_zone
                    else self.cluster.peer_bw_local)
            fetch_bw = base / (self._peer_streams.get(op.src_worker, 0) + 1)
        else:                                   # FETCH via the shared fs
            fetch_bw = self._fs_bw()
        cost = lib.materialize_cost(w.device, fetch_bw=fetch_bw)
        if cost.fetch_s > 0:
            if op.kind is OpKind.PEER_COPY:
                self._take_peer_stream(op.src_worker, cost.fetch_s)
            else:
                self._with_fs_stream(cost.fetch_s)

        def ready_cb(wid=op.worker_id):
            w = self.sched.workers.get(wid)
            if w is None:
                return                          # evicted: plane refunded
            src = op.src_worker
            src_w = self.sched.workers.get(src) if src is not None else None
            failed = (op.recipe_key, wid) in self._failed_transfers
            self._failed_transfers.discard((op.recipe_key, wid))
            if failed or (src is not None and
                          (src_w is None or src_w.frozen_s is not None)):
                # the source died (or a transfer fault hit) mid-flight:
                # abort-refund the op, then retry against an alternate
                # source under capped backoff (never silently complete a
                # copy whose bytes had no live origin)
                self.sched.plane.op_aborted(op, self.loop.now)
                self.transfer_retries += 1
                self._retry_acquire(op.recipe_key, wid, recipe, attempt)
                return
            if w.frozen_s is not None:
                return          # dest crashed silently: the detector's
                                # eviction will refund this op
            w.staging = False
            self.sched.plane.op_completed(op, moved_bytes=cost.fetch_bytes)
            self.pump()

        self.loop.after(cost.total_s, ready_cb)

    def _retry_acquire(self, key: str, wid: str, recipe,
                       attempt: int) -> None:
        """Re-attempt a failed acquire on ``wid`` after capped
        exponential backoff with seeded jitter, against whatever source
        the plane picks NOW (the dead one is tombstoned, so an alternate
        ready peer or the shared fs wins)."""
        delay = min(self.retry_base_s * (2 ** attempt), self.retry_cap_s)
        delay *= 1.0 + self.retry_jitter * self._retry_rng.random()

        def again():
            sched = self.sched
            w = sched.workers.get(wid)
            if w is None or w.frozen_s is not None:
                return                  # dest gone meanwhile
            if w.has_ready(key):
                return                  # another path already staged it
            plane = sched.plane
            view = sched.view(now=self.loop.now)
            src = plane._pick_source(key, w, view)
            nbytes = view.missing_bytes(w, recipe)
            if src is None:
                op = PlanOp(OpKind.FETCH, key, wid, nbytes=nbytes,
                            dst_zone=w.zone)
            else:
                op = PlanOp(OpKind.PEER_COPY, key, wid, nbytes=nbytes,
                            src_worker=src.worker_id, src_zone=src.zone,
                            dst_zone=w.zone)
            plane.commit(PlacementPlan(ops=[op]), now=self.loop.now)
            plane.op_started(op)
            self._materialize_op(op, w, recipe, attempt=attempt + 1)

        self.loop.after(delay, again)

    def fail_transfer(self, recipe_key: str, dst_worker: str) -> None:
        """Mark the in-flight transfer for ``(recipe_key, dst_worker)``
        as failed: its completion event aborts-refunds and retries with
        backoff instead of landing (the FaultInjector's transfer
        fault)."""
        self._failed_transfers.add((recipe_key, dst_worker))

    # -- crash safety: periodic KV checkpoint export -----------------------
    def _ckpt_target(self, req, src: Worker) -> Optional[Worker]:
        """A checkpoint host for ``req``: a live worker with the recipe
        warm, preferring a DIFFERENT failure zone than the decode worker
        (a zone-correlated storm must not take both copies)."""
        sched = self.sched
        ready = sched.registry.ready_workers(req.recipe_key)
        # creation order, not lexical: worker ids come from a
        # process-global counter, so lexical order (or anything keyed on
        # raw id/request numbers) would make placement depend on how
        # many workers unrelated runs in this process created first
        cands = [sched.workers[wid]
                 for wid in sorted(ready, key=lambda i: (len(i), i))
                 if wid != src.worker_id and wid in sched.workers
                 and sched.workers[wid].frozen_s is None]
        if not cands:
            return None
        other_zone = [w for w in cands if w.zone != src.zone]
        pool = other_zone or cands
        # sticky while eligible: each landed snapshot then supersedes
        # the previous one in place on the same host
        for w in pool:
            if w.worker_id == req.ckpt_worker:
                return w
        self._ckpt_rr += 1
        return pool[self._ckpt_rr % len(pool)]

    def _fire_ckpt(self, run: _StreamRun, req, t: float) -> None:
        """Export one settled member's KV snapshot to a checkpoint host:
        price it as a KV_CKPT plane op, admission-check the budget
        window (a checkpoint the window cannot absorb is DEFERRED to the
        next cadence boundary, never dropped), occupy an outbound peer
        stream for the transfer, and record the landed checkpoint on the
        request.  Stale-safe: an eviction of either endpoint aborts the
        in-flight op and the landed event becomes a no-op."""
        sched = self.sched
        plane = sched.plane
        w = run.w
        rid = req.request_id
        if rid in plane._inflight_ckpts:
            return                  # previous snapshot still in transit
        dst = self._ckpt_target(req, w)
        if dst is None:
            sched.kv_ckpts_deferred += 1
            return
        recipe = sched.registry.recipes[req.recipe_key]
        nbytes = recipe.decode_slot_bytes(req.active_params)
        op = plane.kv_ckpt_op(req.recipe_key, w.worker_id, dst.worker_id,
                              nbytes, src_zone=w.zone, dst_zone=dst.zone)
        if not plane.ckpt_admits(op, t):
            sched.kv_ckpts_deferred += 1   # window full: next boundary
            return
        plane.commit_kv_ckpt(rid, op, now=t)
        sched.kv_ckpts += 1
        base = (self.cluster.peer_bw_cross if op.cross_zone
                else self.cluster.peer_bw_local)
        bw = base / (self._peer_streams.get(w.worker_id, 0) + 1)
        delay = op.nbytes / bw if op.nbytes > 0 else 0.0
        steps_at = req.steps_done
        t_land = t + delay

        def landed(op=op):
            if plane._inflight_ckpts.get(rid) is not op:
                return              # aborted (endpoint died): stale event
            src_w = sched.workers.get(op.src_worker)
            if src_w is None or src_w.frozen_s is not None:
                # the source died mid-transfer: the bytes never all left
                plane.kv_ckpt_aborted(rid, self.loop.now)
                return
            plane.kv_ckpt_completed(rid)
            req.ckpt_worker = op.worker_id
            req.ckpt_steps = steps_at
            req.ckpt_nbytes = op.nbytes

        if t_land <= self.loop.now:
            # lazily settled history: this transfer already finished in
            # simulated time (boundaries are materialised out of a bulk
            # settle).  Completing it synchronously keeps chronology
            # exact — the NEXT boundary in the same settle sees no
            # in-flight snapshot and supersedes this one, so the last
            # landed checkpoint is the newest whose transfer beat NOW
            # (for a crashed worker: beat the crash instant).
            landed()
        else:
            if delay > 0:
                self._take_peer_stream(w.worker_id, delay)
            self.loop.at(t_land, landed)

    # -- shared-filesystem contention (Challenge #5) -----------------------
    def _fs_bw(self) -> float:
        c = self.cluster
        return min(c.shared_fs_stream_bw,
                   c.shared_fs_bw / max(1, self._fs_streams + 1))

    def _with_fs_stream(self, duration: float) -> None:
        self._fs_streams += 1
        self.loop.after(duration, self._end_fs_stream)

    def _end_fs_stream(self) -> None:
        self._fs_streams = max(0, self._fs_streams - 1)

    def _take_peer_stream(self, src: str, duration: float) -> None:
        """Occupy one outbound stream on ``src``'s NIC for ``duration``."""
        self._peer_streams[src] = self._peer_streams.get(src, 0) + 1
        self.loop.after(duration, lambda: self._peer_streams.__setitem__(
            src, max(0, self._peer_streams.get(src, 1) - 1)))

    # -- staging time model -------------------------------------------------
    def _staging_cost(self, a: Assignment) -> float:
        """Seconds of context staging for a cold dispatch (0 when warm)."""
        req, w = a.request, a.worker
        recipe = self.sched.registry.recipes[req.recipe_key]
        mode = req.mode
        lib = w.library_for(recipe)
        if mode is NAIVE:
            # sandbox-per-task: deps via shared fs, weights re-downloaded
            # from the model hub, nothing reused (pv1).
            deps = recipe.element("deps")
            weights = recipe.element("weights")
            fs_bw = self._fs_bw()
            fetch = deps.nbytes_disk / fs_bw
            self._with_fs_stream(fetch)
            fetch += weights.nbytes_disk / self.cluster.internet_bw
            load = weights.nbytes(Tier.HOST) / w.device.disk_bw
            h2d = weights.nbytes(Tier.DEVICE) / w.device.h2d_bw
            return fetch + load + h2d + recipe.activation_s
        # partial / pervasive: the library stages against the local cache
        if a.peer_source is not None:
            base = (self.cluster.peer_bw_cross if a.cross_zone
                    else self.cluster.peer_bw_local)
            # source NIC is shared by its concurrent outbound transfers
            n = self._peer_streams.get(a.peer_source, 0)
            fetch_bw = base / (n + 1)
        else:
            fetch_bw = self._fs_bw()
        cost = lib.materialize_cost(w.device, fetch_bw=fetch_bw)
        a.moved_bytes = cost.fetch_bytes    # plan/executed byte accounting
        if cost.fetch_s > 0:
            if a.peer_source is not None:
                self._take_peer_stream(a.peer_source, cost.fetch_s)
            else:
                self._with_fs_stream(cost.fetch_s)
        return cost.total_s

    def _post_exec(self, a: Assignment) -> None:
        """Mode-dependent teardown after a task finishes (paper §5.2 obs 3)."""
        req, w = a.request, a.worker
        recipe = self.sched.registry.recipes[req.recipe_key]
        if req.mode is PERVASIVE:
            return                      # library stays resident
        lib = w.libraries.get(recipe.key)
        if lib is not None:
            lib.teardown()
        if req.mode is PARTIAL:
            # sandbox destroyed but registered disk artefacts survive;
            # elements still pinned by a co-resident library stay put
            for e in recipe.elements:
                if w.cache.tier_of(e.key) is not None \
                        and w.cache.pins(e.key) == 0:
                    w.cache.demote(e.key, Tier.DISK)
        else:                           # naive: nothing survives
            for e in recipe.elements:
                if w.cache.pins(e.key) == 0:
                    w.cache.drop(e.key)

    # -- dispatch loop --------------------------------------------------------
    def pump(self) -> None:
        while True:
            a = self.sched.route()
            if a is None:
                break
            self._start(a)
        # leftover idle workers: replicate hot recipes ahead of demand
        self._apply_warm_pool()
        # elastic supply reacts to the demand this round revealed
        # (re-entrancy is the hook owner's problem: Factory.step guards)
        if self.supply_hook is not None:
            self.supply_hook()
        # with a gateway installed, queued deadlines must fire as DES
        # events — an idle loop would otherwise never notice an expiry
        self._arm_deadline_timer()

    def _arm_deadline_timer(self) -> None:
        gw = self.sched.gateway
        if gw is None:
            return
        nd = gw.next_deadline()
        if nd is None:
            if self._deadline_timer is not None:
                self._deadline_timer.cancel()
                self._deadline_timer = None
            return
        t = max(nd + _EPS, self.loop.now)
        if self._deadline_timer is not None:
            if self._deadline_timer.t <= t + _EPS:
                return                  # an earlier/equal expiry is armed
            self._deadline_timer.cancel()

        def fire():
            self._deadline_timer = None
            self.pump()                 # route() expires overdue requests

        self._deadline_timer = self.loop.at(t, fire)

    def _meter_preemption(self, a: Assignment) -> None:
        """Price the KV bytes a preemption dispatch moves: the victim's
        decode cache spilling host-side, and — on the victim's return —
        the snapshot moving back (sim: the recipe's per-slot estimate)."""
        if a.preempt is None and not a.resumed:
            return
        plane = self.sched.plane
        key = a.request.recipe_key
        recipe = self.sched.registry.recipes[key]
        if a.preempt is not None:
            plane.record_kv_spill(
                key, a.worker.zone,
                recipe.decode_slot_bytes(a.preempt.active_params))
        if a.resumed:
            plane.record_kv_resume(
                key, a.worker.zone,
                recipe.decode_slot_bytes(a.request.active_params))

    def _ship_delay(self, a: Assignment, t0: float) -> float:
        """Price the KV handoff attached to a decode dispatch: occupy an
        outbound stream on the prefill worker's NIC, schedule the plane's
        landed event, and return the transfer seconds the admission must
        wait for.  The landed event is stale-safe — an eviction that
        already aborted the ship makes it a no-op."""
        op = a.kv_ship
        if op is None:
            return 0.0
        base = (self.cluster.peer_bw_cross if op.cross_zone
                else self.cluster.peer_bw_local)
        bw = base / (self._peer_streams.get(op.src_worker, 0) + 1)
        ship_s = op.nbytes / bw if op.nbytes > 0 else 0.0
        if ship_s > 0:
            self._take_peer_stream(op.src_worker, ship_s)
        a.request.ship_s += ship_s
        rid = a.request.request_id
        self.loop.at(t0 + ship_s,
                     lambda: self.sched.plane.kv_ship_completed(rid))
        return ship_s

    def _start_prefill(self, a: Assignment, t0: float,
                       staging_s: float) -> None:
        """A PREFILL dispatch occupies the worker for the FLOP-bound
        prompt pass, then hands the request back to the scheduler as a
        DECODE-phase requeue carrying its KV snapshot, priced at the
        recipe's per-slot estimate (the same pricing preemption spills
        use, so ship and spill bytes stay comparable)."""
        req, w = a.request, a.worker
        wid, tid = w.worker_id, req.request_id
        recipe = self.sched.registry.recipes[req.recipe_key]
        prefill_s = w.device.prefill_time(req.active_params,
                                          req.prompt_units)

        def staged():
            if wid in self.sched.workers and tid in self.sched.running \
                    and w.frozen_s is None:
                self.sched.on_staged(a)

        def done():
            cur = self.sched.running.get(tid)
            if cur is None or cur[1] != wid:
                return              # evicted mid-prefill: already requeued
            if w.frozen_s is not None:
                return              # crashed: nothing completed; the
                                    # detector's eviction requeues
            self.sched.on_prefill_done(
                a, t0, self.loop.now,
                kv_nbytes=recipe.decode_slot_bytes(req.active_params))
            self.pump()

        if not a.warm:
            self.loop.at(t0 + staging_s, staged)
        self.loop.at(t0 + staging_s + prefill_s, done)

    def _start(self, a: Assignment) -> None:
        # the manager is serial: one dispatch per manager_dispatch_s
        t0 = max(self.loop.now, self._manager_free) \
            + self.cluster.manager_dispatch_s
        self._manager_free = t0
        a.t_dispatch = t0
        self.sched.on_start(a)
        self._meter_preemption(a)
        req, w = a.request, a.worker
        wid = w.worker_id
        if a.join:
            run = self._streams.get((wid, req.recipe_key))
            if run is None:
                if a.kv_ship is not None:
                    # no batch to land on: the committed handoff dies too
                    self.sched.plane.kv_ship_aborted(req.request_id,
                                                     self.loop.now)
                return
            # the admission lands once the serial manager finishes this
            # dispatch (t0) plus any KV handoff from the prefill worker
            ship_s = self._ship_delay(a, t0)
            self.loop.at(t0 + ship_s, lambda: run.admit(a))
            return
        staging_s = 0.0 if a.warm else self._staging_cost(a)
        if req.phase == PREFILL:
            self._start_prefill(a, t0, staging_s)
            return
        ship_s = self._ship_delay(a, t0)
        if not req.exclusive:
            # founding member of a stream batch: hand the clock to a runner
            run = _StreamRun(self, a)
            self._streams[(wid, req.recipe_key)] = run
            if not a.warm:
                def staged(run=run):
                    if wid in self.sched.workers and run.alive() \
                            and run.w.frozen_s is None:
                        self.sched.on_staged(a)
                self.loop.at(t0 + staging_s, staged)
            self.loop.at(t0 + staging_s + ship_s, run.begin)
            return
        # deprecated run-to-completion batch: one completion event.  A
        # DECODE-phase exclusive already banked its prompt units as
        # steps_done, so only the remaining (decode) units run here.
        step_s = w.device.step_time(req.active_params, 1)
        infer_s = (req.n_units - req.steps_done) * step_s
        tid = req.request_id

        def staged():
            if wid in self.sched.workers and tid in self.sched.running \
                    and w.frozen_s is None:
                self.sched.on_staged(a)

        def complete():
            cur = self.sched.running.get(tid)
            if cur is None or cur[1] != wid:
                return                  # evicted mid-run; already requeued
                                        # (and possibly re-dispatched)
            if w.frozen_s is not None:
                return                  # crashed mid-run: no completion
            self.sched.on_complete(a, t0, self.loop.now,
                                   t_first_step=t0 + staging_s + ship_s
                                   + step_s)
            self._post_exec(a)
            self.pump()

        if not a.warm:
            self.loop.at(t0 + staging_s, staged)
        self.loop.at(t0 + staging_s + ship_s + infer_s, complete)

    # -- run ------------------------------------------------------------------
    def run(self, *, until: Optional[float] = None) -> float:
        self.pump()
        self.loop.run(until=until,
                      stop=lambda: self.sched.done
                      and not self.pending_arrivals)
        return self.sched.makespan()


class LiveExecutor(_PlanOpExecution):
    """Synchronous wall-clock executor: contexts and requests really run.

    ``fns[recipe_key]`` is the bound function ``fn(payloads, payload)``
    executed inside the library's address space for a deprecated
    run-to-completion task (paper Fig 3's ``infer_model``).

    ``step_fns[recipe_key]`` is the STREAM path: called once per decode
    step with the library payloads and the list of active member
    requests, it returns ``{request_id: step_output}``; outputs
    accumulate in ``results[request_id]`` (a list, one entry per step).
    Membership changes hands between calls: the step function binds
    joiners into a persistent slot pool (admission prefill), steps the
    whole pool through one cached ``decode_step``, and frees finished
    slots (:class:`repro.inference.streaming.StreamingDecoder` does
    exactly this for the PfF application); the executor feeds the pool's
    measured per-slot bytes back into the recipe's slot budget.

    All simulated workers share this container's device; what is real is
    the context lifecycle — import, weight materialisation, jit compile
    on first use, and reuse on subsequent invocations.
    """

    def __init__(self, scheduler: Scheduler,
                 fns: Optional[Dict[str, Callable[..., Any]]] = None,
                 *, warm_pool: Optional[WarmPoolPolicy] = None,
                 step_fns: Optional[Dict[str, Callable[..., Any]]] = None):
        self.sched = scheduler
        scheduler.clock = self.now
        self.fns = fns or {}
        self.step_fns = step_fns or {}
        self.warm_pool = warm_pool
        self.results: Dict[int, Any] = {}
        self._stream_assign: Dict[int, Assignment] = {}
        self._open: List[Tuple[Worker, str]] = []
        # (worker_id, key) -> decoder kv_resume_bytes_total last metered
        self._kv_resume_seen: Dict[Tuple[str, str], int] = {}
        self._t0 = time.perf_counter()

    def now(self) -> float:
        return time.perf_counter() - self._t0

    _now = now                          # deprecated alias

    def _apply_warm_pool(self) -> int:
        """Compile Replicate intents through the context plane and run the
        SAME plan ops the sim executes — here the loaders really run."""
        if self.warm_pool is None:
            return 0
        plane = self.sched.plane
        view = self.sched.view(now=self.now())
        intents = list(plane.recovery_intents(view))
        intents += self.warm_pool.intents(view)
        if not intents:
            return 0
        plan = plane.compile(intents, view)
        plane.commit(plan, now=view.now)
        self.execute_plan(plan)
        return len(plan.acquire_ops())

    # -- shared plan-op path: live staging really runs the loaders ---------
    def _materialize_op(self, op, w: Worker, recipe,
                        attempt: int = 0) -> None:
        lib = w.library_for(recipe)
        if not lib.ready:
            lib.materialize()
        w.staging = False
        # live loaders do not move the plan's network bytes (everything is
        # on this container); account the op as priced
        self.sched.plane.op_completed(op)

    # -- dispatch -------------------------------------------------------
    def _run_exclusive(self, a: Assignment) -> None:
        req, w = a.request, a.worker
        recipe = self.sched.registry.recipes[req.recipe_key]
        lib = w.library_for(recipe)
        if not lib.ready:
            lib.materialize()
        self.sched.on_staged(a)
        out = lib.invoke(self.fns[req.recipe_key], req.payload)
        self.results[req.request_id] = out
        self.sched.on_complete(a, a.t_dispatch, self.now())
        if req.mode is not PERVASIVE:
            lib.teardown()              # pay init again next task
        # warm-pool is demand-driven: it must run while work is still
        # queued, i.e. between tasks, not just per outer run() round
        self._apply_warm_pool()

    def _dispatch_all(self) -> bool:
        progressed = False
        while True:
            a = self.sched.route()
            if a is None:
                return progressed
            progressed = True
            a.t_dispatch = self.now()
            req, w = a.request, a.worker
            self.sched.on_start(a)
            if req.phase == PREFILL:
                self._run_prefill(a)
                continue
            if req.exclusive:
                self._run_exclusive(a)
                continue
            self._stream_assign[req.request_id] = a
            if a.preempt is not None:
                self._suspend_victim(a)
            if not a.join:              # founding member: open the batch
                lib = w.library_for(
                    self.sched.registry.recipes[req.recipe_key])
                if not lib.ready:
                    lib.materialize()
                self.sched.on_staged(a)
                self._open.append((w, req.recipe_key))
            if a.kv_ship is not None:
                self._ship_kv(a)

    def _run_prefill(self, a: Assignment) -> None:
        """Run a PREFILL-phase dispatch to completion: materialise the
        recipe, emit the prompt-phase tokens through the step function's
        ``prefill`` entry, and leave the KV snapshot parked in this
        worker's decoder.  The request goes back to the scheduler as
        DECODE-phase work carrying the snapshot's MEASURED byte size —
        the plane prices any subsequent ship with real bytes.  A recipe
        whose step function cannot prefill without stepping falls back
        to colocated execution (phase cleared, request requeued)."""
        req, w = a.request, a.worker
        t_start = self.now()
        recipe = self.sched.registry.recipes[req.recipe_key]
        lib = w.library_for(recipe)
        if not lib.ready:
            lib.materialize()
        self.sched.on_staged(a)
        prefill = getattr(self.step_fns.get(req.recipe_key), "prefill",
                          None)
        if prefill is None:
            self.sched.abort_prefill(a)
            return
        nbytes, toks = prefill(lib.context.payloads, req)
        self.results.setdefault(req.request_id, []).extend(toks)
        self.sched.on_prefill_done(a, t_start, self.now(),
                                   kv_nbytes=nbytes)

    def _ship_kv(self, a: Assignment) -> None:
        """Execute the KV handoff attached to a decode dispatch: pop the
        snapshot from the prefill worker's decoder and park it in the
        destination library's inbox; the step function adopts it before
        the request's first decode step, so decode resumes bit-exactly
        WITHOUT re-prefill.  A snapshot that died with its library
        (spill / eviction) aborts the ship — the decode admission falls
        back to a fresh prefill and nothing is metered as moved."""
        req, w = a.request, a.worker
        key = req.recipe_key
        plane = self.sched.plane
        src_w = self.sched.workers.get(a.kv_ship.src_worker)
        src_lib = src_w.libraries.get(key) if src_w is not None else None
        src_dec = (src_lib.context.payloads.get("_stream_decoder")
                   if src_lib is not None and src_lib.context is not None
                   else None)
        snap = (src_dec.export_suspended(req.request_id)
                if src_dec is not None else None)
        if snap is None:
            plane.kv_ship_aborted(req.request_id, self.now())
            return
        t0 = self.now()
        lib = w.library_for(self.sched.registry.recipes[key])
        if lib.context is None:
            lib.materialize()
        lib.context.payloads.setdefault("_kv_inbox", {})[
            req.request_id] = snap
        req.ship_s += self.now() - t0
        plane.kv_ship_completed(req.request_id,
                                moved_bytes=_kv_nbytes(snap.get("kv")))

    def _suspend_victim(self, a: Assignment) -> None:
        """Spill the preempted member's KV host-side through the stream
        decoder BEFORE the next step runs, so the interactive admission
        finds the slot free and the victim can later resume without
        re-prefill.  Without a decoder (step_fn never ran) there is no
        device state to save — the victim simply restarts."""
        victim, w, key = a.preempt, a.worker, a.request.recipe_key
        lib = w.libraries.get(key)
        dec = (lib.context.payloads.get("_stream_decoder")
               if lib is not None and lib.context is not None else None)
        nbytes = dec.suspend(victim.request_id) if dec is not None else 0
        if nbytes:
            victim.kv_nbytes = nbytes   # measured, not the sim estimate
            self.sched.plane.record_kv_spill(key, w.zone, nbytes)
        else:                           # nothing saved: back to scratch
            victim.suspended = False
            victim.suspended_on = None
            victim.steps_done = 0
            victim.t_first_step = None

    # -- the live step loop ---------------------------------------------
    def _step_streams(self) -> bool:
        stepped = False
        for w, key in list(self._open):
            if self.sched.workers.get(w.worker_id) is not w:
                self._open.remove((w, key))     # worker evicted mid-batch
                continue
            lib = w.libraries.get(key)
            if lib is None or not lib.batch:
                self._open.remove((w, key))
                self.sched.close_stream(w.worker_id, key)
                continue
            lib.activate()
            members = list(lib.batch.values())
            step_fn = self.step_fns.get(key)
            if step_fn is not None:
                outs = step_fn(lib.context.payloads, members)
                for rid, frag in outs.items():
                    self.results.setdefault(rid, []).append(frag)
                # slot budgets from measured memory: a step function that
                # hosts a slot-pool decoder exposes the REAL per-slot cache
                # footprint after its first admission prefill; feed it back
                # so this recipe's slot budgets stop using the
                # KV_BYTES_PER_PARAM analytic guess (ROADMAP item).
                dec = lib.context.payloads.get("_stream_decoder")
                measured = int(getattr(dec, "measured_slot_bytes", 0) or 0)
                if measured and measured != lib.recipe.measured_slot_bytes:
                    lib.recipe.record_slot_bytes(measured)
                # meter KV snapshots the decoder restored this step
                # (resume happens inside the step_fn, so delta-track it)
                total = int(getattr(dec, "kv_resume_bytes_total", 0) or 0)
                seen = self._kv_resume_seen.get((w.worker_id, key), 0)
                if total > seen:
                    self.sched.plane.record_kv_resume(key, w.zone,
                                                      total - seen)
                    self._kv_resume_seen[(w.worker_id, key)] = total
            finished = lib.step()
            now = self.now()
            stepped = True
            for r in members:
                if r.t_first_step is None:
                    r.t_first_step = now
            for r in finished:
                a = self._stream_assign.pop(r.request_id, None)
                if a is not None:
                    self.sched.on_complete(a, a.t_dispatch, now,
                                           t_first_step=r.t_first_step)
            if not lib.batch:
                self._open.remove((w, key))
                self.sched.close_stream(w.worker_id, key)
        return stepped

    def run(self) -> float:
        while not self.sched.done:
            progressed = self._dispatch_all()
            progressed |= self._step_streams()
            if not progressed:
                gw = self.sched.gateway
                nd = gw.next_deadline() if gw is not None else None
                if nd is not None:
                    # queued work is deadline-gated, not unplaceable:
                    # wait for the expiry (or preemption slack) to open
                    time.sleep(min(max(nd - self.now(), 0.0), 0.05)
                               + 0.001)
                    continue
                raise RuntimeError(
                    "deadlock: requests queued but no worker can host "
                    "them (check worker shapes vs recipe footprints)")
            self._apply_warm_pool()
        return self.sched.makespan()
