"""Task-execution backends: discrete-event simulation and live JAX.

Both executors drive the SAME :class:`~repro.cluster.scheduler.Scheduler`
(routing, registry, cache, policies).  Only the source of task duration
differs:

* :class:`SimExecutor` — durations from the calibrated hardware catalog
  (paper-scale runs: 150 k inferences, 186 GPUs);
* :class:`LiveExecutor` — really materialises contexts (device_put, jit)
  and runs forward passes on this container's device, measuring wall time.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional

from ..core import (ContextMode, NAIVE, PARTIAL, PERVASIVE, Tier,
                    WarmPoolPolicy)
from .events import EventLoop
from .hardware import ClusterSpec
from .scheduler import Assignment, Scheduler


class SimExecutor:
    """Discrete-event executor with the calibrated cluster time model.

    ``prestage=True`` enables proactive spanning-tree context distribution
    (paper §5.3.1): when workers join and a context already has ready
    hosts, the scheduler plans a fanout-capped tree over the joiners and
    stages them immediately, instead of lazily on first task dispatch.

    ``warm_pool`` plugs in a :class:`~repro.core.WarmPoolPolicy`: after
    each dispatch round, hot recipes are replicated onto leftover idle
    capable workers ahead of demand, so the sweep's next tasks route warm.
    """

    def __init__(self, scheduler: Scheduler, loop: Optional[EventLoop] = None,
                 *, prestage: bool = False, fanout_cap: int = 3,
                 warm_pool: Optional[WarmPoolPolicy] = None):
        self.sched = scheduler
        self.loop = loop or EventLoop()
        self.cluster: ClusterSpec = scheduler.cluster
        self.prestage_enabled = prestage
        self.fanout_cap = fanout_cap
        self.warm_pool = warm_pool
        self._manager_free = 0.0
        self._fs_streams = 0
        self._peer_streams: Dict[str, int] = {}   # outbound per source

    # -- proactive spanning-tree distribution (§5.3.1) ---------------------
    def prestage(self, recipe_key: str) -> int:
        """Stage ``recipe_key`` onto every context-less idle worker via a
        topology-aware spanning tree. Returns the number of targets."""
        from ..core import Peer, plan_spanning_tree
        reg = self.sched.registry
        recipe = reg.recipes[recipe_key]
        ready = reg.ready_workers(recipe_key)
        if not ready:
            return 0
        have = reg.workers_with(recipe_key)
        c = self.cluster
        mk = lambda w: Peer(w.worker_id, w.zone, bw_local=c.peer_bw_local,
                            bw_cross=c.peer_bw_cross)
        sources = [mk(self.sched.workers[wid]) for wid in ready
                   if wid in self.sched.workers]
        targets = [mk(w) for w in self.sched.workers.values()
                   if w.worker_id not in have and w.idle
                   and w.can_host(recipe)]
        if not targets or not sources:
            return 0
        plan = plan_spanning_tree(recipe.transfer_bytes, sources, targets,
                                  fanout_cap=self.fanout_cap,
                                  t0=self.loop.now)
        for edge in plan.edges:
            w = self.sched.workers.get(edge.dst)
            if w is None:
                continue
            w.staging = True
            reg.mark_staging(recipe_key, edge.dst)

            def arrive(wid=edge.dst):
                w = self.sched.workers.get(wid)
                if w is None:
                    return                      # evicted while in flight
                for k in w.make_room(recipe):
                    reg.mark_spilled(k, wid)
                    self.sched.spilled_libraries += 1
                lib = w.library_for(recipe)
                cost = lib.materialize_cost(w.device, already_local=False,
                                            fetch_bw=float("inf"))

                def ready_cb(wid=wid):
                    w = self.sched.workers.get(wid)
                    if w is None:
                        return
                    w.staging = False
                    reg.mark_ready(recipe_key, wid)
                    self.pump()

                self.loop.after(cost.total_s, ready_cb)

            self.loop.at(edge.end_s, arrive)
        return len(targets)

    # -- warm-pool replication (demand-driven, beyond prestage) ------------
    def _apply_warm_pool(self) -> int:
        """Stage hot recipes onto leftover idle workers per the policy."""
        if self.warm_pool is None:
            return 0
        plan = self.warm_pool.plan(self.sched)
        for key, wid in plan:
            self._stage_replica(key, wid)
        return len(plan)

    def _stage_replica(self, recipe_key: str, wid: str) -> None:
        w = self.sched.workers.get(wid)
        if w is None or not w.idle:
            return
        reg = self.sched.registry
        recipe = reg.recipes[recipe_key]
        for k in w.make_room(recipe):
            reg.mark_spilled(k, wid)
            self.sched.spilled_libraries += 1
        w.staging = True
        reg.mark_staging(recipe_key, wid)
        lib = w.library_for(recipe)
        src = None
        if w.has_local(recipe):
            fetch_bw = None                     # promotion only, no fetch
        else:
            src, cross = self.sched._pick_peer(recipe_key, w)
            if src is not None:
                base = (self.cluster.peer_bw_cross if cross
                        else self.cluster.peer_bw_local)
                fetch_bw = base / (self._peer_streams.get(src, 0) + 1)
            else:
                fetch_bw = self._fs_bw()
        cost = lib.materialize_cost(w.device, fetch_bw=fetch_bw)
        if cost.fetch_s > 0:
            if src is not None:
                self._take_peer_stream(src, cost.fetch_s)
            else:
                self._with_fs_stream(cost.fetch_s)

        def ready_cb(wid=wid):
            w = self.sched.workers.get(wid)
            if w is None:
                return                          # evicted while staging
            w.staging = False
            reg.mark_ready(recipe_key, wid)
            self.pump()

        self.loop.after(cost.total_s, ready_cb)

    # -- shared-filesystem contention (Challenge #5) -----------------------
    def _fs_bw(self) -> float:
        c = self.cluster
        return min(c.shared_fs_stream_bw,
                   c.shared_fs_bw / max(1, self._fs_streams + 1))

    def _with_fs_stream(self, duration: float) -> None:
        self._fs_streams += 1
        self.loop.after(duration, self._end_fs_stream)

    def _end_fs_stream(self) -> None:
        self._fs_streams = max(0, self._fs_streams - 1)

    def _take_peer_stream(self, src: str, duration: float) -> None:
        """Occupy one outbound stream on ``src``'s NIC for ``duration``."""
        self._peer_streams[src] = self._peer_streams.get(src, 0) + 1
        self.loop.after(duration, lambda: self._peer_streams.__setitem__(
            src, max(0, self._peer_streams.get(src, 1) - 1)))

    # -- staging time model -------------------------------------------------
    def _staging_cost(self, a: Assignment) -> float:
        """Seconds of context staging for a cold dispatch (0 when warm)."""
        task, w = a.task, a.worker
        recipe = self.sched.registry.recipes[task.recipe_key]
        mode = task.mode
        lib = w.library_for(recipe)
        if mode is NAIVE:
            # sandbox-per-task: deps via shared fs, weights re-downloaded
            # from the model hub, nothing reused (pv1).
            deps = recipe.element("deps")
            weights = recipe.element("weights")
            fs_bw = self._fs_bw()
            fetch = deps.nbytes_disk / fs_bw
            self._with_fs_stream(fetch)
            fetch += weights.nbytes_disk / self.cluster.internet_bw
            load = weights.nbytes(Tier.HOST) / w.device.disk_bw
            h2d = weights.nbytes(Tier.DEVICE) / w.device.h2d_bw
            return fetch + load + h2d + recipe.activation_s
        # partial / pervasive: the library stages against the local cache
        if a.peer_source is not None:
            base = (self.cluster.peer_bw_cross if a.cross_zone
                    else self.cluster.peer_bw_local)
            # source NIC is shared by its concurrent outbound transfers
            n = self._peer_streams.get(a.peer_source, 0)
            fetch_bw = base / (n + 1)
        else:
            fetch_bw = self._fs_bw()
        cost = lib.materialize_cost(w.device, fetch_bw=fetch_bw)
        if cost.fetch_s > 0:
            if a.peer_source is not None:
                self._take_peer_stream(a.peer_source, cost.fetch_s)
            else:
                self._with_fs_stream(cost.fetch_s)
        return cost.total_s

    def _post_exec(self, a: Assignment) -> None:
        """Mode-dependent teardown after a task finishes (paper §5.2 obs 3)."""
        task, w = a.task, a.worker
        recipe = self.sched.registry.recipes[task.recipe_key]
        if task.mode is PERVASIVE:
            return                      # library stays resident
        lib = w.libraries.get(recipe.key)
        if lib is not None:
            lib.teardown()
        if task.mode is PARTIAL:
            # sandbox destroyed but registered disk artefacts survive;
            # elements still pinned by a co-resident library stay put
            for e in recipe.elements:
                if w.cache.tier_of(e.key) is not None \
                        and w.cache.pins(e.key) == 0:
                    w.cache.demote(e.key, Tier.DISK)
        else:                           # naive: nothing survives
            for e in recipe.elements:
                if w.cache.pins(e.key) == 0:
                    w.cache.drop(e.key)

    # -- dispatch loop --------------------------------------------------------
    def pump(self) -> None:
        while True:
            a = self.sched.route()
            if a is None:
                break
            self._start(a)
        # leftover idle workers: replicate hot recipes ahead of demand
        self._apply_warm_pool()

    def _start(self, a: Assignment) -> None:
        # the manager is serial: one dispatch per manager_dispatch_s
        t0 = max(self.loop.now, self._manager_free) \
            + self.cluster.manager_dispatch_s
        self._manager_free = t0
        self.sched.on_start(a)
        task, w = a.task, a.worker
        staging_s = 0.0 if a.warm else self._staging_cost(a)
        infer_s = task.n_inferences * w.device.infer_time(task.active_params)
        wid, tid = w.worker_id, task.task_id

        def staged():
            if wid in self.sched.workers and tid in self.sched.running:
                self.sched.on_staged(a)

        def complete():
            if tid not in self.sched.running:
                return                  # evicted mid-run; already requeued
            self.sched.on_complete(a, t0, self.loop.now)
            self._post_exec(a)
            self.pump()

        if not a.warm:
            self.loop.at(t0 + staging_s, staged)
        self.loop.at(t0 + staging_s + infer_s, complete)

    # -- run ------------------------------------------------------------------
    def run(self, *, until: Optional[float] = None) -> float:
        self.pump()
        self.loop.run(until=until, stop=lambda: self.sched.done)
        return self.sched.makespan()


class LiveExecutor:
    """Synchronous wall-clock executor: contexts and tasks really run.

    ``fns[recipe_key]`` is the bound function ``fn(payloads, task_payload)``
    executed inside the library's address space (paper Fig 3's
    ``infer_model``).  All simulated workers share this container's device;
    what is real is the context lifecycle — import, weight materialisation,
    jit compile on first use, and reuse on subsequent invocations.
    """

    def __init__(self, scheduler: Scheduler,
                 fns: Dict[str, Callable[..., Any]],
                 *, warm_pool: Optional[WarmPoolPolicy] = None):
        self.sched = scheduler
        self.fns = fns
        self.warm_pool = warm_pool
        self.results: Dict[int, Any] = {}
        self._t0 = time.perf_counter()

    def _now(self) -> float:
        return time.perf_counter() - self._t0

    def _apply_warm_pool(self) -> int:
        """Materialise warm replicas for hot recipes on idle workers (the
        same policy the sim exercises — here the loaders really run)."""
        if self.warm_pool is None:
            return 0
        reg = self.sched.registry
        plan = self.warm_pool.plan(self.sched)
        for key, wid in plan:
            w = self.sched.workers.get(wid)
            if w is None or not w.idle:
                continue
            recipe = reg.recipes[key]
            for k in w.make_room(recipe):
                reg.mark_spilled(k, wid)
                self.sched.spilled_libraries += 1
            reg.mark_staging(key, wid)
            lib = w.library_for(recipe)
            if not lib.ready:
                lib.materialize()
            reg.mark_ready(key, wid)
        return len(plan)

    def run(self) -> float:
        while not self.sched.done:
            a = self.sched.route()
            if a is None:
                raise RuntimeError(
                    "deadlock: tasks queued but no idle worker can host "
                    "them (check worker shapes vs recipe footprints)")
            task, w = a.task, a.worker
            recipe = self.sched.registry.recipes[task.recipe_key]
            t_start = self._now()
            self.sched.on_start(a)
            lib = w.library_for(recipe)
            if not lib.ready:
                lib.materialize()
            self.sched.on_staged(a)
            out = lib.invoke(self.fns[task.recipe_key], task.payload)
            self.results[task.task_id] = out
            t_end = self._now()
            self.sched.on_complete(a, t_start, t_end)
            if task.mode is not PERVASIVE:
                lib.teardown()          # pay init again next task
            self._apply_warm_pool()
        return self.sched.makespan()
