"""A TaskVine-style worker: pilot job + tiered local cache + libraries.

One worker = the base unit of resource acquisition (paper §5.3.2): a small
pilot job holding (cores, memory, disk, 1 accelerator) that runs at most
``shape.concurrency`` tasks at a time and keeps a byte-accounted local
cache of context elements plus the library processes hosting materialised
contexts.

Workers are genuinely MULTI-CONTEXT: several libraries may be resident at
once, and when a new recipe does not fit alongside them the worker *spills*
the least-recently-used idle library (device/host → local disk, pins
released) instead of tearing it down — switching back to a spilled recipe
re-promotes from local disk rather than re-fetching over the network.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from ..core import (ContextCache, ContextRecipe, Library, Tier, WorkerShape,
                    PAPER_WORKER_SHAPE, resident_footprint)
from .hardware import DeviceModel

_ids = itertools.count()


@dataclass
class Worker:
    device: DeviceModel
    zone: str = "z0"
    shape: WorkerShape = PAPER_WORKER_SHAPE
    worker_id: str = field(default_factory=lambda: f"w{next(_ids)}")
    joined_s: float = 0.0

    def __post_init__(self):
        self.cache = ContextCache(
            disk_bytes=self.shape.disk_gb * 10**9,
            host_bytes=self.shape.memory_gb * 10**9,
            device_bytes=self.device.mem_gb * 10**9,
        )
        self.libraries: Dict[str, Library] = {}
        self.running: int = 0                 # tasks in flight
        self.running_by_recipe: Dict[str, int] = {}
        self.staging: bool = False            # context materialising
        self.tasks_done: int = 0
        self.inferences_done: int = 0
        self._use_seq = itertools.count()
        self._last_used: Dict[str, int] = {}  # recipe key -> use tick (LRU)

    # -- capacity ---------------------------------------------------------
    @property
    def idle(self) -> bool:
        return self.running < self.shape.concurrency and not self.staging

    def _fits(self, recipes: List[ContextRecipe]) -> bool:
        """Would ``recipes`` fit fully resident together on this worker?
        Elements are deduplicated by content key (shared deps count once)."""
        elems = {e.key: e for r in recipes for e in r.elements}
        return all(resident_footprint(elems.values(), tier)
                   <= self.cache.capacity[tier] for tier in Tier)

    def _immovable(self, but: Optional[str] = None) -> List[ContextRecipe]:
        """Recipes that cannot be spilled: those with tasks in flight."""
        return [self.libraries[k].recipe
                for k, n in self.running_by_recipe.items()
                if n > 0 and k != but and k in self.libraries]

    def can_host(self, recipe: ContextRecipe) -> bool:
        """True if ``recipe`` could be made fully resident here, spilling
        every idle library if needed (running ones are immovable)."""
        return self._fits([recipe] + self._immovable(but=recipe.key))

    def make_room(self, recipe: ContextRecipe) -> List[str]:
        """Spill idle resident libraries (LRU first) until ``recipe`` fits
        alongside what must stay.  Returns the spilled recipe keys, which
        the caller (scheduler) reflects into the context registry."""
        spilled: List[str] = []
        while True:
            keep = [lib.recipe for k, lib in self.libraries.items()
                    if lib.ready and k != recipe.key]
            if self._fits([recipe] + keep):
                return spilled
            victims = [k for k, lib in self.libraries.items()
                       if lib.ready and k != recipe.key
                       and self.running_by_recipe.get(k, 0) == 0]
            if not victims:
                return spilled              # cannot fit; caller gated on
            v = min(victims,                # can_host, so shouldn't happen
                    key=lambda k: self._last_used.get(k, -1))
            self.libraries[v].spill()
            spilled.append(v)

    # -- context hosting ----------------------------------------------------
    def touch(self, recipe_key: str) -> None:
        self._last_used[recipe_key] = next(self._use_seq)

    def library_for(self, recipe) -> Library:
        lib = self.libraries.get(recipe.key)
        if lib is None:
            lib = Library(recipe, self.cache)
            self.libraries[recipe.key] = lib
        self.touch(recipe.key)
        return lib

    def has_ready(self, recipe_key: str) -> bool:
        lib = self.libraries.get(recipe_key)
        return bool(lib and lib.ready)

    def has_local(self, recipe: ContextRecipe) -> bool:
        """All elements present in the local cache (any tier) — a cold
        start here pays promotion but no network fetch."""
        return all(self.cache.tier_of(e.key) is not None
                   for e in recipe.elements)
