"""A TaskVine-style worker: pilot job + tiered local cache + libraries.

One worker = the base unit of resource acquisition (paper §5.3.2): a small
pilot job holding (cores, memory, disk, 1 accelerator) that runs at most
``shape.concurrency`` tasks at a time and keeps a byte-accounted local
cache of context elements plus the library processes hosting materialised
contexts.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, Optional

from ..core import ContextCache, Library, WorkerShape, PAPER_WORKER_SHAPE
from .hardware import DeviceModel

_ids = itertools.count()


@dataclass
class Worker:
    device: DeviceModel
    zone: str = "z0"
    shape: WorkerShape = PAPER_WORKER_SHAPE
    worker_id: str = field(default_factory=lambda: f"w{next(_ids)}")
    joined_s: float = 0.0

    def __post_init__(self):
        self.cache = ContextCache(
            disk_bytes=self.shape.disk_gb * 10**9,
            host_bytes=self.shape.memory_gb * 10**9,
            device_bytes=self.device.mem_gb * 10**9,
        )
        self.libraries: Dict[str, Library] = {}
        self.running: int = 0                 # tasks in flight
        self.staging: bool = False            # context materialising
        self.tasks_done: int = 0
        self.inferences_done: int = 0

    # -- capacity ---------------------------------------------------------
    @property
    def idle(self) -> bool:
        return self.running < self.shape.concurrency and not self.staging

    # -- context hosting ----------------------------------------------------
    def library_for(self, recipe) -> Library:
        lib = self.libraries.get(recipe.key)
        if lib is None:
            lib = Library(recipe, self.cache)
            self.libraries[recipe.key] = lib
        return lib

    def has_ready(self, recipe_key: str) -> bool:
        lib = self.libraries.get(recipe_key)
        return bool(lib and lib.ready)

    def drop_library(self, recipe_key: str) -> None:
        lib = self.libraries.pop(recipe_key, None)
        if lib is not None:
            lib.teardown()
