"""A TaskVine-style worker: pilot job + tiered local cache + libraries.

One worker = the base unit of resource acquisition (paper §5.3.2): a small
pilot job holding (cores, memory, disk, 1 accelerator) that runs at most
``shape.concurrency`` tasks at a time and keeps a byte-accounted local
cache of context elements plus the library processes hosting materialised
contexts.

Workers are genuinely MULTI-CONTEXT: several libraries may be resident at
once, and when a new recipe does not fit alongside them the worker *spills*
the least-recently-used idle library (device/host → local disk, pins
released) instead of tearing it down — switching back to a spilled recipe
re-promotes from local disk rather than re-fetching over the network.

A worker running a STREAM batch (continuous batching) occupies one
concurrency slot with the batch as a whole; individual requests are
admitted into the hosting library's dynamic batch up to its device-derived
slot budget without going through the idle check.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set

from ..core import (ContextCache, ContextRecipe, Library, Tier, WorkerShape,
                    PAPER_WORKER_SHAPE, resident_footprint)
from .hardware import DeviceModel

_ids = itertools.count()


@dataclass
class Worker:
    device: DeviceModel
    zone: str = "z0"
    shape: WorkerShape = PAPER_WORKER_SHAPE
    worker_id: str = field(default_factory=lambda: f"w{next(_ids)}")
    joined_s: float = 0.0

    def __post_init__(self):
        self.cache = ContextCache(
            disk_bytes=self.shape.disk_gb * 10**9,
            host_bytes=self.shape.memory_gb * 10**9,
            device_bytes=self.device_bytes,
        )
        self.libraries: Dict[str, Library] = {}
        self.running: int = 0                 # occupied concurrency slots
        self.running_by_recipe: Dict[str, int] = {}   # in-flight REQUESTS
        self.open_streams: Set[str] = set()   # recipes with a live batch
        self.staging: bool = False            # context materialising
        # crash/hang fault marker (repro.cluster.faults): the wall time
        # the worker silently stopped executing.  The SCHEDULER cannot
        # see this — only the FailureDetector's lease/watchdog converts
        # it into an eviction — but the sim executor must stop crediting
        # progress past this instant (a dead GPU completes nothing).
        self.frozen_s: Optional[float] = None
        self.tasks_done: int = 0
        self.inferences_done: int = 0
        self._use_seq = itertools.count()
        self._last_used: Dict[str, int] = {}  # recipe key -> use tick (LRU)

    # -- capacity ---------------------------------------------------------
    @property
    def idle(self) -> bool:
        return self.running < self.shape.concurrency and not self.staging

    @property
    def device_bytes(self) -> int:
        return self.device.mem_gb * 10**9

    def slot_budget(self, recipe_key: str, active_params: float) -> int:
        """Decode-slot budget for ``recipe_key``'s library HERE: device
        memory not occupied by co-resident libraries' device bytes, fed
        through :meth:`Library.slot_budget`.  (The library alone cannot
        see its neighbours, so a multi-context worker must derate.)"""
        lib = self.libraries.get(recipe_key)
        if lib is None:
            return 0
        own = {e.key for e in lib.recipe.elements}
        others = sum(
            e.nbytes(Tier.DEVICE)
            for other in self.libraries.values()
            if other is not lib
            for e in other.recipe.elements
            if e.key not in own
            and self.cache.tier_of(e.key) is Tier.DEVICE)
        return lib.slot_budget(self.device_bytes - others, active_params)

    def stream_slots_free(self, recipe_key: str,
                          active_params: float) -> int:
        """Free dynamic-batch slots for an OPEN stream of ``recipe_key``
        on this worker (0 when no stream batch is live here)."""
        if recipe_key not in self.open_streams:
            return 0
        lib = self.libraries.get(recipe_key)
        if lib is None:
            return 0
        budget = self.slot_budget(recipe_key, active_params)
        return max(0, budget - len(lib.batch))

    def _fits(self, recipes: List[ContextRecipe]) -> bool:
        """Would ``recipes`` fit fully resident together on this worker?
        Elements are deduplicated by content key (shared deps count once)."""
        elems = {e.key: e for r in recipes for e in r.elements}
        return all(resident_footprint(elems.values(), tier)
                   <= self.cache.capacity[tier] for tier in Tier)

    def _immovable(self, but: Optional[str] = None) -> List[ContextRecipe]:
        """Recipes that cannot be spilled: those with tasks in flight."""
        return [self.libraries[k].recipe
                for k, n in self.running_by_recipe.items()
                if n > 0 and k != but and k in self.libraries]

    def can_host(self, recipe: ContextRecipe) -> bool:
        """True if ``recipe`` could be made fully resident here, spilling
        every idle library if needed (running ones are immovable)."""
        return self._fits([recipe] + self._immovable(but=recipe.key))

    def could_host(self, recipe: ContextRecipe) -> bool:
        """Capacity-only host check: would ``recipe`` fit once current
        work drains (every resident library is then spillable)?  Used by
        the anti-starvation reservation — a worker that is never idle
        because its stream batch keeps admitting must still be
        reservable for an aged head it could eventually serve."""
        return self._fits([recipe])

    def spill_preview(self, recipe: ContextRecipe) -> List[str]:
        """Non-mutating preview of :meth:`make_room`: the recipe keys that
        would spill to make ``recipe`` fully resident here.  The context
        plane compiles these into advisory SPILL ops; execution still
        calls :meth:`make_room` (authoritative)."""
        spilled: List[str] = []
        while True:
            keep = [lib.recipe for k, lib in self.libraries.items()
                    if lib.ready and k != recipe.key and k not in spilled]
            if self._fits([recipe] + keep):
                return spilled
            victims = [k for k, lib in self.libraries.items()
                       if lib.ready and k != recipe.key
                       and k not in spilled
                       and self.running_by_recipe.get(k, 0) == 0]
            if not victims:
                return spilled
            spilled.append(min(victims,
                               key=lambda k: self._last_used.get(k, -1)))

    def make_room(self, recipe: ContextRecipe) -> List[str]:
        """Spill idle resident libraries (LRU first) until ``recipe`` fits
        alongside what must stay.  Returns the spilled recipe keys, which
        the caller (scheduler) reflects into the context registry."""
        spilled: List[str] = []
        while True:
            keep = [lib.recipe for k, lib in self.libraries.items()
                    if lib.ready and k != recipe.key]
            if self._fits([recipe] + keep):
                return spilled
            victims = [k for k, lib in self.libraries.items()
                       if lib.ready and k != recipe.key
                       and self.running_by_recipe.get(k, 0) == 0]
            if not victims:
                return spilled              # cannot fit; caller gated on
            v = min(victims,                # can_host, so shouldn't happen
                    key=lambda k: self._last_used.get(k, -1))
            self.libraries[v].spill()
            spilled.append(v)

    # -- context hosting ----------------------------------------------------
    def touch(self, recipe_key: str) -> None:
        self._last_used[recipe_key] = next(self._use_seq)

    def library_for(self, recipe) -> Library:
        lib = self.libraries.get(recipe.key)
        if lib is None:
            lib = Library(recipe, self.cache)
            self.libraries[recipe.key] = lib
        self.touch(recipe.key)
        return lib

    def has_ready(self, recipe_key: str) -> bool:
        lib = self.libraries.get(recipe_key)
        return bool(lib and lib.ready)

    def has_local(self, recipe: ContextRecipe) -> bool:
        """All elements present in the local cache (any tier) — a cold
        start here pays promotion but no network fetch."""
        return all(self.cache.tier_of(e.key) is not None
                   for e in recipe.elements)
