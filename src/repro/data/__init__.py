"""Data substrate: synthetic claims, tokenizer, prompt zoo, loaders."""
from .claims import Claim, LABELS, generate_claims, label_id
from .loader import TokenStream, claim_batches
from .prompts import TEMPLATES, PromptTemplate, accuracy, parse_verdict
from .tokenizer import BOS, EOS, PAD, SEP, ByteTokenizer

__all__ = ["BOS", "ByteTokenizer", "Claim", "EOS", "LABELS", "PAD",
           "PromptTemplate", "SEP", "TEMPLATES", "TokenStream", "accuracy",
           "claim_batches", "generate_claims", "label_id", "parse_verdict"]
