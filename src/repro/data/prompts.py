"""Prompt-template zoo for the Prompt-for-Fact application (paper §6.1).

PfF searches over (LLM, prompt template) pairs; each template renders a
claim (+ evidence) into model input and parses the generation back into a
FEVER label.  The rendered template string is part of the *context inputs*
element of the recipe — identical across a sweep, so it is staged once.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict

from .claims import Claim, LABELS


@dataclass(frozen=True)
class PromptTemplate:
    name: str
    render: Callable[[Claim], str]


def _zero_shot(c: Claim) -> str:
    return (f"verify the claim {c.text} . answer supported refuted or "
            f"not enough info . answer")


def _with_evidence(c: Claim) -> str:
    return (f"evidence {c.evidence} . claim {c.text} . is the claim "
            f"supported refuted or not enough info . answer")


def _few_shot(c: Claim) -> str:
    shots = ("claim the capital of France is Paris . answer supported . "
             "claim the capital of Japan is Oslo . answer refuted . ")
    return shots + f"claim {c.text} . answer"


def _cot(c: Claim) -> str:
    return (f"claim {c.text} . evidence {c.evidence} . think step by step "
            f"then answer supported refuted or not enough info . answer")


TEMPLATES: Dict[str, PromptTemplate] = {
    t.name: t for t in [
        PromptTemplate("zero_shot", _zero_shot),
        PromptTemplate("with_evidence", _with_evidence),
        PromptTemplate("few_shot", _few_shot),
        PromptTemplate("cot", _cot),
    ]
}


def parse_verdict(generated: str) -> str:
    """Map free-form generation to a FEVER label (first match wins)."""
    g = generated.lower()
    first, best = len(g) + 1, "NOT ENOUGH INFO"
    for label, needles in [("SUPPORTED", ("supported", "true")),
                           ("REFUTED", ("refuted", "false")),
                           ("NOT ENOUGH INFO", ("not enough", "unknown"))]:
        for n in needles:
            i = g.find(n)
            if 0 <= i < first:
                first, best = i, label
    return best


def accuracy(predictions, claims) -> float:
    ok = sum(p == c.label for p, c in zip(predictions, claims))
    return ok / max(len(claims), 1)
