"""Byte-fallback tokenizer: deterministic, reversible, vocab-size aware.

Real enough for the live executor (round-trips arbitrary UTF-8) without
shipping a trained BPE: frequent ASCII words get single ids from a fixed
wordlist ("merges"), everything else falls back to byte ids.  All ids are
stable across processes — a property the context-management layer relies on
(the tokenizer is part of the *context inputs* element).
"""
from __future__ import annotations

from typing import Iterable, List

import numpy as np

PAD, BOS, EOS, SEP = 0, 1, 2, 3
_N_SPECIAL = 8          # room for future specials

# a small "merge table" of frequent words in the PfF prompt distribution
_WORDS = [
    "the", "a", "is", "was", "of", "in", "to", "and", "claim", "true",
    "false", "evidence", "supported", "refuted", "not", "enough", "info",
    "verify", "fact", "statement", "answer", "label", "wikipedia", "born",
    "year", "city", "country", "film", "directed", "by", "released",
    "population", "capital", "author", "wrote", "album", "band", "played",
]


class ByteTokenizer:
    """ids: [0..7] specials | [8..8+W) words | [8+W..8+W+256) bytes."""

    def __init__(self, vocab_size: int = 512):
        need = _N_SPECIAL + len(_WORDS) + 256
        if vocab_size < need:
            # shrink the word table to fit tiny vocab configs
            n_words = max(0, vocab_size - _N_SPECIAL - 256)
            if n_words < 0 or vocab_size < _N_SPECIAL + 256:
                raise ValueError(f"vocab_size {vocab_size} < {_N_SPECIAL+256}")
            self.words = _WORDS[:n_words]
        else:
            self.words = list(_WORDS)
        self.vocab_size = vocab_size
        self._word_to_id = {w: _N_SPECIAL + i for i, w in enumerate(self.words)}
        self._byte_base = _N_SPECIAL + len(self.words)

    # -- encode ------------------------------------------------------------
    def encode(self, text: str, *, bos: bool = True,
               eos: bool = False) -> List[int]:
        ids: List[int] = [BOS] if bos else []
        for tok in text.split(" "):
            wid = self._word_to_id.get(tok)     # exact match: reversible
            if wid is not None:
                ids.append(wid)
            else:
                ids.extend(self._byte_base + b for b in tok.encode("utf-8"))
            ids.append(self._byte_base + ord(" "))
        if text:
            ids.pop()                   # trailing space
        if eos:
            ids.append(EOS)
        return ids

    def encode_batch(self, texts: Iterable[str], seq_len: int,
                     *, pad_id: int = PAD) -> np.ndarray:
        rows = []
        for t in texts:
            ids = self.encode(t)[:seq_len]
            rows.append(ids + [pad_id] * (seq_len - len(ids)))
        return np.asarray(rows, dtype=np.int32)

    # -- decode ------------------------------------------------------------
    def decode(self, ids: Iterable[int]) -> str:
        out: List[bytes] = []
        for i in ids:
            i = int(i)
            if i < _N_SPECIAL:
                continue
            if i < self._byte_base:
                out.append((" " + self.words[i - _N_SPECIAL] + " ").encode())
            elif i < self._byte_base + 256:
                out.append(bytes([i - self._byte_base]))
        txt = b"".join(out).decode("utf-8", errors="replace")
        return " ".join(txt.split())
