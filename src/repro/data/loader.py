"""Data pipeline: deterministic synthetic token streams + claim batching.

Training uses an infinite packed-sequence stream (synthetic text rendered
from the claims db and tokenized), so the end-to-end train example runs
without external datasets.  Inference uses claim batches for the PfF app.
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Optional

import numpy as np

from .claims import Claim, generate_claims
from .prompts import TEMPLATES
from .tokenizer import ByteTokenizer, PAD


class TokenStream:
    """Infinite (batch, seq) int32 stream of packed tokenized claims."""

    def __init__(self, tokenizer: ByteTokenizer, *, batch: int,
                 seq_len: int, seed: int = 0, n_claims: int = 4096):
        self.tok = tokenizer
        self.batch, self.seq_len = batch, seq_len
        claims = generate_claims(n_claims, seed=seed)
        tmpl = TEMPLATES["with_evidence"]
        ids: List[int] = []
        for c in claims:
            ids.extend(self.tok.encode(tmpl.render(c) + " " + c.label.lower(),
                                       eos=True))
        self._ids = np.asarray(ids, dtype=np.int32)
        self._rng = np.random.default_rng(seed)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        n = self.batch * self.seq_len
        starts = self._rng.integers(0, len(self._ids) - self.seq_len - 1,
                                    size=self.batch)
        tok = np.stack([self._ids[s:s + self.seq_len] for s in starts])
        return {"tokens": tok.astype(np.int32)}


def claim_batches(claims: List[Claim], batch: int) -> List[List[Claim]]:
    return [claims[i:i + batch] for i in range(0, len(claims), batch)]
