"""Synthetic FEVER-like fact-verification dataset (paper §6.1).

FEVER itself is not available offline, so we generate a verifiable
analogue: a deterministic "wikipedia" of entity facts, plus claims that
either restate a fact (SUPPORTED), contradict it (REFUTED), or reference
an entity absent from the db (NOT ENOUGH INFO).  Like the paper we add a
small control group of empty claims.  Every claim carries its resolved
evidence text, mirroring the paper's pre-joined local database.
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

LABELS = ("SUPPORTED", "REFUTED", "NOT ENOUGH INFO")

_CITIES = ["Paris", "Tokyo", "Lagos", "Lima", "Oslo", "Cairo", "Quito",
           "Hanoi", "Accra", "Sofia", "Turin", "Kyoto", "Davao", "Bergen"]
_COUNTRIES = ["France", "Japan", "Nigeria", "Peru", "Norway", "Egypt",
              "Ecuador", "Vietnam", "Ghana", "Bulgaria", "Italy"]
_NAMES = ["Ada Obi", "Kenji Sato", "Maria Silva", "Lars Berg", "Nadia Riad",
          "Pablo Cruz", "Linh Tran", "Kofi Mensah", "Elena Petrova",
          "Luca Romano", "Aya Tanaka", "Rosa Flores"]


@dataclass(frozen=True)
class Claim:
    claim_id: int
    text: str
    evidence: str
    label: str


@dataclass(frozen=True)
class Fact:
    entity: str
    relation: str
    value: str

    def sentence(self) -> str:
        if self.relation == "capital":
            return f"{self.value} is the capital of {self.entity}"
        if self.relation == "born":
            return f"{self.entity} was born in {self.value}"
        if self.relation == "population":
            return f"the population of {self.entity} is {self.value}"
        return f"{self.entity} {self.relation} {self.value}"


def _facts_db(seed: int) -> List[Fact]:
    rng = random.Random(seed)
    facts: List[Fact] = []
    for c in _COUNTRIES:
        facts.append(Fact(c, "capital", rng.choice(_CITIES)))
        facts.append(Fact(c, "population", str(rng.randint(1, 200)) + " million"))
    for n in _NAMES:
        facts.append(Fact(n, "born", str(rng.randint(1900, 2005))))
    return facts


def generate_claims(n: int, *, seed: int = 0,
                    empty_fraction: float = 0.003) -> List[Claim]:
    """Deterministic claim set with ~uniform label mix + empty controls."""
    rng = random.Random(seed)
    facts = _facts_db(seed)
    out: List[Claim] = []
    for i in range(n):
        if rng.random() < empty_fraction:
            out.append(Claim(i, "", "", "NOT ENOUGH INFO"))
            continue
        f = rng.choice(facts)
        roll = rng.random()
        if roll < 1 / 3:
            out.append(Claim(i, f.sentence(), f.sentence(), "SUPPORTED"))
        elif roll < 2 / 3:
            wrong = _corrupt(f, rng)
            out.append(Claim(i, wrong.sentence(), f.sentence(), "REFUTED"))
        else:
            ghost = Fact("the lost city of " + rng.choice(_CITIES) + "-" +
                         str(rng.randint(2, 99)), f.relation,
                         f.value)
            out.append(Claim(i, ghost.sentence(), "", "NOT ENOUGH INFO"))
    return out


def _corrupt(f: Fact, rng: random.Random) -> Fact:
    if f.relation == "capital":
        alt = rng.choice([c for c in _CITIES if c != f.value])
        return Fact(f.entity, f.relation, alt)
    if f.relation == "born":
        return Fact(f.entity, f.relation, str(int(f.value) + rng.randint(1, 50)))
    return Fact(f.entity, f.relation, f.value + " thousand")


def label_id(label: str) -> int:
    return LABELS.index(label)
