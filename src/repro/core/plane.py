"""The context plane: declarative placement intents, priced and budgeted.

The paper's thesis is that *pervasive context management* — not
scheduling alone — makes opportunistic resources usable.  Before this
module, the context operations (staging, peer transfer, spill,
re-promotion, replication) were scattered across the scheduler, the two
executors and the factory, each mutating :class:`ContextRegistry` ad hoc
and none accounting for the cross-zone bytes it generated.  Aladdin
(arXiv 2405.06856) argues placement and scaling must share one cost
model; SageServe (arXiv 2502.14617) argues proactive scaling needs
arrival-rate signals.  Both land here:

* callers express **intents** — :class:`Acquire` (make a recipe READY on
  a specific worker), :class:`Replicate` (hold ``n`` warm copies),
  :class:`Release` (give a residency back) — instead of hand-rolling
  registry transitions;
* the plane **compiles** intents against a read-only :class:`ClusterView`
  snapshot into a typed :class:`PlacementPlan` of ops (``FETCH``,
  ``PEER_COPY``, ``PROMOTE``, ``SPILL``, ``EVICT``), each priced in
  bytes over the link classes :mod:`repro.core.transfer` distinguishes
  (in-zone NIC vs cross-zone DCN vs shared filesystem);
* a :class:`LinkBudget` meters per-zone in/out bytes over a sliding
  window; proactive ``Replicate`` ops that would blow a zone's window
  are **deferred** (recorded on the plan, re-emitted by the policy next
  round) — never silently dropped — so hot-recipe replication can no
  longer saturate the cross-zone links the spanning-tree transfers use.
  Demand-critical ``Acquire`` ops are charged to the meters but always
  admitted: a queued request must not starve behind a byte budget;
* the plane is the ONLY module that writes the registry (grep-enforced
  by tests/test_context_plane.py): executors feed op lifecycle events
  back through :meth:`op_started` / :meth:`op_completed` /
  :meth:`op_aborted`, and worker loss flows through :meth:`drop_worker`,
  which turns residencies into LOST tombstones and emits re-replication
  intents via :meth:`recovery_intents`.

Both executors run the SAME plan ops; only the source of time differs
(see ``_PlanOpExecution`` in :mod:`repro.cluster.executors`).
"""
from __future__ import annotations

from collections import defaultdict, deque
from dataclasses import dataclass, field
from enum import Enum
from typing import (Any, Deque, Dict, Iterable, List, Mapping, Optional,
                    Sequence, Set, Tuple, Union)

from .registry import ContextRegistry, HostState
from .transfer import Peer, pick_sources


# ---------------------------------------------------------------------------
# Intents — what callers declare
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Acquire:
    """Make ``recipe_key`` READY on ``worker_id`` (demand-critical: a
    request was routed there).  Never deferred by the budget."""
    recipe_key: str
    worker_id: str


@dataclass(frozen=True)
class Replicate:
    """Hold ``n`` warm (READY or staging) copies of ``recipe_key``
    somewhere suitable.  Proactive: the budget may defer part of it."""
    recipe_key: str
    n: int


@dataclass(frozen=True)
class Release:
    """Give back ``worker_id``'s residency of ``recipe_key``: spill a
    READY copy to local disk, or drop a SPILLED record entirely."""
    recipe_key: str
    worker_id: str


Intent = Union[Acquire, Replicate, Release]


# ---------------------------------------------------------------------------
# Plans — what the compiler emits
# ---------------------------------------------------------------------------

class OpKind(str, Enum):
    FETCH = "fetch"            # shared filesystem -> worker local disk
    PEER_COPY = "peer_copy"    # ready peer -> worker local disk
    PROMOTE = "promote"        # local disk -> host/device (no network)
    SPILL = "spill"            # co-resident library demoted to local disk
    EVICT = "evict"            # residency record dropped (spilled copy)
    KV_SHIP = "kv_ship"        # prefill KV snapshot -> decode worker
    KV_CKPT = "kv_ckpt"        # periodic KV snapshot -> other-zone host


ACQUIRE_KINDS = (OpKind.FETCH, OpKind.PEER_COPY, OpKind.PROMOTE)

# op kinds that move bytes over the peer links (NIC in-zone, DCN cross-
# zone) and therefore ride the zone meters and the LinkBudget window.
# KV_SHIP is the disaggregation handoff and KV_CKPT the crash-safety
# checkpoint: unlike PEER_COPY they move REQUEST state (a KV snapshot),
# not a recipe residency, so they never touch the registry — but their
# bytes are priced and admission-checked exactly like replication
# traffic.
PEER_LINK_KINDS = (OpKind.PEER_COPY, OpKind.KV_SHIP, OpKind.KV_CKPT)


@dataclass
class PlanOp:
    """One placement operation, priced in network bytes."""
    kind: OpKind
    recipe_key: str
    worker_id: str
    nbytes: int = 0                    # network bytes this op moves
    src_worker: Optional[str] = None   # PEER_COPY only
    src_zone: Optional[str] = None
    dst_zone: str = "z0"

    @property
    def cross_zone(self) -> bool:
        return self.src_zone is not None and self.src_zone != self.dst_zone


# deferral reasons: only budget-window deferrals are worth retrying on a
# timer — the window's charges expire, so headroom WILL return; a missing
# worker needs a pool change, which re-pumps the dispatch loop anyway
DEFER_BUDGET = "zone link budget window exhausted"
DEFER_NO_WORKER = "no eligible worker"


@dataclass(frozen=True)
class DeferredIntent:
    intent: Intent
    reason: str
    short: int = 1                     # replicas trimmed off the intent

    @property
    def retriable(self) -> bool:
        return self.reason == DEFER_BUDGET


@dataclass
class PlacementPlan:
    """Typed op list plus the intents the budget deferred."""
    ops: List[PlanOp] = field(default_factory=list)
    deferred: List[DeferredIntent] = field(default_factory=list)

    def acquire_op(self) -> Optional[PlanOp]:
        """The (single) network/promotion op of an Acquire compilation."""
        for op in self.ops:
            if op.kind in ACQUIRE_KINDS:
                return op
        return None

    def acquire_ops(self) -> List[PlanOp]:
        return [op for op in self.ops if op.kind in ACQUIRE_KINDS]

    @property
    def planned_bytes(self) -> int:
        return sum(op.nbytes for op in self.ops)


# ---------------------------------------------------------------------------
# The cost model: per-zone byte meters + windowed budget
# ---------------------------------------------------------------------------

# meter fields per zone; "local"/"cross" are the peer link classes
# transfer.py distinguishes, "fs" is the shared-filesystem ingress path
METER_FIELDS = ("in_local", "out_local", "in_cross", "out_cross", "in_fs")


class ZoneMeters:
    """Cumulative per-zone byte counters by direction and link class."""

    def __init__(self):
        self.data: Dict[str, Dict[str, int]] = {}

    def add(self, zone: str, fld: str, nbytes: int) -> None:
        z = self.data.setdefault(zone, {f: 0 for f in METER_FIELDS})
        z[fld] += nbytes

    def get(self, zone: str, fld: str) -> int:
        return self.data.get(zone, {}).get(fld, 0)

    def total(self, fld: Optional[str] = None) -> int:
        flds = METER_FIELDS if fld is None else (fld,)
        return sum(z[f] for z in self.data.values() for f in flds)

    def as_dict(self) -> Dict[str, Dict[str, int]]:
        """All-zero rows are pruned: a zone whose only op was committed
        and then refunded (an aborted KV ship) nets to nothing and must
        compare equal to a meter that never saw the zone at all."""
        return {zone: dict(flds) for zone, flds in sorted(self.data.items())
                if any(flds.values())}

    def charge_op(self, op: PlanOp, sign: int = 1) -> None:
        n = sign * op.nbytes
        if op.nbytes <= 0 or op.kind not in (OpKind.FETCH, *PEER_LINK_KINDS):
            return
        if op.kind is OpKind.FETCH:
            self.add(op.dst_zone, "in_fs", n)
        elif op.cross_zone:
            self.add(op.src_zone, "out_cross", n)
            self.add(op.dst_zone, "in_cross", n)
        else:
            self.add(op.src_zone, "out_local", n)
            self.add(op.dst_zone, "in_local", n)


class LinkBudget:
    """Sliding-window per-zone byte budget over the peer link classes.

    ``cross_bytes_per_window`` / ``local_bytes_per_window`` cap the bytes
    a zone may send OR receive over the respective link class inside any
    ``window_s`` window; ``None`` means unbounded (the default — budgets
    are opt-in, and an unbudgeted plane prices but never defers).  Charges
    expire as the window slides, so deferred replication is retried — not
    dropped — once the link drains.
    """

    def __init__(self, *, cross_bytes_per_window: Optional[float] = None,
                 local_bytes_per_window: Optional[float] = None,
                 window_s: float = 60.0):
        self.cross_bytes_per_window = cross_bytes_per_window
        self.local_bytes_per_window = local_bytes_per_window
        self.window_s = window_s
        # (zone, cls) -> deque[(t, nbytes)]
        self._charges: Dict[Tuple[str, str], Deque[Tuple[float, int]]] = \
            defaultdict(deque)

    @property
    def bounded(self) -> bool:
        return (self.cross_bytes_per_window is not None
                or self.local_bytes_per_window is not None)

    def _cap(self, cls: str) -> Optional[float]:
        return (self.cross_bytes_per_window if cls == "cross"
                else self.local_bytes_per_window)

    def charged(self, zone: str, cls: str, now: float) -> int:
        q = self._charges[(zone, cls)]
        while q and q[0][0] <= now - self.window_s:
            q.popleft()
        return sum(n for _, n in q)

    def headroom(self, zone: str, cls: str, now: float) -> float:
        cap = self._cap(cls)
        if cap is None:
            return float("inf")
        return max(0.0, cap - self.charged(zone, cls, now))

    def _zones_of(self, op: PlanOp) -> Tuple[str, List[str]]:
        cls = "cross" if op.cross_zone else "local"
        zones = [op.dst_zone]
        if op.src_zone is not None and op.src_zone != op.dst_zone:
            zones.append(op.src_zone)
        return cls, zones

    def admits(self, op: PlanOp, now: float,
               pending: Optional[Dict[Tuple[str, str], int]] = None) -> bool:
        """Would ``op`` fit every involved zone's window right now?
        ``pending`` carries same-plan charges not yet committed."""
        if op.kind not in PEER_LINK_KINDS or op.nbytes <= 0:
            return True                 # FETCH rides the shared fs, not
        cls, zones = self._zones_of(op)  # the peer links; PROMOTE is local
        for z in zones:
            extra = (pending or {}).get((z, cls), 0)
            if self.headroom(z, cls, now) < op.nbytes + extra:
                return False
        return True

    def charge(self, op: PlanOp, now: float) -> None:
        if op.kind not in PEER_LINK_KINDS or op.nbytes <= 0:
            return
        cls, zones = self._zones_of(op)
        for z in zones:
            self._charges[(z, cls)].append((now, op.nbytes))

    def refund(self, op: PlanOp, now: float) -> None:
        """Remove the most recent matching charge (op aborted)."""
        if op.kind not in PEER_LINK_KINDS or op.nbytes <= 0:
            return
        cls, zones = self._zones_of(op)
        for z in zones:
            q = self._charges[(z, cls)]
            for i in range(len(q) - 1, -1, -1):
                if q[i][1] == op.nbytes:
                    del q[i]
                    break


# ---------------------------------------------------------------------------
# ClusterView — the read-only snapshot intents compile against
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ClusterView:
    """Read-only view of the pool for intent compilation.

    Holds live references (workers, registry) but the contract is strict:
    compilation MUST NOT mutate anything reachable from a view.  Policies
    (:class:`~repro.core.policies.WarmPoolPolicy`, eviction priority) are
    pure functions of a view, which is what makes them unit-testable
    without a scheduler.
    """
    workers: Mapping[str, Any]                 # worker_id -> Worker-like
    registry: ContextRegistry
    demand: Mapping[str, int] = field(default_factory=dict)
    arrival_rate: Mapping[str, float] = field(default_factory=dict)
    # per-recipe preemption-rate EWMA (events/s): spill storms signal
    # slot-pool pressure the arrival rate cannot see — the warm-pool
    # policy converts it into extra replicas (WarmPoolPolicy.preempt_horizon_s)
    preempt_rate: Mapping[str, float] = field(default_factory=dict)
    # per-recipe FORECAST arrival rate (req/s): the DemandForecaster's
    # trend + burst view of where arrival_rate is heading — what the
    # elastic factory and WarmPoolPolicy.forecast_horizon_s act on
    forecast_rate: Mapping[str, float] = field(default_factory=dict)
    # per-recipe work units still owed (queued + running, minus steps
    # already done) — the backlog term of the elastic capacity model
    backlog_units: Mapping[str, float] = field(default_factory=dict)
    # per-recipe observed mean (prompt_units, decode_steps) per request:
    # converts a request rate into per-phase unit rates
    request_units: Mapping[str, Tuple[float, float]] = \
        field(default_factory=dict)
    now: float = 0.0

    @property
    def n_workers(self) -> int:
        return len(self.workers)

    def idle_workers(self) -> List[Any]:
        return [w for w in self.workers.values() if w.idle]

    def missing_bytes(self, worker, recipe) -> int:
        """Network bytes an Acquire of ``recipe`` on ``worker`` moves."""
        return worker.cache.missing_fetch_bytes(recipe.elements)


# ---------------------------------------------------------------------------
# The plane
# ---------------------------------------------------------------------------

class ContextPlane:
    """Compiles intents into priced plans and owns every registry write.

    Lifecycle of a network op::

        compile() -> commit(plan) -> op_started -> op_completed
                         |                |-> op_aborted (worker lost)
                         |-> budget + planned meters charged
        drop_worker() refunds a worker's in-flight ops and tombstones
        its residencies; recovery_intents() turns fresh tombstones into
        Replicate intents.

    ``planned`` meters the bytes committed plans priced; ``moved`` meters
    the bytes executors reported actually moving.  For a drained system
    the two MUST agree per zone/class — the sim property test and the
    bench smoke job assert exactly that.
    """

    def __init__(self, registry: Optional[ContextRegistry] = None,
                 budget: Optional[LinkBudget] = None):
        self.registry = registry or ContextRegistry()
        self.budget = budget or LinkBudget()
        self.planned = ZoneMeters()
        self.moved = ZoneMeters()
        self.ops_committed = 0
        self.ops_completed = 0
        self.ops_aborted = 0
        self.deferred_intents = 0
        self._inflight: Dict[Tuple[str, str], PlanOp] = {}
        # request_id -> in-flight KV_SHIP op (disaggregation handoffs are
        # per-REQUEST, so they cannot share the residency-keyed table)
        self._inflight_ships: Dict[int, PlanOp] = {}
        # worker_id -> time its FIRST residency turned READY ("warm").
        # The owning scheduler installs its clock; acquire lead time
        # (factory decision -> warm) in pool_summary() reads this.
        self.clock: Any = lambda: 0.0
        self.first_ready_s: Dict[str, float] = {}
        self._tombstones: Dict[str, int] = {}     # recipe -> lost READY copies
        # preemption KV movement, priced per zone like everything else the
        # plane moves.  Spills are WORKER-LOCAL (device -> host, no peer
        # link), so they get their own meters rather than riding the zone
        # link meters the planned/moved parity invariant covers.
        self.kv_spilled: Dict[str, int] = {}      # zone -> bytes spilled
        self.kv_resumed: Dict[str, int] = {}      # zone -> bytes restored
        self.kv_spill_events = 0
        self.kv_resume_events = 0
        # disaggregation KV handoffs (prefill worker -> decode worker):
        # these DO cross the peer links, so they ride the planned/moved
        # zone meters and the LinkBudget window; the per-zone dict below
        # is the phase-attributable view kv_summary() reports.
        self.kv_shipped: Dict[str, int] = {}      # dst zone -> bytes shipped
        self.kv_ship_events = 0
        # crash-safety KV checkpoints (decode worker -> other-zone host):
        # request state over the peer links again, so they ride the
        # planned/moved meters and the budget window like KV_SHIP.  A
        # request can have a ship AND a checkpoint in flight at once, so
        # checkpoints get their own request-keyed table.
        self._inflight_ckpts: Dict[int, PlanOp] = {}
        self.kv_ckpt: Dict[str, int] = {}         # dst zone -> ckpt bytes
        self.kv_ckpt_events = 0
        # KV snapshots voided because their holder died before resume:
        # bytes the crash DESTROYED (vs moved), metered per holder zone.
        self.kv_lost: Dict[str, int] = {}         # holder zone -> bytes lost
        self.kv_lost_events = 0

    # -- registration ------------------------------------------------------
    def register(self, recipe) -> str:
        return self.registry.register(recipe)

    # -- compilation -------------------------------------------------------
    def compile(self, intents: Iterable[Intent],
                view: ClusterView) -> PlacementPlan:
        """Compile ``intents`` against ``view`` into a priced plan.

        Pure with respect to plane state: nothing is charged until
        :meth:`commit`.  Op order follows intent order; within one plan a
        worker is claimed at most once.
        """
        plan = PlacementPlan()
        taken: Set[str] = set()
        pending: Dict[Tuple[str, str], int] = defaultdict(int)
        placed: Dict[str, int] = defaultdict(int)   # per-key, this plan
        for intent in intents:
            if isinstance(intent, Acquire):
                self._compile_acquire(intent, view, plan, taken)
            elif isinstance(intent, Replicate):
                self._compile_replicate(intent, view, plan, taken, pending,
                                        placed)
            elif isinstance(intent, Release):
                self._compile_release(intent, plan)
            else:
                raise TypeError(f"unknown intent {intent!r}")
        return plan

    def _acquire_op_for(self, key: str, worker, view: ClusterView,
                        plan: PlacementPlan) -> PlanOp:
        """SPILL previews + the network/promotion op placing ``key``."""
        recipe = self.registry.recipes[key]
        for k in worker.spill_preview(recipe):
            plan.ops.append(PlanOp(OpKind.SPILL, k, worker.worker_id,
                                   dst_zone=worker.zone))
        if worker.has_local(recipe):
            return PlanOp(OpKind.PROMOTE, key, worker.worker_id,
                          dst_zone=worker.zone)
        nbytes = view.missing_bytes(worker, recipe)
        src = self._pick_source(key, worker, view)
        if src is None:
            return PlanOp(OpKind.FETCH, key, worker.worker_id,
                          nbytes=nbytes, dst_zone=worker.zone)
        return PlanOp(OpKind.PEER_COPY, key, worker.worker_id,
                      nbytes=nbytes, src_worker=src.worker_id,
                      src_zone=src.zone, dst_zone=worker.zone)

    def _pick_source(self, key: str, dst, view: ClusterView) -> Optional[Peer]:
        ready = self.registry.ready_workers(key) - {dst.worker_id}
        peers = [Peer(wid, view.workers[wid].zone) for wid in ready
                 if wid in view.workers]
        if not peers:
            return None
        return pick_sources(peers, dst.zone, max_sources=1)[0]

    def _compile_acquire(self, intent: Acquire, view: ClusterView,
                         plan: PlacementPlan, taken: Set[str]) -> None:
        w = view.workers[intent.worker_id]
        op = self._acquire_op_for(intent.recipe_key, w, view, plan)
        plan.ops.append(op)
        taken.add(intent.worker_id)

    def _compile_replicate(self, intent: Replicate, view: ClusterView,
                           plan: PlacementPlan, taken: Set[str],
                           pending: Dict[Tuple[str, str], int],
                           placed: Dict[str, int]) -> None:
        key, reg = intent.recipe_key, self.registry
        # compile() is pure w.r.t. the registry, so count the replicas
        # THIS plan already placed for the key (recovery and policy
        # intents for the same recipe must not each place a full set)
        have = len(reg.ready_workers(key) | reg.staging_workers(key)) \
            + placed[key]
        need = intent.n - have
        if need <= 0:
            return
        recipe = reg.recipes[key]
        spilled = reg.spilled_workers(key)
        cands = [w for w in view.idle_workers()
                 if w.worker_id not in taken
                 and (reg.state(key, w.worker_id) is None
                      or w.worker_id in spilled)
                 and w.can_host(recipe)]
        # spilled local copies first (promotion beats any fetch), then the
        # fastest device — the ordering the pre-plane WarmPoolPolicy used
        cands.sort(key=lambda w: (w.worker_id not in spilled,
                                  w.device.infer_s))
        n_placed = 0
        window_limited = False
        for w in cands:
            if n_placed >= need:
                break
            op = self._acquire_op_for(key, w, view, plan)
            if not self.budget.admits(op, view.now, pending):
                window_limited = True
                continue            # try the next candidate (may be local)
            plan.ops.append(op)
            if op.kind is OpKind.PEER_COPY and op.nbytes > 0:
                cls = "cross" if op.cross_zone else "local"
                pending[(op.dst_zone, cls)] += op.nbytes
                if op.src_zone is not None and op.src_zone != op.dst_zone:
                    pending[(op.src_zone, cls)] += op.nbytes
            taken.add(w.worker_id)
            n_placed += 1
            placed[key] += 1
        if n_placed < need:
            plan.deferred.append(DeferredIntent(
                intent, DEFER_BUDGET if window_limited
                else DEFER_NO_WORKER, short=need - n_placed))

    def _compile_release(self, intent: Release, plan: PlacementPlan) -> None:
        state = self.registry.state(intent.recipe_key, intent.worker_id)
        if state is HostState.READY:
            plan.ops.append(PlanOp(OpKind.SPILL, intent.recipe_key,
                                   intent.worker_id))
        elif state is HostState.SPILLED:
            plan.ops.append(PlanOp(OpKind.EVICT, intent.recipe_key,
                                   intent.worker_id))

    # -- commitment & execution feedback ----------------------------------
    def commit(self, plan: PlacementPlan, now: float = 0.0) -> None:
        """Charge the budget window and the planned meters for ``plan``.

        Every acquire op becomes in-flight from here: an op the executor
        abandons (worker evicted, pool moved under the plan) is refunded
        by :meth:`op_aborted` / :meth:`drop_worker`, keeping the
        planned/moved meters equal for drained systems.

        ``deferred_intents`` counts deferral EVENTS cumulatively: a
        replica that waits across N compile rounds counts N times (it is
        a pressure gauge, not a population count)."""
        self.deferred_intents += sum(d.short for d in plan.deferred)
        for op in plan.ops:
            if op.kind in ACQUIRE_KINDS:
                self.ops_committed += 1
                self.planned.charge_op(op)
                self.budget.charge(op, now)
                self._inflight[(op.recipe_key, op.worker_id)] = op

    def op_started(self, op: PlanOp) -> None:
        """Executor began staging ``op`` (worker-side room already made)."""
        self.registry.mark_staging(op.recipe_key, op.worker_id)
        self._inflight[(op.recipe_key, op.worker_id)] = op

    def op_completed(self, op: PlanOp,
                     moved_bytes: Optional[int] = None) -> None:
        """Staging finished: residency READY, moved meters charged.

        ``moved_bytes`` is the byte count the executor measured (the sim
        reports :attr:`StagingCost.fetch_bytes`); ``None`` means "as
        priced" (live mode, where loaders do not move plan bytes)."""
        self._inflight.pop((op.recipe_key, op.worker_id), None)
        self.registry.mark_ready(op.recipe_key, op.worker_id)
        self.first_ready_s.setdefault(op.worker_id, self.clock())
        measured = op.nbytes if moved_bytes is None else moved_bytes
        self.moved.charge_op(PlanOp(op.kind, op.recipe_key, op.worker_id,
                                    nbytes=measured,
                                    src_worker=op.src_worker,
                                    src_zone=op.src_zone,
                                    dst_zone=op.dst_zone))
        self.ops_completed += 1

    def op_aborted(self, op: PlanOp, now: float = 0.0) -> None:
        """Op abandoned before completion: refund budget and planned
        meters so plan/executed accounting stays equal.  Idempotent —
        :meth:`drop_worker` already refunds a lost worker's ops."""
        if self._inflight.pop((op.recipe_key, op.worker_id), None) is None:
            return
        self.planned.charge_op(op, sign=-1)
        self.budget.refund(op, now)
        self.ops_aborted += 1

    # -- direct transitions (non-op execution feedback) --------------------
    def note_staging(self, key: str, worker_id: str) -> None:
        """Residency entering STAGING outside a compiled op (prestage
        tree edges, mode-less staging)."""
        self.registry.mark_staging(key, worker_id)

    def note_ready(self, key: str, worker_id: str) -> None:
        self.registry.mark_ready(key, worker_id)
        self.first_ready_s.setdefault(worker_id, self.clock())

    def note_spilled(self, key: str, worker_id: str) -> None:
        self.registry.mark_spilled(key, worker_id)

    def note_released(self, key: str, worker_id: str) -> None:
        self.registry.forget(key, worker_id)

    def record_transfer(self, key: str, src_zone: str, dst_zone: str,
                        nbytes: int) -> None:
        """Meter a transfer executed outside compiled ops (the prestage
        spanning tree): charged to planned AND moved at arrival, so the
        equality invariant covers it trivially."""
        op = PlanOp(OpKind.PEER_COPY, key, "", nbytes=nbytes,
                    src_worker="", src_zone=src_zone, dst_zone=dst_zone)
        self.planned.charge_op(op)
        self.moved.charge_op(op)

    def record_kv_spill(self, key: str, zone: str, nbytes: int) -> None:
        """Meter a preemption KV spill (a batch victim's decode cache
        moving device -> host in ``zone``).  ``key`` is accepted for
        symmetry with :meth:`record_transfer`; spill pricing is per zone."""
        self.kv_spilled[zone] = self.kv_spilled.get(zone, 0) + int(nbytes)
        self.kv_spill_events += 1

    def record_kv_resume(self, key: str, zone: str, nbytes: int) -> None:
        """Meter a suspended request's KV snapshot moving host -> device
        on resume (the re-prefill it replaced cost zero bytes)."""
        self.kv_resumed[zone] = self.kv_resumed.get(zone, 0) + int(nbytes)
        self.kv_resume_events += 1

    # -- disaggregation: KV_SHIP lifecycle ---------------------------------
    def kv_ship_op(self, key: str, src_worker: str, dst_worker: str,
                   nbytes: int, *, src_zone: str, dst_zone: str) -> PlanOp:
        """Price one prefill->decode KV handoff as a plan op.  Pure: the
        router uses the op (plus :meth:`ship_admits`) to DECIDE ship vs
        local; nothing is charged until :meth:`commit_kv_ship`."""
        return PlanOp(OpKind.KV_SHIP, key, dst_worker, nbytes=int(nbytes),
                      src_worker=src_worker, src_zone=src_zone,
                      dst_zone=dst_zone)

    def ship_admits(self, op: PlanOp, now: float) -> bool:
        """Would this ship fit the involved zones' budget windows?  Used
        by the ship-vs-local decision: a ship the window cannot absorb is
        DEFERRED to the local fast path, never dropped — unless decoding
        locally is impossible, in which case the ship is demand-critical
        and committed anyway (charged like a demand Acquire)."""
        return self.budget.admits(op, now)

    def commit_kv_ship(self, request_id: int, op: PlanOp,
                       now: float = 0.0) -> None:
        """Charge budget + planned meters for one KV handoff and register
        it in flight.  Ships never touch the registry: the recipe is
        already resident on both ends — only request state moves."""
        assert op.kind is OpKind.KV_SHIP
        assert request_id not in self._inflight_ships, \
            f"request {request_id} already has a KV ship in flight"
        self.ops_committed += 1
        self.planned.charge_op(op)
        self.budget.charge(op, now)
        self._inflight_ships[request_id] = op

    def kv_ship_completed(self, request_id: int,
                          moved_bytes: Optional[int] = None) -> None:
        """The snapshot landed on the decode worker: charge moved meters
        (measured bytes win over priced) and the phase-attributable
        kv_shipped view.  Stale-safe: a completion event firing after an
        eviction already aborted the ship is a no-op."""
        op = self._inflight_ships.pop(request_id, None)
        if op is None:
            return
        measured = op.nbytes if moved_bytes is None else int(moved_bytes)
        self.moved.charge_op(PlanOp(op.kind, op.recipe_key, op.worker_id,
                                    nbytes=measured,
                                    src_worker=op.src_worker,
                                    src_zone=op.src_zone,
                                    dst_zone=op.dst_zone))
        self.kv_shipped[op.dst_zone] = \
            self.kv_shipped.get(op.dst_zone, 0) + measured
        self.kv_ship_events += 1
        self.ops_completed += 1

    def kv_ship_aborted(self, request_id: int, now: float = 0.0) -> None:
        """Ship abandoned (an endpoint died): refund budget and planned
        meters so the parity invariant survives churn.  Idempotent."""
        op = self._inflight_ships.pop(request_id, None)
        if op is None:
            return
        self.planned.charge_op(op, sign=-1)
        self.budget.refund(op, now)
        self.ops_aborted += 1

    # -- crash safety: KV_CKPT lifecycle -----------------------------------
    def kv_ckpt_op(self, key: str, src_worker: str, dst_worker: str,
                   nbytes: int, *, src_zone: str, dst_zone: str) -> PlanOp:
        """Price one periodic KV checkpoint (decode worker -> a host in a
        different failure zone) as a plan op.  Pure, like
        :meth:`kv_ship_op`: nothing is charged until
        :meth:`commit_kv_ckpt`."""
        return PlanOp(OpKind.KV_CKPT, key, dst_worker, nbytes=int(nbytes),
                      src_worker=src_worker, src_zone=src_zone,
                      dst_zone=dst_zone)

    def ckpt_admits(self, op: PlanOp, now: float) -> bool:
        """Would this checkpoint fit the involved zones' budget windows?
        A checkpoint the window cannot absorb is DEFERRED to the next
        cadence boundary — never dropped, never jumping the queue ahead
        of demand traffic."""
        return self.budget.admits(op, now)

    def commit_kv_ckpt(self, request_id: int, op: PlanOp,
                       now: float = 0.0) -> None:
        """Charge budget + planned meters for one KV checkpoint and
        register it in flight.  Checkpoints never touch the registry:
        only request state moves."""
        assert op.kind is OpKind.KV_CKPT
        assert request_id not in self._inflight_ckpts, \
            f"request {request_id} already has a KV checkpoint in flight"
        self.ops_committed += 1
        self.planned.charge_op(op)
        self.budget.charge(op, now)
        self._inflight_ckpts[request_id] = op

    def kv_ckpt_completed(self, request_id: int,
                          moved_bytes: Optional[int] = None) -> None:
        """The snapshot landed on the checkpoint host: charge moved
        meters and the phase-attributable kv_ckpt view.  Stale-safe: a
        completion firing after an eviction already aborted the
        checkpoint is a no-op."""
        op = self._inflight_ckpts.pop(request_id, None)
        if op is None:
            return
        measured = op.nbytes if moved_bytes is None else int(moved_bytes)
        self.moved.charge_op(PlanOp(op.kind, op.recipe_key, op.worker_id,
                                    nbytes=measured,
                                    src_worker=op.src_worker,
                                    src_zone=op.src_zone,
                                    dst_zone=op.dst_zone))
        self.kv_ckpt[op.dst_zone] = \
            self.kv_ckpt.get(op.dst_zone, 0) + measured
        self.kv_ckpt_events += 1
        self.ops_completed += 1

    def kv_ckpt_aborted(self, request_id: int, now: float = 0.0) -> None:
        """Checkpoint abandoned (an endpoint died mid-transfer): refund
        budget and planned meters.  Idempotent."""
        op = self._inflight_ckpts.pop(request_id, None)
        if op is None:
            return
        self.planned.charge_op(op, sign=-1)
        self.budget.refund(op, now)
        self.ops_aborted += 1

    def record_kv_lost(self, key: str, zone: str, nbytes: int) -> None:
        """Meter a suspended request's KV snapshot voided because its
        holder died before resume (the bytes a crash destroyed — the
        decode that produced them must be repeated)."""
        self.kv_lost[zone] = self.kv_lost.get(zone, 0) + int(nbytes)
        self.kv_lost_events += 1

    def kv_summary(self) -> Dict[str, int]:
        """Preemption + disaggregation + crash-safety KV movement totals."""
        return {"spilled_bytes": sum(self.kv_spilled.values()),
                "resumed_bytes": sum(self.kv_resumed.values()),
                "spill_events": self.kv_spill_events,
                "resume_events": self.kv_resume_events,
                "shipped_bytes": sum(self.kv_shipped.values()),
                "ship_events": self.kv_ship_events,
                "ckpt_bytes": sum(self.kv_ckpt.values()),
                "ckpt_events": self.kv_ckpt_events,
                "lost_bytes": sum(self.kv_lost.values()),
                "lost_events": self.kv_lost_events}

    # -- worker loss & recovery -------------------------------------------
    def drop_worker(self, worker_id: str, now: float = 0.0) -> List[str]:
        """Worker evicted: refund its in-flight ops, tombstone its
        residencies (``HostState.LOST``), count lost READY copies for
        re-replication.  Returns the lost recipe keys.

        Only READY losses are actionable (a warm copy died); LOST records
        for STAGING/SPILLED residencies carry no recovery signal and are
        forgotten immediately so ``registry.hosts`` does not grow with
        every eviction under a churny availability trace."""
        for (key, wid), op in list(self._inflight.items()):
            if wid == worker_id:
                self.op_aborted(op, now)
        for rid, op in list(self._inflight_ships.items()):
            if worker_id in (op.worker_id, op.src_worker):
                self.kv_ship_aborted(rid, now)
        for rid, op in list(self._inflight_ckpts.items()):
            if worker_id in (op.worker_id, op.src_worker):
                self.kv_ckpt_aborted(rid, now)
        reg = self.registry
        was_ready = {key for key, hosts in reg.hosts.items()
                     if hosts.get(worker_id) is HostState.READY}
        lost = reg.drop_worker(worker_id)
        for key in lost:
            if key in was_ready:
                self._tombstones[key] = self._tombstones.get(key, 0) + 1
            else:
                reg.forget(key, worker_id)
        return lost

    def recovery_intents(self, view: ClusterView) -> List[Replicate]:
        """Consume tombstones: recipes that lost their last warm copy
        while demand exists get a ``Replicate(key, 1)`` intent.  Resolved
        tombstones (a copy exists again, or demand is gone) are forgotten
        along with their LOST registry records."""
        out: List[Replicate] = []
        reg = self.registry
        for key in list(self._tombstones):
            if reg.ready_workers(key) or reg.staging_workers(key) \
                    or view.demand.get(key, 0) <= 0:
                del self._tombstones[key]
                for wid in reg.lost_workers(key):
                    reg.forget(key, wid)
                continue
            out.append(Replicate(key, 1))
        return out

    @property
    def inflight_ops(self) -> int:
        return (len(self._inflight) + len(self._inflight_ships)
                + len(self._inflight_ckpts))

    # -- introspection -----------------------------------------------------
    def meters(self) -> Dict[str, Dict[str, Dict[str, int]]]:
        return {"planned": self.planned.as_dict(),
                "moved": self.moved.as_dict()}

    def stats(self) -> Dict[str, int]:
        return {"ops_committed": self.ops_committed,
                "ops_completed": self.ops_completed,
                "ops_aborted": self.ops_aborted,
                "deferred_intents": self.deferred_intents,
                "inflight_ops": self.inflight_ops}
