"""Computational context: recipes, keys, and materialised state.

The paper (§5.2) defines a *computational context* as the reusable state a
task needs before any useful work happens, with four elements: the
function's code, its software dependencies, the context code, and the
context inputs.  We model each element as a :class:`ContextElement` with a
content hash and a byte size, so the management layer (registry, transfer
planner, cache) can reason about identity and placement without caring what
the element *is*.

TPU adaptation (DESIGN.md §2): we add a fifth element the paper could not
have — the compiled XLA executable.  On TPUs, ``jit`` compilation of a
model step is O(10-100 s), the same order as weight staging, so the compile
cache participates in context management as a first-class element keyed by
(config, shapes, mesh).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Callable, Dict, Optional, Tuple


class Tier(str, Enum):
    """Where a materialised context element lives (paper: disk/memory/GPU)."""
    DISK = "disk"
    HOST = "host"
    DEVICE = "device"

    @property
    def order(self) -> int:
        return {"disk": 0, "host": 1, "device": 2}[self.value]


def content_hash(*parts: Any) -> str:
    """Stable content hash over json-serialisable parts."""
    h = hashlib.sha256()
    for p in parts:
        h.update(json.dumps(p, sort_keys=True, default=str).encode())
    return h.hexdigest()[:16]


@dataclass(frozen=True)
class ContextElement:
    """One element of a context recipe.

    ``loader`` (live mode only) materialises the element; in sim mode the
    byte sizes alone drive staging/transfer costs.
    """
    name: str                       # "deps" | "weights" | "code" | ...
    nbytes_disk: int                # size as staged on disk (packed)
    nbytes_host: int = 0            # resident host-memory size (0 = same)
    nbytes_device: int = 0          # accelerator bytes (0 = not device-resident)
    version: str = "0"
    loader: Optional[Callable[[], Any]] = field(
        default=None, compare=False, hash=False)

    @property
    def key(self) -> str:
        return content_hash(self.name, self.nbytes_disk, self.version)

    def nbytes(self, tier: Tier) -> int:
        if tier is Tier.DISK:
            return self.nbytes_disk
        if tier is Tier.HOST:
            return self.nbytes_host or self.nbytes_disk
        return self.nbytes_device

    @property
    def home(self) -> Tier:
        """Residency tier at which this element is fully materialised."""
        if self.nbytes_device:
            return Tier.DEVICE
        if self.nbytes_host or self.nbytes_disk:
            return Tier.HOST
        return Tier.DISK


def resident_footprint(elements, tier: Tier) -> int:
    """Bytes a set of (deduplicated) elements occupies at ``tier`` when each
    is fully resident at its home tier (an element resident at DEVICE keeps
    its HOST and DISK staging copies — same accounting as ContextCache)."""
    return sum(e.nbytes(tier) for e in elements
               if tier.order <= e.home.order)


# Decode-state (KV cache + activations) bytes one admitted request holds on
# the accelerator, as a fraction of the model's active-parameter count.  Used
# to derive a library's continuous-batching slot budget when the recipe does
# not pin an explicit ``slot_bytes``.
KV_BYTES_PER_PARAM = 0.25

# Live-measured per-slot decode-state bytes, keyed by recipe key: the live
# executor records the REAL cache footprint (jax.Array.nbytes over the slot
# pool's cache pytree / capacity) after the first admission prefill, and
# every ContextRecipe instance with the same key sees it — replacing the
# KV_BYTES_PER_PARAM analytic guess for slot budgets (ROADMAP: slot budgets
# from measured memory).
_MEASURED_SLOT_BYTES: Dict[str, int] = {}
# One library never grows its dynamic batch past this many slots, regardless
# of free device memory (straggler/jitter control, same spirit as vLLM's
# max_num_seqs).
MAX_BATCH_SLOTS = 32


@dataclass(frozen=True)
class ContextRecipe:
    """The full recipe for a function's context (paper §5.3.1).

    ``elements`` ordering is the staging order: software deps must land
    before weights can be deserialised, weights before the compiled step
    can run, etc.
    """
    fn_name: str
    elements: Tuple[ContextElement, ...]
    # static per-activation cost in seconds (fork-exec of the library
    # process, import time) paid once per worker even with a warm cache:
    activation_s: float = 0.0
    # device bytes ONE admitted request occupies while decoding (KV cache,
    # sampling state).  0 = derive from active params via KV_BYTES_PER_PARAM.
    slot_bytes: int = 0

    @property
    def key(self) -> str:
        k = self.__dict__.get("_key")      # memoised: hot in scheduler loops
        if k is None:
            k = content_hash(self.fn_name, [e.key for e in self.elements])
            object.__setattr__(self, "_key", k)
        return k

    def element(self, name: str) -> ContextElement:
        for e in self.elements:
            if e.name == name:
                return e
        raise KeyError(name)

    def nbytes(self, tier: Tier) -> int:
        return sum(e.nbytes(tier) for e in self.elements)

    @property
    def transfer_bytes(self) -> int:
        """Bytes that move over the network when peer-transferring."""
        return self.nbytes(Tier.DISK)

    def decode_slot_bytes(self, active_params: float) -> int:
        """Device bytes one in-flight request pins while decoding.

        Preference order: an explicit ``slot_bytes`` pin, then the
        live-measured per-slot footprint (``record_slot_bytes``), then the
        ``KV_BYTES_PER_PARAM`` analytic estimate.

        Under the PAGED KV layout this is a per-request PAGE BUDGET: the
        decoder measures ``max_pages * page_bytes`` — the worst case one
        request can pin with a fully private ring — so admission keeps
        its simple bytes-per-slot arithmetic.  Shared-prefix pages are
        refcounted and counted once, so actual residency is at most (and
        with any prefix reuse strictly below) slots × this figure; the
        slack is intentional headroom, never an over-commit."""
        if self.slot_bytes:
            return self.slot_bytes
        measured = _MEASURED_SLOT_BYTES.get(self.key)
        if measured:
            return measured
        return max(int(active_params * KV_BYTES_PER_PARAM), 1)

    def record_slot_bytes(self, nbytes: int) -> None:
        """Feed back a live-measured per-slot decode footprint (bytes).

        Latest measurement wins: the figure reflects the measuring pool's
        ring length (its ``max_len``) and layout (contiguous per-slot
        rings, or the paged worst-case ``max_pages * page_bytes``), so a
        decoder re-built with a longer ring simply re-records after its
        first admission."""
        if nbytes > 0:
            _MEASURED_SLOT_BYTES[self.key] = int(nbytes)

    @property
    def measured_slot_bytes(self) -> int:
        return _MEASURED_SLOT_BYTES.get(self.key, 0)

    def with_elements(self, *extra: ContextElement) -> "ContextRecipe":
        return dataclasses.replace(self, elements=self.elements + extra)


@dataclass
class MaterializedContext:
    """A recipe realised on a worker: per-element tier + live payloads."""
    recipe: ContextRecipe
    tiers: Dict[str, Tier] = field(default_factory=dict)
    payloads: Dict[str, Any] = field(default_factory=dict)   # live mode

    @property
    def key(self) -> str:
        return self.recipe.key

    def tier_of(self, name: str) -> Optional[Tier]:
        return self.tiers.get(name)

    @property
    def fully_resident(self) -> bool:
        """Every element at its home tier (device if it has device bytes)."""
        for e in self.recipe.elements:
            t = self.tiers.get(e.name)
            if t is None:
                return False
            home = Tier.DEVICE if e.nbytes_device else Tier.HOST
            if t.order < home.order:
                return False
        return True

    def nbytes(self, tier: Tier) -> int:
        """Bytes this context occupies *at* a tier on the worker."""
        total = 0
        for e in self.recipe.elements:
            t = self.tiers.get(e.name)
            if t is None:
                continue
            # an element resident at HOST also keeps its DISK copy (cache);
            # a DEVICE-resident element keeps HOST+DISK staging copies.
            if tier.order <= t.order:
                total += e.nbytes(tier)
        return total


# ---------------------------------------------------------------------------
# Recipe builders
# ---------------------------------------------------------------------------

def model_context_recipe(cfg, *, include_compile: bool = True,
                         shapes_key: str = "", mesh_key: str = "",
                         deps_bytes: int = 3_700_000_000,
                         activation_s: float = 2.0) -> ContextRecipe:
    """Recipe for an LLM inference context from a :class:`ModelConfig`.

    Mirrors the paper's measured artefacts for SmolLM2-1.7B: a 3.7 GB
    Poncho dependency package, 3.7 GB of weights on disk and ~7.4 GB of
    host memory when loaded (fp32 upcast), plus the device copy.
    """
    n_params = cfg.n_params()
    bytes_per_param = 2 if cfg.dtype == "bfloat16" else 4
    w_disk = n_params * bytes_per_param
    elements = [
        ContextElement("deps", nbytes_disk=deps_bytes,
                       nbytes_host=512_000_000,   # import footprint, not pkg
                       version="conda-308pkg"),
        ContextElement("code", nbytes_disk=65_536, version=cfg.arch_id),
        ContextElement("weights", nbytes_disk=w_disk,
                       nbytes_host=2 * w_disk,          # deserialise + cast
                       nbytes_device=w_disk,
                       version=cfg.arch_id),
        ContextElement("context_inputs", nbytes_disk=4_194_304,
                       version="prompt-template+db"),
    ]
    if include_compile:
        elements.append(ContextElement(
            "xla_executable", nbytes_disk=256_000_000,
            nbytes_device=64_000_000,
            version=content_hash(cfg.arch_id, shapes_key, mesh_key)))
    return ContextRecipe(fn_name=f"infer::{cfg.arch_id}",
                         elements=tuple(elements),
                         activation_s=activation_s)


def partial_context_recipe(cfg, **kw) -> ContextRecipe:
    """The paper's *partial context*: software deps + weights only (pv2/pv3).

    Context code/inputs and the compiled step are NOT registered, so every
    task re-runs model load + compile even on a warm worker.
    """
    full = model_context_recipe(cfg, include_compile=False, **kw)
    keep = tuple(e for e in full.elements if e.name in ("deps", "weights"))
    return dataclasses.replace(full, elements=keep,
                               fn_name=f"partial::{cfg.arch_id}")
