"""Pervasive context management — the paper's primary contribution.

Layers:
  context.py   recipes, keys, tiers, materialised state
  cache.py     per-worker tiered byte-accounted LRU
  library.py   per-context hosting process (materialise once, invoke many)
  registry.py  scheduler-side global residency view
  transfer.py  topology-aware spanning-tree peer distribution
  policies.py  worker sizing, context modes, batch-size selection
"""
from .context import (ContextElement, ContextRecipe, KV_BYTES_PER_PARAM,
                      MAX_BATCH_SLOTS, MaterializedContext, Tier,
                      content_hash, model_context_recipe,
                      partial_context_recipe, resident_footprint)
from .cache import CacheFullError, ContextCache
from .library import Library, StagingCost
from .registry import ContextRegistry, HostState
from .transfer import (Peer, TransferEdge, TransferPlan, pick_sources,
                       plan_spanning_tree)
from .policies import (AGING_BOUND_DEFAULT, MODES, NAIVE, PARTIAL, PERVASIVE,
                       PAPER_TASK_SHAPE, PAPER_WORKER_SHAPE, ContextMode,
                       WarmPoolPolicy, WorkerShape, derive_aging_bound,
                       eviction_loss, expected_task_time, optimal_batch_size,
                       worker_sizing)

__all__ = [
    "AGING_BOUND_DEFAULT", "CacheFullError", "ContextCache",
    "ContextElement", "ContextMode", "ContextRecipe", "ContextRegistry",
    "HostState", "KV_BYTES_PER_PARAM", "Library", "MAX_BATCH_SLOTS",
    "MaterializedContext", "MODES", "NAIVE", "PARTIAL", "PERVASIVE",
    "PAPER_TASK_SHAPE", "PAPER_WORKER_SHAPE", "Peer", "StagingCost", "Tier",
    "TransferEdge", "TransferPlan", "WarmPoolPolicy", "WorkerShape",
    "content_hash", "derive_aging_bound", "eviction_loss",
    "expected_task_time", "model_context_recipe", "optimal_batch_size",
    "partial_context_recipe", "pick_sources", "plan_spanning_tree",
    "resident_footprint", "worker_sizing",
]
