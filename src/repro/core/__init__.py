"""Pervasive context management — the paper's primary contribution.

Layers:
  context.py   recipes, keys, tiers, materialised state
  cache.py     per-worker tiered byte-accounted LRU
  library.py   per-context hosting process (materialise once, invoke many)
  registry.py  scheduler-side global residency view (raw state store)
  plane.py     the context plane: declarative intents -> priced, budgeted
               placement plans; the ONLY registry-writing module
  transfer.py  topology-aware spanning-tree peer distribution
  policies.py  worker sizing, context modes, batch-size selection,
               warm-pool intents (pure over a ClusterView)
"""
from .context import (ContextElement, ContextRecipe, KV_BYTES_PER_PARAM,
                      MAX_BATCH_SLOTS, MaterializedContext, Tier,
                      content_hash, model_context_recipe,
                      partial_context_recipe, resident_footprint)
from .cache import CacheFullError, ContextCache
from .library import Library, StagingCost
from .registry import ContextRegistry, HostState
from .plane import (Acquire, ClusterView, ContextPlane, DeferredIntent,
                    Intent, LinkBudget, OpKind, PlacementPlan, PlanOp,
                    Release, Replicate, ZoneMeters)
from .transfer import (Peer, TransferEdge, TransferPlan, pick_sources,
                       plan_spanning_tree)
from .policies import (AGING_BOUND_DEFAULT, MODES, NAIVE, PARTIAL, PERVASIVE,
                       PAPER_TASK_SHAPE, PAPER_WORKER_SHAPE, ContextMode,
                       WarmPoolPolicy, WorkerShape, derive_aging_bound,
                       eviction_loss, expected_task_time, optimal_batch_size,
                       worker_sizing)

__all__ = [
    "AGING_BOUND_DEFAULT", "Acquire", "CacheFullError", "ClusterView",
    "ContextCache", "ContextElement", "ContextMode", "ContextPlane",
    "ContextRecipe", "ContextRegistry", "DeferredIntent", "HostState",
    "Intent", "KV_BYTES_PER_PARAM", "Library", "LinkBudget",
    "MAX_BATCH_SLOTS", "MaterializedContext", "MODES", "NAIVE", "OpKind",
    "PARTIAL", "PERVASIVE", "PAPER_TASK_SHAPE", "PAPER_WORKER_SHAPE",
    "Peer", "PlacementPlan", "PlanOp", "Release", "Replicate",
    "StagingCost", "Tier", "TransferEdge", "TransferPlan",
    "WarmPoolPolicy", "WorkerShape", "ZoneMeters", "content_hash",
    "derive_aging_bound", "eviction_loss", "expected_task_time",
    "model_context_recipe", "optimal_batch_size", "partial_context_recipe",
    "pick_sources", "plan_spanning_tree", "resident_footprint",
    "worker_sizing",
]
