"""Tiered, byte-accounted context cache — one per worker.

The paper's worker keeps context elements in a local cache spanning disk,
host memory, and the accelerator (§5.2: "a context ... can materialize in
any format (disk, memory, GPU)").  This class does the byte accounting,
LRU eviction, and explicit *demotion* (spill) per tier; the
:class:`~repro.core.library.Library` decides *what* to promote or spill.

Pins are COUNTED, not boolean: with multi-context workers, several
libraries may share one element (the deps package, most commonly), and an
element stays pinned until every hosting library releases it.

Invariants (property-tested in tests/test_core_properties.py):
  * per-tier used bytes == sum of resident element bytes, always;
  * used bytes never exceed capacity after any operation;
  * pinned entries (pin count > 0) are never evicted nor demoted;
  * an element resident at tier T keeps its staging copies below T.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from .context import ContextElement, Tier


class CacheFullError(RuntimeError):
    pass


@dataclass
class _Entry:
    element: ContextElement
    tier: Tier
    pins: int = 0


class ContextCache:
    """Byte-accounted LRU over (element-key -> resident tier)."""

    def __init__(self, *, disk_bytes: int, host_bytes: int,
                 device_bytes: int):
        self.capacity: Dict[Tier, int] = {
            Tier.DISK: disk_bytes, Tier.HOST: host_bytes,
            Tier.DEVICE: device_bytes,
        }
        self._entries: "OrderedDict[str, _Entry]" = OrderedDict()
        self.evictions: int = 0
        self.demotions: int = 0
        self.hits: int = 0
        self.misses: int = 0

    # -- accounting ------------------------------------------------------
    def used(self, tier: Tier) -> int:
        total = 0
        for e in self._entries.values():
            if tier.order <= e.tier.order:
                total += e.element.nbytes(tier)
        return total

    def free(self, tier: Tier) -> int:
        return self.capacity[tier] - self.used(tier)

    # -- queries ---------------------------------------------------------
    def tier_of(self, key: str) -> Optional[Tier]:
        e = self._entries.get(key)
        return e.tier if e else None

    def pins(self, key: str) -> int:
        e = self._entries.get(key)
        return e.pins if e else 0

    def lookup(self, key: str) -> Optional[Tier]:
        """Tier of ``key`` with LRU touch + hit/miss accounting."""
        e = self._entries.get(key)
        if e is None:
            self.misses += 1
            return None
        self.hits += 1
        self._entries.move_to_end(key)
        return e.tier

    def keys(self) -> Set[str]:
        return set(self._entries)

    def missing_fetch_bytes(self, elements) -> int:
        """Network bytes a staging of ``elements`` would have to fetch:
        the packed (disk) size of every element not resident at any tier.
        This is the pricing primitive the context plane uses, and by
        construction it equals the bytes :meth:`Library.materialize_cost`
        charges to its fetch phase against this cache."""
        return sum(e.nbytes_disk for e in elements
                   if e.key not in self._entries)

    # -- mutation --------------------------------------------------------
    def _bytes_at(self, element: ContextElement, tier: Tier,
                  at: Tier) -> int:
        """Bytes ``element`` occupies at tier ``at`` if resident at ``tier``."""
        return element.nbytes(at) if at.order <= tier.order else 0

    def _ensure_room(self, element: ContextElement, tier: Tier,
                     exclude: str) -> None:
        for at in (Tier.DISK, Tier.HOST, Tier.DEVICE):
            need = self._bytes_at(element, tier, at)
            if need == 0:
                continue
            if need > self.capacity[at]:
                raise CacheFullError(
                    f"{element.name} needs {need} B at {at.value}, capacity "
                    f"{self.capacity[at]} B")
            # account for the entry's current footprint being replaced
            cur = self._entries.get(exclude)
            cur_b = self._bytes_at(cur.element, cur.tier, at) if cur else 0
            while self.used(at) - cur_b + need > self.capacity[at]:
                if not self._evict_one(at, exclude):
                    raise CacheFullError(
                        f"cannot free {need} B at {at.value} "
                        f"(used {self.used(at)}/{self.capacity[at]}, "
                        f"all remaining entries pinned)")

    def _evict_one(self, tier: Tier, exclude: str) -> bool:
        """Evict/demote the LRU unpinned entry occupying ``tier``."""
        for key, e in self._entries.items():   # OrderedDict = LRU order
            if key == exclude or e.pins > 0:
                continue
            if self._bytes_at(e.element, e.tier, tier) == 0:
                continue
            if tier is Tier.DISK or e.tier is tier is Tier.HOST or \
                    (tier is Tier.HOST and not e.element.nbytes_disk):
                del self._entries[key]          # fully evicted
            elif e.tier.order > tier.order:
                e.tier = tier                   # shouldn't happen, demote
            else:
                # demote one level: DEVICE->HOST, HOST->DISK
                e.tier = Tier(("disk", "host")[e.tier.order - 1])
            self.evictions += 1
            return True
        return False

    def put(self, element: ContextElement, tier: Tier,
            *, pinned: bool = False) -> None:
        """Insert or promote/demote ``element`` to residency ``tier``.

        ``pinned=True`` takes one pin reference on the entry (released with
        :meth:`pin`\\ ``(key, False)``); ``pinned=False`` leaves the current
        pin count untouched.
        """
        self._ensure_room(element, tier, exclude=element.key)
        cur = self._entries.pop(element.key, None)
        pins = (cur.pins if cur else 0) + (1 if pinned else 0)
        self._entries[element.key] = _Entry(element, tier, pins)

    def demote(self, key: str, to: Optional[Tier] = None) -> Tier:
        """Spill an UNPINNED entry down-tier (default: one level; pass
        ``to`` for a direct drop, e.g. DEVICE→DISK).  Frees the bytes of
        every tier above ``to`` while keeping the staging copies at and
        below it.  Returns the new residency tier."""
        e = self._entries[key]
        if e.pins > 0:
            raise ValueError(f"cannot demote pinned entry {key} "
                             f"(pins={e.pins})")
        if to is None:
            to = Tier.HOST if e.tier is Tier.DEVICE else Tier.DISK
        if to.order >= e.tier.order:
            return e.tier                       # already at/below target
        e.tier = to
        self.demotions += 1
        return to

    def pin(self, key: str, pinned: bool = True) -> None:
        """Take (``pinned=True``) or release (``False``) one pin reference."""
        e = self._entries[key]
        e.pins = e.pins + 1 if pinned else max(0, e.pins - 1)

    def drop(self, key: str) -> None:
        self._entries.pop(key, None)

    def clear(self) -> None:
        self._entries.clear()

    # -- introspection ---------------------------------------------------
    def stats(self) -> Dict[str, float]:
        total = self.hits + self.misses
        return {
            "hits": self.hits, "misses": self.misses,
            "hit_rate": self.hits / total if total else 0.0,
            "evictions": self.evictions,
            "demotions": self.demotions,
            **{f"used_{t.value}": self.used(t) for t in Tier},
        }
