"""Tiered, byte-accounted context cache — one per worker.

The paper's worker keeps context elements in a local cache spanning disk,
host memory, and the accelerator (§5.2: "a context ... can materialize in
any format (disk, memory, GPU)").  This class does the byte accounting and
LRU eviction per tier; the :class:`~repro.core.library.Library` decides
*what* to promote.

Invariants (property-tested in tests/test_core_properties.py):
  * per-tier used bytes == sum of resident element bytes, always;
  * used bytes never exceed capacity after any operation;
  * pinned entries are never evicted;
  * an element resident at tier T keeps its staging copies below T.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from .context import ContextElement, Tier


class CacheFullError(RuntimeError):
    pass


@dataclass
class _Entry:
    element: ContextElement
    tier: Tier
    pinned: bool = False


class ContextCache:
    """Byte-accounted LRU over (element-key -> resident tier)."""

    def __init__(self, *, disk_bytes: int, host_bytes: int,
                 device_bytes: int):
        self.capacity: Dict[Tier, int] = {
            Tier.DISK: disk_bytes, Tier.HOST: host_bytes,
            Tier.DEVICE: device_bytes,
        }
        self._entries: "OrderedDict[str, _Entry]" = OrderedDict()
        self.evictions: int = 0
        self.hits: int = 0
        self.misses: int = 0

    # -- accounting ------------------------------------------------------
    def used(self, tier: Tier) -> int:
        total = 0
        for e in self._entries.values():
            if tier.order <= e.tier.order:
                total += e.element.nbytes(tier)
        return total

    def free(self, tier: Tier) -> int:
        return self.capacity[tier] - self.used(tier)

    # -- queries ---------------------------------------------------------
    def tier_of(self, key: str) -> Optional[Tier]:
        e = self._entries.get(key)
        return e.tier if e else None

    def lookup(self, key: str) -> Optional[Tier]:
        """Tier of ``key`` with LRU touch + hit/miss accounting."""
        e = self._entries.get(key)
        if e is None:
            self.misses += 1
            return None
        self.hits += 1
        self._entries.move_to_end(key)
        return e.tier

    def keys(self) -> Set[str]:
        return set(self._entries)

    # -- mutation --------------------------------------------------------
    def _bytes_at(self, element: ContextElement, tier: Tier,
                  at: Tier) -> int:
        """Bytes ``element`` occupies at tier ``at`` if resident at ``tier``."""
        return element.nbytes(at) if at.order <= tier.order else 0

    def _ensure_room(self, element: ContextElement, tier: Tier,
                     exclude: str) -> None:
        for at in (Tier.DISK, Tier.HOST, Tier.DEVICE):
            need = self._bytes_at(element, tier, at)
            if need == 0:
                continue
            if need > self.capacity[at]:
                raise CacheFullError(
                    f"{element.name} needs {need} B at {at.value}, capacity "
                    f"{self.capacity[at]} B")
            # account for the entry's current footprint being replaced
            cur = self._entries.get(exclude)
            cur_b = self._bytes_at(cur.element, cur.tier, at) if cur else 0
            while self.used(at) - cur_b + need > self.capacity[at]:
                if not self._evict_one(at, exclude):
                    raise CacheFullError(
                        f"cannot free {need} B at {at.value} "
                        f"(used {self.used(at)}/{self.capacity[at]}, "
                        f"all remaining entries pinned)")

    def _evict_one(self, tier: Tier, exclude: str) -> bool:
        """Evict/demote the LRU unpinned entry occupying ``tier``."""
        for key, e in self._entries.items():   # OrderedDict = LRU order
            if key == exclude or e.pinned:
                continue
            if self._bytes_at(e.element, e.tier, tier) == 0:
                continue
            if tier is Tier.DISK or e.tier is tier is Tier.HOST or \
                    (tier is Tier.HOST and not e.element.nbytes_disk):
                del self._entries[key]          # fully evicted
            elif e.tier.order > tier.order:
                e.tier = tier                   # shouldn't happen, demote
            else:
                # demote one level: DEVICE->HOST, HOST->DISK
                e.tier = Tier(("disk", "host")[e.tier.order - 1])
            self.evictions += 1
            return True
        return False

    def put(self, element: ContextElement, tier: Tier,
            *, pinned: bool = False) -> None:
        """Insert or promote/demote ``element`` to residency ``tier``."""
        self._ensure_room(element, tier, exclude=element.key)
        cur = self._entries.pop(element.key, None)
        self._entries[element.key] = _Entry(element, tier,
                                            pinned or (cur.pinned if cur
                                                       else False))

    def pin(self, key: str, pinned: bool = True) -> None:
        self._entries[key].pinned = pinned

    def drop(self, key: str) -> None:
        self._entries.pop(key, None)

    def clear(self) -> None:
        self._entries.clear()

    # -- introspection ---------------------------------------------------
    def stats(self) -> Dict[str, float]:
        total = self.hits + self.misses
        return {
            "hits": self.hits, "misses": self.misses,
            "hit_rate": self.hits / total if total else 0.0,
            "evictions": self.evictions,
            **{f"used_{t.value}": self.used(t) for t in Tier},
        }
