"""Scheduler-side context registry: the globally consistent view.

The TaskVine scheduler "keeps a globally consistent view of the
application" (paper §5.1): which recipe is hosted where, which workers are
warming up, and which tasks are waiting on which context.  The scheduler
consults this registry to (a) route tasks to warm workers first and (b)
pick peer-transfer sources for cold workers.

WRITE DISCIPLINE: this class is the raw state store.  Every mutation in
``src/repro`` goes through :class:`repro.core.plane.ContextPlane` (the
single-writer module, grep-enforced by tests/test_context_plane.py);
calling ``mark_*`` directly is reserved for the plane and for tests.
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field
from enum import Enum
from typing import Dict, List, Optional, Set

from .context import ContextRecipe


class HostState(str, Enum):
    STAGING = "staging"       # recipe en route / materialising
    READY = "ready"           # library ack'd, invocations may be routed
    SPILLED = "spilled"       # demoted to the worker's local disk (cheap
                              # re-promotion: load+device, no fetch)
    LOST = "lost"             # worker evicted while hosting


@dataclass
class ContextRegistry:
    recipes: Dict[str, ContextRecipe] = field(default_factory=dict)
    # recipe key -> worker id -> state
    hosts: Dict[str, Dict[str, HostState]] = field(
        default_factory=lambda: defaultdict(dict))

    def register(self, recipe: ContextRecipe) -> str:
        self.recipes[recipe.key] = recipe
        return recipe.key

    # -- host-state transitions (driven by scheduler events) -------------
    def mark_staging(self, key: str, worker_id: str) -> None:
        assert key in self.recipes, f"unregistered recipe {key}"
        self.hosts[key][worker_id] = HostState.STAGING

    def mark_ready(self, key: str, worker_id: str) -> None:
        self.hosts[key][worker_id] = HostState.READY

    def mark_spilled(self, key: str, worker_id: str) -> None:
        """Worker demoted its library for ``key`` to local disk."""
        assert key in self.recipes, f"unregistered recipe {key}"
        self.hosts[key][worker_id] = HostState.SPILLED

    def drop_worker(self, worker_id: str) -> List[str]:
        """Worker evicted: record its residencies as LOST. Returns lost keys.

        The residencies are NOT silently deleted — each surviving entry is
        a tombstone the context plane consumes to trigger re-replication
        of recipes whose warm copies died with the worker.  Use
        :meth:`forget` to clear a tombstone once it has been acted on.
        """
        lost = []
        for key, hosts in self.hosts.items():
            state = hosts.get(worker_id)
            if state is not None and state is not HostState.LOST:
                hosts[worker_id] = HostState.LOST
                lost.append(key)
        return lost

    def forget(self, key: str, worker_id: str) -> None:
        """Erase one residency record (tombstone consumed / copy released)."""
        self.hosts.get(key, {}).pop(worker_id, None)

    # -- queries ----------------------------------------------------------
    def ready_workers(self, key: str) -> Set[str]:
        return {w for w, s in self.hosts.get(key, {}).items()
                if s is HostState.READY}

    def staging_workers(self, key: str) -> Set[str]:
        return {w for w, s in self.hosts.get(key, {}).items()
                if s is HostState.STAGING}

    def spilled_workers(self, key: str) -> Set[str]:
        return {w for w, s in self.hosts.get(key, {}).items()
                if s is HostState.SPILLED}

    def lost_workers(self, key: str) -> Set[str]:
        """Tombstones: workers evicted while hosting ``key``."""
        return {w for w, s in self.hosts.get(key, {}).items()
                if s is HostState.LOST}

    def workers_with(self, key: str) -> Set[str]:
        """Workers holding (or staging/spilling) a live copy — LOST
        tombstones are bookkeeping, not copies, and are excluded."""
        return {w for w, s in self.hosts.get(key, {}).items()
                if s is not HostState.LOST}

    def state(self, key: str, worker_id: str) -> Optional[HostState]:
        return self.hosts.get(key, {}).get(worker_id)

    def replication(self, key: str) -> int:
        return len(self.ready_workers(key))
