"""Scheduler-side context registry: the globally consistent view.

The TaskVine scheduler "keeps a globally consistent view of the
application" (paper §5.1): which recipe is hosted where, which workers are
warming up, and which tasks are waiting on which context.  The scheduler
consults this registry to (a) route tasks to warm workers first and (b)
pick peer-transfer sources for cold workers.
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field
from enum import Enum
from typing import Dict, List, Optional, Set

from .context import ContextRecipe


class HostState(str, Enum):
    STAGING = "staging"       # recipe en route / materialising
    READY = "ready"           # library ack'd, invocations may be routed
    SPILLED = "spilled"       # demoted to the worker's local disk (cheap
                              # re-promotion: load+device, no fetch)
    LOST = "lost"             # worker evicted while hosting


@dataclass
class ContextRegistry:
    recipes: Dict[str, ContextRecipe] = field(default_factory=dict)
    # recipe key -> worker id -> state
    hosts: Dict[str, Dict[str, HostState]] = field(
        default_factory=lambda: defaultdict(dict))

    def register(self, recipe: ContextRecipe) -> str:
        self.recipes[recipe.key] = recipe
        return recipe.key

    # -- host-state transitions (driven by scheduler events) -------------
    def mark_staging(self, key: str, worker_id: str) -> None:
        assert key in self.recipes, f"unregistered recipe {key}"
        self.hosts[key][worker_id] = HostState.STAGING

    def mark_ready(self, key: str, worker_id: str) -> None:
        self.hosts[key][worker_id] = HostState.READY

    def mark_spilled(self, key: str, worker_id: str) -> None:
        """Worker demoted its library for ``key`` to local disk."""
        assert key in self.recipes, f"unregistered recipe {key}"
        self.hosts[key][worker_id] = HostState.SPILLED

    def drop_worker(self, worker_id: str) -> List[str]:
        """Worker evicted: forget all its residencies. Returns lost keys."""
        lost = []
        for key, hosts in self.hosts.items():
            if worker_id in hosts:
                del hosts[worker_id]
                lost.append(key)
        return lost

    # -- queries ----------------------------------------------------------
    def ready_workers(self, key: str) -> Set[str]:
        return {w for w, s in self.hosts.get(key, {}).items()
                if s is HostState.READY}

    def staging_workers(self, key: str) -> Set[str]:
        return {w for w, s in self.hosts.get(key, {}).items()
                if s is HostState.STAGING}

    def spilled_workers(self, key: str) -> Set[str]:
        return {w for w, s in self.hosts.get(key, {}).items()
                if s is HostState.SPILLED}

    def workers_with(self, key: str) -> Set[str]:
        return set(self.hosts.get(key, {}))

    def state(self, key: str, worker_id: str) -> Optional[HostState]:
        return self.hosts.get(key, {}).get(worker_id)

    def replication(self, key: str) -> int:
        return len(self.ready_workers(key))
