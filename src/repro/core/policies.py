"""Policies: worker sizing, task routing, and batch-size selection.

Paper §5.3.2: many small workers (fine-grained eviction loss) rather than
few large ones; 1 task per worker at a time (natural work-stealing across
heterogeneous GPUs).  §4 Challenge #6: batch size trades initialisation
amortisation against heterogeneity straggling and eviction loss — and
pervasive context management collapses the amortisation term, which is the
paper's central quantitative claim (batch-size sensitivity 4306 % → 12.3 %).

``expected_task_time`` is the analytical model behind those claims; the
sim reproduces them empirically and the benchmarks assert both agree.
"""
from __future__ import annotations

import math
import warnings
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from .plane import ClusterView, Replicate


@dataclass(frozen=True)
class WorkerShape:
    """Resource request for one worker (the paper's pilot job)."""
    cores: int = 2
    memory_gb: int = 10
    disk_gb: int = 70
    gpus: int = 1
    concurrency: int = 1            # tasks at a time (paper: 1)


# The paper's per-task request: 2 cores / 10 GB mem / 20 GB disk / 1 GPU.
PAPER_TASK_SHAPE = WorkerShape(cores=2, memory_gb=10, disk_gb=20, gpus=1)
PAPER_WORKER_SHAPE = WorkerShape(cores=2, memory_gb=10, disk_gb=70, gpus=1)


@dataclass(frozen=True)
class ContextMode:
    """Which elements are managed (paper's partial vs pervasive)."""
    name: str
    deps_cached: bool               # software package reused across tasks
    weights_cached: bool            # weights on local disk reused
    state_resident: bool            # model stays ON DEVICE between tasks


NAIVE = ContextMode("naive", False, False, False)            # pv1
PARTIAL = ContextMode("partial", True, True, False)          # pv2/pv3
PERVASIVE = ContextMode("pervasive", True, True, True)       # pv4+
MODES: Dict[str, ContextMode] = {m.name: m for m in (NAIVE, PARTIAL,
                                                     PERVASIVE)}


def expected_task_time(batch_size: int, *, infer_s: float,
                       init_s: float, mode: ContextMode,
                       warm: bool, dispatch_s: float = 0.05) -> float:
    """Expected seconds for one task of ``batch_size`` inferences.

    ``infer_s``: per-inference forward time on this worker's device.
    ``init_s``: full cold-start (fetch+load+device) on this worker.
    ``warm``: the worker has already hosted this context.
    ``dispatch_s``: scheduler round-trip + input/result staging — paid per
    task regardless of context mode (Table 2: pv4_1 mean 0.32 s ≫ the
    sub-ms library call).
    """
    if mode.state_resident and warm:
        overhead = dispatch_s       # invocation runs in the library
    elif mode.weights_cached and warm:
        # skip fetch; pay load+device each task
        overhead = dispatch_s + init_s * 0.45
    else:
        overhead = dispatch_s + init_s
    return overhead + batch_size * infer_s


def eviction_loss(batch_size: int, *, infer_s: float,
                  evict_rate_per_s: float) -> float:
    """Expected inferences lost to eviction per task (Challenge #6).

    A task killed mid-run loses its whole batch (no grace period); the
    longer the task, the likelier the kill: loss ≈ B · (1 - e^{-λ·T}).
    """
    t = batch_size * infer_s
    return batch_size * (1.0 - math.exp(-evict_rate_per_s * t))


def optimal_batch_size(n_total: int, n_workers: int, *, infer_s: float,
                       init_s: float, mode: ContextMode,
                       slowdown_max: float = 3.0,
                       evict_rate_per_s: float = 0.0,
                       manager_dispatch_s: float = 0.02,
                       candidates: Sequence[int] = (1, 10, 100, 1000,
                                                    3000, 7500)) -> int:
    """Pick the batch size minimising expected makespan (§5.3.2 analysis).

    Makespan model: total work spreads over workers, but the *tail* is one
    task on the slowest device (slowdown_max × median) — large batches
    straggle; small batches multiply the per-task overhead AND serialise on
    the single-threaded manager (``manager_dispatch_s`` per task).
    """
    best, best_t = candidates[0], float("inf")
    for b in candidates:
        if b > n_total:
            continue
        n_tasks = math.ceil(n_total / b)
        per_task = expected_task_time(b, infer_s=infer_s, init_s=init_s,
                                      mode=mode, warm=True)
        cold = expected_task_time(b, infer_s=infer_s, init_s=init_s,
                                  mode=mode, warm=False)
        waves = math.ceil(n_tasks / max(n_workers, 1))
        # first wave pays cold start; tail task runs on the slowest device
        makespan = cold + max(waves - 1, 0) * per_task \
            + per_task * (slowdown_max - 1.0)
        # the manager is a serial bottleneck at high task counts
        makespan = max(makespan, n_tasks * manager_dispatch_s)
        if evict_rate_per_s:
            lost = eviction_loss(b, infer_s=infer_s,
                                 evict_rate_per_s=evict_rate_per_s)
            makespan *= 1.0 + lost / b
        if makespan < best_t:
            best, best_t = b, makespan
    return best


AGING_BOUND_DEFAULT = 8


def derive_aging_bound(warm_s: float, cold_s: float, *, lo: int = 2,
                       hi: int = 64) -> int:
    """Aging bound from observed per-recipe service times.

    A starved lane head tolerates being skipped while warm-routed younger
    requests drain, because each skip costs at most one warm service time
    but placing the head cold costs a full cold start.  The break-even
    number of skips is the cold/warm service-time ratio; clamp it so a
    pathological ratio can neither starve the head forever nor disable
    backfill entirely.  Falls back to the static default without data.
    """
    if warm_s <= 0 or cold_s <= 0:
        return AGING_BOUND_DEFAULT
    return max(lo, min(hi, round(cold_s / warm_s)))


@dataclass(frozen=True)
class WarmPoolPolicy:
    """Proactive demand-driven context replication (beyond-paper §5.3.1).

    The spanning-tree prestage replicates a context to *every* joiner;
    this policy instead sizes a warm pool per recipe from its live demand
    and emits :class:`~repro.core.plane.Replicate` intents, which the
    context plane compiles into budget-checked staging ops on idle
    capable workers — so the next request of a hot recipe routes warm
    instead of paying a cold start.

    :meth:`intents` is a PURE function of a
    :class:`~repro.core.plane.ClusterView`: it names *how many* warm
    copies each recipe deserves and leaves worker selection, pricing and
    budget admission to the plane.

    ``arrival_horizon_s > 0`` adds an EWMA arrival-rate term (SageServe's
    proactive-scaling signal): demand is inflated by the requests
    expected to arrive within the horizon, so Replicate intents are
    emitted BEFORE the backlog forms, not after.

    ``preempt_horizon_s > 0`` does the same with the scheduler's
    PREEMPTION EWMA (``ClusterView.preempt_rate``): a spill storm —
    interactive work repeatedly suspending batch members — is demand for
    more warm replicas that the arrival rate cannot see, because the
    suspended requests already arrived.  Each preemption expected within
    the horizon counts as one task of backlog, so the pool grows where
    slots are being fought over.

    ``forecast_horizon_s > 0`` reads ``ClusterView.forecast_rate`` — the
    :class:`~repro.cluster.forecast.DemandForecaster`'s trend + burst
    view — instead of waiting for the EWMA to catch up: during a burst
    the forecast is pinned high, so the warm pool widens BEFORE the
    backlog forms and stays wide through the burst's hold period.
    """
    tasks_per_replica: int = 8      # backlog one warm replica absorbs
    max_fraction: float = 0.5       # pool share one recipe may pre-claim
    min_replicas: int = 1           # keep-warm floor while demand exists
    arrival_horizon_s: float = 0.0  # EWMA look-ahead (0 = reactive only)
    preempt_horizon_s: float = 0.0  # preemption-storm look-ahead
    forecast_horizon_s: float = 0.0  # trend/burst forecast look-ahead

    def target_replicas(self, demand_tasks: float, n_workers: int) -> int:
        if demand_tasks <= 0 or n_workers <= 0:
            return 0
        cap = max(int(n_workers * self.max_fraction), 1)
        want = math.ceil(demand_tasks / self.tasks_per_replica)
        return min(max(want, self.min_replicas), cap)

    def intents(self, view: ClusterView) -> List[Replicate]:
        """Replicate intents for the current demand, hottest first."""
        out: List[Replicate] = []
        reg = view.registry
        for key in sorted(view.demand, key=view.demand.get, reverse=True):
            demand = float(view.demand[key])
            if self.arrival_horizon_s > 0:
                demand += view.arrival_rate.get(key, 0.0) \
                    * self.arrival_horizon_s
            if self.preempt_horizon_s > 0:
                demand += view.preempt_rate.get(key, 0.0) \
                    * self.preempt_horizon_s
            if self.forecast_horizon_s > 0:
                demand += view.forecast_rate.get(key, 0.0) \
                    * self.forecast_horizon_s
            want = self.target_replicas(demand, view.n_workers)
            have = len(reg.ready_workers(key) | reg.staging_workers(key))
            if want > have:
                out.append(Replicate(key, want))
        return out

    def plan(self, sched) -> List[Tuple[str, str]]:
        """DEPRECATED shim: (recipe_key, worker_id) staging pairs.

        Pre-plane callers got worker picks straight from the policy; new
        code compiles :meth:`intents` through the scheduler's context
        plane (which also enforces the link budget) and executes the
        resulting ops.
        """
        warnings.warn("WarmPoolPolicy.plan(scheduler) is deprecated; "
                      "compile WarmPoolPolicy.intents(view) through the "
                      "ContextPlane instead", DeprecationWarning,
                      stacklevel=2)
        view = sched.view()
        plan = sched.plane.compile(self.intents(view), view)
        return [(op.recipe_key, op.worker_id)
                for op in plan.acquire_ops()]


def worker_sizing(total_gpus_hint: int, *,
                  prefer_fine_grained: bool = True) -> WorkerShape:
    """§5.3.2: 1-GPU workers unless the user opts into coarse acquisition."""
    if prefer_fine_grained:
        return PAPER_WORKER_SHAPE
    return WorkerShape(cores=2 * total_gpus_hint,
                       memory_gb=10 * total_gpus_hint,
                       disk_gb=70, gpus=total_gpus_hint,
                       concurrency=total_gpus_hint)
