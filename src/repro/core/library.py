"""The library process: materialises a context once, serves invocations.

Paper §5.2: the worker fork-execs a *library* process per context recipe.
The library stages the recipe's elements into the worker cache, executes the
context code (model load → host → device), keeps the resulting state in its
address space, and then executes every subsequent invocation of the bound
function directly against that state — so initialisation is paid once per
worker, not once per task.

This class is backend-neutral: in *sim* mode :meth:`materialize_cost`
returns the staging time from the hardware model and ``payloads`` stays
empty; in *live* mode :meth:`materialize` actually runs each element's
``loader`` (device_put, jit compile, ...) and :meth:`invoke` calls the
bound function.
"""
from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Set

from .cache import ContextCache
from .context import (ContextRecipe, MAX_BATCH_SLOTS, MaterializedContext,
                      Tier)


@dataclass
class StagingCost:
    """Seconds spent per staging phase of one materialisation.

    ``fetch_bytes`` counts the bytes the fetch phase actually moved over
    the network — the context plane compares it against the bytes its
    :class:`~repro.core.plane.PlacementPlan` priced for the same op, so
    plan/executed byte accounting can be asserted equal.
    """
    fetch_s: float = 0.0      # network/shared-fs → local disk
    load_s: float = 0.0       # disk → host memory (deserialise)
    device_s: float = 0.0     # host → accelerator
    activation_s: float = 0.0  # fork-exec + import
    fetch_bytes: int = 0      # bytes moved over the network by the fetch

    @property
    def total_s(self) -> float:
        return self.fetch_s + self.load_s + self.device_s + self.activation_s


class Library:
    """One hosted context on one worker.

    With multi-context workers several libraries are concurrently resident;
    a library that loses the device/host capacity race is *spilled* — its
    elements demoted to local disk and its pins released — rather than torn
    down, so re-hosting pays load+device but never the network fetch.

    A library also owns a *dynamic batch*: the set of admitted requests it
    decodes together.  Membership changes between steps — :meth:`admit`
    adds a request (it starts stepping at the next step boundary, via
    :meth:`activate`), :meth:`step` advances every active member by one
    decode step and returns the ones that hit their budget, and
    :meth:`drain` removes the unfinished members (eviction / shutdown).
    The slot budget is a function of the hosting device's free memory, so
    the same request stream batches differently across a heterogeneous
    pool.
    """

    def __init__(self, recipe: ContextRecipe, cache: ContextCache):
        self.recipe = recipe
        self.cache = cache
        self.context = MaterializedContext(recipe)
        self.ready = False
        self.invocations = 0
        self.spills = 0
        # continuous-batching state: request_id -> request
        self.batch: "OrderedDict[int, Any]" = OrderedDict()
        self.joining: Set[int] = set()      # admitted, start at next boundary

    # ------------------------------------------------------------------
    # Continuous batching: the admission interface
    # ------------------------------------------------------------------
    def slot_budget(self, device_bytes: int, active_params: float) -> int:
        """How many requests this library may decode concurrently here.

        Derived from the hardware catalog: device memory left after the
        recipe's resident bytes, divided by the per-request decode-state
        footprint, clamped to [1, MAX_BATCH_SLOTS].  In live mode the
        footprint is the MEASURED per-slot cache bytes once the executor
        has fed one back (see ``ContextRecipe.record_slot_bytes``); the
        analytic ``KV_BYTES_PER_PARAM`` estimate only seeds the first
        admission.  With the paged KV layout the measured figure is the
        worst-case per-request page allotment (``max_pages * page_bytes``)
        — shared-prefix pages are refcounted device-side, so the budget
        is conservative and admission arithmetic stays unchanged."""
        free = device_bytes - self.recipe.nbytes(Tier.DEVICE)
        per_slot = self.recipe.decode_slot_bytes(active_params)
        return max(1, min(MAX_BATCH_SLOTS, free // per_slot))

    def admit(self, request, budget: int) -> bool:
        """Add ``request`` to the dynamic batch if a slot is free.  The
        request starts stepping at the next boundary (:meth:`activate`)."""
        if len(self.batch) >= budget:
            return False
        self.batch[request.request_id] = request
        self.joining.add(request.request_id)
        return True

    def activate(self, only: Optional[Set[int]] = None) -> List[Any]:
        """Boundary reached: newly admitted members begin stepping.

        ``only`` restricts activation to a subset of the joining ids —
        the sim runner uses it so a request admitted at time t can never
        be activated at an earlier (lazily settled) boundary."""
        rids = self.joining if only is None else \
            (self.joining & set(only))
        started = [self.batch[rid] for rid in rids if rid in self.batch]
        self.joining -= set(rids)
        return started

    def step(self) -> List[Any]:
        """Advance every ACTIVE member one decode step; pop & return the
        requests that completed their unit budget."""
        finished = []
        for rid, req in list(self.batch.items()):
            if rid in self.joining:
                continue
            req.steps_done += 1
            if req.steps_done >= req.n_units:
                del self.batch[rid]
                finished.append(req)
        return finished

    def drain(self) -> List[Any]:
        """Remove every unfinished member (eviction / spill / teardown)."""
        out = list(self.batch.values())
        self.batch.clear()
        self.joining.clear()
        return out

    @property
    def stepping(self) -> int:
        """Members actually decoding (admitted minus still-joining)."""
        return len(self.batch) - len(self.joining)

    # ------------------------------------------------------------------
    # Sim path: compute cost, update the cache accounting
    # ------------------------------------------------------------------
    def materialize_cost(self, hw, *, already_local: bool = False,
                         fetch_bw: Optional[float] = None) -> StagingCost:
        """Staging cost on hardware ``hw`` given current cache residency.

        ``hw`` provides: ``disk_bw``, ``h2d_bw`` (bytes/s), and
        ``compile_s(recipe)``.  ``fetch_bw`` is the network path (shared fs
        or peer transfer) used for elements not yet on local disk; when
        ``already_local`` the fetch phase is skipped entirely.
        """
        cost = StagingCost(activation_s=self.recipe.activation_s)
        for e in self.recipe.elements:
            tier = self.cache.lookup(e.key)
            home = e.home
            if tier is None and not already_local:
                bw = fetch_bw or hw.disk_bw
                cost.fetch_s += e.nbytes_disk / bw
                cost.fetch_bytes += e.nbytes_disk
                tier = Tier.DISK
            elif tier is None:
                tier = Tier.DISK
            if tier.order < Tier.HOST.order <= home.order:
                cost.load_s += e.nbytes(Tier.HOST) / hw.disk_bw
                tier = Tier.HOST
            if tier.order < Tier.DEVICE.order <= home.order:
                if e.name == "xla_executable":
                    cost.device_s += hw.compile_s(self.recipe)
                else:
                    cost.device_s += e.nbytes(Tier.DEVICE) / hw.h2d_bw
                tier = Tier.DEVICE
            self.cache.put(e, tier, pinned=True)
            self.context.tiers[e.name] = tier
        self.ready = True
        return cost

    # ------------------------------------------------------------------
    # Live path: actually run the loaders
    # ------------------------------------------------------------------
    def materialize(self) -> StagingCost:
        """Run every element's loader; returns measured wall-time cost."""
        cost = StagingCost()
        for e in self.recipe.elements:
            tier = self.cache.tier_of(e.key)
            home = Tier.DEVICE if e.nbytes_device else Tier.HOST
            if tier is not None and tier.order >= home.order and \
                    e.name in self.context.payloads:
                self.context.tiers[e.name] = tier
                continue
            t0 = time.perf_counter()
            if e.loader is not None:
                self.context.payloads[e.name] = e.loader()
            dt = time.perf_counter() - t0
            if e.name == "deps":
                cost.activation_s += dt
            elif home is Tier.DEVICE:
                cost.device_s += dt
            else:
                cost.load_s += dt
            self.cache.put(e, home, pinned=True)
            self.context.tiers[e.name] = home
        self.ready = True
        return cost

    def invoke(self, fn: Callable[..., Any], *args, **kw) -> Any:
        """Execute an invocation inside this library's address space."""
        assert self.ready, "library not materialised"
        self.invocations += 1
        return fn(self.context.payloads, *args, **kw)

    def spill(self, to: Tier = Tier.DISK) -> None:
        """Demote this library's residency to ``to`` (default: local disk).

        Releases this library's pin on every element; an element is only
        demoted once its pin count hits zero, so elements shared with other
        resident libraries (the deps package, typically) stay put.  The
        byte accounting moves with the demotion: DEVICE and HOST bytes are
        freed, the DISK staging copy survives (unpinned — evictable under
        disk pressure), and re-hosting pays load+device but not fetch.
        """
        if not self.ready:
            return
        self.drain()                # callers gate on an empty batch
        for e in self.recipe.elements:
            try:
                self.cache.pin(e.key, False)
            except KeyError:
                continue
            if self.cache.pins(e.key) == 0:
                self.cache.demote(e.key, to)
            t = self.cache.tier_of(e.key)
            if t is not None:
                self.context.tiers[e.name] = t
        self.context.payloads.clear()
        self.ready = False
        self.spills += 1

    def teardown(self) -> None:
        self.drain()
        for e in self.recipe.elements:
            try:
                self.cache.pin(e.key, False)
            except KeyError:
                pass
        self.context.payloads.clear()
        self.ready = False
