"""Spanning-tree peer-transfer planner (paper §5.3.1).

"the scheduler first sends the context to an arbitrary worker, and this
worker sends the context to N other workers, and so on until the context is
fully distributed" — with each worker capped at N concurrent outbound
transfers.

TPU-fleet adaptation (DESIGN.md §2): links are not uniform.  Workers carry
a ``zone`` (pod / rack); the planner builds the tree **topology-aware** —
it always prefers an in-zone source over a cross-zone one, so each zone is
crossed by (ideally) a single edge and fan-out happens over the fast local
links (ICI analogue) rather than the slow cross-pod DCN.

The planner is pure: given sources, targets and a fan-out cap it returns a
schedule of :class:`TransferEdge`s with start/end times; the sim executes
the schedule, live mode uses the edge order.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class Peer:
    worker_id: str
    zone: str = "z0"
    # outbound bandwidth in bytes/s for in-zone and cross-zone edges
    bw_local: float = 12.5e9        # ~100 Gb/s node NIC
    bw_cross: float = 3.0e9


@dataclass(frozen=True)
class TransferEdge:
    src: str
    dst: str
    nbytes: int
    start_s: float
    end_s: float
    cross_zone: bool


@dataclass
class TransferPlan:
    edges: List[TransferEdge] = field(default_factory=list)
    # dst-indexed arrival times (each target receives exactly one edge), so
    # per-worker lookups are O(1) instead of a linear scan over the tree
    _arrival: Dict[str, float] = field(default_factory=dict, repr=False)

    def add(self, edge: TransferEdge) -> None:
        self.edges.append(edge)
        self._arrival[edge.dst] = edge.end_s

    @property
    def makespan_s(self) -> float:
        return max((e.end_s for e in self.edges), default=0.0)

    @property
    def cross_zone_edges(self) -> int:
        return sum(e.cross_zone for e in self.edges)

    def arrival(self, worker_id: str) -> Optional[float]:
        if len(self._arrival) != len(self.edges):
            # edges were appended directly (pre-`add` callers); reindex
            self._arrival = {e.dst: e.end_s for e in self.edges}
        return self._arrival.get(worker_id)


def plan_spanning_tree(nbytes: int, sources: Sequence[Peer],
                       targets: Sequence[Peer], *, fanout_cap: int = 3,
                       t0: float = 0.0) -> TransferPlan:
    """Greedy earliest-finish spanning tree with per-node fan-out cap.

    Event-driven: a min-heap of (time a source slot frees, peer).  Each
    ready source claims the next target, preferring in-zone targets; a
    target that finishes becomes a source itself.  ``fanout_cap`` bounds
    *concurrent* outbound transfers per node (paper's N); we model it by
    giving each node ``fanout_cap`` sequential slots (bandwidth-fair:
    concurrent transfers would each get bw/N — identical finish time for
    equal sizes, so sequential slots are the conservative equivalent that
    also matches TaskVine's real behaviour of queueing beyond the cap).
    """
    if not targets:
        return TransferPlan()
    remaining: Dict[str, Peer] = {p.worker_id: p for p in targets}
    for s in sources:
        remaining.pop(s.worker_id, None)
    plan = TransferPlan()
    # heap entries: (time_slot_free, seq, peer)
    heap: List[Tuple[float, int, Peer]] = []
    seq = 0
    for s in sources:
        for _ in range(max(1, fanout_cap)):
            heapq.heappush(heap, (t0, seq, s)); seq += 1
    if not heap:
        raise ValueError("no sources to transfer from")
    seeded = {s.zone for s in sources}    # zones with a (future) source
    while remaining:
        t_free, _, src = heapq.heappop(heap)
        # prefer an in-zone target; else SEED one unseeded zone (a zone
        # already seeded will be served over its own fast local links by
        # the in-flight copy whose slots are in the heap).
        dst = next((p for p in remaining.values() if p.zone == src.zone),
                   None)
        cross = dst is None
        if cross:
            dst = next((p for p in remaining.values()
                        if p.zone not in seeded), None)
            if dst is None:
                continue            # this slot is useless; drop it
            seeded.add(dst.zone)
        del remaining[dst.worker_id]
        bw = src.bw_cross if cross else src.bw_local
        t_end = t_free + nbytes / bw
        plan.add(TransferEdge(src.worker_id, dst.worker_id,
                              nbytes, t_free, t_end, cross))
        heapq.heappush(heap, (t_end, seq, src)); seq += 1
        for _ in range(max(1, fanout_cap)):
            heapq.heappush(heap, (t_end, seq, dst)); seq += 1
    return plan


def pick_sources(ready_workers: Sequence[Peer], dst_zone: str,
                 *, max_sources: int = 1) -> List[Peer]:
    """Scheduler policy: in-zone ready hosts first, then any.

    Within each zone class, ties break toward the peer with the higher
    local NIC bandwidth (`bw_local`) — the fan-out it will serve once the
    copy lands runs over that link.  The sort is stable, so peers with
    equal bandwidth keep their incoming order (back-compat with the
    original first-match policy)."""
    local = sorted((p for p in ready_workers if p.zone == dst_zone),
                   key=lambda p: -p.bw_local)
    rest = sorted((p for p in ready_workers if p.zone != dst_zone),
                  key=lambda p: -p.bw_local)
    return (local + rest)[:max_sources]
