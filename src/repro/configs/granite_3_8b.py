"""granite-3-8b — dense GQA decoder.

[hf:ibm-granite/granite-3.0-2b-base] (family); assigned dims:
40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155.
"""
from .base import ModelConfig, ParallelConfig

CONFIG = ModelConfig(
    arch_id="granite-3-8b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12800,
    vocab_size=49155,
    rope_theta=10_000.0,
    tie_embeddings=True,
    parallel=ParallelConfig(train_dp_only=True),
    source="[hf:ibm-granite/granite-3.0-2b-base]",
)
