"""whisper-small — encoder-decoder audio transformer. [arXiv:2212.04356]

12L (decoder) d_model=768 12H (kv=12) d_ff=3072 vocab=51865; 12 encoder
layers. The mel-spectrogram + conv frontend is a STUB: ``input_specs``
provides precomputed frame embeddings (batch, n_audio_frames, d_model).
Decode shapes exercise the DECODER (self-attn KV cache + cross-attn over the
encoder output, which is itself a reusable per-request context).
"""
from .base import ModelConfig, ParallelConfig

CONFIG = ModelConfig(
    arch_id="whisper-small",
    family="audio",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    encoder_layers=12,
    n_audio_frames=1500,
    rope_theta=0.0,                   # whisper uses learned/sinusoidal pos
    tie_embeddings=True,
    # §Perf W1: small d_model (768) makes seq-parallel's per-layer
    # activation gathers cost more than they save: dominant train term
    # 1.28 s -> 0.49 s with it off (EXPERIMENTS.md §Perf, E4 generalization)
    parallel=ParallelConfig(seq_parallel=False),
    source="[arXiv:2212.04356]",
)
