"""phi3.5-moe-42b-a6.6b — 16-expert top-2 MoE. [hf:microsoft/Phi-3.5-MoE-instruct]

32L d_model=4096 32H (GQA kv=8) d_ff=6400 vocab=32064, MoE 16e top-2.
"""
from .base import ModelConfig, MoEConfig, ParallelConfig

CONFIG = ModelConfig(
    arch_id="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6400,
    vocab_size=32064,
    rope_theta=10_000.0,
    # §Perf P1: a2a dispatch — dense_onehot's (B,S,E,C) masks cost E/K = 8x
    # useful compute (train_4k compute term 10.5 s -> 0.33 s, 32x).
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=6400,
                  dispatch="a2a"),
    parallel=ParallelConfig(fsdp=True),
    source="[hf:microsoft/Phi-3.5-MoE-instruct]",
)
