"""hymba-1.5b — hybrid: parallel attention + mamba heads. [arXiv:2411.13676]

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Each block runs attention heads and SSM heads IN PARALLEL on the same input
and fuses their (normalised) outputs — the Hymba "hybrid-head" design.
Attention uses a sliding window in most layers (global in a few), which is
what makes long_500k native for this arch.
"""
from .base import ModelConfig, ParallelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    attn_window=1024,                 # hymba: SWA in hybrid blocks
    ssm=SSMConfig(state_dim=16, expand=2),
    hybrid_parallel_heads=True,
    rope_theta=10_000.0,
    parallel=ParallelConfig(train_dp_only=True, ),
    source="[arXiv:2411.13676]",
)
