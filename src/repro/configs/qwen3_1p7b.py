"""qwen3-1.7b — dense GQA decoder with qk-norm. [hf:Qwen/Qwen3-8B] (family)

28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936.
"""
from .base import ModelConfig, ParallelConfig

CONFIG = ModelConfig(
    arch_id="qwen3-1.7b",
    family="dense",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    parallel=ParallelConfig(train_dp_only=True, ),
    source="[hf:Qwen/Qwen3-8B]",
)
