"""Architecture registry: ``get_config(arch_id)`` / ``--arch <id>`` lookup."""
from __future__ import annotations

from typing import Dict, List

from .base import (
    INPUT_SHAPES,
    TRAIN_4K,
    PREFILL_32K,
    DECODE_32K,
    LONG_500K,
    InputShape,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    ParallelConfig,
    SSMConfig,
    smoke_variant,
)

from . import (
    llava_next_34b,
    granite_3_8b,
    llama3_405b,
    qwen3_1p7b,
    hymba_1p5b,
    xlstm_350m,
    whisper_small,
    phi35_moe_42b,
    deepseek_v3_671b,
    olmo_1b,
    smollm2_1p7b,
)

# The 10 assigned architectures (+ the paper's own model, smollm2-1.7b).
ARCH_REGISTRY: Dict[str, ModelConfig] = {
    m.CONFIG.arch_id: m.CONFIG
    for m in (
        llava_next_34b,
        granite_3_8b,
        llama3_405b,
        qwen3_1p7b,
        hymba_1p5b,
        xlstm_350m,
        whisper_small,
        phi35_moe_42b,
        deepseek_v3_671b,
        olmo_1b,
        smollm2_1p7b,
    )
}

ASSIGNED_ARCHS: List[str] = [
    "llava-next-34b",
    "granite-3-8b",
    "llama3-405b",
    "qwen3-1.7b",
    "hymba-1.5b",
    "xlstm-350m",
    "whisper-small",
    "phi3.5-moe-42b-a6.6b",
    "deepseek-v3-671b",
    "olmo-1b",
]


def get_config(arch_id: str) -> ModelConfig:
    try:
        return ARCH_REGISTRY[arch_id]
    except KeyError:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {sorted(ARCH_REGISTRY)}"
        ) from None


def get_smoke_config(arch_id: str) -> ModelConfig:
    return smoke_variant(get_config(arch_id))


def serving_variant(cfg: ModelConfig) -> ModelConfig:
    """Parallelism for decode: FSDP is a *training* optimisation — at
    decode the embed-dim weight shards force an all-gather of the weights
    every token step (llama3-405b decode_32k: 2.0 s collective term,
    §Perf G4). Serving shards params over 'model' only."""
    import dataclasses
    if not cfg.parallel.fsdp:
        return cfg
    return cfg.with_(parallel=dataclasses.replace(cfg.parallel, fsdp=False))


def long_context_variant(cfg: ModelConfig) -> ModelConfig:
    """Variant used for the long_500k shape.

    SSM/hybrid archs are natively sub-quadratic; pure-attention archs get a
    sliding-window (w=8192) variant per the assignment carve-out (DESIGN.md
    §Arch-applicability).
    """
    if cfg.family in ("ssm", "hybrid"):
        return cfg
    if cfg.attn_window:
        return cfg
    return cfg.with_(attn_window=8192)


__all__ = [
    "ARCH_REGISTRY",
    "ASSIGNED_ARCHS",
    "INPUT_SHAPES",
    "TRAIN_4K",
    "PREFILL_32K",
    "DECODE_32K",
    "LONG_500K",
    "InputShape",
    "MLAConfig",
    "ModelConfig",
    "MoEConfig",
    "ParallelConfig",
    "SSMConfig",
    "get_config",
    "get_smoke_config",
    "long_context_variant",
    "smoke_variant",
]
