"""smollm2-1.7b — the paper's own model (PfF fact verifier). [arXiv:2502.02737]

24L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=49152 — the SmolLM2-1.7B
card. This is the model the paper's evaluation (§6) serves; it anchors the
live examples and the Prompt-for-Fact application.
"""
from .base import ModelConfig, ParallelConfig

CONFIG = ModelConfig(
    arch_id="smollm2-1.7b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=49152,
    rope_theta=130_000.0,
    tie_embeddings=True,
    parallel=ParallelConfig(),
    source="[arXiv:2502.02737]",
)
