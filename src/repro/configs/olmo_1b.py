"""olmo-1b — dense decoder with non-parametric LayerNorm. [arXiv:2402.00838]

16L d_model=2048 16H (kv=16) d_ff=8192 vocab=50304.
"""
from .base import ModelConfig, ParallelConfig

CONFIG = ModelConfig(
    arch_id="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=8192,
    vocab_size=50304,
    nonparametric_norm=True,
    rope_theta=10_000.0,
    tie_embeddings=True,
    parallel=ParallelConfig(train_dp_only=True, ),
    source="[arXiv:2402.00838]",
)
