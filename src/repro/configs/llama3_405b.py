"""llama3-405b — dense GQA decoder, 128k vocab. [arXiv:2407.21783]

126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256.

Stress config: violates the paper's Condition #1 (<= a dozen B params) — kept
for the dry-run/roofline per the assignment; noted in DESIGN.md
§Arch-applicability. Trains on 256 v5e only with FSDP + bf16 optimizer
moments + microbatching (see ParallelConfig below and EXPERIMENTS.md §Dry-run).
"""
from .base import ModelConfig, ParallelConfig

CONFIG = ModelConfig(
    arch_id="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    head_dim=128,
    d_ff=53248,
    vocab_size=128256,
    rope_theta=500_000.0,
    parallel=ParallelConfig(
        fsdp=True,
        microbatch=8,
        optimizer_moment_dtype="bfloat16",
        # §Perf E4: with FSDP over 'data', sequence-sharding the residual
        # stream makes every per-layer dW reduction span both mesh axes;
        # XLA resolves it with replicated stacked grads + full-size f32
        # all-reduces (53 TB/step -> 6.8 TB/step, 7.8x). See EXPERIMENTS.md.
        seq_parallel=False,
    ),
    source="[arXiv:2407.21783]",
)
