"""Config system: model, parallelism, and input-shape descriptions.

Every assigned architecture gets one ``<arch>.py`` module in this package that
builds a :class:`ModelConfig` with the exact published dimensions (source cited
in the module docstring).  The registry in ``__init__.py`` exposes them for
``--arch <id>`` selection, and :func:`smoke_variant` derives the reduced
CPU-runnable variant used by the per-arch smoke tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration."""
    n_experts: int                    # routed experts
    top_k: int
    d_ff_expert: int                  # hidden dim of each routed expert
    n_shared_experts: int = 0         # always-on shared experts (DeepSeek-style)
    d_ff_shared: int = 0              # hidden dim of the shared expert(s)
    capacity_factor: float = 1.25
    dispatch: str = "dense_onehot"    # "dense_onehot" | "sort_scatter"
    router_dtype: str = "float32"
    router_noise: float = 0.0
    aux_loss_weight: float = 0.01     # load-balance loss


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2/V3) dims. [arXiv:2412.19437]"""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Selective-SSM (Mamba-style) head configuration."""
    state_dim: int = 16               # N: per-channel state size
    conv_width: int = 4
    expand: int = 2                   # inner dim = expand * d_model
    dt_rank: int = 0                  # 0 -> ceil(d_model / 16)
    chunk: int = 128                  # chunked-scan block length


@dataclass(frozen=True)
class ParallelConfig:
    """How a config shards on the production mesh (see sharding.py)."""
    fsdp: bool = False                # shard params over ('pod','data') too
    # §Perf X3: small models should not tensor-parallel at all — per-chip
    # compute is trivial and every TP all-reduce is pure overhead. False
    # drops the 'model' axis from all param rules (pure data parallelism;
    # the model axis stays idle for them on the shared production mesh).
    tensor_parallel: bool = True
    # §Perf Q1: train-shape-only ZeRO-style policy — train_4k's global
    # batch (256) can fill all 256 chips with pure DP + FSDP-sharded
    # params, dropping the TP all-reduces (qwen3 1.36->0.54s, olmo
    # 2.06->0.33s, granite 4.3->2.1s). Prefill/decode batches cannot, so
    # this applies to train_step only (see launch.steps.make_step).
    train_dp_only: bool = False
    seq_parallel: bool = True         # shard residual stream seq dim over 'model'
    # §Perf G1: shard the decode KV cache's seq dim over 'model' (XLA
    # inserts the flash-decode partial-softmax combine). GQA archs have
    # kv_heads < |model| so the head axis cannot use the mesh; without
    # this the cache replicates 16x and decode_32k blows HBM (granite:
    # 45 GB/chip -> 4.7 GB/chip). Default ON; resolve() drops the axis
    # whenever it does not divide.
    context_parallel_decode: bool = True
    microbatch: int = 1               # gradient-accumulation steps
    remat: str = "block"              # "none" | "block" (checkpoint each layer)
    optimizer_moment_dtype: str = "float32"
    expert_parallel: bool = True      # MoE expert axis over 'model'


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    # attention options
    qk_norm: bool = False             # Qwen3-style per-head RMSNorm on q,k
    attn_window: int = 0              # 0 = full attention; >0 = sliding window
    rope_theta: float = 500_000.0
    attn_logit_softcap: float = 0.0
    # norm / embedding options
    nonparametric_norm: bool = False  # OLMo: LayerNorm without learnable params
    tie_embeddings: bool = False
    # family-specific sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    first_k_dense: int = 0            # DeepSeek: first k layers use dense FFN
    mtp_depth: int = 0                # DeepSeek: multi-token-prediction heads
    block_pattern: Tuple[str, ...] = ()   # xLSTM: e.g. ('slstm','mlstm')*12
    hybrid_parallel_heads: bool = False   # Hymba: attn & SSM heads in parallel
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    n_audio_frames: int = 1500        # stub frontend output length
    # vlm (llava)
    n_vision_patches: int = 0         # patch embeddings per request (anyres tiles)
    # numerics
    dtype: str = "bfloat16"
    # §Perf G5: store the decode KV cache quantised (e.g. "int8", with
    # per-(b,t,head) f16 scales) — halves the dominant decode memory term.
    # "" = cache in model dtype.
    kv_cache_dtype: str = ""
    norm_eps: float = 1e-5
    # parallelism defaults for this arch
    parallel: ParallelConfig = field(default_factory=ParallelConfig)
    source: str = ""                  # citation

    # ---- derived -----------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def n_params(self) -> int:
        """Approximate total parameter count (for roofline MODEL_FLOPS)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        hd = self.resolved_head_dim
        emb = V * D * (1 if self.tie_embeddings else 2)
        per_layer = 0          # common (attention / ssm) per-layer params
        if self.mla is not None:
            m = self.mla
            qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
            per_layer += D * m.q_lora_rank + m.q_lora_rank * self.n_heads * qk_hd
            per_layer += D * (m.kv_lora_rank + m.qk_rope_head_dim)
            per_layer += m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
            per_layer += self.n_heads * m.v_head_dim * D
        elif self.family != "ssm":
            per_layer += D * self.n_heads * hd          # Wq
            per_layer += 2 * D * self.n_kv_heads * hd   # Wk, Wv
            per_layer += self.n_heads * hd * D          # Wo
        if self.ssm is not None:
            inner = self.ssm.expand * D
            per_layer += 2 * D * inner + inner * D      # in/gate/out proj
            per_layer += inner * self.ssm.state_dim * 2  # B,C proj (approx)
        total = emb + self.n_layers * per_layer
        if self.moe is not None:
            e = self.moe
            routed = e.n_experts * 3 * D * e.d_ff_expert
            shared = e.n_shared_experts * 3 * D * (e.d_ff_shared or e.d_ff_expert)
            n_moe_layers = self.n_layers - self.first_k_dense
            total += n_moe_layers * (routed + shared + D * e.n_experts)
            total += self.first_k_dense * 3 * D * F     # dense-FFN head layers
        elif F > 0:
            total += self.n_layers * 3 * D * F          # SwiGLU
        if self.encoder_layers:
            enc_layer = 4 * D * D + 3 * D * F
            total += self.encoder_layers * enc_layer
            total += self.n_layers * 4 * D * D          # cross-attention
        return total

    def n_active_params(self) -> int:
        """Active (per-token) parameters — differs for MoE."""
        if self.moe is None:
            return self.n_params()
        e = self.moe
        D = self.d_model
        per_layer_routed_all = e.n_experts * 3 * D * e.d_ff_expert
        per_layer_routed_act = e.top_k * 3 * D * e.d_ff_expert
        n_moe_layers = self.n_layers - self.first_k_dense
        return self.n_params() - n_moe_layers * (per_layer_routed_all - per_layer_routed_act)

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                         # "train" | "prefill" | "decode"


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

INPUT_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced variant of the same family: 2 layers, d_model<=512, <=4 experts."""
    d_model = min(cfg.d_model, 256)
    n_heads = min(cfg.n_heads, 4)
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    # keep the GQA ratio flavour: at least 2:1 when original had grouping
    if cfg.n_kv_heads < cfg.n_heads and n_kv == n_heads:
        n_kv = max(1, n_heads // 2)
    head_dim = min(cfg.resolved_head_dim, 64)
    kw = dict(
        n_layers=2,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        parallel=ParallelConfig(remat="none"),
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe,
            n_experts=min(cfg.moe.n_experts, 4),
            top_k=min(cfg.moe.top_k, 2),
            d_ff_expert=min(cfg.moe.d_ff_expert, 256),
            n_shared_experts=min(cfg.moe.n_shared_experts, 1),
            d_ff_shared=min(cfg.moe.d_ff_shared, 256) if cfg.moe.d_ff_shared else 0,
            # effectively dropless at smoke scale so prefill+decode is
            # bit-consistent with the full forward (capacity bucketing
            # depends on which tokens are co-batched)
            capacity_factor=8.0,
        )
        kw["first_k_dense"] = min(cfg.first_k_dense, 1)
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                              qk_nope_head_dim=32, qk_rope_head_dim=16,
                              v_head_dim=32)
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, state_dim=min(cfg.ssm.state_dim, 8),
                                        chunk=32)
    if cfg.block_pattern:
        kw["block_pattern"] = cfg.block_pattern[:2]
    if cfg.encoder_layers:
        kw["encoder_layers"] = 2
        kw["n_audio_frames"] = 32
    if cfg.n_vision_patches:
        kw["n_vision_patches"] = 16
    if cfg.mtp_depth:
        kw["mtp_depth"] = 1
    return cfg.with_(**kw)
