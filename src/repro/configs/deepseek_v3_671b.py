"""deepseek-v3-671b — MLA + 256-expert top-8 MoE + MTP. [arXiv:2412.19437]

61L d_model=7168 128H (kv=128 — MLA shares the latent) d_ff=2048(expert),
vocab=129280; 1 shared + 256 routed experts, top-8; first 3 layers dense
(d_ff=18432); multi-token-prediction depth 1.

Stress config (violates paper Condition #1) — see DESIGN.md. Uses the
sort_scatter MoE dispatch (E=256 makes GShard one-hot masks prohibitive) and
bf16 optimizer moments + FSDP to fit 256 chips.
"""
from .base import ModelConfig, MoEConfig, MLAConfig, ParallelConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,                     # v_head_dim; qk dims come from MLA
    d_ff=18432,                       # dense-FFN dim for the first_k_dense layers
    vocab_size=129280,
    rope_theta=10_000.0,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    # §Perf D2: shard_map all-to-all dispatch. The pjit sort_scatter path
    # forces a full (E*C, D) buffer all-reduce per layer (110 TB/step);
    # a2a moves only the routed tokens: collective term 2202 s -> 61 s.
    moe=MoEConfig(n_experts=256, top_k=8, d_ff_expert=2048,
                  n_shared_experts=1, d_ff_shared=2048,
                  dispatch="a2a"),
    first_k_dense=3,
    mtp_depth=1,
    parallel=ParallelConfig(
        fsdp=True,
        microbatch=4,
        optimizer_moment_dtype="bfloat16",
        seq_parallel=False,              # §Perf E4/D3 (same as llama3-405b)
    ),
    source="[arXiv:2412.19437]",
)
