"""xlstm-350m — sLSTM + mLSTM block stack. [arXiv:2405.04517]

24L d_model=1024 4H (kv=4) d_ff=0 vocab=50304.  Blocks alternate
(sLSTM, mLSTM); d_ff=0 means the blocks use their own up/down projections
(pre-up-projection mLSTM / post-up-projection sLSTM) rather than a separate
SwiGLU FFN. Fully recurrent => long_500k is native (constant state).
"""
from .base import ModelConfig, ParallelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab_size=50304,
    ssm=SSMConfig(state_dim=256, expand=2, chunk=128),
    block_pattern=("slstm", "mlstm") * 12,
    # §Perf X2-X4: a 350M recurrent model on 256 chips wants pure 256-way
    # data parallelism — sequence sharding is meaningless for a time-
    # sequential recurrence, and TP all-reduces of tiny tensors dominate.
    # With chunk-checkpointed scans (X1) + batch-local shard_map recurrence
    # (X4) the train_4k dominant term drops 6.25 s -> 0.032 s (195x).
    parallel=ParallelConfig(seq_parallel=False, tensor_parallel=False),
    source="[arXiv:2405.04517]",
)
