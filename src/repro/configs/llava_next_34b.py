"""llava-next-34b — VLM backbone with anyres tiling.

[hf:llava-hf/llava-v1.6-mistral-7b-hf] (family); backbone dims per assignment:
60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.

The ViT/SigLIP vision tower + projector is a STUB: ``input_specs`` provides
precomputed patch embeddings of shape (batch, n_vision_patches, d_model) —
the anyres tiling of a 672x672 image into 5 tiles of 24x24 patches => 2880
patch embeddings per request.
"""
from .base import ModelConfig, ParallelConfig

CONFIG = ModelConfig(
    arch_id="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    rope_theta=5_000_000.0,
    n_vision_patches=2880,            # anyres: 5 tiles x 576 patches
    parallel=ParallelConfig(fsdp=True),
    source="[hf:llava-hf/llava-v1.6-mistral-7b-hf]",
)
