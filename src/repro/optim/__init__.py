from .adamw import AdamWState, adamw_init, adamw_update, global_norm  # noqa: F401
from .schedules import constant, linear_warmup_cosine  # noqa: F401
