"""AdamW with configurable moment dtype (bf16 moments fit the 405B/671B
train_4k dry-runs in v5e HBM — see EXPERIMENTS.md §Dry-run) and global-norm
clipping. Pure pytree implementation (no optax dependency)."""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def adamw_init(params, moment_dtype: str = "float32") -> AdamWState:
    dt = jnp.dtype(moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree_util.tree_map(zeros, params),
        nu=jax.tree_util.tree_map(zeros, params),
    )


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(grads, state: AdamWState, params, *, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1,
                 clip_norm: float = 1.0) -> Tuple[Any, AdamWState, Dict]:
    """Returns (new_params, new_state, metrics). ``lr`` may be a scalar array
    (schedules evaluate it outside)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if clip_norm else jnp.float32(1.0)

    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32) * scale
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = m_new / c1
        vhat = v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay \
            * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return (p_new.astype(p.dtype), m_new.astype(m.dtype),
                v_new.astype(v.dtype))

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v), metrics
