"""Logical-axis sharding rules -> NamedShardings, plus in-model hints.

Model code never names mesh axes directly; it annotates tensors with
*logical* axes (``hint(x, "batch", "seq_act", "embed")``).  A
:class:`ShardingCtx` (active inside ``with sharding_ctx(mesh, cfg):``)
resolves logical axes to mesh axes via the rules table, dropping any axis
whose size does not divide the tensor dim (e.g. 8 kv heads on a 16-way
'model' axis -> replicated).  Outside a context the hints are no-ops, so the
same model code runs single-device (smoke tests, live executor) and on the
production mesh (dry-run, launchers).
"""
from __future__ import annotations

import contextlib
import contextvars
import re
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# logical axis -> preferred mesh axes (first that exists & divides wins; a
# tuple value means "shard over the product of these axes").
def default_rules(cfg) -> Dict[str, Tuple[Tuple[str, ...], ...]]:
    par = cfg.parallel
    fsdp_axes = (("pod", "data"), ("data",)) if par.fsdp else ()
    tp = (("model",),) if par.tensor_parallel else ()   # §Perf X3
    # without TP the model axis joins data parallelism (256-way DP)
    batch_rules = ((("pod", "data")), ("data",)) if par.tensor_parallel \
        else (("pod", "data", "model"), ("data", "model"),
              ("pod", "data"), ("data",))
    return {
        "batch": batch_rules,
        "seq_act": ((("model",),) if par.seq_parallel else ()),   # activations
        "cache_seq": ((("model",),) if par.context_parallel_decode else ()),
        "heads": tp,
        "kv_heads": tp,
        "ff": tp,
        "experts": (("model",),),    # expert parallelism is its own knob
        "expert_cap": (),
        "vocab": tp,
        "embed": fsdp_axes,          # FSDP: param d_model dim over data axes
        "embed_act": (),             # activation d_model dim: replicated
        "qk": (), "state": (), "lora": (), "conv": (), "inner": tp,
        None: (),
    }


class ShardingCtx:
    def __init__(self, mesh: Mesh, cfg):
        self.mesh = mesh
        self.cfg = cfg
        self.rules = default_rules(cfg)
        self.axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def resolve(self, logical: Sequence[Optional[str]],
                shape: Sequence[int]) -> P:
        spec = []
        used: set = set()
        for dim, name in zip(shape, logical):
            if name is None:
                spec.append(None)
                continue
            cands = self.rules.get(name, ())
            # normalise: each candidate is a tuple of mesh axis names
            norm = []
            for c in cands:
                if isinstance(c, str):
                    norm.append((c,))
                else:
                    norm.append(tuple(c))
            chosen = None
            for axes in norm:
                axes = tuple(a for a in axes if a in self.axis_sizes
                             and a not in used)
                if not axes:
                    continue
                size = int(np.prod([self.axis_sizes[a] for a in axes]))
                if size > 1 and dim % size == 0:
                    chosen = axes
                    break
            if chosen:
                used.update(chosen)
                spec.append(chosen if len(chosen) > 1 else chosen[0])
            else:
                spec.append(None)
        return P(*spec)

    def sharding(self, logical, shape) -> NamedSharding:
        return NamedSharding(self.mesh, self.resolve(logical, shape))


_ACTIVE: contextvars.ContextVar[Optional[ShardingCtx]] = \
    contextvars.ContextVar("sharding_ctx", default=None)


@contextlib.contextmanager
def sharding_ctx(mesh: Mesh, cfg):
    ctx = ShardingCtx(mesh, cfg)
    token = _ACTIVE.set(ctx)
    try:
        with mesh:
            yield ctx
    finally:
        _ACTIVE.reset(token)


def active_ctx() -> Optional[ShardingCtx]:
    return _ACTIVE.get()


def hint(x, *logical: Optional[str]):
    """Annotate ``x``'s dims with logical axes; no-op outside a context."""
    ctx = _ACTIVE.get()
    if ctx is None:
        return x
    assert len(logical) == x.ndim, (logical, x.shape)
    return jax.lax.with_sharding_constraint(
        x, ctx.sharding(logical, x.shape))


def cotangent_dtype_pin(x, dtype):
    """Identity that casts the COTANGENT to ``dtype`` at this boundary.

    The attention/rope/softmax internals run in f32; without a boundary
    pin XLA propagates f32 cotangents across the residual stream and the
    per-layer TP all-reduces of dx run at double width (llama3-405b:
    136 s → 74 s collective — EXPERIMENTS.md §Perf E5)."""
    import jax.numpy as jnp

    @jax.custom_vjp
    def ident(t):
        return t

    def fwd(t):
        return t, None

    def bwd(_, g):
        return (g.astype(dtype),)

    ident.defvjp(fwd, bwd)
    return ident(x)


def grad_hint(tree):
    """Identity on ``tree`` that pins the COTANGENT's sharding to the param
    rules.  Applied to each scanned layer's params: without it, the
    backward-of-scan carries stacked dW replicated and every layer's
    weight-grad becomes a full-size all-reduce instead of a reduce-scatter
    (measured 25.5 TB/step on llama3-405b train_4k — EXPERIMENTS.md §Perf).
    No-op outside a sharding context."""
    ctx = _ACTIVE.get()
    if ctx is None:
        return tree
    spec_tree = param_specs(tree, ctx)

    @jax.custom_vjp
    def ident(t):
        return t

    def fwd(t):
        return t, None

    def bwd(_, g):
        return (jax.lax.with_sharding_constraint(g, spec_tree),)

    ident.defvjp(fwd, bwd)
    return ident(tree)


# ---------------------------------------------------------------------------
# Param specs by leaf-name rules
# ---------------------------------------------------------------------------

# Leaf-name -> logical axes of the *unstacked* trailing dims.  Stacked layer
# axes (any leading dims beyond the rule length) resolve to None.
_PARAM_RULES = [
    (r"embed$", ("vocab", "embed")),
    (r"lm_head$", ("embed", "vocab")),
    (r"proj_vision.*w1$", ("embed", "ff")),
    (r"proj_vision.*w2$", ("ff", "embed")),
    (r"wq$", ("embed", "heads", None)),
    (r"wk$", ("embed", "kv_heads", None)),
    (r"wv$", ("embed", "kv_heads", None)),
    (r"wo$", ("heads", None, "embed")),
    (r"wq_a$", ("embed", "lora")),
    (r"wq_b$", ("lora", "heads", None)),
    (r"wkv_a$", ("embed", None)),
    (r"wk_b$", ("lora", "heads", None)),
    (r"wv_b$", ("lora", "heads", None)),
    (r"w1$", ("embed", "ff")),
    (r"w3$", ("embed", "ff")),
    (r"w2$", ("ff", "embed")),
    (r"router$", ("embed", None)),
    (r"we1$", ("experts", "embed", None)),
    (r"we3$", ("experts", "embed", None)),
    (r"we2$", ("experts", None, "embed")),
    (r"ws1$", ("embed", "ff")),
    (r"ws3$", ("embed", "ff")),
    (r"ws2$", ("ff", "embed")),
    (r"in_proj$", ("embed", "inner")),
    (r"out_proj$", ("inner", "embed")),
    (r"x_proj$", ("inner", None)),
    (r"dt_proj$", (None, "inner")),
    (r"A_log$", ("inner", None)),
    (r"(^|/)D$", ("inner",)),
    (r"conv$", (None, "inner")),
    (r"(wz|wi|wf|wo_g|wo_gate)$", ("embed", "heads", None)),
    (r"(rz|ri|rf|ro)$", ("heads", None, None)),
    (r"(up|up_z)$", ("embed", "inner")),
    (r"down$", ("inner", "embed")),
]


def _leaf_spec(path: str, shape: Tuple[int, ...], ctx: ShardingCtx) -> P:
    for pat, logical in _PARAM_RULES:
        if re.search(pat, path):
            pad = len(shape) - len(logical)
            if pad < 0:      # e.g. non-parametric norm scalars
                break
            full = (None,) * pad + tuple(logical)
            return ctx.resolve(full, shape)
    return P()               # replicate (norms, biases, small tables)


def param_specs(params, ctx: ShardingCtx):
    """PartitionSpec pytree for a param tree, by leaf-name rules."""
    def visit(path, leaf):
        keys = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        return _leaf_spec(keys, leaf.shape, ctx)
    return jax.tree_util.tree_map_with_path(visit, params)


def param_shardings(params, ctx: ShardingCtx):
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(ctx.mesh, spec),
        param_specs(params, ctx),
        is_leaf=lambda s: isinstance(s, P))


# ---------------------------------------------------------------------------
# Cache specs (decode)
# ---------------------------------------------------------------------------

def cache_specs(cache, ctx: ShardingCtx):
    """Shard KV/state caches: batch over data axes; kv-head axis over model
    when divisible; else (context parallelism) the cache seq axis."""
    def visit(path, leaf):
        keys = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        shape = leaf.shape
        if re.search(r"(^|/)(k|v)$", keys) and leaf.ndim == 5:
            # (L, B, T, K, hd): when context_parallel_decode is on the
            # cache_seq rule claims 'model' first (dim order) and kv heads
            # replicate; otherwise heads take 'model' when divisible.
            return ctx.resolve((None, "batch", "cache_seq", "kv_heads", None),
                               shape)
        if re.search(r"(k|v)_scale$", keys) and leaf.ndim == 4:
            # int8-cache scales (L,B,T,K) — §Perf G5
            return ctx.resolve((None, "batch", "cache_seq", "kv_heads"),
                               shape)
        if re.search(r"ckv$|k_rope$", keys) and leaf.ndim == 4:
            return ctx.resolve((None, "batch", "cache_seq", None), shape)
        if leaf.ndim >= 2:
            return ctx.resolve((None, "batch") + (None,) * (leaf.ndim - 2),
                               shape)
        return P()
    return jax.tree_util.tree_map_with_path(visit, cache)
