"""Serving driver: opportunistic throughput-oriented inference, live.

Runs the Prompt-for-Fact application through the REAL context-management
stack on this host: a pool of simulated workers (sharing this container's
device) is driven by the LiveExecutor; contexts are really materialised
(imports, weights, jit) and really reused.

Two submission modes:

* ``--stream`` (default) — the request-stream API: one request per claim
  with a decode-step budget; resident libraries continuously admit
  requests into their in-flight batch (the padded JAX batch is re-formed
  between steps with bucketed shapes).  Reports throughput AND the
  per-request latency distributions (queue wait, time-to-first-step).
* ``--batch-tasks`` — the deprecated run-to-completion batch path (the
  paper's original pv2/pv4 shape), kept as the comparison baseline.

  PYTHONPATH=src python -m repro.launch.serve --claims 64 \
      --mode pervasive --workers 3 --stream
"""
from __future__ import annotations

import argparse
import sys
import time

from repro.cluster import (Application, Gateway, LiveExecutor, Scheduler,
                           Worker, format_class_latency, format_gateway,
                           format_latency, format_pool, format_zone_bytes,
                           pool_summary)
from repro.cluster.hardware import GPU_CATALOG
from repro.configs import get_smoke_config
from repro.core import MODES
from repro.data import accuracy, claim_batches, generate_claims
from repro.data.tokenizer import ByteTokenizer
from repro.inference import (MAX_NEW, build_context_recipe, infer_claims,
                             make_pff_step_fn, stream_verdict)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm2-1.7b")
    ap.add_argument("--claims", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8,
                    help="claims per task in --batch-tasks mode")
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--mode", default="pervasive",
                    choices=sorted(MODES))
    ap.add_argument("--template", default="with_evidence")
    group = ap.add_mutually_exclusive_group()
    group.add_argument("--stream", action="store_true", default=True,
                       help="request-stream API with continuous batching "
                            "(default)")
    group.add_argument("--batch-tasks", dest="stream",
                       action="store_false",
                       help="deprecated run-to-completion batch tasks")
    ap.add_argument("--interactive-every", type=int, default=0,
                    metavar="N",
                    help="mark every Nth claim INTERACTIVE (deadline'd, "
                         "may preempt batch slots); 0 = all batch class")
    ap.add_argument("--deadline", type=float, default=60.0,
                    help="relative queue deadline for interactive "
                         "requests (seconds)")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch)
    claims = generate_claims(args.claims, seed=1)
    recipe = build_context_recipe(cfg, args.template)
    mode = MODES[args.mode]
    if args.stream and not mode.state_resident:
        # continuous batching presupposes a resident context; the
        # partial/naive baselines only exist as run-to-completion tasks
        print(f"[serve] mode={args.mode} is not state-resident; "
              f"falling back to --batch-tasks")
        args.stream = False

    sched = Scheduler()
    app = Application(sched, default_mode=mode)
    key = app.register(recipe)
    for _ in range(args.workers):
        sched.add_worker(Worker(GPU_CATALOG["NVIDIA A10"], zone="z0"))

    t0 = time.perf_counter()
    if args.stream:
        # the serving gateway fronts every stream submission: SLO classes,
        # bounded queues, deadline semantics (all-batch traffic passes
        # through untouched — the batch class queues unbounded)
        from repro.cluster import ClassPolicy
        gw = Gateway(sched, interactive=ClassPolicy(
            max_queue=64, overflow="reject", deadline_s=args.deadline))
        ex = LiveExecutor(sched, step_fns={key: make_pff_step_fn()})
        every = args.interactive_every
        for i, c in enumerate(claims):
            slo = ("interactive" if every and (i % every == 0)
                   else "batch")
            app.submit(key, decode_steps=MAX_NEW, payload=c,
                       arrival_s=ex.now(), slo=slo)
        ex.run()
        tok = ByteTokenizer(cfg.vocab_size)
        preds = [stream_verdict(tok, ex.results[r.request_id])
                 for r in app.requests
                 if r.request_id in ex.results]
        n_done = len(preds)
    else:
        import warnings
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            from repro.cluster.scheduler import Task
            for b in claim_batches(claims, args.batch):
                sched.submit(Task(key, len(b), mode, payload=b))
        ex = LiveExecutor(sched, {key: infer_claims})
        ex.run()
        preds = []
        for tid in sorted(ex.results):
            preds.extend(ex.results[tid])
        n_done = len(preds)
    dt = time.perf_counter() - t0

    acc = accuracy(preds, claims)
    recs = sched.records
    cold = [r.exec_s for r in recs if not r.warm]
    warm = [r.exec_s for r in recs if r.warm]
    api = "stream" if args.stream else "batch-tasks"
    print(f"[serve] api={api} mode={args.mode} workers={args.workers} "
          f"claims={len(claims)}")
    print(f"  wall {dt:.2f}s  throughput {n_done/dt:.1f} inf/s  "
          f"accuracy {acc:.3f}")
    if cold:
        print(f"  cold requests: {len(cold)}  "
              f"mean {sum(cold)/len(cold):.2f}s")
    if warm:
        print(f"  warm requests: {len(warm)}  "
              f"mean {sum(warm)/len(warm):.3f}s")
    if args.stream:
        print(format_class_latency(app.class_latency_summary()))
        print(format_gateway(gw))
        # supply-side view: per-class joins/evictions (no factory in the
        # live path — target/lead-time rows appear only under one)
        print(format_pool(pool_summary(sched)))
        print(f"  admissions into live batches: {sched.admissions}  "
              f"preemptions: {sched.preemptions}")
    # context-plane run summary: per-zone transfer bytes + op counters
    print(format_zone_bytes(sched.plane))
    return 0


if __name__ == "__main__":
    sys.exit(main())
