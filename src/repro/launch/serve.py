"""Serving driver: opportunistic throughput-oriented inference, live.

Runs the Prompt-for-Fact application through the REAL context-management
stack on this host: a pool of simulated workers (sharing this container's
device) is driven by the LiveExecutor; contexts are really materialised
(imports, weights, jit) and really reused.  Reports per-mode throughput —
the live analogue of the paper's pv2 vs pv4 comparison.

  PYTHONPATH=src python -m repro.launch.serve --claims 64 --batch 8 \
      --mode pervasive --workers 3
"""
from __future__ import annotations

import argparse
import sys
import time

from repro.cluster import LiveExecutor, Scheduler, Worker
from repro.cluster.hardware import GPU_CATALOG
from repro.configs import get_smoke_config
from repro.core import MODES
from repro.data import accuracy, claim_batches, generate_claims
from repro.inference import build_context_recipe, infer_claims


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm2-1.7b")
    ap.add_argument("--claims", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--mode", default="pervasive",
                    choices=sorted(MODES))
    ap.add_argument("--template", default="with_evidence")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch)
    claims = generate_claims(args.claims, seed=1)
    recipe = build_context_recipe(cfg, args.template)
    mode = MODES[args.mode]

    sched = Scheduler()
    key = sched.register_context(recipe)
    for w in range(args.workers):
        sched.add_worker(Worker(GPU_CATALOG["NVIDIA A10"], zone="z0"))
    batches = claim_batches(claims, args.batch)
    from repro.cluster.scheduler import Task
    for b in batches:
        sched.submit(Task(key, len(b), mode, payload=b))

    ex = LiveExecutor(sched, {key: infer_claims})
    t0 = time.perf_counter()
    ex.run()
    dt = time.perf_counter() - t0

    preds = []
    for tid in sorted(ex.results):
        preds.extend(ex.results[tid])
    acc = accuracy(preds, claims)
    recs = sched.records
    cold = [r.exec_s for r in recs if not r.warm]
    warm = [r.exec_s for r in recs if r.warm]
    print(f"[serve] mode={args.mode} workers={args.workers} "
          f"claims={len(claims)} batch={args.batch}")
    print(f"  wall {dt:.2f}s  throughput {len(claims)/dt:.1f} inf/s  "
          f"accuracy {acc:.3f}")
    if cold:
        print(f"  cold tasks: {len(cold)}  mean {sum(cold)/len(cold):.2f}s")
    if warm:
        print(f"  warm tasks: {len(warm)}  mean {sum(warm)/len(warm):.3f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
