"""ShapeDtypeStruct stand-ins for every model input (dry-run inputs).

``input_specs(cfg, shape)`` returns exactly what ``train_step`` /
``prefill_step`` / ``serve_step`` take, as ShapeDtypeStructs — weak-type
correct, shardable, zero allocation.  Modality frontends are stubs per the
assignment: VLM/audio entries provide precomputed patch/frame embeddings.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs import InputShape, ModelConfig
from ..models import model as M
from ..models.model import VISION_EMBED_DIM


def batch_specs(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, Any]:
    """The token batch (+ stub modality embeddings) for one step."""
    dt = jnp.dtype(cfg.dtype)
    specs: Dict[str, Any] = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
    if cfg.family == "vlm":
        specs["vision_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_vision_patches, VISION_EMBED_DIM), dt)
    if cfg.is_encdec:
        specs["audio_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_audio_frames, cfg.d_model), dt)
    return specs


def params_specs(cfg: ModelConfig) -> Any:
    return jax.eval_shape(
        lambda k: M.init_params(cfg, k), jax.random.PRNGKey(0))


def cache_specs_struct(cfg: ModelConfig, batch: int, max_len: int) -> Any:
    return jax.eval_shape(lambda: M.cache_init(cfg, batch, max_len))


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """All inputs for the step this shape lowers (see launch.steps)."""
    if shape.kind == "train":
        return {"batch": batch_specs(cfg, shape.global_batch, shape.seq_len)}
    if shape.kind == "prefill":
        return {"batch": batch_specs(cfg, shape.global_batch, shape.seq_len)}
    # decode: ONE new token against a seq_len-deep cache
    return {
        "cache": cache_specs_struct(cfg, shape.global_batch, shape.seq_len),
        "tokens": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32),
    }
