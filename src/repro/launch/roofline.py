"""Scan-depth-corrected roofline terms from the compiled dry-run.

XLA's ``cost_analysis()`` on the partitioned module reports PER-DEVICE
numbers and counts each ``lax.scan`` body ONCE regardless of trip count
(verified empirically — see EXPERIMENTS.md §Roofline methodology).  Since
the models scan over layers, the raw numbers undercount by ~n_layers.

Correction: lower each stage's body separately (same mesh, same logical-
axis shardings), take its per-device flops / bytes / collective bytes, and
add ``(trip_count - 1) ×`` body for every scanned stage.  Train bodies are
lowered as ``grad(body)`` (fwd+bwd+remat — matching what the full step's
forward and backward scans contain); decode bodies take a per-layer cache
slice.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import InputShape, ModelConfig
from ..models import model as M
from ..models.blocks import BLOCKS
from ..models.model import VISION_EMBED_DIM, stages_for
from ..sharding import ShardingCtx, cache_specs, param_specs


def _ns(ctx: ShardingCtx, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(ctx.mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P))


def _strip_lead(spec: P) -> P:
    return P(*tuple(spec)[1:])


def _body_metrics(fn, args, in_sh, parse_collectives) -> Dict[str, float]:
    lowered = jax.jit(fn, in_shardings=in_sh).lower(*args)
    compiled = lowered.compile()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):    # older jax: list of per-program dicts
        cost = cost[0] if cost else {}
    coll = parse_collectives(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(sum(coll.values())),
    }


def stage_body_metrics(cfg: ModelConfig, shape: InputShape,
                       ctx: ShardingCtx, btype: str,
                       parse_collectives) -> Dict[str, float]:
    """Per-device metrics of ONE scanned iteration of stage ``btype``."""
    dtype = jnp.dtype(cfg.dtype)
    layer_p = jax.eval_shape(
        lambda k: BLOCKS[btype]["init"](k, cfg, dtype), jax.random.PRNGKey(0))
    p_sh = _ns(ctx, param_specs(layer_p, ctx))
    B, S = shape.global_batch, shape.seq_len
    D = cfg.d_model
    extras_spec: Dict[str, Any] = {}
    extras_sh: Dict[str, Any] = {}
    if btype in ("dec",):                      # whisper decoder cross-attn
        extras_spec["enc_out"] = jax.ShapeDtypeStruct(
            (B, cfg.n_audio_frames, D), dtype)
        extras_sh["enc_out"] = ctx.sharding(("batch", None, None),
                                            extras_spec["enc_out"].shape)

    if shape.kind == "decode":
        x = jax.ShapeDtypeStruct((B, 1, D), dtype)
        x_sh = ctx.sharding(("batch", None, "embed_act"), x.shape)
        cache1 = jax.eval_shape(
            lambda: BLOCKS[btype]["cache_init"](cfg, B, shape.seq_len, 1,
                                                dtype))
        cache_l = jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype), cache1)
        c_sh = jax.tree_util.tree_map(
            lambda s: NamedSharding(ctx.mesh, _strip_lead(s)),
            cache_specs(cache1, ctx), is_leaf=lambda s: isinstance(s, P))
        pos = jax.ShapeDtypeStruct((), jnp.int32)

        def fn(p, xx, cl, pp, ex):
            return BLOCKS[btype]["decode"](p, cfg, xx, cl, pp, ex)

        return _body_metrics(fn, (layer_p, x, cache_l, pos, extras_spec),
                             (p_sh, x_sh, c_sh,
                              NamedSharding(ctx.mesh, P()), extras_sh),
                             parse_collectives)

    S_eff = S + (cfg.n_vision_patches if cfg.family == "vlm" else 0)
    if btype == "enc":
        S_eff = cfg.n_audio_frames
    x = jax.ShapeDtypeStruct((B, S_eff, D), dtype)
    x_sh = ctx.sharding(("batch", "seq_act", "embed_act"), x.shape)
    positions = jax.ShapeDtypeStruct((S_eff,), jnp.int32)
    pos_sh = NamedSharding(ctx.mesh, P())
    apply = BLOCKS[btype]["apply"]

    if shape.kind == "train":
        def fwd(p, xx, ex):
            return apply(p, cfg, xx, jnp.arange(S_eff), ex)[0]
        if cfg.parallel.remat == "block":
            fwd = jax.checkpoint(fwd)
        fn = jax.grad(
            lambda p, xx, ex: fwd(p, xx, ex).astype(jnp.float32).sum(),
            argnums=(0, 1))
        return _body_metrics(fn, (layer_p, x, extras_spec),
                             (p_sh, x_sh, extras_sh), parse_collectives)

    # prefill: forward + cache build (encoders have no prefill: plain apply)
    if BLOCKS[btype].get("prefill") is None:
        def fn(p, xx, pp, ex):
            return apply(p, cfg, xx, pp, ex)
    else:
        def fn(p, xx, pp, ex):
            return BLOCKS[btype]["prefill"](p, cfg, xx, pp, ex,
                                            shape.seq_len)

    return _body_metrics(fn, (layer_p, x, positions, extras_spec),
                         (p_sh, x_sh, pos_sh, extras_sh), parse_collectives)


def scan_corrections(cfg: ModelConfig, shape: InputShape, ctx: ShardingCtx,
                     parse_collectives) -> Tuple[Dict[str, float],
                                                 Dict[str, float]]:
    """Returns (extra, per_stage_detail): per-device metric deltas to add to
    the raw full-step numbers so scanned stages count ×trip instead of ×1."""
    extra = {"flops": 0.0, "bytes": 0.0, "coll": 0.0}
    detail: Dict[str, float] = {}
    stages = list(stages_for(cfg))
    if cfg.is_encdec and shape.kind != "decode":
        stages.append(("enc", cfg.encoder_layers))
    seen: Dict[str, Dict[str, float]] = {}
    for btype, n in stages:
        if n <= 1:
            continue
        if btype not in seen:
            seen[btype] = stage_body_metrics(cfg, shape, ctx, btype,
                                             parse_collectives)
        m = seen[btype]
        for k in extra:
            extra[k] += (n - 1) * m[k]
        detail[f"{btype}_flops_per_layer"] = m["flops"]
    return extra, detail
