"""End-to-end training driver (runs on this host's devices).

Trains a reduced variant of any assigned architecture on the synthetic
claim stream — the full pipeline: config → init → sharded train_step →
data loader → checkpointing.

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --steps 200 \
      --d-model 256 --layers 4 --batch 8 --seq 256 [--ckpt /tmp/ck]
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro.checkpointing import restore_checkpoint, save_checkpoint
from repro.configs import ParallelConfig, get_config
from repro.data import ByteTokenizer, TokenStream
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.models import model as M
from repro.optim import adamw_init
from repro.sharding import sharding_ctx


def reduced(cfg, d_model: int, layers: int):
    n_heads = max(2, min(cfg.n_heads, d_model // 64))
    n_kv = max(1, n_heads // max(1, cfg.q_per_kv))
    kw = dict(n_layers=layers, d_model=d_model, n_heads=n_heads,
              n_kv_heads=n_kv, head_dim=min(64, d_model // n_heads),
              d_ff=min(cfg.d_ff, 4 * d_model) if cfg.d_ff else 0,
              vocab_size=min(cfg.vocab_size, 2048),
              parallel=ParallelConfig(remat="none"))
    if cfg.block_pattern:
        kw["block_pattern"] = tuple(
            cfg.block_pattern[i % len(cfg.block_pattern)]
            for i in range(layers))
    if cfg.encoder_layers:
        kw["encoder_layers"] = 2
        kw["n_audio_frames"] = 64
    if cfg.n_vision_patches:
        kw["n_vision_patches"] = 16
    return cfg.with_(**kw)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = reduced(get_config(args.arch), args.d_model, args.layers)
    mesh = make_host_mesh()
    tok = ByteTokenizer(cfg.vocab_size)
    stream = iter(TokenStream(tok, batch=args.batch, seq_len=args.seq))

    with sharding_ctx(mesh, cfg):
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        n_par = M.count_params(params)
        print(f"[train] {args.arch} reduced: {n_par/1e6:.1f}M params, "
              f"mesh {mesh.devices.shape}")
        opt = adamw_init(params, cfg.parallel.optimizer_moment_dtype)
        step_fn = jax.jit(make_train_step(cfg), donate_argnums=(0, 1))
        start = 0
        if args.ckpt:
            from repro.checkpointing import checkpoint_step
            s = checkpoint_step(args.ckpt)
            if s is not None:
                params = restore_checkpoint(args.ckpt, params)
                start = s
                print(f"[train] resumed from step {start}")
        t0 = time.time()
        losses = []
        for step in range(start, args.steps):
            batch = {k: jax.numpy.asarray(v)
                     for k, v in next(stream).items()}
            if cfg.family == "vlm":
                batch["vision_embeds"] = jax.numpy.zeros(
                    (args.batch, cfg.n_vision_patches, 1024), cfg.dtype)
            if cfg.is_encdec:
                batch["audio_embeds"] = jax.numpy.zeros(
                    (args.batch, cfg.n_audio_frames, cfg.d_model), cfg.dtype)
            params, opt, metrics = step_fn(params, opt, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            if step % args.log_every == 0 or step == args.steps - 1:
                dt = time.time() - t0
                tput = (step - start + 1) * args.batch * args.seq / max(dt, 1e-9)
                print(f"  step {step:4d}  loss {loss:7.4f}  "
                      f"gnorm {float(metrics['grad_norm']):7.3f}  "
                      f"{tput:,.0f} tok/s")
            if np.isnan(loss):
                print("[train] NaN loss — aborting")
                return 1
        if args.ckpt:
            nbytes = save_checkpoint(args.ckpt, params, step=args.steps)
            print(f"[train] checkpoint: {nbytes/1e6:.1f} MB -> {args.ckpt}")
        first, last = np.mean(losses[:5]), np.mean(losses[-5:])
        print(f"[train] loss {first:.4f} -> {last:.4f} "
              f"({'improved' if last < first else 'NOT improved'})")
        return 0 if last < first else 1


if __name__ == "__main__":
    sys.exit(main())
