import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh).

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder host devices build the production meshes, every
step function must lower AND compile against them, and the compiled
artifact yields the roofline terms (cost_analysis + collective bytes from
the HLO) recorded in EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
      --shape train_4k [--multi-pod]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] \
      --out experiments/dryrun.jsonl
"""
import argparse
import json
import re
import sys
import time
from typing import Any, Dict, Optional

import jax

from repro.configs import (ASSIGNED_ARCHS, INPUT_SHAPES, get_config,
                           long_context_variant, serving_variant)
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_step
from repro.sharding import sharding_ctx

# --- TPU v5e hardware constants (roofline denominators) -------------------
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of an HLO shape string like 'bf16[16,1024]{1,0}'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes of every collective op, by op kind.

    Output-shape bytes is the standard proxy for wire traffic (exact
    per-algorithm factors like the all-gather's (n-1)/n are dropped; they
    are ≤1 and uniform across the comparisons we make).
    """
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        # '%x = TYPE[...] all-gather(...)' — op name after the shape
        m = re.search(r"=\s+((?:\([^)]*\)|\S+))\s+([\w-]+)", ls)
        if not m:
            continue
        op = m.group(2).rstrip(".0123456789")
        if op.endswith("-start"):
            op = op[:-6]
        if op in _COLLECTIVES:
            out[op] += _shape_bytes(m.group(1))
    return out


def _computations(hlo_text: str) -> Dict[str, str]:
    """Split an HLO module's text into named computation bodies."""
    comps: Dict[str, list] = {}
    cur = None
    for line in hlo_text.splitlines():
        m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(", line)
        if m and line.rstrip().endswith("{"):
            cur = m.group(2)
            comps[cur] = []
            if m.group(1):
                comps["__entry__"] = comps[cur]
        elif line.startswith("}"):
            cur = None
        elif cur is not None:
            comps[cur].append(line)
    return {k: "\n".join(v) for k, v in comps.items()}


_WHILE_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count":\{"n":"(\d+)"')


def collective_bytes_scaled(hlo_text: str) -> Dict[str, int]:
    """Collective bytes with while-loop bodies ×known_trip_count.

    ``lax.scan`` lowers to a while loop whose body appears ONCE in the
    module; XLA records the trip count in the op's backend_config.  We
    recurse through nested loops so per-layer collectives are counted
    once per layer, not once per program.
    """
    comps = _computations(hlo_text)
    memo: Dict[str, Dict[str, int]] = {}

    def total(name: str) -> Dict[str, int]:
        if name in memo:
            return memo[name]
        memo[name] = {k: 0 for k in _COLLECTIVES}   # break cycles
        text = comps.get(name, "")
        out = collective_bytes(text)
        for line in text.splitlines():
            if " while(" not in line:
                continue
            mb = _WHILE_BODY_RE.search(line)
            mt = _TRIP_RE.search(line)
            trip = int(mt.group(1)) if mt else 1
            if mb and mb.group(1) in comps:
                sub = total(mb.group(1))
                for k, v in sub.items():
                    out[k] += trip * v
        memo[name] = out
        return out

    return total("__entry__")


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N = active params."""
    n = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch          # one token per sequence


def baseline_variant(cfg):
    """Paper-faithful pre-optimisation parallelism (the §Perf baseline):
    the naive sharding a straightforward port would use — seq-parallel
    hints on, pjit-only MoE dispatch, replicated decode cache, FSDP
    everywhere.  Selected with --baseline / baseline=True."""
    import dataclasses
    kw = dict(seq_parallel=True, context_parallel_decode=False)
    cfg = cfg.with_(parallel=dataclasses.replace(cfg.parallel, **kw))
    if cfg.moe is not None and cfg.moe.dispatch == "a2a":
        disp = "sort_scatter" if cfg.moe.n_experts > 64 else "dense_onehot"
        cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, dispatch=disp))
    return cfg


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               mesh=None, verbose: bool = True,
               baseline: bool = False) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    t0 = time.time()
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "chips": n_chips, "variant": "baseline" if baseline else "optimized",
    }
    eff_cfg = long_context_variant(cfg) if shape.name == "long_500k" else cfg
    if baseline:
        eff_cfg = baseline_variant(eff_cfg)
        cfg = eff_cfg
    elif shape.kind == "decode":
        eff_cfg = serving_variant(eff_cfg)       # §Perf G4: no FSDP at decode
    elif shape.kind == "train":
        from repro.launch.steps import train_variant
        eff_cfg = train_variant(eff_cfg)         # §Perf Q1
    rec["attn_window"] = eff_cfg.attn_window
    from repro.launch.roofline import scan_corrections
    with sharding_ctx(mesh, eff_cfg) as ctx:
        fn, args, in_sh = make_step(cfg, shape, ctx,
                                    serving_fsdp_off=not baseline)
        # decode donates its cache (as a serving loop does every step);
        # train donates params+opt. Without donation XLA materialises a
        # full temp copy of the donated buffers (§Perf G3).
        donate = () if baseline else \
            {"decode": (1,), "train": (0, 1)}.get(shape.kind, ())
        lowered = jax.jit(fn, in_shardings=in_sh,
                          donate_argnums=donate).lower(*args)
        compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):  # older jax: per-program dicts
            cost = cost[0] if cost else {}
        # collectives: exact — while bodies scaled by known_trip_count
        coll = collective_bytes_scaled(compiled.as_text())
        # flops: cost_analysis counts scan bodies once; correct by lowering
        # each stage body separately (launch/roofline.py)
        extra, per_stage = scan_corrections(eff_cfg, shape, ctx,
                                            collective_bytes)

    flops_raw = float(cost.get("flops", 0.0))
    bytes_raw = float(cost.get("bytes accessed", 0.0))
    flops = flops_raw + extra["flops"]
    bytes_accessed = bytes_raw + extra["bytes"]
    coll_total = float(sum(coll.values()))
    # HBM traffic proxy: resident args + outputs + 2× temp churn.  The
    # operand-sum "bytes accessed" counts pre-fusion operand bytes and
    # overstates HBM traffic by ~10-100×; memory_analysis sizes are what
    # actually lives in (and must cross) HBM.
    hbm_bytes = 0.0
    if mem is not None:
        hbm_bytes = (float(getattr(mem, "argument_size_in_bytes", 0))
                     + float(getattr(mem, "output_size_in_bytes", 0))
                     + 2.0 * float(getattr(mem, "temp_size_in_bytes", 0)))
    # roofline terms are whole-step seconds: per-device work / per-chip peak
    rec.update({
        "hlo_flops_raw": flops_raw,
        "hlo_flops": flops,
        "hlo_bytes": bytes_accessed,
        "hbm_bytes": hbm_bytes,
        "collective_bytes": coll_total,
        "collectives": coll,
        "per_stage": per_stage,
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": hbm_bytes / HBM_BW,
        "collective_s": coll_total / ICI_BW,
        "model_flops": model_flops(cfg, shape),
        "lower_compile_s": round(time.time() - t0, 1),
    })
    total_flops = flops * n_chips
    rec["useful_flops_frac"] = (rec["model_flops"] / total_flops
                                if total_flops else 0.0)
    terms = {k: rec[k] for k in ("compute_s", "memory_s", "collective_s")}
    rec["bottleneck"] = max(terms, key=terms.get)
    if mem is not None:
        for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                rec[f"mem_{attr}"] = int(v)
    if verbose:
        print(f"[dryrun] {arch} × {shape_name} × {rec['mesh']}: "
              f"compile ok in {rec['lower_compile_s']}s")
        print(f"  memory_analysis: {mem}")
        print(f"  cost_analysis: flops={flops:.3e} bytes={bytes_accessed:.3e}")
        print(f"  collectives: {coll}")
        print(f"  roofline: compute={rec['compute_s']:.3e}s "
              f"memory={rec['memory_s']:.3e}s "
              f"collective={rec['collective_s']:.3e}s "
              f"-> {rec['bottleneck']}")
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=sorted(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--baseline", action="store_true",
                    help="paper-faithful pre-optimisation sharding")
    ap.add_argument("--all", action="store_true",
                    help="every assigned arch × shape")
    ap.add_argument("--out", default=None, help="append jsonl here")
    args = ap.parse_args(argv)

    combos = []
    if args.all:
        combos = [(a, s) for a in ASSIGNED_ARCHS for s in INPUT_SHAPES]
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape (or --all) required")
        combos = [(args.arch, args.shape)]

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    failures = []
    for arch, shape in combos:
        try:
            rec = dryrun_one(arch, shape, multi_pod=args.multi_pod,
                             mesh=mesh, baseline=args.baseline)
        except Exception as e:  # noqa: BLE001 — report, keep sweeping
            rec = {"arch": arch, "shape": shape, "error": repr(e)[:500],
                   "mesh": "x".join(map(str, mesh.devices.shape))}
            failures.append((arch, shape, repr(e)[:200]))
            print(f"[dryrun] FAIL {arch} × {shape}: {repr(e)[:200]}")
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for a, s, e in failures:
            print(f"  {a} × {s}: {e}")
        return 1
    print(f"\nall {len(combos)} combos compiled OK "
          f"on mesh {'x'.join(map(str, mesh.devices.shape))}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
