"""Step functions + their shardings: what the launchers and dry-run lower.

``make_step(cfg, shape)`` returns (fn, arg_specs, in_shardings) for the
step kind the input shape dictates:

  train   -> train_step(params, opt_state, batch) -> (params, opt, metrics)
  prefill -> prefill_step(params, batch)          -> (last logits, cache)
  decode  -> serve_step(params, cache, tokens)    -> (logits, cache)

All sharding decisions flow from sharding.py's logical-axis rules resolved
against the active mesh; nothing here names mesh axes directly.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs import (InputShape, ModelConfig, long_context_variant,
                       serving_variant)
from ..models import model as M
from ..optim import adamw_init, adamw_update
from ..sharding import ShardingCtx, cache_specs, param_specs
from jax.sharding import NamedSharding, PartitionSpec as P

from .specs import batch_specs, cache_specs_struct, input_specs, params_specs


# ---------------------------------------------------------------------------
# Step functions (pure; close over cfg only)
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig) -> Callable:
    k = max(1, cfg.parallel.microbatch)

    def train_step(params, opt_state, batch):
        if k == 1:
            (loss, metrics), grads = jax.value_and_grad(
                functools.partial(M.loss_fn, cfg), has_aux=True)(
                    params, batch)
        else:
            # gradient accumulation over k microbatches via lax.scan.
            # The accumulator MUST be constrained to the param shardings:
            # left to itself XLA replicates it, which turns each layer's
            # dW into a full-weight all-reduce per microbatch (measured:
            # 25.5 TB/step on llama3-405b — EXPERIMENTS.md §Perf E1).
            from ..sharding import active_ctx, param_specs
            ctx = active_ctx()
            g_spec = param_specs(params, ctx) if ctx is not None else None

            def pin(g):
                if g_spec is None:
                    return g
                return jax.lax.with_sharding_constraint(g, g_spec)

            # microbatches are UNROLLED (python loop), not lax.scan'd: the
            # scan carrier forces a single sharding on the stacked grads
            # that XLA resolves to `replicated`, turning every per-layer
            # dW into a full-size all-reduce (§Perf E1/E1b).
            grads = pin(jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            loss_sum = jnp.zeros((), jnp.float32)
            for i in range(k):
                mb = jax.tree_util.tree_map(
                    lambda x: x.reshape(
                        (k, x.shape[0] // k) + x.shape[1:])[i], batch)
                (loss, _m), g = jax.value_and_grad(
                    functools.partial(M.loss_fn, cfg), has_aux=True)(
                        params, mb)
                grads = pin(jax.tree_util.tree_map(
                    jnp.add, grads, pin(g)))
                loss_sum = loss_sum + loss
            grads = jax.tree_util.tree_map(lambda g: g / k, grads)
            loss = loss_sum / k
            metrics = {"loss": loss}
        new_params, new_opt, om = adamw_update(
            grads, opt_state, params, lr=3e-4)
        return new_params, new_opt, {**metrics, **om}

    return train_step


def make_prefill_step(cfg: ModelConfig, max_len: int) -> Callable:
    def prefill_step(params, batch):
        return M.prefill(cfg, params, batch, max_len)
    return prefill_step


def make_serve_step(cfg: ModelConfig) -> Callable:
    def serve_step(params, cache, tokens):
        return M.decode_step(cfg, params, cache, tokens)
    return serve_step


# ---------------------------------------------------------------------------
# Shardings
# ---------------------------------------------------------------------------

def _ns(ctx: ShardingCtx, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(ctx.mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P))


def shardings_for(cfg: ModelConfig, shape: InputShape, ctx: ShardingCtx
                  ) -> Tuple[Any, ...]:
    """in_shardings pytree matching make_step's arg order."""
    p_specs = param_specs(params_specs(cfg), ctx)
    p_sh = _ns(ctx, p_specs)
    if shape.kind == "train":
        opt_shape = jax.eval_shape(
            lambda: adamw_init(params_specs(cfg),
                               cfg.parallel.optimizer_moment_dtype))
        opt_sh = type(opt_shape)(
            step=NamedSharding(ctx.mesh, P()),
            mu=_ns(ctx, param_specs(opt_shape.mu, ctx)),
            nu=_ns(ctx, param_specs(opt_shape.nu, ctx)))
        b_sh = {
            k: NamedSharding(
                ctx.mesh, ctx.resolve(("batch",) + (None,) * (len(v.shape) - 1),
                                      v.shape))
            for k, v in batch_specs(cfg, shape.global_batch,
                                    shape.seq_len).items()}
        return (p_sh, opt_sh, b_sh)
    if shape.kind == "prefill":
        b_sh = {
            k: NamedSharding(
                ctx.mesh, ctx.resolve(("batch",) + (None,) * (len(v.shape) - 1),
                                      v.shape))
            for k, v in batch_specs(cfg, shape.global_batch,
                                    shape.seq_len).items()}
        return (p_sh, b_sh)
    # decode
    cache_shape = cache_specs_struct(cfg, shape.global_batch, shape.seq_len)
    c_sh = _ns(ctx, cache_specs(cache_shape, ctx))
    tok_sh = NamedSharding(ctx.mesh,
                           ctx.resolve(("batch", None),
                                       (shape.global_batch, 1)))
    return (p_sh, c_sh, tok_sh)


# ---------------------------------------------------------------------------
# One-call assembly
# ---------------------------------------------------------------------------

def train_variant(cfg: ModelConfig) -> ModelConfig:
    """§Perf Q1: ZeRO-style pure-DP for train when the config asks."""
    import dataclasses
    if not cfg.parallel.train_dp_only:
        return cfg
    return cfg.with_(parallel=dataclasses.replace(
        cfg.parallel, tensor_parallel=False, fsdp=True, seq_parallel=False))


def make_step(cfg: ModelConfig, shape: InputShape, ctx: ShardingCtx,
              *, serving_fsdp_off: bool = True):
    """Returns (step_fn, ordered arg specs tuple, in_shardings tuple)."""
    cfg = long_context_variant(cfg) if shape.name == "long_500k" else cfg
    if shape.kind == "train" and serving_fsdp_off:
        cfg = train_variant(cfg)                 # §Perf Q1
    if shape.kind == "decode" and serving_fsdp_off:
        cfg = serving_variant(cfg)               # §Perf G4: no FSDP at decode
    specs = input_specs(cfg, shape)
    in_sh = shardings_for(cfg, shape, ctx)
    if shape.kind == "train":
        fn = make_train_step(cfg)
        args = (params_specs(cfg),
                jax.eval_shape(lambda: adamw_init(
                    params_specs(cfg), cfg.parallel.optimizer_moment_dtype)),
                specs["batch"])
    elif shape.kind == "prefill":
        fn = make_prefill_step(cfg, max_len=shape.seq_len)
        args = (params_specs(cfg), specs["batch"])
    else:
        fn = make_serve_step(cfg)
        args = (params_specs(cfg), specs["cache"], specs["tokens"])
    return fn, args, in_sh
