"""Launchers: production mesh, multi-pod dry-run, train/serve drivers.

Deliberately import-free: ``python -m repro.launch.dryrun`` executes this
package __init__ BEFORE dryrun.py can set XLA_FLAGS, so nothing here may
touch jax.  Import the submodules directly::

    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import input_specs
"""
