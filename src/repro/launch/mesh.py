"""Production mesh construction (multi-pod dry-run target).

A FUNCTION, not a module-level constant — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips (one v5e pod), or 2×16×16 = 512 (two pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this process actually has (smoke tests, live executor)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))
