"""Pallas TPU kernels for the compute hot-spots, each with:

- ``<name>.py``  — the ``pl.pallas_call`` kernel with explicit BlockSpec VMEM
  tiling (TPU is the target; validated via ``interpret=True`` on CPU),
- ``ops.py``     — jit'd wrapper that dispatches kernel vs reference by
  platform (CPU / dry-run lowers the pure-XLA reference path),
- ``ref.py``     — pure-jnp oracle used by the allclose test sweeps.

Kernels: flash_attention (prefill/train), decode_attention (single-token GQA
attention against a ring KV cache), ssm_scan (selective-SSM chunked scan).
"""
from . import flash_attention, decode_attention, ssm_scan  # noqa: F401
