"""Pure-jnp oracle for single-token GQA decode attention over a ring cache.

Two cache layouts share one oracle: the dense per-row ring ((B,T,K,hd),
``decode_attention_ref``) and the PAGED pool ((n_pages,P,K,hd) physical
pages addressed through a per-row (B, max_pages) int32 page table,
``decode_attention_paged_ref`` — gather-by-table recovers the dense view,
so the paged path is exact by construction against the dense one).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k, v, n_valid, *, softcap: float = 0.0,
                         scale: float | None = None):
    """q: (B,Sq,H,hd) (Sq is typically 1); k,v: (B,T,K,hd) ring cache;
    n_valid: int32 scalar or (B,) vector — number of valid slots per row
    (ring slots < n_valid[b] are attended; with a full ring n_valid == T).
    A vector lets every row of a persistent slot pool sit at its own
    sequence length.  Returns (B,Sq,H,hd)."""
    B, Sq, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    if scale is None:
        scale = 1.0 / (hd ** 0.5)
    n_valid = jnp.asarray(n_valid, jnp.int32)
    if n_valid.ndim == 0:
        n_valid = jnp.full((B,), n_valid, jnp.int32)
    # keep the KV cache in its storage dtype — an explicit .astype(f32)
    # materialises a double-width copy of the whole cache shard per step
    # (granite decode_32k: 9.7 GB of temps — EXPERIMENTS.md §Perf G2)
    qg = q.reshape(B, Sq, K, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        scores = softcap * jnp.tanh(scores / softcap)
    mask = (jnp.arange(T)[None, None, None, None, :]
            < n_valid[:, None, None, None, None])
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def gather_pages_ref(pages, page_table):
    """Dense (B, max_pages*P, ...) view of a paged pool.

    pages: (n_pages, P, ...) physical page pool; page_table: (B, max_pages)
    int32 — physical page id per logical page (entries are clamped to >= 0,
    so unmapped rows may alias the reserved trash page 0: those slots sit
    past ``n_valid`` and are masked before the softmax ever sees them)."""
    table = jnp.maximum(jnp.asarray(page_table, jnp.int32), 0)
    B, max_pages = table.shape
    P = pages.shape[1]
    dense = jnp.take(pages, table.reshape(-1), axis=0)
    return dense.reshape((B, max_pages * P) + pages.shape[2:])


def decode_attention_paged_ref(q, k_pages, v_pages, page_table, n_valid, *,
                               softcap: float = 0.0,
                               scale: float | None = None):
    """Paged oracle: q (B,Sq,H,hd); k_pages/v_pages (n_pages,P,K,hd);
    page_table (B,max_pages) int32; n_valid int32 scalar or (B,).  The
    logical ring of row b is the concatenation of its mapped pages
    (T = max_pages*P slots); everything past n_valid[b] is masked."""
    k = gather_pages_ref(k_pages, page_table)
    v = gather_pages_ref(v_pages, page_table)
    return decode_attention_ref(q, k, v, n_valid, softcap=softcap,
                                scale=scale)
