"""Pure-jnp oracle for single-token GQA decode attention over a ring cache."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q, k, v, n_valid, *, softcap: float = 0.0,
                         scale: float | None = None):
    """q: (B,Sq,H,hd) (Sq is typically 1); k,v: (B,T,K,hd) ring cache;
    n_valid: int32 scalar or (B,) vector — number of valid slots per row
    (ring slots < n_valid[b] are attended; with a full ring n_valid == T).
    A vector lets every row of a persistent slot pool sit at its own
    sequence length.  Returns (B,Sq,H,hd)."""
    B, Sq, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    if scale is None:
        scale = 1.0 / (hd ** 0.5)
    n_valid = jnp.asarray(n_valid, jnp.int32)
    if n_valid.ndim == 0:
        n_valid = jnp.full((B,), n_valid, jnp.int32)
    # keep the KV cache in its storage dtype — an explicit .astype(f32)
    # materialises a double-width copy of the whole cache shard per step
    # (granite decode_32k: 9.7 GB of temps — EXPERIMENTS.md §Perf G2)
    qg = q.reshape(B, Sq, K, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        scores = softcap * jnp.tanh(scores / softcap)
    mask = (jnp.arange(T)[None, None, None, None, :]
            < n_valid[:, None, None, None, None])
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)
