"""Single-token GQA decode attention Pallas TPU kernels (dense + paged).

Decode attention is memory-bound: the whole KV cache streams HBM->VMEM once
while compute is a (G x bk) @ (bk x hd) matmul per block — arithmetic
intensity ~G. The dense kernel therefore:

- tiles over (B, K, T/bk): one program per (batch, kv-head), sequential over
  KV blocks, all G grouped q-heads processed together so each KV tile is
  read exactly ONCE (the GQA bandwidth win — a naive per-q-head kernel would
  read the cache G times);
- carries the online-softmax state (m, l, acc) in fp32 VMEM scratch;
- masks ring slots >= n_valid[b] ((B,) vector in SMEM, indexed by the batch
  program — each row of a persistent slot pool is masked at its OWN length,
  so a dynamic batch with ragged prefixes decodes in one kernel launch).

The PAGED kernel (``decode_attention_paged_pallas``) reads a physical page
pool (n_pages, P, K, hd) through a per-row (B, max_pages) int32 page table
instead of a dense (B, T) cache slice: the table rides in as a
scalar-prefetch argument (``pltpu.PrefetchScalarGridSpec``) so the KV
BlockSpec index_map can pick each program's physical page —
``table[b, ki]`` — before the kernel body runs; one KV block == one page.
Refcounted shared-prefix pages are thus gathered per-row at DMA time with
zero data duplication (vLLM's PagedAttention access pattern).

G is padded to the 8-sublane minimum by the wrapper when n_heads == n_kv
(MHA decode).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(n_valid_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, softcap: float, bk: int, n_kv_blocks: int):
    bi = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    n_valid = n_valid_ref[bi]
    block_live = ki * bk < n_valid

    @pl.when(block_live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)           # (G, hd)
        k = k_ref[0, 0].astype(jnp.float32)           # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)           # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < n_valid, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, -1, keepdims=True)
        acc_scr[...] = (acc_scr[...] * corr +
                        jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def decode_attention_pallas(q, k, v, n_valid, *, softcap: float = 0.0,
                            scale: float | None = None, bk: int = 256,
                            interpret: bool = False):
    """q: (B,1,H,hd); k,v: (B,T,K,hd); n_valid int32 scalar or (B,)."""
    B, Sq, H, hd = q.shape
    assert Sq == 1, "decode kernel is single-token"
    T, K = k.shape[1], k.shape[2]
    G = H // K
    if scale is None:
        scale = 1.0 / math.sqrt(hd)
    bk = min(bk, T)
    assert T % bk == 0, (T, bk)
    n_kv_blocks = T // bk

    qg = q.reshape(B, K, G, hd)                        # group q-heads by kv head
    kt = k.transpose(0, 2, 1, 3)                       # (B,K,T,hd)
    vt = v.transpose(0, 2, 1, 3)
    n_valid_arr = jnp.asarray(n_valid, jnp.int32)
    if n_valid_arr.ndim == 0:
        n_valid_arr = jnp.full((B,), n_valid_arr, jnp.int32)
    assert n_valid_arr.shape == (B,), n_valid_arr.shape

    grid = (B, K, n_kv_blocks)
    kern = functools.partial(_kernel, scale=scale, softcap=softcap, bk=bk,
                             n_kv_blocks=n_kv_blocks)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, hd), lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, ki: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(n_valid_arr, qg, kt, vt)
    return out.reshape(B, 1, H, hd)


def _paged_kernel(n_valid_ref, table_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, scale: float, softcap: float,
                  bk: int, n_kv_blocks: int):
    # the page table is consumed by the BlockSpec index_maps (the DMA-time
    # gather); the body itself is the same online softmax as the dense
    # kernel with one KV block per physical page
    del table_ref
    _kernel(n_valid_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
            scale=scale, softcap=softcap, bk=bk, n_kv_blocks=n_kv_blocks)


def decode_attention_paged_pallas(q, k_pages, v_pages, page_table, n_valid, *,
                                  softcap: float = 0.0,
                                  scale: float | None = None,
                                  interpret: bool = False):
    """q: (B,1,H,hd); k_pages/v_pages: (n_pages,P,K,hd) physical pools;
    page_table: (B,max_pages) int32 (entries < 0 = unmapped, clamped to the
    reserved trash page 0 — always masked by n_valid); n_valid int32 scalar
    or (B,).  Row b's logical ring is its mapped pages back to back."""
    B, Sq, H, hd = q.shape
    assert Sq == 1, "decode kernel is single-token"
    n_pages, P, K = k_pages.shape[0], k_pages.shape[1], k_pages.shape[2]
    G = H // K
    max_pages = page_table.shape[1]
    if scale is None:
        scale = 1.0 / math.sqrt(hd)

    qg = q.reshape(B, K, G, hd)
    kt = k_pages.transpose(0, 2, 1, 3)                 # (n_pages, K, P, hd)
    vt = v_pages.transpose(0, 2, 1, 3)
    table = jnp.maximum(jnp.asarray(page_table, jnp.int32), 0)
    n_valid_arr = jnp.asarray(n_valid, jnp.int32)
    if n_valid_arr.ndim == 0:
        n_valid_arr = jnp.full((B,), n_valid_arr, jnp.int32)
    assert n_valid_arr.shape == (B,), n_valid_arr.shape
    assert table.shape == (B, max_pages)

    kern = functools.partial(_paged_kernel, scale=scale, softcap=softcap,
                             bk=P, n_kv_blocks=max_pages)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                 # n_valid + page table in SMEM
        grid=(B, K, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd),
                         lambda b, h, ki, nv, tbl: (b, h, 0, 0)),
            # the paged gather: this program's KV block is the physical
            # page the table maps for row b's ki-th logical page
            pl.BlockSpec((1, 1, P, hd),
                         lambda b, h, ki, nv, tbl: (tbl[b, ki], h, 0, 0)),
            pl.BlockSpec((1, 1, P, hd),
                         lambda b, h, ki, nv, tbl: (tbl[b, ki], h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, h, ki, nv, tbl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        interpret=interpret,
    )(n_valid_arr, table, qg, kt, vt)
    return out.reshape(B, 1, H, hd)
