"""Jit'd wrappers for decode attention (dense + paged) with platform dispatch."""
from __future__ import annotations

import jax

from .decode_attention import (decode_attention_paged_pallas,
                               decode_attention_pallas)
from .ref import decode_attention_paged_ref, decode_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def decode_attention(q, k, v, n_valid, *, softcap: float = 0.0,
                     scale: float | None = None,
                     use_pallas: bool | None = None,
                     interpret: bool = False):
    """q: (B,1,H,hd); k,v ring cache (B,T,K,hd); n_valid int32 scalar or
    (B,) vector (per-row valid length — slot-pool decode)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    T = k.shape[1]
    if use_pallas and q.shape[1] == 1 and T % min(256, T) == 0:
        return decode_attention_pallas(q, k, v, n_valid, softcap=softcap,
                                       scale=scale,
                                       interpret=interpret or not _on_tpu())
    return decode_attention_ref(q, k, v, n_valid, softcap=softcap, scale=scale)


def decode_attention_paged(q, k_pages, v_pages, page_table, n_valid, *,
                           softcap: float = 0.0, scale: float | None = None,
                           use_pallas: bool | None = None,
                           interpret: bool = False):
    """Paged decode attention: q (B,1,H,hd); k_pages/v_pages physical pools
    (n_pages,P,K,hd); page_table (B,max_pages) int32 (clamped >= 0, unmapped
    entries alias the trash page and sit past n_valid); n_valid int32 scalar
    or (B,) per-row valid length over the LOGICAL ring (max_pages*P slots)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    P = k_pages.shape[1]
    # TPU lane constraint: one KV block per page, so the page must tile
    if use_pallas and q.shape[1] == 1 and P % min(128, P) == 0:
        return decode_attention_paged_pallas(
            q, k_pages, v_pages, page_table, n_valid, softcap=softcap,
            scale=scale, interpret=interpret or not _on_tpu())
    return decode_attention_paged_ref(q, k_pages, v_pages, page_table,
                                      n_valid, softcap=softcap, scale=scale)
