from . import ops, ref  # noqa: F401
from .decode_attention import decode_attention_pallas  # noqa: F401
from .ops import decode_attention  # noqa: F401
from .ref import decode_attention_ref  # noqa: F401
