from . import ops, ref  # noqa: F401
from .decode_attention import (decode_attention_paged_pallas,  # noqa: F401
                               decode_attention_pallas)
from .ops import decode_attention, decode_attention_paged  # noqa: F401
from .ref import decode_attention_paged_ref, decode_attention_ref  # noqa: F401
