"""Pure-jnp oracle for the selective-SSM scan (Mamba-style).

Recurrence (per batch b, channel d, state n):
    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * x_t
    y_t = sum_n C_t[n] * h_t[:, n] + D * x_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_scan_ref(x, dt, A, B, C, D, h0=None):
    """x, dt: (Bt, L, DI); A: (DI, N); B, C: (Bt, L, N); D: (DI,).

    Returns (y (Bt,L,DI) in x.dtype, h_final (Bt,DI,N) fp32)."""
    Bt, L, DI = x.shape
    N = A.shape[1]
    xf = x.astype(jnp.float32)
    dtf = jax.nn.softplus(dt.astype(jnp.float32))
    Af = A.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    Df = D.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((Bt, DI, N), jnp.float32)

    def step(h, t):
        x_t, dt_t, B_t, C_t = t                      # (Bt,DI),(Bt,DI),(Bt,N),(Bt,N)
        dA = jnp.exp(dt_t[..., None] * Af[None])     # (Bt,DI,N)
        dBx = dt_t[..., None] * B_t[:, None, :] * x_t[..., None]
        h = dA * h + dBx
        y_t = jnp.einsum("bdn,bn->bd", h, C_t) + Df[None] * x_t
        return h, y_t

    xs = (xf.transpose(1, 0, 2), dtf.transpose(1, 0, 2),
          Bf.transpose(1, 0, 2), Cf.transpose(1, 0, 2))
    h_final, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2).astype(x.dtype)
    return y, h_final


def ssm_step_ref(x_t, dt_t, A, B_t, C_t, D, h):
    """Single decode step. x_t, dt_t: (Bt, DI); B_t, C_t: (Bt, N);
    h: (Bt, DI, N) fp32. Returns (y_t (Bt,DI), h)."""
    dtf = jax.nn.softplus(dt_t.astype(jnp.float32))
    dA = jnp.exp(dtf[..., None] * A.astype(jnp.float32)[None])
    dBx = dtf[..., None] * B_t.astype(jnp.float32)[:, None, :] \
        * x_t.astype(jnp.float32)[..., None]
    h = dA * h + dBx
    y = jnp.einsum("bdn,bn->bd", h, C_t.astype(jnp.float32)) \
        + D.astype(jnp.float32)[None] * x_t.astype(jnp.float32)
    return y.astype(x_t.dtype), h
