"""Selective-SSM chunked scan Pallas TPU kernel.

The scan is time-sequential but memory-bound; the TPU adaptation is the
HBM->VMEM *chunking*, not warp-level parallelism (the GPU Mamba kernel's
shared-memory/warp tricks have no analogue here — see DESIGN.md):

- grid = (B, L/chunk) with the chunk axis sequential ("arbitrary"), so the
  fp32 state h (DI, N) lives in VMEM scratch across chunks and HBM traffic
  is exactly one read of x/dt/B/C and one write of y per token;
- inside a chunk, a fori_loop steps the recurrence on VMEM-resident tiles;
  all per-step tensors are (DI, N) VREG-friendly outer products;
- the final state is written once by the last chunk (needed to seed decode).

VMEM budget: x/dt tiles 2*chunk*DI*2B + B/C tiles 2*chunk*N*4B + h DI*N*4B;
for DI=3200, N=16, chunk=128 that is ~1.9 MB — comfortably inside the
~16 MB/core VMEM envelope, leaving room for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams; support both.
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) \
    or pltpu.TPUCompilerParams


def _kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, D_ref, y_ref, hout_ref,
            h_scr, *, chunk: int, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    A = A_ref[...].astype(jnp.float32)                 # (DI, N)
    D = D_ref[...].astype(jnp.float32)                 # (1, DI)

    def step(t, h):
        x_t = x_ref[0, t].astype(jnp.float32)          # (DI,)
        dt_t = jax.nn.softplus(dt_ref[0, t].astype(jnp.float32))
        B_t = B_ref[0, t].astype(jnp.float32)          # (N,)
        C_t = C_ref[0, t].astype(jnp.float32)          # (N,)
        dA = jnp.exp(dt_t[:, None] * A)                # (DI, N)
        h = dA * h + (dt_t * x_t)[:, None] * B_t[None, :]
        y_t = jnp.sum(h * C_t[None, :], axis=-1) + D[0] * x_t
        y_ref[0, t] = y_t.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scr[...])
    h_scr[...] = h

    @pl.when(ci == n_chunks - 1)
    def _final():
        hout_ref[0] = h


def ssm_scan_pallas(x, dt, A, B, C, D, *, chunk: int = 128,
                    interpret: bool = False):
    """x, dt: (Bt,L,DI); A: (DI,N); B, C: (Bt,L,N); D: (DI,).

    Returns (y (Bt,L,DI), h_final (Bt,DI,N) fp32). L % chunk must be 0."""
    Bt, L, DI = x.shape
    N = A.shape[1]
    chunk = min(chunk, L)
    assert L % chunk == 0, (L, chunk)
    n_chunks = L // chunk
    grid = (Bt, n_chunks)
    kern = functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks)
    y, h_final = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, DI), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, DI), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((DI, N), lambda b, ci: (0, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, DI), lambda b, ci: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, DI), lambda b, ci: (b, ci, 0)),
            pl.BlockSpec((1, DI, N), lambda b, ci: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bt, L, DI), x.dtype),
            jax.ShapeDtypeStruct((Bt, DI, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((DI, N), jnp.float32)],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, A, jnp.asarray(B), jnp.asarray(C), D.reshape(1, DI))
    return y, h_final
