"""Jit'd wrapper for the selective-SSM scan with platform dispatch."""
from __future__ import annotations

import jax

from .ref import ssm_scan_ref, ssm_step_ref
from .ssm_scan import ssm_scan_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def ssm_scan(x, dt, A, B, C, D, *, chunk: int = 128,
             use_pallas: bool | None = None, interpret: bool = False):
    """Dispatching entry point. Shapes as in ref.py."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    L = x.shape[1]
    if use_pallas and L % min(chunk, L) == 0:
        return ssm_scan_pallas(x, dt, A, B, C, D, chunk=chunk,
                               interpret=interpret or not _on_tpu())
    return ssm_scan_ref(x, dt, A, B, C, D)


ssm_step = ssm_step_ref  # single-token decode step (pure jnp everywhere)
