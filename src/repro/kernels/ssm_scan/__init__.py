from . import ops, ref  # noqa: F401
from .ops import ssm_scan, ssm_step  # noqa: F401
from .ref import ssm_scan_ref, ssm_step_ref  # noqa: F401
from .ssm_scan import ssm_scan_pallas  # noqa: F401
