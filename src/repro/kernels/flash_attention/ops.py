"""Jit'd wrapper for flash attention with platform dispatch.

TPU -> Pallas kernel; CPU (tests, dry-run) -> pure-jnp reference.  The
dry-run intentionally lowers the reference path: ``cost_analysis()`` needs
the XLA-visible FLOPs, and custom-call kernels are opaque to it.
"""
from __future__ import annotations

import jax

from .flash_attention import flash_attention_pallas
from .ref import flash_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, scale: float | None = None,
                    use_pallas: bool | None = None,
                    interpret: bool = False):
    """Dispatching entry point used by the model code.

    q: (B,S,H,hd); k,v: (B,T,K,hd); H = G*K. Sliding ``window`` and
    ``softcap`` are static. Returns (B,S,H,hd) in q.dtype.
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    S, T = q.shape[1], k.shape[1]
    aligned = S % min(128, S) == 0 and T % min(128, T) == 0
    if use_pallas and aligned:
        return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                      softcap=softcap, scale=scale,
                                      interpret=interpret or not _on_tpu())
    return flash_attention_ref(q, k, v, causal=causal, window=window,
                               softcap=softcap, scale=scale)
