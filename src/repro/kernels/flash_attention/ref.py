"""Pure-jnp oracle for flash attention (GQA, causal, sliding-window, softcap)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        softcap: float = 0.0, scale: float | None = None):
    """q: (B,S,H,hd); k,v: (B,T,K,hd) with H = G*K. Returns (B,S,H,hd).

    Computation in fp32 without materialising a repeated KV — the grouped
    einsum keeps the GQA structure explicit (same contraction the TPU kernel
    performs per kv-head).
    """
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    hd_v = v.shape[-1]           # may differ from hd (MLA)
    G = H // K
    if scale is None:
        scale = 1.0 / (hd ** 0.5)
    # keep operands in model dtype and accumulate in f32: an explicit
    # .astype(f32) makes XLA all-gather the seq-parallel K/V at DOUBLE
    # width (measured on llama3-405b train — EXPERIMENTS.md §Perf E3)
    qg = q.reshape(B, S, K, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        scores = softcap * jnp.tanh(scores / softcap)
    if causal or window:
        qpos = jnp.arange(S)[:, None]
        kpos = jnp.arange(T)[None, :]
        mask = jnp.ones((S, T), dtype=bool)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, S, H, hd_v).astype(q.dtype)
