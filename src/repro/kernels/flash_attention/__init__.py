from . import ops, ref  # noqa: F401
from .flash_attention import flash_attention_pallas  # noqa: F401
from .ops import flash_attention  # noqa: F401
from .ref import flash_attention_ref  # noqa: F401
