"""Flash attention Pallas TPU kernel (GQA, causal, sliding window, softcap).

Online-softmax flash attention tiled for VMEM/MXU:

- grid = (B, H, S/bq, T/bk); the KV-block axis is the innermost sequential
  dimension, with fp32 scratch accumulators (m, l, acc) carried across it.
- q/k/v tiles are MXU-aligned: bq = bk = 128, head_dim padded to a multiple
  of 128 by the wrapper (ops.py) when needed.
- GQA is expressed in the BlockSpec index maps: the k/v tile for q-head h is
  kv-head h // group_size — no repeated KV is ever materialised in VMEM.
- causal + sliding-window blocks that are fully masked are skipped via
  ``pl.when`` on block indices (no MXU work, no VMEM loads beyond the tile
  prefetch).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window: int, softcap: float,
            bq: int, bk: int, n_kv_blocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq
    k_start = ki * bk

    # block-level skip: block is live iff some (qpos, kpos) pair is unmasked
    live = True
    if causal:
        live = jnp.logical_and(live, k_start <= q_start + bq - 1)
    if window:
        live = jnp.logical_and(live, k_start + bk - 1 > q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)           # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)           # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)           # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), dtype=jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                           # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                        # (bq, bk)
        correction = jnp.exp(m_prev - m_new)          # (bq, 1)
        l_scr[...] = l_scr[...] * correction + jnp.sum(p, -1, keepdims=True)
        acc_scr[...] = (acc_scr[...] * correction +
                        jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                            preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)               # fully-masked rows -> 0
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           softcap: float = 0.0, scale: float | None = None,
                           bq: int = 128, bk: int = 128,
                           interpret: bool = False):
    """q: (B,S,H,hd); k: (B,T,K,hd); v: (B,T,K,hd_v) — hd_v may differ (MLA).
    Requires S % bq == 0 and T % bk == 0."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    hd_v = v.shape[-1]
    G = H // K
    if scale is None:
        scale = 1.0 / math.sqrt(hd)
    bq = min(bq, S)
    bk = min(bk, T)
    assert S % bq == 0 and T % bk == 0, (S, T, bq, bk)
    n_kv_blocks = T // bk

    # layout: (B, H, S, hd) so the lane dim is hd and sublane is seq
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    grid = (B, H, S // bq, n_kv_blocks)
    kern = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, softcap=softcap,
        bq=bq, bk=bk, n_kv_blocks=n_kv_blocks)

    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, qi, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd_v), lambda b, h, qi, ki: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd_v), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd_v), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # running sum
            pltpu.VMEM((bq, hd_v), jnp.float32), # output accumulator
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
