"""Block zoo: one init/apply/prefill/decode quartet per block type.

A *block* is one layer of a stage; stages stack homogeneous blocks along a
leading layer axis and run them under ``lax.scan`` (models/model.py).
``apply`` is the cache-free path (training), ``prefill`` additionally emits
the block's decode cache, ``decode`` consumes/updates one layer's cache for
a single token.

Block types:
  dense      — GQA attention + SwiGLU            (granite, qwen3, olmo,
                                                  llama3, smollm2, llava)
  moe        — GQA attention + MoE FFN           (phi3.5-moe)
  dense_mla  — MLA attention + SwiGLU            (deepseek first_k_dense)
  moe_mla    — MLA attention + MoE FFN           (deepseek)
  hymba      — parallel GQA + SSM heads + SwiGLU (hymba)
  slstm/mlstm— xLSTM blocks (own up/down, no FFN)(xlstm)
  enc        — bidirectional attention + SwiGLU  (whisper encoder)
  dec        — causal self-attn + cross-attn + SwiGLU (whisper decoder)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding import hint
from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from . import xlstm as xlstm_mod
from .layers import mlp_apply, mlp_init, rmsnorm, rmsnorm_init

ZERO = jnp.zeros((), jnp.float32)


def _norm_init(cfg, dtype):
    return None if cfg.nonparametric_norm else rmsnorm_init(cfg.d_model, dtype)


def _norm(x, w, cfg):
    return rmsnorm(x, w, cfg.norm_eps)


def _res_hint(x):
    return hint(x, "batch", "seq_act", "embed_act")


# ---------------------------------------------------------------------------
# dense / moe (GQA attention)
# ---------------------------------------------------------------------------

def dense_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {"ln1": _norm_init(cfg, dtype), "attn": attn.gqa_init(k1, cfg, dtype),
            "ln2": _norm_init(cfg, dtype),
            "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, dtype)}


def dense_apply(p, cfg, x, positions, extras):
    x = x + attn.gqa_apply(p["attn"], cfg, _norm(x, p["ln1"], cfg),
                           positions=positions)
    x = _res_hint(x)
    x = x + mlp_apply(p["mlp"], _norm(x, p["ln2"], cfg))
    return _res_hint(x), ZERO


def dense_prefill(p, cfg, x, positions, extras, max_len):
    h = _norm(x, p["ln1"], cfg)
    q, k, v = attn.gqa_project_qkv(p["attn"], cfg, h, positions)
    from ..kernels.flash_attention import ops as flash_ops
    out = flash_ops.flash_attention(q, k, v, causal=True,
                                    window=cfg.attn_window,
                                    softcap=cfg.attn_logit_softcap)
    x = x + jnp.einsum("bshk,hkd->bsd", out, p["attn"]["wo"])
    x = _res_hint(x)
    x = x + mlp_apply(p["mlp"], _norm(x, p["ln2"], cfg))
    T = attn.gqa_cache_len(cfg, max_len)
    B = x.shape[0]
    empty = attn.gqa_empty_cache_layer(cfg, B, max_len, k.dtype)
    cache = attn.gqa_cache_write_prefill(empty, cfg, k, v, max_len)
    return _res_hint(x), cache, ZERO


def _gqa_decode_routed(p, cfg, h, cache_layer, pos, extras):
    """Dense-ring or paged decode for one GQA layer, keyed on whether the
    caller's cache carries a page table (``extras["page_table"]``)."""
    table = extras.get("page_table")
    if table is not None:
        return attn.gqa_decode_paged(p, cfg, h, cache_layer, table, pos,
                                     write_mask=extras.get("step_mask"))
    return attn.gqa_decode(p, cfg, h, cache_layer, pos)


def dense_decode(p, cfg, x, cache_layer, pos, extras):
    y, cache_layer = _gqa_decode_routed(p["attn"], cfg,
                                        _norm(x, p["ln1"], cfg), cache_layer,
                                        pos, extras)
    x = x + y
    x = x + mlp_apply(p["mlp"], _norm(x, p["ln2"], cfg))
    return x, cache_layer


def dense_prefill_paged(p, cfg, x, positions, cache_layer, table, lengths):
    """Tail prefill through the page table (shared prefix already paged)."""
    h = _norm(x, p["ln1"], cfg)
    y, cache_layer = attn.gqa_prefill_into_pages(p["attn"], cfg, h,
                                                 cache_layer, table,
                                                 positions, lengths)
    x = x + y
    x = _res_hint(x)
    x = x + mlp_apply(p["mlp"], _norm(x, p["ln2"], cfg))
    return _res_hint(x), cache_layer


def dense_cache_init(cfg, batch, max_len, n_layers, dtype):
    return attn.gqa_cache_init(cfg, batch, max_len, n_layers, dtype)


def moe_init_fn(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {"ln1": _norm_init(cfg, dtype), "attn": attn.gqa_init(k1, cfg, dtype),
            "ln2": _norm_init(cfg, dtype), "moe": moe_mod.moe_init(k2, cfg, dtype)}


def moe_apply_fn(p, cfg, x, positions, extras):
    x = x + attn.gqa_apply(p["attn"], cfg, _norm(x, p["ln1"], cfg),
                           positions=positions)
    x = _res_hint(x)
    y, aux = moe_mod.moe_apply(p["moe"], cfg, _norm(x, p["ln2"], cfg))
    return _res_hint(x + y), aux


def moe_prefill(p, cfg, x, positions, extras, max_len):
    h = _norm(x, p["ln1"], cfg)
    q, k, v = attn.gqa_project_qkv(p["attn"], cfg, h, positions)
    from ..kernels.flash_attention import ops as flash_ops
    out = flash_ops.flash_attention(q, k, v, causal=True,
                                    window=cfg.attn_window)
    x = x + jnp.einsum("bshk,hkd->bsd", out, p["attn"]["wo"])
    y, aux = moe_mod.moe_apply(p["moe"], cfg, _norm(x, p["ln2"], cfg))
    x = x + y
    T = attn.gqa_cache_len(cfg, max_len)
    B = x.shape[0]
    empty = attn.gqa_empty_cache_layer(cfg, B, max_len, k.dtype)
    cache = attn.gqa_cache_write_prefill(empty, cfg, k, v, max_len)
    return _res_hint(x), cache, aux


def moe_decode(p, cfg, x, cache_layer, pos, extras):
    y, cache_layer = _gqa_decode_routed(p["attn"], cfg,
                                        _norm(x, p["ln1"], cfg), cache_layer,
                                        pos, extras)
    x = x + y
    y, _ = moe_mod.moe_apply(p["moe"], cfg, _norm(x, p["ln2"], cfg))
    return x + y, cache_layer


def moe_prefill_paged(p, cfg, x, positions, cache_layer, table, lengths):
    h = _norm(x, p["ln1"], cfg)
    y, cache_layer = attn.gqa_prefill_into_pages(p["attn"], cfg, h,
                                                 cache_layer, table,
                                                 positions, lengths)
    x = x + y
    y, _ = moe_mod.moe_apply(p["moe"], cfg, _norm(x, p["ln2"], cfg))
    return _res_hint(x + y), cache_layer


# ---------------------------------------------------------------------------
# MLA variants (deepseek)
# ---------------------------------------------------------------------------

def dense_mla_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {"ln1": _norm_init(cfg, dtype), "attn": attn.mla_init(k1, cfg, dtype),
            "ln2": _norm_init(cfg, dtype),
            "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, dtype)}


def dense_mla_apply(p, cfg, x, positions, extras):
    x = x + attn.mla_apply(p["attn"], cfg, _norm(x, p["ln1"], cfg),
                           positions=positions)
    x = _res_hint(x)
    x = x + mlp_apply(p["mlp"], _norm(x, p["ln2"], cfg))
    return _res_hint(x), ZERO


def _mla_prefill_cache(p, cfg, h, positions, max_len):
    ckv, k_rope = attn._mla_kv_latent(p, cfg, h, positions)
    T = attn.gqa_cache_len(cfg, max_len)
    B = h.shape[0]
    m = cfg.mla
    empty = {"ckv": jnp.zeros((B, T, m.kv_lora_rank), ckv.dtype),
             "k_rope": jnp.zeros((B, T, m.qk_rope_head_dim), k_rope.dtype)}
    return attn.mla_cache_write_prefill(empty, cfg, ckv, k_rope, max_len)


def dense_mla_prefill(p, cfg, x, positions, extras, max_len):
    h = _norm(x, p["ln1"], cfg)
    cache = _mla_prefill_cache(p["attn"], cfg, h, positions, max_len)
    x = x + attn.mla_apply(p["attn"], cfg, h, positions=positions)
    x = x + mlp_apply(p["mlp"], _norm(x, p["ln2"], cfg))
    return _res_hint(x), cache, ZERO


def dense_mla_decode(p, cfg, x, cache_layer, pos, extras):
    y, cache_layer = attn.mla_decode(p["attn"], cfg,
                                     _norm(x, p["ln1"], cfg), cache_layer, pos)
    x = x + y
    x = x + mlp_apply(p["mlp"], _norm(x, p["ln2"], cfg))
    return x, cache_layer


def moe_mla_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {"ln1": _norm_init(cfg, dtype), "attn": attn.mla_init(k1, cfg, dtype),
            "ln2": _norm_init(cfg, dtype), "moe": moe_mod.moe_init(k2, cfg, dtype)}


def moe_mla_apply(p, cfg, x, positions, extras):
    x = x + attn.mla_apply(p["attn"], cfg, _norm(x, p["ln1"], cfg),
                           positions=positions)
    x = _res_hint(x)
    y, aux = moe_mod.moe_apply(p["moe"], cfg, _norm(x, p["ln2"], cfg))
    return _res_hint(x + y), aux


def moe_mla_prefill(p, cfg, x, positions, extras, max_len):
    h = _norm(x, p["ln1"], cfg)
    cache = _mla_prefill_cache(p["attn"], cfg, h, positions, max_len)
    x = x + attn.mla_apply(p["attn"], cfg, h, positions=positions)
    y, aux = moe_mod.moe_apply(p["moe"], cfg, _norm(x, p["ln2"], cfg))
    return _res_hint(x + y), cache, aux


def moe_mla_decode(p, cfg, x, cache_layer, pos, extras):
    y, cache_layer = attn.mla_decode(p["attn"], cfg,
                                     _norm(x, p["ln1"], cfg), cache_layer, pos)
    x = x + y
    y, _ = moe_mod.moe_apply(p["moe"], cfg, _norm(x, p["ln2"], cfg))
    return x + y, cache_layer


def mla_cache_init(cfg, batch, max_len, n_layers, dtype):
    return attn.mla_cache_init(cfg, batch, max_len, n_layers, dtype)


# ---------------------------------------------------------------------------
# hymba (parallel attention + SSM heads)
# ---------------------------------------------------------------------------

def hymba_init(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": _norm_init(cfg, dtype),
        "attn": attn.gqa_init(k1, cfg, dtype),
        "ssm": ssm_mod.ssm_init(k2, cfg, dtype),
        "attn_norm": rmsnorm_init(cfg.d_model, dtype),
        "ssm_norm": rmsnorm_init(cfg.d_model, dtype),
        "ln2": _norm_init(cfg, dtype),
        "mlp": mlp_init(k3, cfg.d_model, cfg.d_ff, dtype),
    }


def _hymba_fuse(p, cfg, a, s):
    return 0.5 * (rmsnorm(a, p["attn_norm"], cfg.norm_eps)
                  + rmsnorm(s, p["ssm_norm"], cfg.norm_eps))


def hymba_apply(p, cfg, x, positions, extras):
    h = _norm(x, p["ln1"], cfg)
    a = attn.gqa_apply(p["attn"], cfg, h, positions=positions)
    s = ssm_mod.ssm_apply(p["ssm"], cfg, h)
    x = x + _hymba_fuse(p, cfg, a, s)
    x = _res_hint(x)
    x = x + mlp_apply(p["mlp"], _norm(x, p["ln2"], cfg))
    return _res_hint(x), ZERO


def hymba_prefill(p, cfg, x, positions, extras, max_len):
    h = _norm(x, p["ln1"], cfg)
    q, k, v = attn.gqa_project_qkv(p["attn"], cfg, h, positions)
    from ..kernels.flash_attention import ops as flash_ops
    out = flash_ops.flash_attention(q, k, v, causal=True,
                                    window=cfg.attn_window)
    a = jnp.einsum("bshk,hkd->bsd", out, p["attn"]["wo"])
    s, ssm_cache = ssm_mod.ssm_prefill(p["ssm"], cfg, h)
    x = x + _hymba_fuse(p, cfg, a, s)
    x = x + mlp_apply(p["mlp"], _norm(x, p["ln2"], cfg))
    T = attn.gqa_cache_len(cfg, max_len)
    B = x.shape[0]
    empty = attn.gqa_empty_cache_layer(cfg, B, max_len, k.dtype)
    kv = attn.gqa_cache_write_prefill(empty, cfg, k, v, max_len)
    cache = {**kv, **ssm_cache}
    return _res_hint(x), cache, ZERO


def hymba_decode(p, cfg, x, cache_layer, pos, extras):
    h = _norm(x, p["ln1"], cfg)
    kv_cache = {"k": cache_layer["k"], "v": cache_layer["v"]}
    a, kv_cache = attn.gqa_decode(p["attn"], cfg, h, kv_cache, pos)
    ssm_cache = {"conv": cache_layer["conv"], "h": cache_layer["h"]}
    s, ssm_cache = ssm_mod.ssm_decode(p["ssm"], cfg, h, ssm_cache)
    x = x + _hymba_fuse(p, cfg, a, s)
    x = x + mlp_apply(p["mlp"], _norm(x, p["ln2"], cfg))
    return x, {**kv_cache, **ssm_cache}


def hymba_cache_init(cfg, batch, max_len, n_layers, dtype):
    kv = attn.gqa_cache_init(cfg, batch, max_len, n_layers, dtype)
    s = ssm_mod.ssm_cache_init(cfg, batch, n_layers, dtype)
    return {**kv, **s}


# ---------------------------------------------------------------------------
# xLSTM blocks
# ---------------------------------------------------------------------------

def slstm_block_init(key, cfg, dtype):
    return {"ln": _norm_init(cfg, dtype),
            "cell": xlstm_mod.slstm_init(key, cfg, dtype)}


def slstm_block_apply(p, cfg, x, positions, extras):
    y = xlstm_mod._batch_local(xlstm_mod.slstm_apply, p["cell"], cfg,
                               _norm(x, p["ln"], cfg), False)
    return _res_hint(x + y), ZERO


def slstm_block_prefill(p, cfg, x, positions, extras, max_len):
    y, st = xlstm_mod._batch_local(xlstm_mod.slstm_apply, p["cell"], cfg,
                                   _norm(x, p["ln"], cfg), True)
    return _res_hint(x + y), st, ZERO


def slstm_block_decode(p, cfg, x, cache_layer, pos, extras):
    y, st = xlstm_mod.slstm_decode(p["cell"], cfg, _norm(x, p["ln"], cfg),
                                   cache_layer)
    return x + y, st


def mlstm_block_init(key, cfg, dtype):
    return {"ln": _norm_init(cfg, dtype),
            "cell": xlstm_mod.mlstm_init(key, cfg, dtype)}


def mlstm_block_apply(p, cfg, x, positions, extras):
    y = xlstm_mod._batch_local(xlstm_mod.mlstm_apply, p["cell"], cfg,
                               _norm(x, p["ln"], cfg), False)
    return _res_hint(x + y), ZERO


def mlstm_block_prefill(p, cfg, x, positions, extras, max_len):
    y, st = xlstm_mod._batch_local(xlstm_mod.mlstm_apply, p["cell"], cfg,
                                   _norm(x, p["ln"], cfg), True)
    return _res_hint(x + y), st, ZERO


def mlstm_block_decode(p, cfg, x, cache_layer, pos, extras):
    y, st = xlstm_mod.mlstm_decode(p["cell"], cfg, _norm(x, p["ln"], cfg),
                                   cache_layer)
    return x + y, st


# ---------------------------------------------------------------------------
# whisper encoder / decoder
# ---------------------------------------------------------------------------

def enc_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    p = attn.gqa_init(k1, cfg, dtype)
    return {"ln1": rmsnorm_init(cfg.d_model, dtype), "attn": p,
            "ln2": rmsnorm_init(cfg.d_model, dtype),
            "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, dtype)}


def enc_apply(p, cfg, x, positions, extras):
    x = x + attn.gqa_apply(p["attn"], cfg, _norm(x, p["ln1"], cfg),
                           positions=positions, causal=False)
    x = x + mlp_apply(p["mlp"], _norm(x, p["ln2"], cfg))
    return _res_hint(x), ZERO


def dec_init(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": rmsnorm_init(cfg.d_model, dtype),
            "attn": attn.gqa_init(k1, cfg, dtype),
            "ln_x": rmsnorm_init(cfg.d_model, dtype),
            "xattn": attn.cross_attn_init(k2, cfg, dtype),
            "ln2": rmsnorm_init(cfg.d_model, dtype),
            "mlp": mlp_init(k3, cfg.d_model, cfg.d_ff, dtype)}


def dec_apply(p, cfg, x, positions, extras):
    enc_out = extras["enc_out"]
    x = x + attn.gqa_apply(p["attn"], cfg, _norm(x, p["ln1"], cfg),
                           positions=positions)
    ck, cv = attn.cross_attn_kv(p["xattn"], enc_out)
    x = x + attn.cross_attn_apply(p["xattn"], cfg, _norm(x, p["ln_x"], cfg),
                                  ck, cv)
    x = x + mlp_apply(p["mlp"], _norm(x, p["ln2"], cfg))
    return _res_hint(x), ZERO


def dec_prefill(p, cfg, x, positions, extras, max_len):
    enc_out = extras["enc_out"]
    h = _norm(x, p["ln1"], cfg)
    q, k, v = attn.gqa_project_qkv(p["attn"], cfg, h, positions)
    from ..kernels.flash_attention import ops as flash_ops
    out = flash_ops.flash_attention(q, k, v, causal=True, window=0)
    x = x + jnp.einsum("bshk,hkd->bsd", out, p["attn"]["wo"])
    ck, cv = attn.cross_attn_kv(p["xattn"], enc_out)
    x = x + attn.cross_attn_apply(p["xattn"], cfg, _norm(x, p["ln_x"], cfg),
                                  ck, cv)
    x = x + mlp_apply(p["mlp"], _norm(x, p["ln2"], cfg))
    T = attn.gqa_cache_len(cfg, max_len)
    B = x.shape[0]
    empty = attn.gqa_empty_cache_layer(cfg, B, max_len, k.dtype)
    kv = attn.gqa_cache_write_prefill(empty, cfg, k, v, max_len)
    return _res_hint(x), {**kv, "ck": ck, "cv": cv}, ZERO


def dec_decode(p, cfg, x, cache_layer, pos, extras):
    kv = {"k": cache_layer["k"], "v": cache_layer["v"]}
    y, kv = attn.gqa_decode(p["attn"], cfg, _norm(x, p["ln1"], cfg), kv, pos)
    x = x + y
    x = x + attn.cross_attn_apply(p["xattn"], cfg, _norm(x, p["ln_x"], cfg),
                                  cache_layer["ck"], cache_layer["cv"])
    x = x + mlp_apply(p["mlp"], _norm(x, p["ln2"], cfg))
    return x, {**kv, "ck": cache_layer["ck"], "cv": cache_layer["cv"]}


def dec_cache_init(cfg, batch, max_len, n_layers, dtype):
    kv = attn.gqa_cache_init(cfg, batch, max_len, n_layers, dtype)
    H, hd = cfg.n_heads, cfg.resolved_head_dim
    F = cfg.n_audio_frames
    kv["ck"] = jnp.zeros((n_layers, batch, F, H, hd), dtype)
    kv["cv"] = jnp.zeros((n_layers, batch, F, H, hd), dtype)
    return kv


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

BLOCKS: Dict[str, Dict[str, Any]] = {
    "dense": dict(init=dense_init, apply=dense_apply, prefill=dense_prefill,
                  decode=dense_decode, cache_init=dense_cache_init,
                  prefill_paged=dense_prefill_paged),
    "moe": dict(init=moe_init_fn, apply=moe_apply_fn, prefill=moe_prefill,
                decode=moe_decode, cache_init=dense_cache_init,
                prefill_paged=moe_prefill_paged),
    "dense_mla": dict(init=dense_mla_init, apply=dense_mla_apply,
                      prefill=dense_mla_prefill, decode=dense_mla_decode,
                      cache_init=mla_cache_init),
    "moe_mla": dict(init=moe_mla_init, apply=moe_mla_apply,
                    prefill=moe_mla_prefill, decode=moe_mla_decode,
                    cache_init=mla_cache_init),
    "hymba": dict(init=hymba_init, apply=hymba_apply, prefill=hymba_prefill,
                  decode=hymba_decode, cache_init=hymba_cache_init),
    "slstm": dict(init=slstm_block_init, apply=slstm_block_apply,
                  prefill=slstm_block_prefill, decode=slstm_block_decode,
                  cache_init=lambda cfg, b, m, n, dt:
                      xlstm_mod.slstm_state_init(cfg, b, n)),
    "mlstm": dict(init=mlstm_block_init, apply=mlstm_block_apply,
                  prefill=mlstm_block_prefill, decode=mlstm_block_decode,
                  cache_init=lambda cfg, b, m, n, dt:
                      xlstm_mod.mlstm_state_init(cfg, b, n)),
    "enc": dict(init=enc_init, apply=enc_apply, prefill=None, decode=None,
                cache_init=None),
    "dec": dict(init=dec_init, apply=dec_apply, prefill=dec_prefill,
                decode=dec_decode, cache_init=dec_cache_init),
}
