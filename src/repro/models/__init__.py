"""JAX model zoo: dense GQA, MoE, MLA, SSM, xLSTM, hybrid, enc-dec, VLM."""
from . import attention, blocks, layers, model, moe, ssm, xlstm  # noqa: F401
from .model import (cache_init, count_params, decode_step, forward,  # noqa: F401
                    init_params, loss_fn, prefill, prefill_into_slots,
                    stages_for)
