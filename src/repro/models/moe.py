"""Mixture-of-Experts: top-k router + two expert-parallel dispatch modes.

``dense_onehot`` — GShard-style dispatch/combine einsums over a
(B, S, E, C) one-hot tensor. Simple, SPMD-friendly, but the mask scales with
E — used for small expert counts (phi3.5, E=16).

``sort_scatter`` — flatten tokens, argsort by expert id, scatter into an
(E, C, D) capacity-bucketed buffer, run experts batched, gather back with
the gate weights. O(N·K) memory independent of E — used for DeepSeek-V3
(E=256). Dropped tokens (over capacity) fall into a sacrificial row.

Both modes are pure pjit: the expert axis carries a sharding hint
('experts' -> 'model') and XLA SPMD inserts the all-to-alls. Equivalence of
the two modes is property-tested (tests/test_moe.py).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from ..sharding import hint
from .layers import trunc_normal


def moe_init(key, cfg, dtype):
    e = cfg.moe
    D = cfg.d_model
    ks = jax.random.split(key, 7)
    p = {
        "router": trunc_normal(ks[0], (D, e.n_experts), dtype=jnp.float32),
        "we1": trunc_normal(ks[1], (e.n_experts, D, e.d_ff_expert), dtype=dtype),
        "we3": trunc_normal(ks[2], (e.n_experts, D, e.d_ff_expert), dtype=dtype),
        "we2": trunc_normal(ks[3], (e.n_experts, e.d_ff_expert, D), dtype=dtype),
    }
    if e.n_shared_experts:
        f_sh = (e.d_ff_shared or e.d_ff_expert) * e.n_shared_experts
        p["ws1"] = trunc_normal(ks[4], (D, f_sh), dtype=dtype)
        p["ws3"] = trunc_normal(ks[5], (D, f_sh), dtype=dtype)
        p["ws2"] = trunc_normal(ks[6], (f_sh, D), dtype=dtype)
    return p


def router_topk(p, cfg, x) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (gates (B,S,K) normalised, experts (B,S,K) int32, aux_loss)."""
    e = cfg.moe
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, e.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    # load-balance aux loss: E * sum_e f_e * P_e   (Switch / GShard)
    E = e.n_experts
    chosen_onehot = jax.nn.one_hot(experts, E, dtype=jnp.float32)   # (B,S,K,E)
    f = jnp.mean(jnp.sum(chosen_onehot, axis=2), axis=(0, 1))        # (E,)
    P_mean = jnp.mean(probs, axis=(0, 1))                            # (E,)
    aux = E * jnp.sum(f * P_mean) * e.aux_loss_weight
    return gates, experts, aux


def _capacity(n_tokens: int, cfg) -> int:
    e = cfg.moe
    c = math.ceil(n_tokens * e.top_k / e.n_experts * e.capacity_factor)
    return max(8, -(-c // 8) * 8)      # round up to 8 (TPU sublane)


def _experts_ffn(p, h):
    """h: (E, C, D) -> (E, C, D) batched SwiGLU over the expert axis."""
    h = hint(h, "experts", None, None)
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, p["we1"]))
    u = jnp.einsum("ecd,edf->ecf", h, p["we3"])
    out = jnp.einsum("ecf,efd->ecd", g * u, p["we2"])
    return hint(out, "experts", None, None)


def moe_apply_dense_onehot(p, cfg, x):
    """(B,S,D) -> (B,S,D). GShard dispatch over (B,S,E,C) one-hot masks."""
    e = cfg.moe
    B, S, D = x.shape
    gates, experts, aux = router_topk(p, cfg, x)      # (B,S,K)
    E = e.n_experts
    C = _capacity(S, cfg)                             # per batch row

    onehot = jax.nn.one_hot(experts, E, dtype=jnp.float32)          # (B,S,K,E)
    # position of each (token, k) within its expert: s-major, k-minor priority
    # (matches the stable argsort order of the sort_scatter mode)
    flat = onehot.reshape(B, S * e.top_k, E)
    pos = jnp.cumsum(flat, axis=1) - flat                           # (B,SK,E)
    pos = pos.reshape(B, S, e.top_k, E).astype(jnp.int32)           # (B,S,K,E)
    keep = pos < C
    gk = gates[..., None] * onehot * keep                           # (B,S,K,E)
    pos_oh = jax.nn.one_hot(pos, C, dtype=jnp.float32) * keep[..., None]
    combine = jnp.einsum("bske,bskec->bsec", gk, pos_oh)            # (B,S,E,C)
    dispatch = (combine > 0).astype(x.dtype)

    expert_in = jnp.einsum("bsec,bsd->ebcd", dispatch, x)           # (E,B,C,D)
    expert_in = expert_in.reshape(E, B * C, D)
    expert_out = _experts_ffn(p, expert_in).reshape(E, B, C, D)
    y = jnp.einsum("bsec,ebcd->bsd", combine.astype(x.dtype), expert_out)
    if e.n_shared_experts:
        y = y + _shared_ffn(p, x)
    return y, aux


def moe_apply_sort_scatter(p, cfg, x):
    """(B,S,D) -> (B,S,D). Sort-based capacity bucketing, O(N*K) memory."""
    e = cfg.moe
    B, S, D = x.shape
    gates, experts, aux = router_topk(p, cfg, x)
    N = B * S
    K = e.top_k
    E = e.n_experts
    C = _capacity(N, cfg)

    xf = x.reshape(N, D)
    expert_flat = experts.reshape(N * K)
    gate_flat = gates.reshape(N * K)
    token_idx = jnp.arange(N * K, dtype=jnp.int32) // K

    order = jnp.argsort(expert_flat)                  # stable
    sorted_e = expert_flat[order]
    counts = jnp.zeros((E,), jnp.int32).at[expert_flat].add(1)
    starts = jnp.cumsum(counts) - counts
    pos_in_expert = jnp.arange(N * K, dtype=jnp.int32) - starts[sorted_e]
    keep = pos_in_expert < C
    dest = jnp.where(keep, sorted_e * C + pos_in_expert, E * C)     # drop row

    buf = jnp.zeros((E * C + 1, D), x.dtype)
    buf = buf.at[dest].set(xf[token_idx[order]])
    expert_in = buf[: E * C].reshape(E, C, D)
    expert_out = _experts_ffn(p, expert_in).reshape(E * C, D)
    expert_out = jnp.concatenate(
        [expert_out, jnp.zeros((1, D), x.dtype)], axis=0)

    contrib = expert_out[dest] * gate_flat[order][:, None].astype(x.dtype)
    y = jnp.zeros((N, D), x.dtype).at[token_idx[order]].add(contrib)
    y = y.reshape(B, S, D)
    if e.n_shared_experts:
        y = y + _shared_ffn(p, x)
    return y, aux


def _shared_ffn(p, x):
    g = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["ws1"]))
    u = jnp.einsum("bsd,df->bsf", x, p["ws3"])
    return jnp.einsum("bsf,fd->bsd", g * u, p["ws2"])


# ---------------------------------------------------------------------------
# Expert-parallel all-to-all dispatch (shard_map)
# ---------------------------------------------------------------------------

def _local_bucket(xf, bucket_flat, n_buckets: int, C: int):
    """Sort-scatter ``xf`` (N,D) rows into (n_buckets, C, D) by bucket id.

    Returns (buf, order, dest): ``order`` is the stable sort order of rows
    by bucket, ``dest`` the flat slot each sorted row landed in (the drop
    row ``n_buckets*C`` when over capacity) — enough to invert the routing
    when combining.
    """
    N, D = xf.shape
    order = jnp.argsort(bucket_flat)                  # stable
    sorted_b = bucket_flat[order]
    counts = jnp.zeros((n_buckets,), jnp.int32).at[bucket_flat].add(1)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(N, dtype=jnp.int32) - starts[sorted_b]
    keep = pos < C
    dest = jnp.where(keep, sorted_b * C + pos, n_buckets * C)
    buf = jnp.zeros((n_buckets * C + 1, D), xf.dtype)
    buf = buf.at[dest].set(xf[order])
    return buf[: n_buckets * C].reshape(n_buckets, C, D), order, dest


def moe_apply_a2a(p, cfg, x, *, mesh, data_axes, model_axis="model"):
    """Expert parallelism with explicit all-to-alls under ``shard_map``.

    The pjit sort_scatter path scatters tokens into a global (E*C, D)
    buffer that SPMD can only combine with a full-buffer all-reduce
    (measured 110 TB/step on deepseek-v3 train_4k — EXPERIMENTS.md §Perf).
    Here each (data, model) shard routes a DISTINCT slice of tokens:
    bucket by destination model-shard -> all_to_all -> bucket by local
    expert -> expert FFN -> all_to_all back -> weighted combine.  When the
    residual stream is sequence-sharded the token slice is the seq shard;
    otherwise each shard slices its 1/n_sh of the flat tokens and the
    combined output is psum'd back to replicated.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    e = cfg.moe
    E = e.n_experts
    n_sh = mesh.shape[model_axis]
    E_loc = E // n_sh
    B, S, D = x.shape
    seq_sharded = bool(cfg.parallel.seq_parallel) and S % n_sh == 0
    d_axes = tuple(data_axes)

    x_spec = P(d_axes or None, model_axis if seq_sharded else None, None)
    w_e = P(model_axis, None, None)        # expert-sharded weights
    rep = P()

    def route_and_exchange(xf, router_w, we1, we3, we2):
        """xf: (N, D) — this shard's distinct tokens."""
        N = xf.shape[0]
        K = e.top_k
        logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32),
                            router_w.astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)
        gates, experts = jax.lax.top_k(probs, K)
        gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
        f = jnp.mean(jnp.sum(jax.nn.one_hot(experts, E, dtype=jnp.float32),
                             axis=1), axis=0)
        aux = E * jnp.sum(f * jnp.mean(probs, axis=0)) * e.aux_loss_weight

        expert_flat = experts.reshape(N * K)
        gate_flat = gates.reshape(N * K).astype(xf.dtype)
        token_idx = jnp.arange(N * K, dtype=jnp.int32) // K
        xrep = xf[token_idx]                          # (N*K, D)

        # --- dispatch: bucket by destination model shard ---------------
        C_sh = _capacity(max(N * K // n_sh, 1), cfg)
        dest_shard = expert_flat // E_loc
        send, order, dest = _local_bucket(xrep, dest_shard, n_sh, C_sh)
        ids = jnp.full((n_sh * C_sh + 1,), -1, jnp.int32)
        ids = ids.at[dest].set((expert_flat % E_loc)[order])
        ids = ids[: n_sh * C_sh].reshape(n_sh, C_sh)

        recv = jax.lax.all_to_all(send, model_axis, split_axis=0,
                                  concat_axis=0, tiled=True)
        recv_ids = jax.lax.all_to_all(ids, model_axis, split_axis=0,
                                      concat_axis=0, tiled=True)

        # --- run MY experts over the received tokens -------------------
        M = n_sh * C_sh
        rflat = recv.reshape(M, D)
        idflat = jnp.where(recv_ids.reshape(M) < 0, E_loc,
                           recv_ids.reshape(M))      # pads -> drop bucket
        C_loc = _capacity(max(M // max(E_loc, 1), 1), cfg)
        ebuf, eorder, edest = _local_bucket(rflat, idflat, E_loc + 1, C_loc)
        ein = ebuf[:E_loc]                            # (E_loc, C_loc, D)
        g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ein, we1))
        u = jnp.einsum("ecd,edf->ecf", ein, we3)
        eout = jnp.einsum("ecf,efd->ecd", g * u, we2)
        # invert local bucketing: sorted row i came from rflat[eorder[i]]
        eflat = jnp.concatenate(
            [eout.reshape(E_loc * C_loc, D),
             jnp.zeros((C_loc + 1, D), eout.dtype)], axis=0)
        back = jnp.zeros((M, D), eout.dtype)
        back = back.at[eorder].set(eflat[edest])
        back = back.reshape(n_sh, C_sh, D)

        # --- return trip + weighted combine ----------------------------
        ret = jax.lax.all_to_all(back, model_axis, split_axis=0,
                                 concat_axis=0, tiled=True)
        retflat = jnp.concatenate(
            [ret.reshape(n_sh * C_sh, D),
             jnp.zeros((1, D), ret.dtype)], axis=0)
        contrib = retflat[dest] * gate_flat[order][:, None]
        y = jnp.zeros((N, D), xf.dtype).at[token_idx[order]].add(contrib)
        return y, aux

    if seq_sharded:
        def body(x_blk, router_w, we1, we3, we2):
            B_loc, S_loc, _ = x_blk.shape
            y, aux = route_and_exchange(x_blk.reshape(B_loc * S_loc, D),
                                        router_w, we1, we3, we2)
            return y.reshape(B_loc, S_loc, D), aux[None]
    else:
        def body(x_blk, router_w, we1, we3, we2):
            B_loc, S_loc, _ = x_blk.shape
            N_tot = B_loc * S_loc
            N = N_tot // n_sh
            mi = jax.lax.axis_index(model_axis)
            xf = jax.lax.dynamic_slice_in_dim(
                x_blk.reshape(N_tot, D), mi * N, N, axis=0)
            y_loc, aux = route_and_exchange(xf, router_w, we1, we3, we2)
            y = jnp.zeros((N_tot, D), y_loc.dtype)
            y = jax.lax.dynamic_update_slice_in_dim(y, y_loc, mi * N, axis=0)
            y = jax.lax.psum(y, model_axis)
            return y.reshape(B_loc, S_loc, D), aux[None]

    y, aux = shard_map(
        body, mesh=mesh,
        in_specs=(x_spec, rep, w_e, w_e, w_e),
        out_specs=(x_spec, P(model_axis)),
        check_rep=False,
    )(x, p["router"], p["we1"], p["we3"], p["we2"])
    if e.n_shared_experts:
        y = y + _shared_ffn(p, x)
    return y, jnp.mean(aux)


def _a2a_applicable(cfg, x, ctx) -> bool:
    """a2a needs every shard to own an equal, non-empty token slice."""
    if ctx is None or "model" not in ctx.axis_sizes:
        return False
    n_sh = ctx.axis_sizes["model"]
    if n_sh <= 1 or cfg.moe.n_experts % n_sh:
        return False
    B, S, _ = x.shape
    n_data = 1
    for a in ("pod", "data"):
        n_data *= ctx.axis_sizes.get(a, 1)
    if B % n_data:
        return False
    B_loc = B // n_data
    if cfg.parallel.seq_parallel and S % n_sh == 0:
        return True
    return (B_loc * S) % n_sh == 0 and (B_loc * S) >= n_sh


def moe_apply(p, cfg, x):
    if cfg.moe.dispatch == "a2a":
        from ..sharding import active_ctx
        ctx = active_ctx()
        if _a2a_applicable(cfg, x, ctx):
            data_axes = tuple(a for a in ("pod", "data")
                              if a in ctx.axis_sizes)
            return moe_apply_a2a(p, cfg, x, mesh=ctx.mesh,
                                 data_axes=data_axes)
        # fallback (single device / tiny decode batches): pjit dispatch
        return moe_apply_sort_scatter(p, cfg, x)
    if cfg.moe.dispatch == "sort_scatter":
        return moe_apply_sort_scatter(p, cfg, x)
    return moe_apply_dense_onehot(p, cfg, x)
