"""Attention variants: GQA (optionally qk-norm / sliding-window), MLA, cross.

Dense compute goes through the kernel wrappers in ``repro.kernels`` which
dispatch to the Pallas TPU kernels on TPU and to the pure-jnp reference on
CPU (and in the dry-run).

Shapes:  x (B,S,D); q (B,S,H,hd); k,v (B,T,K,hd) with H = G*K (GQA).
KV caches are ring buffers of length T = min(window or max_len, max_len);
slot(pos) = pos % T; K is stored *post-RoPE* so ring eviction needs no
re-rotation.  Decode positions are PER-ROW: ``pos`` may be a (B,) vector
(scalar broadcasts), each row ring-writing at its own slot and masking at
its own length — what lets a persistent slot pool decode a ragged dynamic
batch in lock-step.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels.flash_attention import ops as flash_ops
from ..kernels.decode_attention import ops as decode_ops
from .layers import apply_rope, rmsnorm, rmsnorm_init, trunc_normal


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def gqa_init(key, cfg, dtype):
    D, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": trunc_normal(ks[0], (D, H, hd), dtype=dtype),
        "wk": trunc_normal(ks[1], (D, K, hd), dtype=dtype),
        "wv": trunc_normal(ks[2], (D, K, hd), dtype=dtype),
        "wo": trunc_normal(ks[3], (H, hd, D), dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dtype)
        p["k_norm"] = rmsnorm_init(hd, dtype)
    return p


def gqa_project_qkv(p, cfg, x, positions):
    """Project and rope q/k/v for a full sequence. positions: (S,) or (B,S)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_apply(p, cfg, x, *, positions=None, causal: bool = True):
    """Full-sequence attention (train / prefill)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    q, k, v = gqa_project_qkv(p, cfg, x, positions)
    out = flash_ops.flash_attention(
        q, k, v, causal=causal, window=cfg.attn_window,
        softcap=cfg.attn_logit_softcap)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def gqa_cache_len(cfg, max_len: int) -> int:
    return min(cfg.attn_window, max_len) if cfg.attn_window else max_len


def _quantize_kv(x):
    """(..., hd) -> int8 values + per-row f16 scale (§Perf G5)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale[..., 0].astype(jnp.float16)


def _dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32)
            * scale.astype(jnp.float32)[..., None]).astype(dtype)


def gqa_cache_init(cfg, batch: int, max_len: int, n_layers: int, dtype):
    K, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    T = gqa_cache_len(cfg, max_len)
    shape = (n_layers, batch, T, K, hd) if n_layers else (batch, T, K, hd)
    if cfg.kv_cache_dtype == "int8":       # §Perf G5
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(shape[:-1], jnp.float16),
                "v_scale": jnp.zeros(shape[:-1], jnp.float16)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def gqa_empty_cache_layer(cfg, batch: int, max_len: int, dtype):
    """One layer's empty ring cache (prefill writes into this)."""
    return gqa_cache_init(cfg, batch, max_len, 0, dtype)


def gqa_cache_write_prefill(cache_layer, cfg, k, v, max_len: int):
    """Write a prefill's K/V (B,S,K,hd) into one layer's ring cache (B,T,K,hd)."""
    T = cache_layer["k"].shape[1]
    S = k.shape[1]
    if S > T:
        # keep the last T positions, placed at their ring slots
        slots = (jnp.arange(S - T, S, dtype=jnp.int32) % T)
        order = jnp.argsort(slots)
        k = jnp.take(k[:, S - T:], order, axis=1)
        v = jnp.take(v[:, S - T:], order, axis=1)

    def upd(c, val):
        return jax.lax.dynamic_update_slice_in_dim(c, val, 0, axis=1)

    if cfg.kv_cache_dtype == "int8":
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        return {"k": upd(cache_layer["k"], kq),
                "v": upd(cache_layer["v"], vq),
                "k_scale": upd(cache_layer["k_scale"], ks),
                "v_scale": upd(cache_layer["v_scale"], vs)}
    return {"k": upd(cache_layer["k"], k), "v": upd(cache_layer["v"], v)}


def gqa_cache_write_decode(cache_layer, cfg, k, v, slots):
    """Ring-write one decode token's K/V (B,1,K,hd) at PER-ROW ``slots``
    (B,) of one layer's cache (B,T,K,hd) — a batched scatter, so every row
    of a persistent slot pool advances at its own ring position."""
    B = k.shape[0]
    rows = jnp.arange(B)

    def upd(c, val):
        return c.at[rows, slots].set(val[:, 0])

    if cfg.kv_cache_dtype == "int8":       # §Perf G5
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        return {"k": upd(cache_layer["k"], kq),
                "v": upd(cache_layer["v"], vq),
                "k_scale": upd(cache_layer["k_scale"], ks),
                "v_scale": upd(cache_layer["v_scale"], vs)}
    return {"k": upd(cache_layer["k"], k), "v": upd(cache_layer["v"], v)}


# ---------------------------------------------------------------------------
# Paged GQA cache (refcounted shared-prefix pages)
# ---------------------------------------------------------------------------
#
# The paged layout replaces each layer's dense per-row ring (B, T, K, hd)
# with a physical page POOL (n_pages, P, K, hd) addressed through a per-row
# int32 page table (B, max_pages): row b's logical ring slot s lives at
# ``pool[table[b, s // P], s % P]``, so two rows whose tables map the same
# physical page SHARE those K/V bytes (a common prompt prefix is prefilled
# once and refcounted, never copied).  Physical page 0 is reserved as the
# TRASH page: unmapped table entries and masked lock-step writes land there
# and are never attended (always past a row's n_valid).

def gqa_paged_cache_init(cfg, n_pages: int, page_size: int, n_layers: int,
                         dtype):
    """One stage's paged KV pool: leaves (L, n_pages, P, K, hd)."""
    assert cfg.kv_cache_dtype != "int8", \
        "paged KV does not support int8 cache quantisation"
    K, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    shape = (n_layers, n_pages, page_size, K, hd) if n_layers else \
        (n_pages, page_size, K, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def gqa_cache_write_decode_paged(cache_layer, cfg, k, v, pages, offsets):
    """Scatter one decode token's K/V (B,1,K,hd) at page-offset coordinates
    — ``pages``/``offsets`` (B,) physical coords into one layer's pool
    (n_pages, P, K, hd).  Masked/idle rows are routed to the trash page by
    the caller, so lock-step junk writes can never touch a live page."""
    def upd(c, val):
        return c.at[pages, offsets].set(val[:, 0])

    return {"k": upd(cache_layer["k"], k), "v": upd(cache_layer["v"], v)}


def gqa_decode_paged(p, cfg, x, cache_layer, table, pos, write_mask=None):
    """One-token decode for one layer through a page table.

    x: (B,1,D); table: (B, max_pages) int32 physical page ids (<= 0 =
    unmapped → trash); pos: (B,) or scalar tokens-already-in-context.
    Returns (out, new_cache_layer).  The ring length is max_pages * P; a
    write whose ring slot falls in an unmapped logical page goes to trash
    (the host allocator maps a real page before any live row's write).
    ``write_mask`` (B,) bool routes idle rows' lock-step writes to trash
    too — unlike the contiguous ring, an idle row's slot may sit in a
    REFCOUNT-SHARED page, where a junk write would corrupt the page for
    its other holders instead of self-healing."""
    B = x.shape[0]
    P = cache_layer["k"].shape[1]
    max_pages = table.shape[1]
    T = max_pages * P
    pos = decode_positions(pos, B)
    q, k, v = gqa_project_qkv(p, cfg, x, pos[:, None])
    slot = pos % T
    logical = slot // P
    phys = jnp.take_along_axis(jnp.maximum(table, 0), logical[:, None],
                               axis=1)[:, 0]
    if write_mask is not None:
        phys = jnp.where(write_mask, phys, 0)
    new_cache = gqa_cache_write_decode_paged(cache_layer, cfg, k, v,
                                             phys, slot % P)
    n_valid = jnp.minimum(pos + 1, T)
    out = decode_ops.decode_attention_paged(
        q, new_cache["k"], new_cache["v"], table, n_valid,
        softcap=cfg.attn_logit_softcap)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache


def gqa_prefill_into_pages(p, cfg, x, cache_layer, table, positions,
                           lengths):
    """Tail prefill THROUGH the page table for one layer.

    x: (Bn,S,D) normed tail hidden states; table: (Bn, max_pages) the
    admitted rows' physical page maps; positions: (Bn,S) absolute token
    positions (``base + t`` — base is the shared-prefix length already in
    pages, so the tail K/V ring-writes land right after the shared span);
    lengths: (Bn,) true tail lengths (padding positions write to trash).

    Tail queries attend over the row's WHOLE mapped ring — the refcounted
    shared-prefix pages plus the tail just written — under the absolute
    causal mask ``slot <= position``, which is exactly full-prompt prefill
    as long as nothing wrapped (prompts are admission-checked <= max_len).
    Returns (attn output (Bn,S,D), updated cache_layer)."""
    B, S, _ = x.shape
    P = cache_layer["k"].shape[1]
    max_pages = table.shape[1]
    T = max_pages * P
    q, k, v = gqa_project_qkv(p, cfg, x, positions)
    valid = jnp.arange(S)[None, :] < lengths[:, None]          # (Bn,S)
    slot = positions % T
    phys = jnp.take_along_axis(jnp.maximum(table, 0), slot // P, axis=1)
    phys = jnp.where(valid, phys, 0)                           # pad → trash
    off = slot % P
    new_k = cache_layer["k"].at[phys, off].set(k.astype(cache_layer["k"].dtype))
    new_v = cache_layer["v"].at[phys, off].set(v.astype(cache_layer["v"].dtype))
    # dense per-row view of the updated pool: (Bn, T, K, hd)
    from ..kernels.decode_attention.ref import gather_pages_ref
    kd = gather_pages_ref(new_k, table)
    vd = gather_pages_ref(new_v, table)
    K_h, hd = k.shape[2], k.shape[3]
    G = q.shape[2] // K_h
    qg = q.reshape(B, S, K_h, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, kd,
                        preferred_element_type=jnp.float32)
    scores = scores * (1.0 / (hd ** 0.5))
    if cfg.attn_logit_softcap > 0.0:
        scores = cfg.attn_logit_softcap * jnp.tanh(
            scores / cfg.attn_logit_softcap)
    # absolute causal mask: ring slot t attendable by the query at
    # absolute position positions[b, s] iff t <= positions[b, s]
    mask = (jnp.arange(T)[None, None, None, None, :]
            <= positions[:, None, None, :, None])
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1).astype(vd.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, vd,
                     preferred_element_type=jnp.float32)
    out = out.reshape(B, S, K_h * G, hd).astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, {"k": new_k, "v": new_v}


def decode_positions(pos, batch: int):
    """Normalise a decode position to per-row (B,) int32 (scalar broadcasts
    — the fixed-lockstep engine path and the slot pool share one code path)."""
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        pos = jnp.full((batch,), pos, jnp.int32)
    return pos


def gqa_decode(p, cfg, x, cache_layer, pos):
    """One-token decode for one layer. x: (B,1,D); pos: int32 scalar or (B,)
    = number of tokens already in each row's context (per-row positions let
    a slot pool decode a ragged batch). Returns (out, new_cache_layer)."""
    B = x.shape[0]
    T = cache_layer["k"].shape[1]
    pos = decode_positions(pos, B)
    q, k, v = gqa_project_qkv(p, cfg, x, pos[:, None])  # q (B,1,H,hd); k,v (B,1,K,hd)
    new_cache = gqa_cache_write_decode(cache_layer, cfg, k, v, pos % T)
    if cfg.kv_cache_dtype == "int8":       # §Perf G5
        ck = _dequantize_kv(new_cache["k"], new_cache["k_scale"], k.dtype)
        cv = _dequantize_kv(new_cache["v"], new_cache["v_scale"], v.dtype)
    else:
        ck, cv = new_cache["k"], new_cache["v"]
    n_valid = jnp.minimum(pos + 1, T)                   # (B,)
    out = decode_ops.decode_attention(q, ck, cv, n_valid,
                                      softcap=cfg.attn_logit_softcap)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder)
# ---------------------------------------------------------------------------

def cross_attn_init(key, cfg, dtype):
    D, H, hd = cfg.d_model, cfg.n_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": trunc_normal(ks[0], (D, H, hd), dtype=dtype),
        "wk": trunc_normal(ks[1], (D, H, hd), dtype=dtype),
        "wv": trunc_normal(ks[2], (D, H, hd), dtype=dtype),
        "wo": trunc_normal(ks[3], (H, hd, D), dtype=dtype),
    }


def cross_attn_kv(p, enc_out):
    """Precompute cross K/V from encoder output (a reusable request context)."""
    k = jnp.einsum("btd,dhk->bthk", enc_out, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", enc_out, p["wv"])
    return k, v


def cross_attn_apply(p, cfg, x, k, v):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    out = flash_ops.flash_attention(q, k, v, causal=False, window=0)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3)
# ---------------------------------------------------------------------------

def mla_init(key, cfg, dtype):
    m = cfg.mla
    D, H = cfg.d_model, cfg.n_heads
    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": trunc_normal(ks[0], (D, m.q_lora_rank), dtype=dtype),
        "q_norm": rmsnorm_init(m.q_lora_rank, dtype),
        "wq_b": trunc_normal(ks[1], (m.q_lora_rank, H, qk_hd), dtype=dtype),
        "wkv_a": trunc_normal(ks[2], (D, m.kv_lora_rank + m.qk_rope_head_dim),
                              dtype=dtype),
        "kv_norm": rmsnorm_init(m.kv_lora_rank, dtype),
        "wk_b": trunc_normal(ks[3], (m.kv_lora_rank, H, m.qk_nope_head_dim),
                             dtype=dtype),
        "wv_b": trunc_normal(ks[4], (m.kv_lora_rank, H, m.v_head_dim),
                             dtype=dtype),
        "wo": trunc_normal(ks[5], (H, m.v_head_dim, D), dtype=dtype),
    }


def _mla_q(p, cfg, x, positions):
    m = cfg.mla
    q_lat = rmsnorm(jnp.einsum("bsd,dr->bsr", x, p["wq_a"]), p["q_norm"],
                    cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", q_lat, p["wq_b"])
    q_nope = q[..., : m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_kv_latent(p, cfg, x, positions):
    """Compressed latent ckv (B,S,r) and shared roped key k_rope (B,S,rope)."""
    m = cfg.mla
    kv = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    ckv = rmsnorm(kv[..., : m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_rope = kv[..., m.kv_lora_rank:]
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]
    return ckv, k_rope


def mla_apply(p, cfg, x, *, positions=None):
    """Full-sequence MLA (train / prefill): decompress K,V then flash attention."""
    m = cfg.mla
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    ckv, k_rope = _mla_kv_latent(p, cfg, x, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", ckv, p["wk_b"])
    v = jnp.einsum("bsr,rhk->bshk", ckv, p["wv_b"])
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, S, cfg.n_heads, m.qk_rope_head_dim))],
        axis=-1)
    out = flash_ops.flash_attention(q, k, v, causal=True,
                                    window=cfg.attn_window)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def mla_cache_init(cfg, batch: int, max_len: int, n_layers: int, dtype):
    m = cfg.mla
    T = gqa_cache_len(cfg, max_len)
    return {
        "ckv": jnp.zeros((n_layers, batch, T, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((n_layers, batch, T, m.qk_rope_head_dim), dtype),
    }


def mla_cache_write_prefill(cache_layer, cfg, ckv, k_rope, max_len: int):
    T = cache_layer["ckv"].shape[1]
    S = ckv.shape[1]
    if S > T:
        ckv, k_rope = ckv[:, S - T:], k_rope[:, S - T:]
    c1 = jax.lax.dynamic_update_slice_in_dim(cache_layer["ckv"], ckv, 0, axis=1)
    c2 = jax.lax.dynamic_update_slice_in_dim(cache_layer["k_rope"], k_rope, 0,
                                             axis=1)
    return {"ckv": c1, "k_rope": c2}


def mla_decode(p, cfg, x, cache_layer, pos):
    """Absorbed-form MLA decode: attention runs in the compressed latent space
    (this is the TPU-friendly 'weight absorption' trick from the DeepSeek
    papers — K/V are never decompressed per step).  ``pos`` is int32 scalar
    or (B,) per-row positions (slot-pool decode)."""
    m = cfg.mla
    B = x.shape[0]
    T = cache_layer["ckv"].shape[1]
    pos = decode_positions(pos, B)
    positions = pos[:, None]                             # (B,1)
    q_nope, q_rope = _mla_q(p, cfg, x, positions)        # (B,1,H,·)
    ckv_new, k_rope_new = _mla_kv_latent(p, cfg, x, positions)
    rows = jnp.arange(B)
    slots = pos % T
    ckv = cache_layer["ckv"].at[rows, slots].set(ckv_new[:, 0])
    k_rope = cache_layer["k_rope"].at[rows, slots].set(k_rope_new[:, 0])
    # absorb wk_b into the query: q_lat (B,1,H,r)
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["wk_b"])
    scale = 1.0 / jnp.sqrt(jnp.float32(m.qk_nope_head_dim + m.qk_rope_head_dim))
    scores = (jnp.einsum("bshr,btr->bhst", q_lat, ckv)
              + jnp.einsum("bshk,btk->bhst", q_rope, k_rope)).astype(jnp.float32)
    scores = scores * scale
    n_valid = jnp.minimum(pos + 1, T)                    # (B,)
    mask = jnp.arange(T)[None, None, None, :] < n_valid[:, None, None, None]
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1).astype(ckv.dtype)
    out_lat = jnp.einsum("bhst,btr->bshr", probs, ckv)   # (B,1,H,r)
    out = jnp.einsum("bshr,rhk->bshk", out_lat, p["wv_b"])
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, {"ckv": ckv, "k_rope": k_rope}
