"""Model assembly: stages of scanned homogeneous blocks + LM heads.

Public API (all pure functions of (cfg, params, ...)):

  init_params(cfg, key)                  -> params pytree
  forward(cfg, params, batch)            -> full logits (small models/tests)
  loss_fn(cfg, params, batch)            -> (loss, metrics)   [chunked CE]
  cache_init(cfg, batch, max_len)        -> decode cache pytree
  prefill(cfg, params, batch, max_len)   -> (last-token logits, cache)
  decode_step(cfg, params, cache, tok)   -> (logits (B,1,V), cache)

Layer stacks are grouped into consecutive homogeneous *stages* (run-length
encoding of the block-type sequence) and each stage runs under
``jax.lax.scan`` over stacked params — HLO size stays O(#stages), which is
what makes the 126-layer llama3-405b dry-run compile tractable.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..sharding import cotangent_dtype_pin, grad_hint, hint
from .blocks import BLOCKS
from .layers import (embed_init, rmsnorm, rmsnorm_init, sinusoidal_positions,
                     trunc_normal)

VISION_EMBED_DIM = 1024          # stub ViT tower output width (llava)


# ---------------------------------------------------------------------------
# Stage layout
# ---------------------------------------------------------------------------

def layer_types(cfg) -> List[str]:
    if cfg.block_pattern:
        return list(cfg.block_pattern)
    if cfg.family == "audio":
        return ["dec"] * cfg.n_layers
    if cfg.mla is not None and cfg.moe is not None:
        return (["dense_mla"] * cfg.first_k_dense
                + ["moe_mla"] * (cfg.n_layers - cfg.first_k_dense))
    if cfg.moe is not None:
        return ["moe"] * cfg.n_layers
    if cfg.hybrid_parallel_heads:
        return ["hymba"] * cfg.n_layers
    return ["dense"] * cfg.n_layers


def stages_for(cfg) -> List[Tuple[str, int]]:
    """Run-length encode the layer-type sequence into scanned stages."""
    out: List[Tuple[str, int]] = []
    for t in layer_types(cfg):
        if out and out[-1][0] == t:
            out[-1] = (t, out[-1][1] + 1)
        else:
            out.append((t, 1))
    return out


def _stack_layers(key, cfg, btype: str, n: int, dtype):
    init = BLOCKS[btype]["init"]
    keys = jax.random.split(key, n)
    per_layer = [init(k, cfg, dtype) for k in keys]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per_layer)


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_params(cfg, key) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": (None if cfg.nonparametric_norm
                       else rmsnorm_init(cfg.d_model, dtype)),
        "stages": [
            _stack_layers(jax.random.fold_in(keys[1], i), cfg, btype, n, dtype)
            for i, (btype, n) in enumerate(stages_for(cfg))
        ],
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = trunc_normal(keys[2],
                                         (cfg.d_model, cfg.vocab_size),
                                         dtype=dtype)
    if cfg.family == "vlm":
        params["proj_vision"] = {
            "w1": trunc_normal(keys[3], (VISION_EMBED_DIM, cfg.d_model),
                               dtype=dtype),
            "w2": trunc_normal(keys[4], (cfg.d_model, cfg.d_model),
                               dtype=dtype),
        }
    if cfg.is_encdec:
        params["enc"] = {
            "stages": [_stack_layers(keys[5], cfg, "enc", cfg.encoder_layers,
                                     dtype)],
            "final_norm": rmsnorm_init(cfg.d_model, dtype),
        }
    if cfg.mtp_depth:
        mtp_key = keys[6]
        btype = "moe_mla" if (cfg.mla and cfg.moe) else "dense"
        params["mtp"] = {
            "block": _stack_layers(mtp_key, cfg, btype, 1, dtype),
            "proj": trunc_normal(jax.random.fold_in(mtp_key, 1),
                                 (2 * cfg.d_model, cfg.d_model), dtype=dtype),
            "norm_h": rmsnorm_init(cfg.d_model, dtype),
            "norm_e": rmsnorm_init(cfg.d_model, dtype),
        }
    return params


# ---------------------------------------------------------------------------
# Embedding of inputs
# ---------------------------------------------------------------------------

def _embed_tokens(cfg, params, tokens, base_pos=None):
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.rope_theta <= 0:      # sinusoidal absolute positions (whisper)
        S = tokens.shape[1]
        table = jnp.asarray(sinusoidal_positions(
            max(4096, S + 1), cfg.d_model), dtype=x.dtype)
        if base_pos is None:
            x = x + table[None, :S]
        else:
            base = jnp.asarray(base_pos, jnp.int32)
            if base.ndim == 0:
                x = x + jax.lax.dynamic_slice_in_dim(table, base, S)[None]
            else:            # per-row decode positions: (B,) gather
                x = x + table[base[:, None] + jnp.arange(S)]
    return x


def _proj_vision(params, vision_embeds):
    h = jnp.einsum("bpe,ed->bpd", vision_embeds, params["proj_vision"]["w1"])
    h = jax.nn.gelu(h)
    return jnp.einsum("bpd,de->bpe", h, params["proj_vision"]["w2"])


def _encode_audio(cfg, params, audio_embeds):
    F = audio_embeds.shape[1]
    table = jnp.asarray(sinusoidal_positions(F, cfg.d_model),
                        dtype=audio_embeds.dtype)
    x = audio_embeds + table[None]
    positions = jnp.arange(F, dtype=jnp.int32)
    for stacked in params["enc"]["stages"]:
        def body(carry, layer_p):
            y, _ = BLOCKS["enc"]["apply"](layer_p, cfg, carry, positions, {})
            return y, None
        x, _ = jax.lax.scan(body, x, stacked)
    return rmsnorm(x, params["enc"]["final_norm"], cfg.norm_eps)


def embed_batch(cfg, params, batch):
    """Returns (x (B,S,D), positions (S,), extras, n_prefix).

    n_prefix = number of leading positions with no LM labels (vision tiles)."""
    extras: Dict[str, Any] = {}
    n_prefix = 0
    tokens = batch["tokens"]
    x = _embed_tokens(cfg, params, tokens)
    if cfg.family == "vlm":
        v = _proj_vision(params, batch["vision_embeds"].astype(x.dtype))
        x = jnp.concatenate([v, x], axis=1)
        n_prefix = v.shape[1]
    if cfg.is_encdec:
        extras["enc_out"] = _encode_audio(
            cfg, params, batch["audio_embeds"])
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    x = hint(x, "batch", "seq_act", "embed_act")
    return x, positions, extras, n_prefix


# ---------------------------------------------------------------------------
# Stage execution
# ---------------------------------------------------------------------------

def _run_stages_apply(cfg, params, x, positions, extras):
    aux_total = jnp.zeros((), jnp.float32)
    for (btype, _n), stacked in zip(stages_for(cfg), params["stages"]):
        apply = BLOCKS[btype]["apply"]

        def body(carry, layer_p, _apply=apply):
            layer_p = grad_hint(layer_p)     # keep dW sharded in the bwd
            carry = cotangent_dtype_pin(carry, carry.dtype)  # bf16 dx
            y, aux = _apply(layer_p, cfg, carry, positions, extras)
            return y, aux

        if cfg.parallel.remat == "block":
            body = jax.checkpoint(body)
        x, auxs = jax.lax.scan(body, x, stacked)
        aux_total = aux_total + jnp.sum(auxs)
    return x, aux_total


def _run_stages_prefill(cfg, params, x, positions, extras, max_len):
    caches = []
    for (btype, _n), stacked in zip(stages_for(cfg), params["stages"]):
        prefill = BLOCKS[btype]["prefill"]

        def body(carry, layer_p, _prefill=prefill):
            y, cache_l, aux = _prefill(layer_p, cfg, carry, positions, extras,
                                       max_len)
            return y, (cache_l, aux)

        x, (cache_i, _auxs) = jax.lax.scan(body, x, stacked)
        caches.append(cache_i)
    return x, caches


def _run_stages_decode(cfg, params, x, caches, pos, extras):
    new_caches = []
    for (btype, _n), stacked, cache_i in zip(stages_for(cfg),
                                             params["stages"], caches):
        decode = BLOCKS[btype]["decode"]

        def body(carry, xs, _decode=decode):
            layer_p, cache_l = xs
            y, new_cache_l = _decode(layer_p, cfg, carry, cache_l, pos, extras)
            return y, new_cache_l

        x, new_cache_i = jax.lax.scan(body, x, (stacked, cache_i))
        new_caches.append(new_cache_i)
    return x, new_caches


# ---------------------------------------------------------------------------
# Forward / loss
# ---------------------------------------------------------------------------

def _unembed(cfg, params, h):
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", h, params["embed"])
    else:
        logits = jnp.einsum("...d,dv->...v", h, params["lm_head"])
    return logits


def forward(cfg, params, batch):
    """Full-sequence logits — for tests/small models (materialises B,S,V)."""
    x, positions, extras, _ = embed_batch(cfg, params, batch)
    h, _aux = _run_stages_apply(cfg, params, x, positions, extras)
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    return _unembed(cfg, params, h)


def _chunked_ce(cfg, params, h, targets, mask, chunk: int = 1024):
    """Cross-entropy without materialising (B,S,V): scan over seq chunks.

    h: (B,S,D); targets, mask: (B,S). Returns (sum_nll, sum_mask)."""
    B, S, D = h.shape
    pad = (-S) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    n = (S + pad) // chunk
    hc = h.reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    tc = targets.reshape(B, n, chunk).transpose(1, 0, 2)
    mc = mask.reshape(B, n, chunk).transpose(1, 0, 2)

    def body(acc, xs):
        h_i, t_i, m_i = xs
        logits = _unembed(cfg, params, h_i).astype(jnp.float32)
        logits = hint(logits, "batch", None, "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, t_i[..., None], axis=-1)[..., 0]
        nll = (lse - ll) * m_i
        return (acc[0] + jnp.sum(nll), acc[1] + jnp.sum(m_i)), None

    (nll_sum, m_sum), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hc, tc, mc))
    return nll_sum, m_sum


def loss_fn(cfg, params, batch):
    """Next-token LM loss (+ MoE aux + optional MTP). batch['tokens'] (B,S)."""
    tokens = batch["tokens"]
    x, positions, extras, n_prefix = embed_batch(cfg, params, batch)
    h, aux = _run_stages_apply(cfg, params, x, positions, extras)
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    h_text = h[:, n_prefix:]                       # positions with labels
    B, S = tokens.shape
    targets = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
    mask = jnp.pad(jnp.ones((B, S - 1), jnp.float32), ((0, 0), (0, 1)))
    if "loss_mask" in batch:
        mask = mask * batch["loss_mask"].astype(jnp.float32)
    nll_sum, m_sum = _chunked_ce(cfg, params, h_text, targets, mask)
    loss = nll_sum / jnp.maximum(m_sum, 1.0)
    metrics = {"ce": loss, "aux": aux, "tokens": m_sum}
    if cfg.mtp_depth and "mtp" in params:
        mtp = params["mtp"]
        emb_next = jnp.take(params["embed"], targets, axis=0)
        h_in = jnp.concatenate(
            [rmsnorm(h_text, mtp["norm_h"], cfg.norm_eps),
             rmsnorm(emb_next, mtp["norm_e"], cfg.norm_eps)], axis=-1)
        h_in = jnp.einsum("bsd,dk->bsk", h_in, mtp["proj"])
        btype = "moe_mla" if (cfg.mla and cfg.moe) else "dense"
        layer_p = jax.tree_util.tree_map(lambda a: a[0], mtp["block"])
        h_mtp, _ = BLOCKS[btype]["apply"](layer_p, cfg, h_in, positions[:S],
                                          extras)
        # at position i we now predict t_{i+2}
        t2 = jnp.pad(tokens[:, 2:], ((0, 0), (0, 2)))
        m2 = jnp.pad(jnp.ones((B, S - 2), jnp.float32), ((0, 0), (0, 2)))
        nll2, ms2 = _chunked_ce(cfg, params, h_mtp, t2, m2)
        mtp_loss = nll2 / jnp.maximum(ms2, 1.0)
        metrics["mtp"] = mtp_loss
        loss = loss + 0.3 * mtp_loss
    loss = loss + aux
    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def cache_init(cfg, batch: int, max_len: int):
    """Decode cache with PER-ROW positions: ``cache["pos"]`` is (B,) int32,
    so each row decodes at its own sequence length (slot-pool serving); the
    lock-step engine path simply keeps all rows equal."""
    dtype = jnp.dtype(cfg.dtype)
    caches = []
    for btype, n in stages_for(cfg):
        ci = BLOCKS[btype]["cache_init"]
        caches.append(ci(cfg, batch, max_len, n, dtype))
    return {"stages": caches, "pos": jnp.zeros((batch,), jnp.int32)}


def supports_paging(cfg) -> bool:
    """Whether this model family can run the PAGED decode cache.

    Paging covers the GQA ring-KV block types; recurrent state (SSM/xLSTM/
    hymba), MLA latents, cross-attention K/V, int8-quantised caches and
    sliding-window rings keep the contiguous per-slot layout."""
    return (all(t in ("dense", "moe") for t in layer_types(cfg))
            and cfg.kv_cache_dtype != "int8"
            and not cfg.attn_window
            and cfg.family != "vlm"
            and not cfg.is_encdec)


def paged_cache_init(cfg, batch: int, n_pages: int, page_size: int,
                     max_pages: int):
    """Paged decode cache: per-stage physical page pools + the page table.

    ``cache["stages"]`` leaves are (L, n_pages, P, K, hd) page POOLS shared
    by every row; ``cache["table"]`` (B, max_pages) int32 maps each row's
    logical pages to physical ones (0 = unmapped → the reserved trash
    page); ``cache["pos"]`` stays per-row.  The logical ring length is
    ``max_pages * page_size``."""
    assert supports_paging(cfg), "model family does not support paged KV"
    from .attention import gqa_paged_cache_init
    dtype = jnp.dtype(cfg.dtype)
    caches = [gqa_paged_cache_init(cfg, n_pages, page_size, n, dtype)
              for _btype, n in stages_for(cfg)]
    return {"stages": caches, "pos": jnp.zeros((batch,), jnp.int32),
            "table": jnp.zeros((batch, max_pages), jnp.int32)}


def prefill(cfg, params, batch, max_len: int):
    """Run the prompt, build the decode cache. Returns (last logits, cache)."""
    x, positions, extras, _n_prefix = embed_batch(cfg, params, batch)
    x, caches = _run_stages_prefill(cfg, params, x, positions, extras, max_len)
    h = rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = _unembed(cfg, params, h)
    B, S = x.shape[0], x.shape[1]
    return logits, {"stages": caches, "pos": jnp.full((B,), S, jnp.int32)}


def decode_step(cfg, params, cache, tokens, step_mask=None):
    """One token for the whole batch. tokens: (B,1). Returns (logits, cache).

    ``cache["pos"]`` is per-row, so rows may sit at different lengths: each
    embeds/RoPEs at its own position, ring-writes K/V at its own slot, and
    masks attention at its own valid length.

    ``step_mask`` (B,) bool marks the rows actually decoding; unmasked rows
    (free / not-yet-admitted slots of a slot pool) keep their position, and
    the junk K/V the lock-step write leaves at an unmasked row's current
    slot is overwritten by that row's next REAL step before it is ever
    attended (the write-then-attend order makes idle rows self-healing for
    ring-cache attention; SSM/xLSTM state rows are only exact when every
    occupied slot steps together)."""
    pos = cache["pos"]
    if cfg.rope_theta <= 0:
        x = _embed_tokens(cfg, params, tokens, base_pos=pos)
    else:
        x = _embed_tokens(cfg, params, tokens)
    extras: Dict[str, Any] = {}
    if "table" in cache:            # paged cache: route writes/attention
        extras["page_table"] = cache["table"]
        if step_mask is not None:   # idle rows' junk writes → trash page
            extras["step_mask"] = jnp.asarray(step_mask)
    x = hint(x, "batch", None, "embed_act")
    x, new_caches = _run_stages_decode(cfg, params, x, cache["stages"], pos,
                                       extras)
    h = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = _unembed(cfg, params, h)
    new_pos = pos + 1 if step_mask is None else \
        jnp.where(jnp.asarray(step_mask), pos + 1, pos)
    # preserve any additional cache entries (the page table) verbatim
    return logits, {**cache, "stages": new_caches, "pos": new_pos}


def prefill_into_slots(cfg, params, batch, cache, slots, lengths,
                       max_len: int):
    """Prompt-only prefill for NEWLY ADMITTED rows of a persistent slot pool.

    Runs the prefill forward over ``batch`` (Bn rows, right-padded to a
    bucketed S) and scatters the resulting per-layer K/V rows plus per-row
    positions into the SHARED decode cache at batch indices ``slots`` (Bn,)
    — live rows (every other slot) are untouched, so admission churn never
    re-pays prefill for requests already in flight.

    ``lengths`` (Bn,) are the true (unpadded) token counts; the returned
    logits are gathered at each row's own last real position.  ``max_len``
    MUST equal the max_len the shared cache was built with (same ring T).
    Returns (next-token logits (Bn,1,V), updated cache).
    """
    x, positions, extras, n_prefix = embed_batch(cfg, params, batch)
    x, caches = _run_stages_prefill(cfg, params, x, positions, extras,
                                    max_len)
    lengths = jnp.asarray(lengths, jnp.int32)
    Bn, _S, D = x.shape
    last = n_prefix + lengths - 1                       # (Bn,)
    h = jnp.take_along_axis(
        x, jnp.broadcast_to(last[:, None, None], (Bn, 1, D)), axis=1)
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = _unembed(cfg, params, h)

    # stage-cache leaves are (L, Bn, ...): scatter rows at batch axis 1.
    def scatter(big, small):
        return big.at[:, slots].set(small.astype(big.dtype))

    new_stages = jax.tree_util.tree_map(scatter, cache["stages"], caches)
    new_pos = cache["pos"].at[slots].set(n_prefix + lengths)
    # preserve any additional cache entries (sampling state etc.) verbatim
    return logits, {**cache, "stages": new_stages, "pos": new_pos}


def prefill_into_pages(cfg, params, batch, cache, slots, base, lengths):
    """Tail-only prefill for newly admitted rows of a PAGED slot pool.

    The shared prompt prefix — ``base`` (Bn,) tokens per row, page-aligned —
    is ALREADY resident in refcounted pages mapped by each row's page
    table, so only the unshared tail ``batch["tokens"]`` (Bn, S_tail,
    right-padded to a bucketed S) runs through the model: admission FLOPs
    and fresh KV bytes are flat in the shared-prefix length.  Tail queries
    attend over the row's whole mapped ring (shared pages + the tail being
    written) under the absolute causal mask, which equals full-prompt
    prefill exactly — shared pages hold the same post-RoPE K at the same
    absolute positions any private prefill would have written.

    ``slots`` (Bn,) are the rows' table indices; ``lengths`` (Bn,) the true
    tail token counts (padding positions write to the trash page).  The
    caller must have mapped private pages covering ``[base, base+length)``
    in ``cache["table"]`` before calling.  Returns (next-token logits
    (Bn,1,V) gathered at each row's last real tail position, updated
    cache)."""
    tokens = batch["tokens"]
    Bn, S = tokens.shape
    base = jnp.asarray(base, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    slots = jnp.asarray(slots, jnp.int32)
    x = _embed_tokens(cfg, params, tokens, base_pos=base)
    positions = base[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
    table_rows = jnp.take(cache["table"], slots, axis=0)   # (Bn, max_pages)
    x = hint(x, "batch", "seq_act", "embed_act")
    new_stages = []
    for (btype, _n), stacked, cache_i in zip(stages_for(cfg),
                                             params["stages"],
                                             cache["stages"]):
        paged_prefill = BLOCKS[btype]["prefill_paged"]

        def body(carry, xs, _pp=paged_prefill):
            layer_p, cache_l = xs
            y, new_cache_l = _pp(layer_p, cfg, carry, positions, cache_l,
                                 table_rows, lengths)
            return y, new_cache_l

        x, new_cache_i = jax.lax.scan(body, x, (stacked, cache_i))
        new_stages.append(new_cache_i)
    D = x.shape[-1]
    last = lengths - 1
    h = jnp.take_along_axis(
        x, jnp.broadcast_to(last[:, None, None], (Bn, 1, D)), axis=1)
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = _unembed(cfg, params, h)
    new_pos = cache["pos"].at[slots].set(base + lengths)
    return logits, {**cache, "stages": new_stages, "pos": new_pos}


def count_params(params) -> int:
    return int(sum(np.prod(x.shape)
                   for x in jax.tree_util.tree_leaves(params)))
