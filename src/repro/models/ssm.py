"""Selective-SSM (Mamba-style) mixer used by hymba's hybrid heads.

Layer:  x -> in_proj -> (u, z);  u -> causal conv -> silu -> selective scan
        -> * silu(z) -> out_proj.
The scan itself goes through the ssm_scan kernel wrapper (Pallas on TPU,
jnp reference elsewhere). Decode keeps (conv window, scan state) as cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..kernels.ssm_scan import ops as scan_ops
from .layers import trunc_normal


def ssm_inner_dim(cfg) -> int:
    return cfg.ssm.expand * cfg.d_model


def ssm_dt_rank(cfg) -> int:
    return cfg.ssm.dt_rank or max(1, -(-cfg.d_model // 16))


def ssm_init(key, cfg, dtype):
    s = cfg.ssm
    D = cfg.d_model
    DI = ssm_inner_dim(cfg)
    R = ssm_dt_rank(cfg)
    N = s.state_dim
    ks = jax.random.split(key, 6)
    # S4D-real initialisation for A
    A = jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), (DI, N))
    return {
        "in_proj": trunc_normal(ks[0], (D, 2 * DI), dtype=dtype),
        "conv": trunc_normal(ks[1], (s.conv_width, DI), scale=0.1, dtype=dtype),
        "x_proj": trunc_normal(ks[2], (DI, R + 2 * N), dtype=dtype),
        "dt_proj": trunc_normal(ks[3], (R, DI), scale=R ** -0.5, dtype=dtype),
        "A_log": jnp.log(A),
        "D": jnp.ones((DI,), jnp.float32),
        "out_proj": trunc_normal(ks[4], (DI, D), dtype=dtype),
    }


def _causal_conv(u, w, init_state=None):
    """u: (B,L,DI); w: (W,DI) depthwise. Returns (y (B,L,DI), tail (B,W-1,DI))."""
    B, L, DI = u.shape
    W = w.shape[0]
    if init_state is None:
        init_state = jnp.zeros((B, W - 1, DI), u.dtype)
    up = jnp.concatenate([init_state, u], axis=1)          # (B, L+W-1, DI)
    y = sum(up[:, i: i + L] * w[i][None, None, :] for i in range(W))
    tail = (jax.lax.dynamic_slice_in_dim(up, L, W - 1, axis=1)
            if W > 1 else jnp.zeros((B, 0, DI), u.dtype))
    return y, tail


def _project_scan_inputs(p, cfg, u):
    """u: (B,L,DI) post-conv. Returns dt, Bm, Cm for the scan."""
    N = cfg.ssm.state_dim
    R = ssm_dt_rank(cfg)
    dbc = jnp.einsum("bld,dr->blr", u, p["x_proj"])
    dt_low, Bm, Cm = jnp.split(dbc, [R, R + N], axis=-1)
    dt = jnp.einsum("blr,rd->bld", dt_low, p["dt_proj"])
    return dt, Bm, Cm


def ssm_apply(p, cfg, x):
    """Full-sequence mixer: (B,L,D) -> (B,L,D)."""
    DI = ssm_inner_dim(cfg)
    uz = jnp.einsum("bld,de->ble", x, p["in_proj"])
    u, z = jnp.split(uz, [DI], axis=-1)
    u, _ = _causal_conv(u, p["conv"])
    u = jax.nn.silu(u)
    dt, Bm, Cm = _project_scan_inputs(p, cfg, u)
    A = -jnp.exp(p["A_log"])
    y, _ = scan_ops.ssm_scan(u, dt, A, Bm, Cm, p["D"], chunk=cfg.ssm.chunk)
    y = y * jax.nn.silu(z)
    return jnp.einsum("bld,de->ble", y, p["out_proj"])


def ssm_cache_init(cfg, batch: int, n_layers: int, dtype):
    DI = ssm_inner_dim(cfg)
    W = cfg.ssm.conv_width
    N = cfg.ssm.state_dim
    return {
        "conv": jnp.zeros((n_layers, batch, W - 1, DI), dtype),
        "h": jnp.zeros((n_layers, batch, DI, N), jnp.float32),
    }


def ssm_prefill(p, cfg, x):
    """Like ssm_apply but also returns the decode cache for this layer."""
    DI = ssm_inner_dim(cfg)
    uz = jnp.einsum("bld,de->ble", x, p["in_proj"])
    u, z = jnp.split(uz, [DI], axis=-1)
    u, conv_tail = _causal_conv(u, p["conv"])
    u = jax.nn.silu(u)
    dt, Bm, Cm = _project_scan_inputs(p, cfg, u)
    A = -jnp.exp(p["A_log"])
    y, h = scan_ops.ssm_scan(u, dt, A, Bm, Cm, p["D"], chunk=cfg.ssm.chunk)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bld,de->ble", y, p["out_proj"])
    return out, {"conv": conv_tail, "h": h}


def ssm_decode(p, cfg, x, cache_layer):
    """One-token step. x: (B,1,D). Returns (out (B,1,D), new cache)."""
    DI = ssm_inner_dim(cfg)
    uz = jnp.einsum("bld,de->ble", x, p["in_proj"])
    u, z = jnp.split(uz, [DI], axis=-1)                    # (B,1,DI)
    conv_hist = cache_layer["conv"]                        # (B,W-1,DI)
    window = jnp.concatenate([conv_hist, u], axis=1)       # (B,W,DI)
    u_t = jnp.einsum("bwd,wd->bd", window, p["conv"])[:, None, :]
    new_conv = window[:, 1:]
    u_t = jax.nn.silu(u_t)
    dt, Bm, Cm = _project_scan_inputs(p, cfg, u_t)
    A = -jnp.exp(p["A_log"])
    y_t, h = scan_ops.ssm_step(u_t[:, 0], dt[:, 0], A, Bm[:, 0], Cm[:, 0],
                               p["D"], cache_layer["h"])
    y = y_t[:, None, :] * jax.nn.silu(z)
    out = jnp.einsum("bld,de->ble", y, p["out_proj"])
    return out, {"conv": new_conv, "h": h}
