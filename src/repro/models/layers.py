"""Shared primitive layers: norms, rotary embeddings, MLPs, initialisers.

All model code in this package is purely functional: params are plain pytrees
of jnp arrays, every layer is ``init(key, ...) -> params`` +
``apply(params, x, ...) -> y``.  Layer params are built *stacked* along a
leading layer axis by the model assembly (models/model.py) so whole stages
run under ``jax.lax.scan``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def param_dtype(cfg):
    return jnp.dtype(cfg.dtype)


def trunc_normal(key, shape, scale=0.02, dtype=jnp.float32):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype) -> jnp.ndarray:
    return jnp.ones((d,), dtype=dtype)


def rmsnorm(x: jnp.ndarray, weight, eps: float = 1e-5) -> jnp.ndarray:
    """RMSNorm with fp32 accumulation; weight=None => non-parametric (OLMo)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    if weight is not None:
        y = y * weight.astype(jnp.float32)
    return y.astype(x.dtype)


def layernorm_init(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(x, p, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    if p is not None:
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    """Inverse frequencies for RoPE (host-side constant)."""
    half = head_dim // 2
    return 1.0 / (theta ** (np.arange(0, half, dtype=np.float64) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotate the last dim of ``x`` (..., T, n_heads, head_dim) by positions (T,) or (B,T)."""
    if theta <= 0:
        return x
    head_dim = x.shape[-1]
    inv = jnp.asarray(rope_frequencies(head_dim, theta), dtype=jnp.float32)
    pos = positions.astype(jnp.float32)
    ang = pos[..., None] * inv                      # (..., T, half)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    # broadcast over the head axis: x is (..., T, H, hd)
    sin = sin[..., None, :]
    cos = cos[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n_pos: int, dim: int) -> np.ndarray:
    """Whisper-style sinusoidal embeddings (host-side constant)."""
    log_timescale = np.log(10_000.0) / (dim // 2 - 1)
    inv = np.exp(-log_timescale * np.arange(dim // 2))
    scaled = np.arange(n_pos)[:, None] * inv[None, :]
    return np.concatenate([np.sin(scaled), np.cos(scaled)], axis=1).astype(np.float32)


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w1": trunc_normal(k1, (d_model, d_ff), dtype=dtype),   # gate
        "w3": trunc_normal(k3, (d_model, d_ff), dtype=dtype),   # up
        "w2": trunc_normal(k2, (d_ff, d_model), dtype=dtype),   # down
    }


def mlp_apply(p, x):
    h = jax.nn.silu(jnp.einsum("...d,df->...f", x, p["w1"]))
    h = h * jnp.einsum("...d,df->...f", x, p["w3"])
    return jnp.einsum("...f,fd->...d", h, p["w2"])


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_init(key, vocab: int, d_model: int, dtype):
    return trunc_normal(key, (vocab, d_model), scale=0.02, dtype=dtype)


def embed_apply(table, tokens):
    return jnp.take(table, tokens, axis=0)


def unembed_apply(table_or_head, x, tied: bool):
    if tied:
        return jnp.einsum("...d,vd->...v", x, table_or_head)
    return jnp.einsum("...d,dv->...v", x, table_or_head)
