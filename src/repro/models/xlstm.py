"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

[arXiv:2405.04517] — faithful recurrences with exponential gating and
log-space stabilisation:

mLSTM (parallelisable matrix-memory LSTM):
    C_t = f_t C_{t-1} + i_t v_t k_t^T        (per head, C in R^{hd x hd})
    n_t = f_t n_{t-1} + i_t k_t
    h_t = (C_t q_t) / max(|n_t . q_t|, 1)
with m_t = max(log f_t + m_{t-1}, log i_t) stabilising i/f.

sLSTM (scalar-memory LSTM with recurrent head mixing):
    c_t = f c_{t-1} + i z_t ; n_t = f n_{t-1} + i ; h_t = o * c_t / n_t
with block-diagonal (per-head) recurrent weights R_{z,i,f,o}.

Both are time-sequential ``lax.scan``s (the recurrent form is also exactly
what decode needs); train_4k lowers as a scan so HLO stays O(1) in L.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .layers import rmsnorm, rmsnorm_init, trunc_normal


def _heads(cfg) -> Tuple[int, int]:
    return cfg.n_heads, cfg.resolved_head_dim


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg, dtype):
    D = cfg.d_model
    H, hd = _heads(cfg)
    inner = H * hd
    ks = jax.random.split(key, 8)
    return {
        "wq": trunc_normal(ks[0], (D, H, hd), dtype=dtype),
        "wk": trunc_normal(ks[1], (D, H, hd), dtype=dtype),
        "wv": trunc_normal(ks[2], (D, H, hd), dtype=dtype),
        "wi": trunc_normal(ks[3], (D, H), scale=0.01, dtype=dtype),
        "wf": trunc_normal(ks[4], (D, H), scale=0.01, dtype=dtype),
        "bf": jnp.ones((H,), jnp.float32) * 3.0,     # forget-gate bias >0
        "up_z": trunc_normal(ks[5], (D, inner), dtype=dtype),
        "down": trunc_normal(ks[6], (inner, D), dtype=dtype),
        "out_norm": rmsnorm_init(hd, dtype),
    }


def _mlstm_gates(p, cfg, x):
    """log-input/forget gates. x: (B,L,D) -> (B,L,H) fp32 each."""
    log_i = jnp.einsum("bld,dh->blh", x, p["wi"]).astype(jnp.float32)
    f_pre = jnp.einsum("bld,dh->blh", x, p["wf"]).astype(jnp.float32) + p["bf"]
    log_f = -jax.nn.softplus(-f_pre)                 # log sigmoid
    return log_i, log_f


def mlstm_state_init(cfg, batch: int, n_layers: int):
    H, hd = _heads(cfg)
    return {
        "C": jnp.zeros((n_layers, batch, H, hd, hd), jnp.float32),
        "n": jnp.zeros((n_layers, batch, H, hd), jnp.float32),
        "m": jnp.full((n_layers, batch, H), -1e30, jnp.float32),
    }


def _mlstm_step(qkv_t, log_i_t, log_f_t, state):
    """One recurrence step. qkv_t: (q,k,v) each (B,H,hd) fp32."""
    q, k, v = qkv_t
    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(log_f_t + m, log_i_t)                 # (B,H)
    i_sc = jnp.exp(log_i_t - m_new)
    f_sc = jnp.exp(log_f_t + m - m_new)
    C = f_sc[..., None, None] * C + i_sc[..., None, None] * \
        (v[..., :, None] * k[..., None, :])                   # (B,H,hd,hd)
    n = f_sc[..., None] * n + i_sc[..., None] * k
    num = jnp.einsum("bhij,bhj->bhi", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n, q)), 1.0)
    h = num / den[..., None]
    return h, {"C": C, "n": n, "m": m_new}


def _chunked_scan(step, init, xs, length: int, chunk: int = 128):
    """lax.scan with gradient checkpointing at chunk boundaries.

    The naive per-timestep scan saves every step's carry for the backward
    pass — for the mLSTM's (B,H,hd,hd) matrix state over L=4096 that is
    ~68 GB/layer (measured 2.6 TB/chip on xlstm train_4k, §Perf X1).
    Chunking saves only boundary carries and recomputes inside each chunk;
    values are bit-identical.
    """
    c = min(chunk, length)
    n, r = divmod(length, c)

    def inner(carry, chunk_xs):
        return jax.lax.scan(step, carry, chunk_xs)

    take = jax.tree_util.tree_map(lambda a: a[: n * c], xs)
    chunked = jax.tree_util.tree_map(
        lambda a: a.reshape((n, c) + a.shape[1:]), take)
    carry, hs = jax.lax.scan(jax.checkpoint(inner), init, chunked)
    hs = jax.tree_util.tree_map(
        lambda a: a.reshape((n * c,) + a.shape[2:]), hs)
    if r:
        rest = jax.tree_util.tree_map(lambda a: a[n * c:], xs)
        carry, hs_r = jax.lax.scan(step, carry, rest)
        hs = jnp.concatenate([hs, hs_r], axis=0)
    return carry, hs


def mlstm_apply(p, cfg, x, state=None, return_state: bool = False):
    """x: (B,L,D) -> (B,L,D)."""
    B, L, D = x.shape
    H, hd = _heads(cfg)
    scale = hd ** -0.5
    q = jnp.einsum("bld,dhk->blhk", x, p["wq"]).astype(jnp.float32) * scale
    k = jnp.einsum("bld,dhk->blhk", x, p["wk"]).astype(jnp.float32) * scale
    v = jnp.einsum("bld,dhk->blhk", x, p["wv"]).astype(jnp.float32)
    log_i, log_f = _mlstm_gates(p, cfg, x)
    if state is None:
        st = jax.tree_util.tree_map(lambda a: a[0],
                                    mlstm_state_init(cfg, B, 1))
    else:
        st = state

    def step(carry, t):
        q_t, k_t, v_t, li_t, lf_t = t
        h, carry = _mlstm_step((q_t, k_t, v_t), li_t, lf_t, carry)
        return carry, h

    xs = (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), log_i.transpose(1, 0, 2),
          log_f.transpose(1, 0, 2))
    st, hs = _chunked_scan(step, st, xs, L)
    h = hs.transpose(1, 0, 2, 3)                              # (B,L,H,hd)
    h = rmsnorm(h, p["out_norm"], cfg.norm_eps).astype(x.dtype)
    z = jax.nn.silu(jnp.einsum("bld,de->ble", x, p["up_z"]))
    out = jnp.einsum("ble,ed->bld", h.reshape(B, L, H * hd) * z, p["down"])
    if return_state:
        return out, st
    return out


def mlstm_decode(p, cfg, x, state):
    """x: (B,1,D). Returns (out (B,1,D), new state)."""
    B = x.shape[0]
    H, hd = _heads(cfg)
    scale = hd ** -0.5
    q = jnp.einsum("bld,dhk->blhk", x, p["wq"]).astype(jnp.float32)[:, 0] * scale
    k = jnp.einsum("bld,dhk->blhk", x, p["wk"]).astype(jnp.float32)[:, 0] * scale
    v = jnp.einsum("bld,dhk->blhk", x, p["wv"]).astype(jnp.float32)[:, 0]
    log_i, log_f = _mlstm_gates(p, cfg, x)
    h, st = _mlstm_step((q, k, v), log_i[:, 0], log_f[:, 0], state)
    h = rmsnorm(h[:, None], p["out_norm"], cfg.norm_eps).astype(x.dtype)
    z = jax.nn.silu(jnp.einsum("bld,de->ble", x, p["up_z"]))
    out = jnp.einsum("ble,ed->bld", h.reshape(B, 1, H * hd) * z, p["down"])
    return out, st


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, cfg, dtype):
    D = cfg.d_model
    H, hd = _heads(cfg)
    ks = jax.random.split(key, 9)
    p = {
        "wz": trunc_normal(ks[0], (D, H, hd), dtype=dtype),
        "wi": trunc_normal(ks[1], (D, H, hd), scale=0.01, dtype=dtype),
        "wf": trunc_normal(ks[2], (D, H, hd), scale=0.01, dtype=dtype),
        "wo_g": trunc_normal(ks[3], (D, H, hd), dtype=dtype),
        "rz": trunc_normal(ks[4], (H, hd, hd), dtype=dtype),
        "ri": trunc_normal(ks[5], (H, hd, hd), scale=0.01, dtype=dtype),
        "rf": trunc_normal(ks[6], (H, hd, hd), scale=0.01, dtype=dtype),
        "ro": trunc_normal(ks[7], (H, hd, hd), dtype=dtype),
        "bf": jnp.ones((H, hd), jnp.float32) * 3.0,
        "down": trunc_normal(ks[8], (H * hd, D), dtype=dtype),
        "out_norm": rmsnorm_init(hd, dtype),
    }
    return p


def slstm_state_init(cfg, batch: int, n_layers: int):
    H, hd = _heads(cfg)
    z = jnp.zeros((n_layers, batch, H, hd), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full_like(z, -1e30)}


def _slstm_step(p, pre_t, state):
    """pre_t: dict of pre-activations (B,H,hd) fp32 (input-side only)."""
    c, n, h_prev, m = state["c"], state["n"], state["h"], state["m"]
    rec = lambda name: jnp.einsum("bhj,hji->bhi", h_prev,
                                  p[name].astype(jnp.float32))
    z = jnp.tanh(pre_t["z"] + rec("rz"))
    log_i = pre_t["i"] + rec("ri")
    f_pre = pre_t["f"] + rec("rf") + p["bf"]
    log_f = -jax.nn.softplus(-f_pre)
    o = jax.nn.sigmoid(pre_t["o"] + rec("ro"))
    m_new = jnp.maximum(log_f + m, log_i)
    i_sc = jnp.exp(log_i - m_new)
    f_sc = jnp.exp(log_f + m - m_new)
    c = f_sc * c + i_sc * z
    n = f_sc * n + i_sc
    h = o * c / jnp.maximum(n, 1.0)
    return h, {"c": c, "n": n, "h": h, "m": m_new}


def _slstm_preact(p, x):
    f32 = jnp.float32
    return {
        "z": jnp.einsum("bld,dhk->blhk", x, p["wz"]).astype(f32),
        "i": jnp.einsum("bld,dhk->blhk", x, p["wi"]).astype(f32),
        "f": jnp.einsum("bld,dhk->blhk", x, p["wf"]).astype(f32),
        "o": jnp.einsum("bld,dhk->blhk", x, p["wo_g"]).astype(f32),
    }


def slstm_apply(p, cfg, x, state=None, return_state: bool = False):
    B, L, D = x.shape
    H, hd = _heads(cfg)
    pre = _slstm_preact(p, x)
    if state is None:
        st = jax.tree_util.tree_map(lambda a: a[0],
                                    slstm_state_init(cfg, B, 1))
    else:
        st = state

    def step(carry, t):
        h, carry = _slstm_step(p, t, carry)
        return carry, h

    xs = jax.tree_util.tree_map(lambda a: a.transpose(1, 0, 2, 3), pre)
    st, hs = _chunked_scan(step, st, xs, L)
    h = hs.transpose(1, 0, 2, 3)
    h = rmsnorm(h, p["out_norm"], cfg.norm_eps).astype(x.dtype)
    out = jnp.einsum("ble,ed->bld", h.reshape(B, L, H * hd), p["down"])
    if return_state:
        return out, st
    return out


def slstm_decode(p, cfg, x, state):
    B = x.shape[0]
    H, hd = _heads(cfg)
    pre = _slstm_preact(p, x)
    pre_t = jax.tree_util.tree_map(lambda a: a[:, 0], pre)
    h, st = _slstm_step(p, pre_t, state)
    h = rmsnorm(h[:, None], p["out_norm"], cfg.norm_eps).astype(x.dtype)
    out = jnp.einsum("ble,ed->bld", h.reshape(B, 1, H * hd), p["down"])
    return out, st

def _batch_local(apply_fn, p, cfg, x, return_state: bool):
    """Run a recurrent apply under shard_map with batch fully local.

    Left to the SPMD partitioner, the backward of the per-timestep
    recurrence all-reduces the recurrent-weight gradients ONCE PER STEP
    (xlstm train_4k: 137 GB/step of in-loop dR all-reduces — §Perf X4).
    shard_map fences it: params replicate in, dR accumulates locally, and
    the single psum happens at the shard_map transpose boundary.
    """
    from ..sharding import active_ctx
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    ctx = active_ctx()
    if ctx is None or cfg.parallel.tensor_parallel:
        return apply_fn(p, cfg, x, return_state=return_state)
    spec = ctx.resolve(("batch", None, None), x.shape)
    if spec[0] is None:
        return apply_fn(p, cfg, x, return_state=return_state)
    out_specs = (spec, P(spec[0])) if return_state else spec

    def inner(p_, x_):
        return apply_fn(p_, cfg, x_, return_state=return_state)

    return shard_map(inner, mesh=ctx.mesh, in_specs=(P(), spec),
                     out_specs=out_specs, check_rep=False)(p, x)
