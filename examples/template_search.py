"""Prompt-template search — the actual purpose of the PfF application.

Sweeps all prompt templates against the same (reduced) LLM, reusing one
hosted context per template (template text is a *context input*, so each
template is its own recipe), and reports the accuracy leaderboard the
paper's users are after.

  PYTHONPATH=src python examples/template_search.py [--claims 48]
"""
import argparse
import os
import sys
import time
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_smoke_config
from repro.data import TEMPLATES, accuracy, generate_claims
from repro.inference import sweep_accuracy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--claims", type=int, default=48)
    ap.add_argument("--arch", default="smollm2-1.7b")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    claims = generate_claims(args.claims, seed=5)
    board = []
    for name in TEMPLATES:
        t0 = time.perf_counter()
        acc = sweep_accuracy(cfg, name, claims, batch=8)
        board.append((acc, name, time.perf_counter() - t0))
        print(f"  {name:15s} accuracy {acc:.3f}  ({board[-1][2]:.1f}s)")
    board.sort(reverse=True)
    print(f"\nbest (LLM, template) pair: ({args.arch}, {board[0][1]}) "
          f"at {board[0][0]:.3f}")
    print("note: the reduced model is untrained — accuracies hover around "
          "chance; at paper scale this sweep is exactly what the "
          "opportunistic cluster runs 150k times per pair.")


if __name__ == "__main__":
    main()
