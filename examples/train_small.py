"""End-to-end training driver: ~100M-param model, few hundred steps on CPU.

The full pipeline — config registry, sharded train_step, synthetic data
stream, checkpointing — on a reduced config of any assigned architecture.

  PYTHONPATH=src python examples/train_small.py [--arch granite-3-8b]
    [--steps 300]

(default dims give ~95M params; --d-model 512 --layers 8 reaches ~140M)
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main as train_main

if __name__ == "__main__":
    argv = sys.argv[1:] or ["--arch", "granite-3-8b", "--steps", "300",
                            "--d-model", "384", "--layers", "6",
                            "--batch", "8", "--seq", "256",
                            "--ckpt", "/tmp/repro_train_small"]
    sys.exit(train_main(argv))
