"""Quickstart: pervasive context management in ~60 lines.

Mirrors the paper's Fig 3: define a context (model load), bind it to an
inference function, submit batched tasks, and watch the context being
staged ONCE per worker and reused by every subsequent task.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys
import os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.cluster import LiveExecutor, Scheduler, Worker
from repro.cluster.hardware import GPU_CATALOG
from repro.cluster.scheduler import Task
from repro.configs import get_smoke_config
from repro.core import PERVASIVE
from repro.data import accuracy, claim_batches, generate_claims
from repro.inference import build_context_recipe, infer_claims


def main():
    # 1. the application: fact-verify claims with a (reduced) LLM
    cfg = get_smoke_config("smollm2-1.7b")
    claims = generate_claims(32, seed=1)

    # 2. the context recipe (Fig 3's load_model): deps + weights +
    #    tokenizer/template + the jit-compiled engine
    recipe = build_context_recipe(cfg, "with_evidence")
    print(f"context recipe {recipe.key}: "
          f"{[e.name for e in recipe.elements]}")

    # 3. a manager with two workers
    sched = Scheduler()
    key = sched.register_context(recipe)
    for _ in range(2):
        sched.add_worker(Worker(GPU_CATALOG["NVIDIA A10"]))

    # 4. submit one task per claim batch
    for batch in claim_batches(claims, 8):
        sched.submit(Task(key, len(batch), PERVASIVE, payload=batch))

    # 5. run LIVE: contexts really materialise (imports, weights, jit)
    ex = LiveExecutor(sched, {key: infer_claims})
    ex.run()

    preds = [p for tid in sorted(ex.results) for p in ex.results[tid]]
    print(f"accuracy: {accuracy(preds, claims):.3f}")
    for r in sorted(sched.records, key=lambda r: r.t_start):
        kind = "warm" if r.warm else "COLD"
        print(f"  task {r.task_id}: {kind} {r.exec_s:6.2f}s on {r.worker_id}")
    cold = [r.exec_s for r in sched.records if not r.warm]
    warm = [r.exec_s for r in sched.records if r.warm]
    print(f"cold start paid {len(cold)}x (once per worker); "
          f"warm tasks are {min(cold) / max(warm):.0f}x faster")


if __name__ == "__main__":
    main()
