"""Prompt-for-Fact at paper scale: the full pv0→pv6 story on the simulator.

Replays the paper's §6 evaluation — 150k inferences over the heterogeneous
opportunistic cluster — through the same scheduler/registry/cache code the
live executor uses.  Takes ~2 minutes.

  PYTHONPATH=src python examples/fact_verification_sweep.py [--n 150000]
"""
import argparse
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=150_000)
    args = ap.parse_args()

    from benchmarks import bench_fig4_scaling_efforts as fig4
    res = fig4.main(args.n)
    pv0, pv6 = res["pv0"][0], res["pv6"][0]
    print(f"\nheadline: {pv0:,.0f}s on 1 dedicated GPU -> {pv6:,.0f}s "
          f"opportunistic = {100 * (1 - pv6 / pv0):.1f}% reduction "
          f"(paper: 98.1%)")


if __name__ == "__main__":
    main()
