"""Opportunistic serving, live: eviction mid-run, the context follows.

Starts the PfF application on one worker; after a third of the work the
worker is EVICTED with no grace period (its running task is requeued, its
hosted context is lost).  A fresh opportunistic joiner takes over: the
scheduler re-stages the context there once and completes the run — the
paper's Challenge #1 handled by design, live.

  PYTHONPATH=src python examples/serve_opportunistic.py
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.cluster import LiveExecutor, Scheduler, Worker
from repro.cluster.hardware import GPU_CATALOG
from repro.cluster.scheduler import Task
from repro.configs import get_smoke_config
from repro.core import PERVASIVE
from repro.data import accuracy, claim_batches, generate_claims
from repro.inference import build_context_recipe, infer_claims


def main():
    cfg = get_smoke_config("smollm2-1.7b")
    claims = generate_claims(48, seed=3)
    recipe = build_context_recipe(cfg, "zero_shot")

    sched = Scheduler()
    key = sched.register_context(recipe)
    w0 = Worker(GPU_CATALOG["NVIDIA A10"])
    sched.add_worker(w0)
    for b in claim_batches(claims, 8):
        sched.submit(Task(key, len(b), PERVASIVE, payload=b))

    ex = LiveExecutor(sched, {key: infer_claims})
    evicted = {"done": False}
    orig_route = sched.route

    def route_with_eviction():
        if (not evicted["done"]
                and sched.completed_inferences >= len(claims) // 3):
            requeued = sched.on_evict(w0.worker_id)
            joiner = Worker(GPU_CATALOG["NVIDIA TITAN X (Pascal)"])
            sched.add_worker(joiner)
            evicted["done"] = True
            print(f"[pool] {w0.worker_id} EVICTED "
                  f"({len(requeued)} tasks requeued, context lost); "
                  f"{joiner.worker_id} joined cold")
            assert sched.registry.ready_workers(key) == set()
        return orig_route()

    sched.route = route_with_eviction
    ex.run()
    preds = [p for tid in sorted(ex.results) for p in ex.results[tid]]
    print(f"completed {sched.completed_inferences}/{len(claims)} "
          f"inferences, accuracy {accuracy(preds, claims):.3f}")
    for r in sorted(sched.records, key=lambda r: r.t_start):
        kind = "warm" if r.warm else "COLD"
        print(f"  task {r.task_id:2d}: {kind} {r.exec_s:6.2f}s "
              f"on {r.worker_id}")


if __name__ == "__main__":
    main()
