"""Eviction resilience (paper Fig 6): a cluster that turns busy mid-run.

20 GPUs for 15 minutes, then 1 reclaimed per minute (A10s first, no grace
period).  Compares partial vs pervasive context management on completed
work and evicted work.

  PYTHONPATH=src python examples/busy_cluster_drain.py
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    from benchmarks import bench_fig6_busy_cluster as fig6
    res = fig6.main(150_000)
    s, p = res["pv5s"], res["pv5p"]
    print(f"\npervasive kept {s.completed - p.completed:,} more inferences "
          f"alive through the drain; evicted work "
          f"{s.evicted_inferences:,} vs {p.evicted_inferences:,}")


if __name__ == "__main__":
    main()
