"""Context-plane link budget: replication pressure vs staging makespan.

A 3-zone pool with ONE warm seed (z0) hosting two contexts:

* HOT — an 8B-class recipe under replication pressure: every 10 s an
  explicit ``Replicate(hot, 9)`` intent is compiled through the context
  plane, asking for a warm copy on every capable worker (z1/z2
  "bystanders");
* VICTIM — the paper's small recipe, whose requests arrive at t=5 s and
  must cold-stage onto 8 small workers (z1/z2) over the SAME cross-zone
  links from the same seed NIC.

Three conditions execute the identical workload:

  idle        no replication pressure (the idle-link baseline);
  unbudgeted  pressure with an unbounded LinkBudget (pre-plane
              behaviour): all 8 hot copies fetch cross-zone at once and
              saturate the seed's NIC exactly when the victim stages;
  budgeted    ``LinkBudget(cross_bytes_per_window=12 GB, window=60 s)``:
              the plane admits ~one cross-zone hot copy per window and
              DEFERS the rest (never drops them — once a zone owns a
              copy, the remaining replicas ride the in-zone links, and
              replication still completes).

Claims asserted (the ISSUE's acceptance criteria):
  * budgeted victim staging makespan within 10 % of the idle baseline;
  * unbudgeted pressure degrades it by >= 30 %;
  * deferred intents are re-admitted as the window slides: hot
    replication still reaches every bystander under the budget;
  * per zone and link class, the bytes the committed plans priced EXACTLY
    match the bytes the sim executor moved (plan/executed accounting).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.core import (ContextElement, ContextRecipe, LinkBudget,
                        PERVASIVE, Replicate, WorkerShape)
from repro.cluster import GPU_CATALOG, Application, Scheduler, SimExecutor, \
    Worker, format_zone_bytes

from .common import CFG, RECIPE, ACTIVE_PARAMS, Report

HOT_AP = 8.0e9
HOT_RECIPE = ContextRecipe("infer::hot-8b", (
    RECIPE.element("deps"),             # shared deps package (same key)
    ContextElement("code", nbytes_disk=65_536, version="hot-8b"),
    ContextElement("weights", nbytes_disk=8_000_000_000,
                   nbytes_host=16_000_000_000,
                   nbytes_device=8_000_000_000, version="hot-8b"),
), activation_s=2.0)

SEED_SHAPE = WorkerShape(cores=2, memory_gb=28, disk_gb=70, gpus=1)
VICTIM_SHAPE = WorkerShape(cores=2, memory_gb=10, disk_gb=70, gpus=1)
BYSTANDER_SHAPE = WorkerShape(cores=2, memory_gb=20, disk_gb=70, gpus=1)

N_VICTIMS = 8                    # 4 per joiner zone, small workers
N_BYSTANDERS = 8                 # 4 per joiner zone, can host HOT
REPLICAS_WANTED = 1 + N_BYSTANDERS
VICTIM_ARRIVAL_S = 5.0
PRESSURE_EVERY_S = 10.0
PRESSURE_UNTIL_S = 420.0
RUN_UNTIL_S = 500.0
CROSS_BUDGET = LinkBudget(cross_bytes_per_window=12e9, window_s=60.0)


def run_condition(cond: str):
    """cond in {"idle", "unbudgeted", "budgeted"}."""
    a10 = GPU_CATALOG["NVIDIA A10"]
    budget = CROSS_BUDGET if cond == "budgeted" else None
    sched = Scheduler(link_budget=LinkBudget(
        cross_bytes_per_window=budget.cross_bytes_per_window,
        window_s=budget.window_s) if budget else None)
    ex = SimExecutor(sched)
    app = Application(sched)
    k_hot = app.register(HOT_RECIPE, active_params=HOT_AP)
    k_vic = app.register(RECIPE, active_params=ACTIVE_PARAMS)

    # one warm seed in z0 hosting BOTH contexts: the single cross-zone
    # source, so hot replication and victim staging share its NIC
    seed = Worker(a10, zone="z0", shape=SEED_SHAPE)
    sched.add_worker(seed)
    for recipe, key in ((HOT_RECIPE, k_hot), (RECIPE, k_vic)):
        seed.library_for(recipe).materialize_cost(seed.device,
                                                  fetch_bw=float("inf"))
        sched.plane.note_ready(key, seed.worker_id)
    for i in range(N_VICTIMS):
        sched.add_worker(Worker(a10, zone=f"z{1 + i % 2}",
                                shape=VICTIM_SHAPE))
    for i in range(N_BYSTANDERS):
        sched.add_worker(Worker(a10, zone=f"z{1 + i % 2}",
                                shape=BYSTANDER_SHAPE))

    # a long-running hot stream batch keeps the seed busy (its copy warm
    # but its concurrency slot taken, so victims never route onto it)
    app.submit_stream(ex, [dict(recipe_key=k_hot, decode_steps=1_000_000,
                                arrival_s=0.0)])
    app.submit_stream(ex, [dict(recipe_key=k_vic, decode_steps=1,
                                arrival_s=VICTIM_ARRIVAL_S, exclusive=True)
                           for _ in range(N_VICTIMS)])

    if cond != "idle":
        def pressure():
            view = sched.view(now=ex.loop.now)
            plan = sched.plane.compile([Replicate(k_hot, REPLICAS_WANTED)],
                                       view)
            sched.plane.commit(plan, now=view.now)
            ex.execute_plan(plan)

        t = 0.0
        while t <= PRESSURE_UNTIL_S:
            ex.loop.at(t, pressure)
            t += PRESSURE_EVERY_S

    ex.run(until=RUN_UNTIL_S)
    vic_records = [r for r in sched.records if r.n_units == 1]
    assert len(vic_records) == N_VICTIMS, \
        f"{cond}: {len(vic_records)}/{N_VICTIMS} victim requests done"
    makespan = max(r.t_end for r in vic_records) - VICTIM_ARRIVAL_S
    return makespan, sched, k_hot


def check_byte_accounting(sched: Scheduler, cond: str) -> None:
    plane = sched.plane
    assert plane.inflight_ops == 0, \
        f"{cond}: {plane.inflight_ops} staging ops still in flight"
    planned, moved = plane.planned.as_dict(), plane.moved.as_dict()
    assert planned == moved, (
        f"{cond}: plan/executed byte accounting mismatch\n"
        f"  planned: {planned}\n  moved:   {moved}")


def main(smoke: bool = False) -> float:
    rep = Report("Context-plane link budget: victim staging under hot-"
                 "recipe replication pressure (1 seed, 8+8 joiners, "
                 "3 zones)",
                 ["condition", "victim_makespan_s", "vs_idle",
                  "hot_replicas", "deferred", "z0_out_cross_gb"])
    results: Dict[str, Tuple[float, Scheduler, str]] = {}
    for cond in ("idle", "unbudgeted", "budgeted"):
        results[cond] = run_condition(cond)
    base = results["idle"][0]
    for cond, (makespan, sched, k_hot) in results.items():
        plane = sched.plane
        rep.add(cond, f"{makespan:.1f}", f"{makespan / base:.2f}x",
                sched.registry.replication(k_hot),
                plane.deferred_intents,
                f"{plane.moved.get('z0', 'out_cross') / 1e9:.1f}")
        check_byte_accounting(sched, cond)
    rep.print()

    mk_unbudgeted = results["unbudgeted"][0]
    mk_budgeted, sched_b, k_hot = results["budgeted"][0], \
        results["budgeted"][1], results["budgeted"][2]
    assert mk_unbudgeted / base >= 1.3, (
        f"unbudgeted replication should saturate the cross-zone link: "
        f"{mk_unbudgeted / base:.2f}x")
    assert mk_budgeted / base <= 1.10, (
        f"budgeted staging makespan must stay within 10% of the idle "
        f"baseline: {mk_budgeted / base:.2f}x")
    assert sched_b.plane.deferred_intents > 0, \
        "the budget never deferred anything — pressure did not bind"
    assert sched_b.registry.replication(k_hot) >= REPLICAS_WANTED, (
        "deferred replication must complete once the window slides "
        f"(got {sched_b.registry.replication(k_hot)})")
    print(format_zone_bytes(sched_b.plane, label="budgeted"))
    print(f"\nbudgeted {mk_budgeted / base:.2f}x vs idle, "
          f"unbudgeted {mk_unbudgeted / base:.2f}x")
    print("context-plane budget claims: OK")
    return mk_budgeted / base


if __name__ == "__main__":
    import sys
    main(smoke="--smoke" in sys.argv)
