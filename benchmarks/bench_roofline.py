"""Roofline table: reads the dry-run jsonl artifacts (launch/dryrun.py).

Per (arch × shape × mesh): the three roofline terms, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS (useful-compute fraction), and HBM fit.
Regenerate inputs with:
  PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun_single.jsonl
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod --out experiments/dryrun_multipod.jsonl
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

from .common import Report

EXP_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments")
FILES = ["dryrun_single_opt.jsonl", "dryrun_multipod_opt.jsonl",
         "dryrun_single_baseline2.jsonl"]

V5E_HBM_GB = 16.0


def load(path: Optional[str] = None) -> List[Dict]:
    recs = []
    for fname in ([path] if path else FILES):
        p = fname if os.path.isabs(fname) else os.path.join(EXP_DIR, fname)
        if not os.path.exists(p):
            continue
        with open(p) as f:
            for line in f:
                recs.append(json.loads(line))
    return recs


def main():
    recs = load()
    if not recs:
        print("no dry-run artifacts found — run repro.launch.dryrun first")
        return []
    rep = Report("Roofline — per (arch × shape × mesh), terms in seconds",
                 ["arch", "shape", "mesh", "compute_s", "memory_s",
                  "collective_s", "bottleneck", "useful_flops",
                  "hbm_gb/chip", "fits"])
    n_fail = 0
    for r in recs:
        if "error" in r:
            rep.add(r["arch"], r["shape"], r.get("mesh", "?"), "-", "-",
                    "-", "ERROR", "-", "-", "-")
            n_fail += 1
            continue
        hbm_gb = (r.get("mem_argument_size_in_bytes", 0)
                  + r.get("mem_temp_size_in_bytes", 0)) / 1e9
        rep.add(r["arch"], r["shape"],
                r["mesh"] + ("/base" if r.get("variant") == "baseline"
                             else ""),
                f"{r['compute_s']:.2e}", f"{r['memory_s']:.2e}",
                f"{r['collective_s']:.2e}", r["bottleneck"],
                f"{r['useful_flops_frac']:.2f}", f"{hbm_gb:.1f}",
                "y" if hbm_gb <= V5E_HBM_GB else "OVER")
    rep.print()
    single = [r for r in recs if r.get("mesh") == "16x16" and "error" not in r
              and r.get("variant", "optimized") == "optimized"]
    print(f"\ncombos: {len(recs)} ({n_fail} errors); single-pod optimized: "
          f"{len(single)}")
    by_bn = {}
    for r in single:
        by_bn[r["bottleneck"]] = by_bn.get(r["bottleneck"], 0) + 1
    print("single-pod bottleneck distribution:", by_bn)
    # baseline vs optimized deltas on the dominant term
    base = {(r["arch"], r["shape"]): r for r in recs
            if r.get("variant") == "baseline" and "error" not in r}
    if base and single:
        print("\nbaseline -> optimized (dominant-term seconds):")
        rows = []
        for r in single:
            b = base.get((r["arch"], r["shape"]))
            if not b:
                continue
            b_dom = max(b["compute_s"], b["memory_s"], b["collective_s"])
            o_dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
            if b_dom > 0 and b_dom / max(o_dom, 1e-12) >= 1.05:
                rows.append((b_dom / o_dom, r["arch"], r["shape"], b_dom,
                             o_dom))
        for x, a, sh, bd, od in sorted(rows, reverse=True):
            print(f"  {a:22s} {sh:12s} {bd:9.3g} -> {od:9.3g}  ({x:.1f}x)")
    return recs


if __name__ == "__main__":
    main()
