"""Headline-claim validation: the paper's quantitative claims, asserted.

  C1  98.1 % execution-time reduction (pv0 → pv6): ours must be ≥ 95 %.
  C2  an inattentive solution DEGRADES execution by 245.3 % (pv3_1 vs pv0):
      ours must degrade by ≥ 150 %.
  C3  best 20-GPU speedup ≈ 13.9× (pv4_100): ours in [11, 17]×.
  C4  batch-size sensitivity collapses 4306 % → 12.3 %: ours must show
      partial ≥ 20× and pervasive ≤ 1.25× across batch 1..1000.
  C5  busy-cluster drain: pervasive completes more work than partial
      (paper: +36.7 %): ours must be ≥ +5 % with ≤ ¼ the evicted work.
"""
from __future__ import annotations

from repro.core import PARTIAL, PERVASIVE

from . import bench_fig4_scaling_efforts as fig4
from . import bench_fig6_busy_cluster as fig6
from .common import Report


def main(n_total: int = 150_000, res=None, drain=None):
    # claims are calibrated to the paper's 150k-scale experiments
    res = res or fig4.run_all(n_total)
    drain = drain or fig6.run_pair(n_total)
    pv0 = res["pv0"][0]

    reduction = 1 - res["pv6"][0] / pv0
    degradation = res["pv3_1"][0] / pv0 - 1
    speedup = pv0 / res["pv4_100"][0]
    sens_partial = max(res[f"pv3_{t}"][0] for t in ("1", "100", "1k")) / \
        min(res[f"pv3_{t}"][0] for t in ("1", "100", "1k"))
    sens_perv = max(res[f"pv4_{t}"][0] for t in ("1", "100", "1k")) / \
        min(res[f"pv4_{t}"][0] for t in ("1", "100", "1k"))
    drain_gain = drain["pv5s"].completed / max(drain["pv5p"].completed,
                                               1) - 1
    evict_ratio = drain["pv5s"].evicted_inferences / \
        max(drain["pv5p"].evicted_inferences, 1)

    rep = Report("Headline claims — sim vs paper",
                 ["claim", "paper", "sim", "pass"])
    checks = [
        ("C1 exec-time reduction", "98.1%", f"{100*reduction:.1f}%",
         reduction >= 0.95),
        ("C2 inattentive degradation", "+245.3%", f"+{100*degradation:.1f}%",
         degradation >= 1.5),
        ("C3 20-GPU speedup", "13.9x", f"{speedup:.1f}x",
         11 <= speedup <= 17),
        ("C4a partial batch sensitivity", "4306%",
         f"{100*(sens_partial-1):.0f}%", sens_partial >= 20),
        ("C4b pervasive batch sensitivity", "12.3%",
         f"{100*(sens_perv-1):.1f}%", sens_perv <= 1.25),
        ("C5a drain completed-work gain", "+36.7%",
         f"+{100*drain_gain:.1f}%", drain_gain >= 0.05),
        ("C5b drain evicted-work ratio", "2k vs 20k (0.10)",
         f"{evict_ratio:.2f}", evict_ratio <= 0.25),
    ]
    ok = True
    for name, paper, sim, passed in checks:
        rep.add(name, paper, sim, "OK" if passed else "FAIL")
        ok &= passed
    rep.print()
    if not ok:
        raise SystemExit("headline claim validation FAILED")
    print("all headline claims validated")
    return checks


if __name__ == "__main__":
    main()
