"""Fig 7: progress adapts seamlessly to opportunistic availability.

For three pv6 traces we verify the paper's qualitative claim: the
application's throughput tracks the (wildly varying) number of connected
workers — correlation between instantaneous worker count and inference
rate must be strongly positive, and progress never stalls while any
worker is connected.
"""
from __future__ import annotations

import statistics

from repro.core import PERVASIVE
from repro.cluster import opportunistic_supply, traces

from .common import Report, run_experiment


def rate_vs_workers(r, bucket_s: float = 60.0):
    """(worker_count, inference_rate) samples over time buckets."""
    end = r.makespan_s
    prog = r.sched.progress_events
    wev = sorted(r.sched.worker_events)
    samples = []
    t = bucket_s
    pi = wi = 0
    prev_done = 0
    cur_workers = 0
    while t <= end + bucket_s:
        while pi < len(prog) and prog[pi][0] <= t:
            pi += 1
        done = prog[pi - 1][1] if pi else 0
        while wi < len(wev) and wev[wi][0] <= t - bucket_s / 2:
            cur_workers = wev[wi][1]
            wi += 1
        samples.append((cur_workers, (done - prev_done) / bucket_s))
        prev_done = done
        t += bucket_s
    return samples


def main(n_total: int = 150_000):
    rep = Report("Fig 7 — resilience to opportunistic availability",
                 ["exp", "makespan_s", "avg_workers", "rate_worker_corr"])
    results = {}
    for exp, trace in [("pv6_10a", traces.diurnal(10)),
                       ("pv6_11p", traces.diurnal(23)),
                       ("pv6", traces.quiet_day())]:
        r = run_experiment(exp, mode=PERVASIVE, batch=100, n_total=n_total,
                           devices=opportunistic_supply(200), trace=trace)
        samples = rate_vs_workers(r)
        ws = [s[0] for s in samples]
        varying = (len(samples) > 2 and statistics.pstdev(ws)
                   > 0.05 * max(statistics.mean(ws), 1.0))
        if varying:
            corr = statistics.correlation(ws, [s[1] for s in samples])
        else:
            corr = float("nan")    # availability ~constant: corr undefined
        rep.add(exp, f"{r.makespan_s:.0f}", f"{r.avg_workers:.0f}",
                f"{corr:.2f}")
        results[exp] = (r, corr, samples)
    rep.print()
    for exp, (r, corr, samples) in results.items():
        if corr == corr and len(samples) >= 5:
            assert corr > 0.3, f"{exp}: throughput must track workers"
    print("fig7 qualitative checks: OK")
    return results


if __name__ == "__main__":
    main()
