"""Fig 7: progress adapts seamlessly to opportunistic availability.

For three pv6 traces we verify the paper's qualitative claim: the
application's throughput tracks the (wildly varying) number of connected
workers — correlation between instantaneous worker count and inference
rate must be strongly positive, and progress never stalls while any
worker is connected.

:func:`main_storms` extends the claim to CORRELATED loss: the same pv6
trace with a train of zone-correlated eviction storms layered on top
(via :class:`~repro.cluster.ChurnInjector`) still completes all work,
with bounded makespan degradation and exact context-plane byte
accounting after every storm.
"""
from __future__ import annotations

import statistics

from repro.core import PERVASIVE
from repro.cluster import (ChurnInjector, make_sim, opportunistic_supply,
                           storm_schedule, traces)

from .common import ACTIVE_PARAMS, RECIPE, Report, run_experiment


def rate_vs_workers(r, bucket_s: float = 60.0):
    """(worker_count, inference_rate) samples over time buckets."""
    end = r.makespan_s
    prog = r.sched.progress_events
    wev = sorted(r.sched.worker_events)
    samples = []
    t = bucket_s
    pi = wi = 0
    prev_done = 0
    cur_workers = 0
    while t <= end + bucket_s:
        while pi < len(prog) and prog[pi][0] <= t:
            pi += 1
        done = prog[pi - 1][1] if pi else 0
        while wi < len(wev) and wev[wi][0] <= t - bucket_s / 2:
            cur_workers = wev[wi][1]
            wi += 1
        samples.append((cur_workers, (done - prev_done) / bucket_s))
        prev_done = done
        t += bucket_s
    return samples


def main(n_total: int = 150_000):
    rep = Report("Fig 7 — resilience to opportunistic availability",
                 ["exp", "makespan_s", "avg_workers", "rate_worker_corr"])
    results = {}
    for exp, trace in [("pv6_10a", traces.diurnal(10)),
                       ("pv6_11p", traces.diurnal(23)),
                       ("pv6", traces.quiet_day())]:
        r = run_experiment(exp, mode=PERVASIVE, batch=100, n_total=n_total,
                           devices=opportunistic_supply(200), trace=trace)
        samples = rate_vs_workers(r)
        ws = [s[0] for s in samples]
        varying = (len(samples) > 2 and statistics.pstdev(ws)
                   > 0.05 * max(statistics.mean(ws), 1.0))
        if varying:
            corr = statistics.correlation(ws, [s[1] for s in samples])
        else:
            corr = float("nan")    # availability ~constant: corr undefined
        rep.add(exp, f"{r.makespan_s:.0f}", f"{r.avg_workers:.0f}",
                f"{corr:.2f}")
        results[exp] = (r, corr, samples)
    rep.print()
    for exp, (r, corr, samples) in results.items():
        if corr == corr and len(samples) >= 5:
            assert corr > 0.3, f"{exp}: throughput must track workers"
    print("fig7 qualitative checks: OK")
    return results


def main_storms(n_total: int = 150_000, batch: int = 10, seed: int = 2):
    """pv6 trace ± correlated eviction storms (batch 10 → 10x the
    request count of the Fig 7 runs above, all on the DES executor).
    ``seed`` fixes the storm victim sequence — same seed, same kills."""
    rep = Report("Fig 7b — pv6 availability + correlated eviction storms",
                 ["exp", "makespan_s", "killed", "goodput inf/s"])
    trace = traces.diurnal(10)
    out = {}
    storms = []                      # placed after the calm run's makespan
    for label, get_storms in [("pv6_calm", lambda: []),
                              ("pv6_storms", lambda: storms)]:
        sched, ex, fac = make_sim(devices=opportunistic_supply(200),
                                  trace=trace)
        key = sched.register_context(RECIPE)
        sched.submit_sweep(key, n_total, batch, PERVASIVE,
                           active_params=ACTIVE_PARAMS)
        inj = ChurnInjector(ex, get_storms(), seed=seed)
        inj.arm()
        ex.pump()
        ex.loop.run(stop=lambda: sched.done)
        mk = sched.makespan()
        rep.add(label, f"{mk:.0f}", inj.killed, f"{n_total / mk:.0f}")
        if label == "pv6_calm":
            # a storm train spanning the middle of the run at any scale
            storms.extend(storm_schedule(first_s=0.2 * mk,
                                         every_s=0.15 * mk, n_storms=4,
                                         n_workers=15))
        else:
            assert inj.killed > 0, "no storm ever fired"
        assert sched.completed_inferences >= n_total, \
            f"{label}: lost work ({sched.completed_inferences}/{n_total})"
        plane = sched.plane
        assert plane.inflight_ops == 0, \
            f"{label}: {plane.inflight_ops} plane op(s) leaked"
        assert plane.planned.as_dict() == plane.moved.as_dict(), \
            f"{label}: planned/moved byte meters diverge after storms"
        out[label] = mk
    rep.print()
    # 4 storms each reclaim ~a quarter of the pool (lost batch progress
    # + re-staging, factory refills at the next trace point): bounded
    # degradation, not a stall or collapse
    assert out["pv6_storms"] < 2.5 * out["pv6_calm"], \
        "storms must degrade makespan gracefully, not collapse it"
    print("fig7b storm checks: OK")
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--seed", type=int, default=2,
                    help="storm victim-selection seed (reproducible runs)")
    args = ap.parse_args()
    main()
    main_storms(seed=args.seed)
