"""Batch-size policy vs simulation (Challenge #6 closed-loop check).

``repro.core.policies.optimal_batch_size`` is the analytical makespan
model behind the paper's batch-sizing discussion; here we validate it
against the simulator: the batch the model picks must be within 15 % of
the empirically best batch's makespan, for both context modes.
"""
from __future__ import annotations

from repro.core import PARTIAL, PERVASIVE, optimal_batch_size

from .common import Report, run_experiment

CANDIDATES = (1, 100, 1000, 3000, 7500)


def main(n_total: int = 150_000):
    rep = Report("Batch policy vs sim",
                 ["mode", "policy_pick", "sim_best", "policy_pick_s",
                  "sim_best_s", "regret"])
    ok = True
    for mode in (PARTIAL, PERVASIVE):
        sims = {}
        for b in CANDIDATES:
            r = run_experiment(f"{mode.name}_{b}", mode=mode, batch=b,
                               n_total=n_total)
            sims[b] = r.makespan_s
        pick = optimal_batch_size(
            n_total, 20, infer_s=0.27, init_s=55.0, mode=mode,
            slowdown_max=0.675 / 0.27, candidates=CANDIDATES)
        best = min(sims, key=sims.get)
        regret = sims[pick] / sims[best] - 1
        rep.add(mode.name, pick, best, f"{sims[pick]:.0f}",
                f"{sims[best]:.0f}", f"{100*regret:.1f}%")
        ok &= regret <= 0.15
    rep.print()
    assert ok, "policy regret exceeded 15%"
    print("batch policy validated")


if __name__ == "__main__":
    main()
