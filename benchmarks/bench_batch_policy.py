"""Batch-size policy vs simulation (Challenge #6 closed-loop check).

``repro.core.policies.optimal_batch_size`` is the analytical makespan
model behind the paper's batch-sizing discussion; here we validate it
against the simulator: the batch the model picks must be within 15 % of
the empirically best batch's makespan, for both context modes.

``main_mixed`` stresses the policy on a TWO-recipe pool (the backfill
scheduler's target workload): each recipe gets its own policy-picked
batch, and the run must beat the same sweep under the seed FIFO router.
"""
from __future__ import annotations

from repro.core import PARTIAL, PERVASIVE, WarmPoolPolicy, optimal_batch_size

from .common import Report, run_experiment, run_mixed_experiment

CANDIDATES = (1, 100, 1000, 3000, 7500)


def main(n_total: int = 150_000):
    rep = Report("Batch policy vs sim",
                 ["mode", "policy_pick", "sim_best", "policy_pick_s",
                  "sim_best_s", "regret"])
    ok = True
    for mode in (PARTIAL, PERVASIVE):
        sims = {}
        for b in CANDIDATES:
            r = run_experiment(f"{mode.name}_{b}", mode=mode, batch=b,
                               n_total=n_total)
            sims[b] = r.makespan_s
        pick = optimal_batch_size(
            n_total, 20, infer_s=0.27, init_s=55.0, mode=mode,
            slowdown_max=0.675 / 0.27, candidates=CANDIDATES)
        best = min(sims, key=sims.get)
        regret = sims[pick] / sims[best] - 1
        rep.add(mode.name, pick, best, f"{sims[pick]:.0f}",
                f"{sims[best]:.0f}", f"{100*regret:.1f}%")
        ok &= regret <= 0.15
    rep.print()
    assert ok, "policy regret exceeded 15%"
    print("batch policy validated")


def main_mixed(n_small: int = 15_000, n_big: int = 4_000):
    """Per-recipe policy batches on a mixed pool, backfill vs seed FIFO."""
    # 10 A10s can host the big recipe, all 20 the small one
    b_small = optimal_batch_size(n_small, 20, infer_s=0.27, init_s=55.0,
                                 mode=PERVASIVE, slowdown_max=0.675 / 0.27,
                                 candidates=CANDIDATES)
    b_big = optimal_batch_size(n_big, 10, infer_s=0.27 * 8.0 / 1.71,
                               init_s=90.0, mode=PERVASIVE,
                               slowdown_max=1.0, candidates=CANDIDATES)
    sweeps = [("big", n_big, b_big), ("small", n_small, b_small)]
    res = {}
    for exp, backfill, pool in [("fifo", False, None),
                                ("backfill", True, None),
                                ("backfill+warm", True,
                                 WarmPoolPolicy(tasks_per_replica=4))]:
        res[exp] = run_mixed_experiment(exp, sweeps=sweeps,
                                        backfill=backfill, warm_pool=pool)
    rep = Report("Batch policy on a mixed two-recipe pool",
                 ["exp", "batch_small", "batch_big", "makespan_s",
                  "completed", "warm_tasks"])
    for exp, r in res.items():
        rep.add(exp, b_small, b_big, f"{r.makespan_s:.0f}", r.completed,
                sum(1 for rec in r.records if rec.warm))
    rep.print()
    assert all(r.completed == n_small + n_big for r in res.values())
    assert res["backfill"].makespan_s < res["fifo"].makespan_s
    print("mixed-recipe policy batches validated")


if __name__ == "__main__":
    main()
    main_mixed()
