"""Fig 5 + Table 2: task execution-time distributions, pv[3,4]_[1,100].

Pervasive context must give lower and more stable task times at small
batch sizes; Table 2 reports mean/std/min/max against the paper's values.
"""
from __future__ import annotations

import statistics
from typing import Dict, List

from repro.core import PARTIAL, PERVASIVE

from .common import Report, run_experiment

# paper Table 2: exp -> (mean, std, min, max)
PAPER = {
    "pv3_1": (15.10, 27.26, 5.55, 390.03),
    "pv4_1": (0.32, 0.13, 0.0008, 15.25),
    "pv3_100": (46.78, 32.88, 5.93, 195.89),
    "pv4_100": (31.91, 9.3, 0.0008, 79.05),
}


def task_time_stats(n_total: int = 150_000) -> Dict[str, List[float]]:
    out = {}
    for exp, mode, batch in [("pv3_1", PARTIAL, 1),
                             ("pv4_1", PERVASIVE, 1),
                             ("pv3_100", PARTIAL, 100),
                             ("pv4_100", PERVASIVE, 100)]:
        r = run_experiment(exp, mode=mode, batch=batch, n_total=n_total)
        out[exp] = [rec.exec_s for rec in r.records]
    return out


def main(n_total: int = 150_000):
    stats = task_time_stats(n_total)
    rep = Report("Table 2 — task exec time stats (sim | paper)",
                 ["exp", "mean", "std", "min", "max",
                  "paper_mean", "paper_std", "paper_min", "paper_max"])
    for exp, xs in stats.items():
        pm = PAPER[exp]
        rep.add(exp, f"{statistics.mean(xs):.2f}",
                f"{statistics.pstdev(xs):.2f}",
                f"{min(xs):.2f}", f"{max(xs):.2f}",
                *(f"{v}" for v in pm))
    rep.print()

    # Fig 5's qualitative claims, asserted:
    import statistics as st
    assert st.mean(stats["pv4_1"]) < st.mean(stats["pv3_1"]) / 5, \
        "pervasive must collapse batch-1 task times"
    assert st.pstdev(stats["pv4_100"]) < st.pstdev(stats["pv3_100"]), \
        "pervasive must stabilise task times"
    print("fig5 qualitative checks: OK")
    return stats


if __name__ == "__main__":
    main()
