"""Fig 4: the 21-experiment incremental-scaling sweep (pv0 → pv6).

Reproduces the paper's full evaluation narrative on the SimExecutor and
compares each experiment against the published execution time.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

from repro.core import NAIVE, PARTIAL, PERVASIVE
from repro.cluster import opportunistic_supply, traces

from .common import Report, run_experiment

# exp id -> (mode, batch, published_seconds or None)
PAPER_S: Dict[str, Optional[float]] = {
    "pv0": 40_900, "pv1": 10_400, "pv2": 5_300,
    "pv3_1": 141_100, "pv3_100": None, "pv3_1k": None, "pv3_3k": None,
    "pv3_7.5k": None,
    "pv4_1": None, "pv4_100": 2_900, "pv4_1k": None, "pv4_3k": None,
    "pv4_7.5k": None,
    "pv6_10a": None, "pv6_1p": None, "pv6_2p": 1_211, "pv6_6p": None,
    "pv6_11p": None, "pv6": 783,
}

BATCHES = {"1": 1, "100": 100, "1k": 1000, "3k": 3000, "7.5k": 7500}


def run_all(n_total: int = 150_000) -> Dict[str, Tuple[float, float, int]]:
    out: Dict[str, Tuple[float, float, int]] = {}

    r = run_experiment("pv0", mode=PERVASIVE, batch=100, n_workers=1,
                       n_total=n_total,
                       devices=[__import__("repro.cluster",
                                           fromlist=["GPU_CATALOG"])
                                .GPU_CATALOG["NVIDIA A10"]])
    out["pv0"] = (r.makespan_s, r.avg_workers, r.evicted_inferences)

    r = run_experiment("pv1", mode=NAIVE, batch=100, n_total=n_total)
    out["pv1"] = (r.makespan_s, r.avg_workers, r.evicted_inferences)

    r = run_experiment("pv2", mode=PARTIAL, batch=100, n_total=n_total)
    out["pv2"] = (r.makespan_s, r.avg_workers, r.evicted_inferences)

    for tag, b in BATCHES.items():
        r = run_experiment(f"pv3_{tag}", mode=PARTIAL, batch=b,
                           n_total=n_total)
        out[f"pv3_{tag}"] = (r.makespan_s, r.avg_workers,
                             r.evicted_inferences)
    for tag, b in BATCHES.items():
        r = run_experiment(f"pv4_{tag}", mode=PERVASIVE, batch=b,
                           n_total=n_total)
        out[f"pv4_{tag}"] = (r.makespan_s, r.avg_workers,
                             r.evicted_inferences)

    for exp, hour in [("pv6_10a", 10), ("pv6_1p", 13), ("pv6_2p", 14),
                      ("pv6_6p", 18), ("pv6_11p", 23)]:
        r = run_experiment(exp, mode=PERVASIVE, batch=100, n_total=n_total,
                           devices=opportunistic_supply(200),
                           trace=traces.diurnal(hour))
        out[exp] = (r.makespan_s, r.avg_workers, r.evicted_inferences)
    r = run_experiment("pv6", mode=PERVASIVE, batch=100, n_total=n_total,
                       devices=opportunistic_supply(200),
                       trace=traces.quiet_day())
    out["pv6"] = (r.makespan_s, r.avg_workers, r.evicted_inferences)
    return out


def main(n_total: int = 150_000, res=None) -> Dict[str, Tuple[float, float, int]]:
    res = res or run_all(n_total)
    pv0 = res["pv0"][0]
    rep = Report("Fig 4 — scaling efforts (sim vs paper)",
                 ["exp", "sim_s", "paper_s", "speedup", "avg_workers",
                  "evicted_inf"])
    for exp, (t, w, ev) in res.items():
        paper = PAPER_S.get(exp)
        rep.add(exp, f"{t:.0f}", f"{paper:.0f}" if paper else "-",
                f"{pv0 / t:.1f}x", f"{w:.1f}", ev)
    rep.print()
    return res


if __name__ == "__main__":
    main()
