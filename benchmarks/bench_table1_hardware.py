"""Table 1: the heterogeneous GPU catalog + calibration constants."""
from __future__ import annotations

from repro.cluster import GPU_CATALOG, TPU_CATALOG, pool_rate
from repro.cluster.hardware import IDLE_PROPENSITY, REF_ACTIVE_PARAMS

from .common import Report


def main():
    rep = Report("Table 1 — GPU catalog (counts are the paper's; infer_s "
                 "calibrated from §6)",
                 ["device", "year", "count", "infer_s", "mem_gb",
                  "idle_propensity"])
    for m in GPU_CATALOG.values():
        rep.add(m.name, m.year, m.count, f"{m.infer_s:.3f}", m.mem_gb,
                IDLE_PROPENSITY.get(m.name, 1.0))
    rep.print()
    total = sum(m.count for m in GPU_CATALOG.values())
    print(f"catalogued GPUs: {total} (paper: 567 total, 8 majors = 75%)")

    rep2 = Report("TPU analogue catalog (fleet mode)",
                  ["device", "year", "count", "infer_s", "compile_s"])
    for m in TPU_CATALOG.values():
        rep2.add(m.name, m.year, m.count, f"{m.infer_s:.3f}",
                 m.compile_base_s)
    rep2.print()
    return GPU_CATALOG


if __name__ == "__main__":
    main()
