"""Serving gateway: interactive p95 under batch overload, with preemption.

The gateway tentpole claim: with SLO classes and deadline-driven batch
preemption, an INTERACTIVE trickle keeps its unloaded latency while the
cluster is saturated by 10x+ BATCH overload — and the batch class loses
no work (preempted requests suspend their KV state and resume without
re-prefill, so every submitted batch decode step still completes).

Three DES runs on an identical 4xA10 pool (16 decode slots):

* ``unloaded``  — the interactive trickle alone: the latency floor.
* ``baseline``  — the same trickle + batch flood, NO gateway: pure FIFO
  (interactive requests queue behind the whole backlog).
* ``gateway``   — same workload fronted by the :class:`Gateway`:
  deadline'd interactive heads preempt settled batch slots.

Reported: per-class p95 e2e over the steady-state window, completed
batch decode units, preemption/spill/resume counters.

The LIVE section drives a real :class:`StreamingDecoder` through the
suspend/resume path (both paged and contiguous KV layouts): a victim is
suspended mid-decode, others keep stepping, the victim resumes — and its
token stream must be BIT-EXACT against an uninterrupted run.  Slot and
page accounting must balance to zero afterwards.

``--smoke`` (the CI guard): FAILS if gateway interactive p95 exceeds
1.2x the unloaded p95, if the gateway run completes less batch work than
the FIFO baseline, if no preemption actually happened, if resumed tokens
diverge, or if any slot/page/byte accounting leaks.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys

from repro.cluster import (Application, ClassPolicy, GPU_CATALOG, Gateway,
                           make_sim, percentile)

from .common import ACTIVE_PARAMS, RECIPE, Report

# -- sim scenario -----------------------------------------------------------
N_WORKERS = 4
SLOT_BYTES = 5_000_000_000        # pins 4 decode slots per 24 GB A10
BATCH_REQS = 320                  # ~10x overload vs the 16-slot pool
BATCH_STEPS = 48
BURST_T0, BURST_END, BURST_EVERY = 40.0, 300.0, 20.0
BURST_N, INT_STEPS = 4, 6
DEADLINE_S = 12.0                 # relative queue deadline (interactive)
MEASURE_FROM = 75.0               # skip the cold-start bursts (staging
                                  # runs until ~67s even unloaded)
UNTIL = 5_000.0


def _sim_pool():
    sched, ex, fac = make_sim(devices=[GPU_CATALOG["NVIDIA A10"]] * N_WORKERS,
                              workers_per_zone=N_WORKERS)
    app = Application(sched)
    # pin the decode-slot footprint so the slot budget is deterministic
    recipe = dataclasses.replace(RECIPE, slot_bytes=SLOT_BYTES)
    key = app.register(recipe, active_params=ACTIVE_PARAMS)
    return sched, ex, fac, app, key


def _interactive_specs(slo: str):
    out, t = [], BURST_T0
    while t <= BURST_END + 1e-9:
        out.extend(dict(decode_steps=INT_STEPS, arrival_s=t, slo=slo)
                   for _ in range(BURST_N))
        t += BURST_EVERY
    return out


def _batch_specs():
    return [dict(decode_steps=BATCH_STEPS, arrival_s=0.0, slo="batch")
            for _ in range(BATCH_REQS)]


def _run(name: str, *, with_batch: bool, with_gateway: bool):
    """One DES run; returns (sched, gateway, interactive ids, batch ids)."""
    sched, ex, fac, app, key = _sim_pool()
    gw = None
    if with_gateway:
        gw = Gateway(sched, interactive=ClassPolicy(
            max_queue=64, overflow="reject", deadline_s=DEADLINE_S,
            preempt_slack_s=DEADLINE_S))
    bids = set()
    if with_batch:
        bs = app.submit_stream(ex, [dict(s, recipe_key=key)
                                    for s in _batch_specs()])
        bids = {r.request_id for r in bs}
    # the FIFO baseline submits the trickle untagged — no class priority
    slo = "interactive" if with_gateway or not with_batch else "batch"
    irs = app.submit_stream(ex, [dict(s, recipe_key=key)
                                 for s in _interactive_specs(slo)])
    iids = {r.request_id for r in irs}
    fac.reconcile(N_WORKERS)
    ex.run(until=UNTIL)
    assert sched.done, f"{name}: run hit the {UNTIL:.0f}s safety net"
    return sched, gw, iids, bids


def _e2e_window(sched, ids):
    """Steady-state e2e latencies of served requests in ``ids``."""
    return [r.t_end - r.t_arrival for r in sched.records
            if r.request_id in ids and r.outcome == "done"
            and r.t_arrival >= MEASURE_FROM]


def _batch_units_done(sched, bids):
    return sum(r.n_units for r in sched.records
               if r.request_id in bids and r.outcome == "done")


def _assert_no_sim_leaks(sched, gw):
    assert not sched.running, f"requests stuck in running: {sched.running}"
    assert all(not lane for lane in sched.lanes.values()), "non-empty lane"
    for w in sched.workers.values():
        for lib in w.libraries.values():
            assert not lib.batch, \
                f"slot leak: {w.worker_id} still holds {set(lib.batch)}"
    if gw is not None:
        assert not gw.pending_overflow, "requests parked in overflow"
    kv = sched.plane.kv_summary()
    assert kv["spill_events"] == sched.preemptions, \
        f"spill meter {kv['spill_events']} != preemptions {sched.preemptions}"
    assert kv["resume_events"] == kv["spill_events"], \
        f"{kv['spill_events']} spills but {kv['resume_events']} resumes: " \
        "a victim never returned"


def sim_section(smoke: bool):
    runs = {
        "unloaded": _run("unloaded", with_batch=False, with_gateway=False),
        "baseline": _run("baseline", with_batch=True, with_gateway=False),
        "gateway": _run("gateway", with_batch=True, with_gateway=True),
    }
    rep = Report(
        f"serving gateway: interactive p95 under {BATCH_REQS}-request "
        f"batch overload ({N_WORKERS}xA10, {BURST_N}-request bursts)",
        ["run", "int p95 s", "int done", "int t/o", "batch units",
         "preempt", "makespan s"])
    p95 = {}
    for name, (sched, gw, iids, bids) in runs.items():
        xs = _e2e_window(sched, iids)
        p95[name] = percentile(xs, 95)
        irec = [r for r in sched.records if r.request_id in iids]
        n_to = sum(r.outcome == "timed_out" for r in irec)
        n_done = sum(r.outcome == "done" for r in irec)
        rep.add(name, f"{p95[name]:.2f}", n_done, n_to,
                _batch_units_done(sched, bids), sched.preemptions,
                f"{sched.makespan():.0f}")
    rep.print()

    sched_gw, gw, iids_gw, bids_gw = runs["gateway"]
    sched_fifo, _, _, bids_fifo = runs["baseline"]
    ratio = p95["gateway"] / p95["unloaded"]
    print(f"interactive p95: unloaded {p95['unloaded']:.2f}s, "
          f"FIFO {p95['baseline']:.2f}s, gateway {p95['gateway']:.2f}s "
          f"({ratio:.2f}x unloaded) — {sched_gw.preemptions} preemption(s)")
    _assert_no_sim_leaks(sched_gw, gw)
    for name, (sched, g, _, _) in runs.items():
        if name != "gateway":
            _assert_no_sim_leaks(sched, g)
    if smoke:
        assert sched_gw.preemptions > 0, \
            "overload never triggered a preemption — the deadline path " \
            "is dead code in this scenario"
        assert ratio <= 1.2, \
            f"gateway interactive p95 is {ratio:.2f}x unloaded (> 1.2x): " \
            "the SLO class did not hold under overload"
        assert p95["baseline"] > 3 * p95["unloaded"], \
            "FIFO baseline was not actually overloaded — the comparison " \
            "is vacuous"
        done_gw = _batch_units_done(sched_gw, bids_gw)
        done_fifo = _batch_units_done(sched_fifo, bids_fifo)
        assert done_gw == done_fifo, \
            f"gateway completed {done_gw} batch units vs FIFO " \
            f"{done_fifo}: preemption lost work"
        rec_gw = [r for r in sched_gw.records if r.request_id in iids_gw]
        assert all(r.outcome in ("done", "timed_out") for r in rec_gw)
        print("smoke OK: interactive p95 held <= 1.2x unloaded at equal "
              "batch work, zero slot leaks")


# -- live suspend/resume ----------------------------------------------------
def _token_exactness(cfg, params, *, paged: bool):
    """Suspend a victim mid-decode, step the others, resume: the victim's
    tokens must be bit-exact vs an uninterrupted run.  Returns the
    (suspended, resumed) byte counters for the caller to check."""
    import numpy as np
    from repro.inference import StreamingDecoder

    rng = np.random.default_rng(7)
    prompts = {r: list(rng.integers(4, cfg.vocab_size, 12 + 3 * r))
               for r in range(3)}
    kw = dict(max_len=64, paged=paged)
    if paged:
        kw["page_size"] = 8

    def fresh():
        dec = StreamingDecoder(cfg, params, None, None, **kw)
        for r, p in prompts.items():
            dec.ensure_tokens(r, list(p))
        return dec

    def collect(dec, rids, steps, outs):
        for _ in range(steps):
            for r, t in dec.step(rids).items():
                outs.setdefault(r, []).append(t)

    victim = 0
    dec, outs = fresh(), {}
    collect(dec, [0, 1, 2], 4, outs)
    nb = dec.suspend(victim)
    assert nb > 0, "suspend moved zero bytes"
    assert victim not in dec.pool.slot_of, "victim kept its slot"
    collect(dec, [1, 2], 3, outs)            # others decode while spilled
    dec.resume(victim)
    collect(dec, [0, 1, 2], 6, outs)
    for r in range(3):
        dec.finish(r)

    ref, routs = fresh(), {}
    collect(ref, [0, 1, 2], 10, routs)
    for r in range(3):
        ref.finish(r)

    layout = "paged" if paged else "contiguous"
    assert outs[victim] == routs[victim], \
        f"{layout}: resumed token stream diverged from the " \
        f"uninterrupted reference ({outs[victim]} vs {routs[victim]})"
    assert not dec._suspended, "suspended snapshot leaked"
    assert dec.pool.free == dec.pool.capacity, \
        f"{layout}: slot leak ({dec.pool.free}/{dec.pool.capacity} free)"
    if paged:
        assert dec.pages.in_use == 0, \
            f"{layout}: {dec.pages.in_use} page(s) leaked"
    assert dec.kv_suspend_bytes_total == dec.kv_resume_bytes_total > 0
    return layout, dec.kv_suspend_bytes_total


def _retention_check():
    """PagePool prefix retention: park at refcount zero, revive on hit,
    reclaim LRU-first only under allocation pressure."""
    from repro.inference.streaming import PagePool
    pool = PagePool(4, retained_cap=2)       # pages 1..3 (0 is TRASH)
    evicted = []
    pool.on_evict_retained = evicted.append
    p0, p1 = pool.alloc(), pool.alloc()
    assert pool.decref(p0) is False and pool.retained_count == 1
    pool.incref(p0)                          # prefix hit revives the park
    assert pool.retained_count == 0 and pool.refcount(p0) == 1
    assert pool.decref(p0) is False and pool.decref(p1) is False
    assert pool.retained_count == 2 and pool.in_use == 0
    pool.alloc()                             # last truly-free page
    got = pool.alloc()                       # pressure: LRU park reclaimed
    assert got == p0 and evicted == [p0], \
        f"expected LRU-first reclaim of {p0}, got {got} (evicted {evicted})"
    assert pool.retained_count == 1
    print("retention OK: park at zero, revive on hit, LRU reclaim under "
          "pressure only")


def live_section(smoke: bool):
    import jax
    from repro.configs import get_smoke_config
    from repro.models import model as M

    print("\n== live suspend/resume: token exactness + accounting ==")
    _retention_check()
    cfg = get_smoke_config("smollm2-1.7b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    for paged in (False, True):
        layout, nbytes = _token_exactness(cfg, params, paged=paged)
        print(f"{layout}: victim resumed bit-exact after mid-decode "
              f"suspension ({nbytes} KV bytes spilled+restored, zero "
              "slot/page leaks)")
    if smoke:
        print("smoke OK: suspend/resume token-exact on both KV layouts")


def main(smoke: bool = False) -> int:
    sim_section(smoke)
    live_section(smoke)
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI guard: fail on p95 regression, lost batch "
                         "work, token divergence, or accounting leaks")
    args = ap.parse_args()
    sys.exit(main(smoke=args.smoke))
