"""Fig 6 (pv5): pervasive vs partial context in a busy, draining cluster.

15 minutes stable at 20 workers, then 1 GPU reclaimed per minute (A10s
first).  Pervasive context (batch 100) must complete more inferences than
partial (batch 1000) and lose far fewer to eviction.

``main_mixed`` is the beyond-paper scenario: TWO recipes on one pool where
the big recipe only fits the A10s.  The seed head-of-line FIFO stalls the
TITANs whenever a big task heads the queue; context-aware backfill + tier
spill keeps them fed and must reduce makespan.
"""
from __future__ import annotations

from repro.core import PARTIAL, PERVASIVE
from repro.cluster import traces

from .common import Report, run_experiment, run_mixed_experiment

def a10_first(w) -> tuple:
    return (w.device.name == "NVIDIA A10", w.joined_s)


def run_pair(n_total: int = 150_000):
    # quick mode scales the drain timeline with the workload so the
    # reclamation still interrupts the run (paper: 15 min + 1 GPU/min)
    scale = n_total / 150_000
    stable_s = 900 * scale
    rate = 1 / (60 * scale)
    until = stable_s + 20 / rate + 60
    res = {}
    for exp, mode, batch in [("pv5p", PARTIAL, 1000),
                             ("pv5s", PERVASIVE, 100)]:
        res[exp] = run_experiment(
            exp, mode=mode, batch=batch, n_total=n_total,
            trace=traces.drain(20, stable_s=stable_s, rate_per_s=rate),
            evict_priority=a10_first, until=until)
    return res


def main(n_total: int = 150_000, res=None):
    res = res or run_pair(n_total)
    rep = Report("Fig 6 — busy-cluster drain (pv5)",
                 ["exp", "completed", "evicted_inf", "tasks_evicted"])
    for exp, r in res.items():
        rep.add(exp, r.completed, r.evicted_inferences,
                r.sched.evicted_tasks)
    rep.print()
    gain = res["pv5s"].completed / max(res["pv5p"].completed, 1) - 1
    print(f"pervasive completed {100*gain:.1f}% more work (paper: +36.7%)")
    # timeline for the figure
    print("\n-- pv5s progress timeline (t, completed) --")
    ev = res["pv5s"].sched.progress_events
    for t, n in ev[:: max(1, len(ev) // 12)]:
        print(f"  {t:7.0f}s  {n:7d}")
    assert res["pv5s"].completed > res["pv5p"].completed
    assert res["pv5s"].evicted_inferences < res["pv5p"].evicted_inferences
    return res


def main_mixed(n_small: int = 15_000, n_big: int = 4_000):
    """Mixed two-recipe pool: backfill + spill vs the seed FIFO."""
    res = {}
    for exp, backfill in [("fifo", False), ("backfill", True)]:
        res[exp] = run_mixed_experiment(
            exp, sweeps=[("big", n_big, 100), ("small", n_small, 100)],
            backfill=backfill)
    rep = Report("Fig 6b — mixed two-recipe pool (backfill + spill vs FIFO)",
                 ["exp", "makespan_s", "completed", "backfills", "spills"])
    for exp, r in res.items():
        rep.add(exp, f"{r.makespan_s:.0f}", r.completed,
                r.sched.backfills, r.sched.spilled_libraries)
    rep.print()
    gain = res["fifo"].makespan_s / max(res["backfill"].makespan_s, 1e-9) - 1
    print(f"backfill reduced makespan by {100 * gain / (1 + gain):.1f}% "
          f"(speedup {1 + gain:.2f}x)")
    assert res["backfill"].completed == res["fifo"].completed
    assert res["backfill"].makespan_s < res["fifo"].makespan_s, \
        "backfill + spill must beat the seed FIFO on the mixed scenario"
    assert res["backfill"].sched.backfills > 0
    return res


if __name__ == "__main__":
    main()
    main_mixed()
