"""Prefill/decode disaggregation: throughput per pooled FLOP + KV_SHIP.

The disaggregation tentpole claim: on a HETEROGENEOUS pool the two
inference phases rank devices differently — prefill is FLOP-bound
(~150x spread across the catalog), decode is HBM-bound (~10x spread) —
so phase-aware routing (prefill to compute-rich workers, decode to
memory-side slot pools, KV handoff over the context plane's KV_SHIP op
class) completes the same work in less wall-clock than colocated
routing on the SAME pool, i.e. strictly more throughput per pooled
TFLOP (arXiv 2504.15303).

Two DES runs on an identical mixed pool (2x RTX 6000 Ada + 6x A10, two
zones, so ships cross both peer link classes):

* ``colocated``      — phase-blind routing: each request prefills and
  decodes wherever the request lands.
* ``disaggregated``  — ``Scheduler(disaggregate=True)``: requests
  phase-split at submit; decode placement scores every candidate by
  estimated decode seconds PLUS the KV handoff over the peer link, so
  the same-worker fast path wins whenever shipping would lose.

Reported: makespan, completed units, units/s/pooled-TFLOP, ships vs
local fast-path decodes, shipped KV bytes by landing zone, and the
per-phase latency breakdown (prefill / ship / decode percentiles).

The LIVE section drives real :class:`StreamingDecoder` instances on a
two-worker rig built to force ships (compute-rich/slow-HBM prefill
device, fast-HBM decode device): after prefill the KV snapshot is
exported bit-exact (`export_suspended`), parked in the destination
worker's inbox, adopted into its slot pool, and decode resumes WITHOUT
re-prefill — the full token stream must be BIT-EXACT vs a colocated run
of the same claims, on both KV layouts (contiguous and paged).

``--smoke`` (the CI guard): FAILS if disaggregated throughput falls
below colocated at equal completed work, if no KV handoff actually
happened, if shipped tokens diverge from colocated on either layout, or
if any plan/moved/inflight KV byte accounting leaks.
"""
from __future__ import annotations

import argparse
import sys

from repro.cluster import (Application, GPU_CATALOG, LiveExecutor,
                           Scheduler, Worker, latency_summary, format_latency,
                           format_zone_bytes, make_sim, pool_rate)
from repro.cluster.hardware import DeviceModel

from .common import ACTIVE_PARAMS, RECIPE, Report

# -- sim scenario -----------------------------------------------------------
# two zones of 4: z0 = both Adas + 2 A10s, z1 = 4 A10s — ships exercise
# the local AND cross peer link classes
POOL = [GPU_CATALOG["NVIDIA RTX 6000 Ada Generation"]] * 2 \
    + [GPU_CATALOG["NVIDIA A10"]] * 6
WORKERS_PER_ZONE = 4
N_REQS = 120
PROMPT_UNITS = 4
DECODE_STEPS = 32
ARRIVAL_EVERY = 0.25
UNTIL = 10_000.0


def _run_sim(name: str, *, disaggregate: bool):
    sched, ex, fac = make_sim(devices=POOL,
                              workers_per_zone=WORKERS_PER_ZONE,
                              disaggregate=disaggregate)
    app = Application(sched)
    key = app.register(RECIPE, active_params=ACTIVE_PARAMS)
    specs = [dict(recipe_key=key, prompt_units=PROMPT_UNITS,
                  decode_steps=DECODE_STEPS, arrival_s=i * ARRIVAL_EVERY)
             for i in range(N_REQS)]
    app.submit_stream(ex, specs)
    fac.reconcile(len(POOL))
    ex.run(until=UNTIL)
    assert sched.done, f"{name}: run hit the {UNTIL:.0f}s safety net"
    return sched


def _units_done(sched) -> int:
    return sum(r.n_units for r in sched.records if r.outcome == "done")


def _assert_no_kv_leaks(sched):
    """Drained run: every planned byte moved, every ship either landed
    or was refunded, nothing in flight."""
    assert sched.plane.planned.as_dict() == sched.plane.moved.as_dict(), \
        "planned != moved: a KV_SHIP (or staging op) leaked bytes"
    assert sched.plane.inflight_ops == 0, \
        f"{sched.plane.inflight_ops} op(s) still in flight after drain"
    assert not sched.running, f"requests stuck running: {sched.running}"
    assert all(not lane for lane in sched.lanes.values()), "non-empty lane"
    kv = sched.plane.kv_summary()
    by_zone = sum(getattr(sched.plane, "kv_shipped", {}).values())
    assert by_zone == kv["shipped_bytes"], \
        f"per-zone kv_shipped {by_zone} != shipped_bytes " \
        f"{kv['shipped_bytes']}"


def sim_section(smoke: bool):
    runs = {name: _run_sim(name, disaggregate=d)
            for name, d in (("colocated", False), ("disaggregated", True))}
    pooled_tflops = sum(d.tflops for d in POOL)
    rep = Report(
        f"prefill/decode disaggregation: {N_REQS} requests "
        f"({PROMPT_UNITS}u prefill + {DECODE_STEPS}u decode) on "
        f"2x RTX 6000 Ada + 6x A10 ({pooled_tflops:.0f} pooled TFLOPs)",
        ["run", "makespan s", "units", "units/s/TFLOP", "ships",
         "local fast-path", "shipped GB"])
    tput = {}
    for name, sched in runs.items():
        units = _units_done(sched)
        mk = sched.makespan()
        tput[name] = units / mk / pooled_tflops
        kv = sched.plane.kv_summary()
        rep.add(name, f"{mk:.1f}", units, f"{tput[name]:.4f}",
                sched.kv_ships, sched.local_decodes,
                f"{kv['shipped_bytes'] / 1e9:.2f}")
    rep.print()

    dis, col = runs["disaggregated"], runs["colocated"]
    gain = tput["disaggregated"] / tput["colocated"]
    # the decode-capacity view the router balances against: every device
    # counts toward decode (prefill workers backfill decode slots)
    print(f"pool rate: prefill {pool_rate(POOL, ACTIVE_PARAMS, phase='prefill'):.1f} u/s, "
          f"decode {pool_rate(POOL, ACTIVE_PARAMS, phase='decode'):.1f} u/s")
    print(f"throughput/pooled-TFLOP: {gain:.2f}x colocated "
          f"({dis.kv_ships} ship(s), {dis.local_decodes} same-worker "
          f"fast path(s), {dis.prefills_done} prefill(s))")
    print(format_zone_bytes(dis.plane, label="disaggregated"))
    print(format_latency(latency_summary(dis.records),
                         label="disaggregated"))
    for sched in runs.values():
        _assert_no_kv_leaks(sched)
    if smoke:
        assert _units_done(dis) == _units_done(col) > 0, \
            "runs completed unequal work — the comparison is vacuous"
        assert dis.kv_ships > 0, \
            "no KV handoff happened — KV_SHIP is dead code here"
        assert dis.local_decodes > 0, \
            "no same-worker fast path taken — the ship-vs-local rule " \
            "never chose local"
        assert dis.prefills_done == N_REQS, \
            f"{dis.prefills_done} prefills for {N_REQS} requests"
        assert gain >= 1.0, \
            f"disaggregated throughput is {gain:.2f}x colocated (< 1x): " \
            "phase-aware routing lost on its home turf"
        summ = latency_summary(dis.records)
        assert summ.get("n_phased", 0) == N_REQS, "phase latency missing"
        assert summ.get("n_shipped", 0) == dis.kv_ships
        print("smoke OK: disaggregation >= colocated throughput at equal "
              "work, ships metered, zero KV byte leaks")


# -- live shipped-KV token exactness ----------------------------------------
# a rig built to make shipping WIN: the prefill device is compute-rich
# but decodes slowly (weak HBM); the decode device is the reverse — so
# after each prefill the router's score favours paying the handoff
PREFILL_RIG = DeviceModel("prefill-rig", 2024, 1, 1.0, 24, 500e6, 8e9,
                          tflops=500.0)
DECODE_RIG = DeviceModel("decode-rig", 2024, 1, 0.08, 80, 500e6, 8e9,
                         tflops=5.0)
LIVE_CLAIMS = 6
LIVE_PROMPT_UNITS = 3
LIVE_DECODE_STEPS = 8


def _run_live(claims, recipe, *, disaggregate: bool, paged: bool):
    from repro.inference import make_pff_step_fn

    sched = Scheduler(disaggregate=disaggregate)
    app = Application(sched)
    key = app.register(recipe)
    sched.add_worker(Worker(PREFILL_RIG))
    sched.add_worker(Worker(DECODE_RIG))
    for c in claims:
        app.submit(key, prompt_units=LIVE_PROMPT_UNITS,
                   decode_steps=LIVE_DECODE_STEPS, payload=c)
    ex = LiveExecutor(sched, step_fns={key: make_pff_step_fn(paged=paged)})
    ex.run()
    # submission order, not request_id: ids are process-global
    toks = [ex.results[r.request_id] for r in app.requests]
    return toks, sched


def live_section(smoke: bool):
    from repro.configs import get_smoke_config
    from repro.data import generate_claims
    from repro.inference import build_context_recipe

    print("\n== live shipped-KV decode: token exactness + accounting ==")
    cfg = get_smoke_config("smollm2-1.7b")
    claims = generate_claims(LIVE_CLAIMS, seed=2)
    recipe = build_context_recipe(cfg, "with_evidence")
    for paged in (False, True):
        layout = "paged" if paged else "contiguous"
        base, _ = _run_live(claims, recipe, disaggregate=False, paged=paged)
        dis, sched = _run_live(claims, recipe, disaggregate=True,
                               paged=paged)
        kv = sched.plane.kv_summary()
        assert base == dis, \
            f"{layout}: shipped-KV decode diverged from colocated"
        assert sched.kv_ships > 0, \
            f"{layout}: the rig never shipped — scoring regression"
        assert sched.prefills_done == LIVE_CLAIMS
        _assert_no_kv_leaks(sched)
        print(f"{layout}: {LIVE_CLAIMS} requests bit-exact vs colocated "
              f"({sched.kv_ships} ship(s), {kv['shipped_bytes']} KV bytes "
              f"handed off, {sched.local_decodes} local)")
    if smoke:
        print("smoke OK: shipped-KV decode token-exact on both KV layouts")


def main(smoke: bool = False) -> int:
    sim_section(smoke)
    live_section(smoke)
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI guard: fail if disaggregation loses to "
                         "colocated, ships never happen, shipped tokens "
                         "diverge, or KV byte accounting leaks")
    args = ap.parse_args()
    sys.exit(main(smoke=args.smoke))
