"""Per-step LIVE decode latency vs prefix length: slot-cached vs full-forward.

The slot-pool :class:`~repro.inference.StreamingDecoder` decodes a dynamic
batch at O(1) FLOPs per token — the compiled step works on a FIXED
(B_max, T) cache regardless of how long each row's prefix is — while the
pre-slot full-forward path re-runs prompt+generated through ``M.forward``
every step, O(S) per token.  This benchmark admits a small batch at several
prompt lengths S into ONE pool (same T for every S: apples-to-apples),
applies membership churn (finish + admit mid-run), and reports the median
quiet-step latency plus the one-off admission (prefill) cost.

Expected: slot-cached step time FLAT in S (admission cost grows — prefill
is inherently O(S), paid once); full-forward step time grows with S.

``--smoke`` (the CI guard): FAILS if the cached per-step time grows with S
beyond a noise factor.
"""
from __future__ import annotations

import argparse
import statistics
import sys
import time

import jax
import numpy as np

ROWS = 4                 # admitted batch per prompt length
STEPS = 10               # timed quiet steps per prompt length
DECODE_BUDGET = 24       # ring headroom past the longest prompt


def _decoder(cfg, params, *, slot_cached, max_len):
    from repro.inference import StreamingDecoder
    return StreamingDecoder(cfg, params, None, None, slot_cached=slot_cached,
                            max_len=max_len)


def _measure(cfg, params, S, *, slot_cached, max_len, rows=ROWS,
             steps=STEPS, seed=0):
    """Admit ``rows`` prompts of length ``S``, churn one row mid-run, and
    time the quiet (no-admission) steps.  Returns (step_ms, admit_ms)."""
    rng = np.random.default_rng(seed)
    dec = _decoder(cfg, params, slot_cached=slot_cached, max_len=max_len)
    mk = lambda: list(rng.integers(4, cfg.vocab_size, S))
    rids = list(range(rows))
    for r in rids:
        dec.ensure_tokens(r, mk())
    t0 = time.perf_counter()
    dec.step(rids)                               # admission prefill + compile
    admit_s = time.perf_counter() - t0
    dec.step(rids)                               # first cached step: compile
    quiet = []
    for i in range(steps):
        if i == steps // 2:                      # membership churn mid-run
            dec.finish(rids.pop(0))
            nxt = rows + i
            dec.ensure_tokens(nxt, mk())
            rids.append(nxt)
            dec.step(rids)                       # admission step (untimed)
            continue
        t0 = time.perf_counter()
        dec.step(rids)
        quiet.append(time.perf_counter() - t0)
    for r in rids:
        dec.finish(r)
    return statistics.median(quiet) * 1e3, admit_s * 1e3


def main(smoke: bool = False, lengths=None, steps: int = STEPS) -> int:
    from repro.configs import get_smoke_config
    from repro.models import model as M

    lengths = lengths or ([32, 160] if smoke else [32, 64, 128, 256])
    max_len = max(lengths) + DECODE_BUDGET
    cfg = get_smoke_config("smollm2-1.7b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    print("== live decode: per-step latency vs prefix length "
          f"(B={ROWS}, pool T={max_len}, churn mid-run) ==")
    print(f"{'S':>6} {'slot step ms':>14} {'full step ms':>14} "
          f"{'slot admit ms':>14}")
    slot_ms = {}
    full_ms = {}
    for S in lengths:
        s_ms, a_ms = _measure(cfg, params, S, slot_cached=True,
                              max_len=max_len, steps=steps)
        f_ms, _ = _measure(cfg, params, S, slot_cached=False,
                           max_len=max_len, steps=steps)
        slot_ms[S], full_ms[S] = s_ms, f_ms
        print(f"{S:>6} {s_ms:>14.2f} {f_ms:>14.2f} {a_ms:>14.2f}")

    lo, hi = min(lengths), max(lengths)
    grow_slot = slot_ms[hi] / slot_ms[lo]
    grow_full = full_ms[hi] / full_ms[lo]
    print(f"step-time growth {lo}→{hi}: slot-cached {grow_slot:.2f}x, "
          f"full-forward {grow_full:.2f}x")
    if smoke:
        # the tentpole claim: cached step time is FLAT in prefix length
        # (2.5x allows CI timer noise; a genuinely O(S) step would grow
        # ~hi/lo = 5x here)
        assert grow_slot < 2.5, \
            f"slot-cached step time grew {grow_slot:.2f}x from S={lo} " \
            f"to S={hi} — the cached decode path is not O(1) in S"
        print("smoke OK: slot-cached per-step time flat in prefix length")
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI guard: fail if cached step time grows with S")
    args = ap.parse_args()
    sys.exit(main(smoke=args.smoke))
