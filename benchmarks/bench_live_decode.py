"""Per-step LIVE decode latency vs prefix length: slot-cached vs full-forward.

The slot-pool :class:`~repro.inference.StreamingDecoder` decodes a dynamic
batch at O(1) FLOPs per token — the compiled step works on a FIXED
(B_max, T) cache regardless of how long each row's prefix is — while the
pre-slot full-forward path re-runs prompt+generated through ``M.forward``
every step, O(S) per token.  This benchmark admits a small batch at several
prompt lengths S into ONE pool (same T for every S: apples-to-apples),
applies membership churn (finish + admit mid-run), and reports the median
quiet-step latency plus the one-off admission (prefill) cost.

Expected: slot-cached step time FLAT in S (admission cost grows — prefill
is inherently O(S), paid once); full-forward step time grows with S.

The SHARED-PREFIX sweep exercises the paged KV layout: one producer
request makes a prompt prefix resident, then a batch of consumers whose
prompts share that prefix is admitted.  With refcounted prefix reuse the
consumers' admission cost (tokens actually prefilled) and fresh KV bytes
(pages newly allocated) are FLAT in the shared-prefix length — only the
per-consumer tails are paid — while the paged decoder stays token-exact
against the full-forward reference under membership churn.

``--smoke`` (the CI guard): FAILS if the cached per-step time grows with S
beyond a noise factor, if consumer admission cost or fresh KV bytes grow
with the shared-prefix length, or if paged tokens diverge from the
full-forward reference.
"""
from __future__ import annotations

import argparse
import statistics
import sys
import time

import jax
import numpy as np

ROWS = 4                 # admitted batch per prompt length
STEPS = 10               # timed quiet steps per prompt length
DECODE_BUDGET = 24       # ring headroom past the longest prompt


def _decoder(cfg, params, *, slot_cached, max_len):
    from repro.inference import StreamingDecoder
    return StreamingDecoder(cfg, params, None, None, slot_cached=slot_cached,
                            max_len=max_len)


def _measure(cfg, params, S, *, slot_cached, max_len, rows=ROWS,
             steps=STEPS, seed=0):
    """Admit ``rows`` prompts of length ``S``, churn one row mid-run, and
    time the quiet (no-admission) steps.  Returns (step_ms, admit_ms)."""
    rng = np.random.default_rng(seed)
    dec = _decoder(cfg, params, slot_cached=slot_cached, max_len=max_len)
    mk = lambda: list(rng.integers(4, cfg.vocab_size, S))
    rids = list(range(rows))
    for r in rids:
        dec.ensure_tokens(r, mk())
    t0 = time.perf_counter()
    dec.step(rids)                               # admission prefill + compile
    admit_s = time.perf_counter() - t0
    dec.step(rids)                               # first cached step: compile
    quiet = []
    for i in range(steps):
        if i == steps // 2:                      # membership churn mid-run
            dec.finish(rids.pop(0))
            nxt = rows + i
            dec.ensure_tokens(nxt, mk())
            rids.append(nxt)
            dec.step(rids)                       # admission step (untimed)
            continue
        t0 = time.perf_counter()
        dec.step(rids)
        quiet.append(time.perf_counter() - t0)
    for r in rids:
        dec.finish(r)
    return statistics.median(quiet) * 1e3, admit_s * 1e3


def _measure_shared(cfg, params, shared_len, *, page_size=16, rows=ROWS,
                    steps=6, seed=0):
    """One producer makes a ``shared_len`` prefix resident; ``rows``
    consumers sharing it are then admitted and churned.  Returns
    (consumer prefill tokens, consumer fresh pages, admit ms, exact) —
    the first two must be FLAT in ``shared_len`` under prefix reuse."""
    from repro.inference import StreamingDecoder
    rng = np.random.default_rng(seed)
    max_len = shared_len + 8 + DECODE_BUDGET
    shared = list(rng.integers(4, cfg.vocab_size, shared_len))
    # same tail lengths at every shared_len → cost comparable across sweep
    prompts = {r: shared + list(rng.integers(4, cfg.vocab_size, 4 + r))
               for r in range(rows + 1)}
    dec = StreamingDecoder(cfg, params, None, None, max_len=max_len,
                           paged=True, page_size=page_size)
    ref = StreamingDecoder(cfg, params, None, None, slot_cached=False,
                           max_len=max_len)

    def run(d):
        outs = {}
        def step(rids):
            for r in rids:
                if r not in d._tokens:
                    d.ensure_tokens(r, prompts[r])
            for r, t in d.step(rids).items():
                outs.setdefault(r, []).append(t)
        step([0])                                 # producer: prefix resident
        marks = (d.prefill_tokens_total,
                 d.pages.in_use if d.paged else 0,
                 time.perf_counter())
        step(list(range(rows + 1)))               # consumers join (shared)
        cost = (d.prefill_tokens_total - marks[0],
                (d.pages.in_use if d.paged else 0) - marks[1],
                (time.perf_counter() - marks[2]) * 1e3)
        live = list(range(rows + 1))
        for i in range(steps):                    # churn: finish mid-run
            if i == steps // 2:
                d.finish(live.pop(0))
            step(live)
        for r in live:
            d.finish(r)
        return outs, cost

    out_paged, cost = run(dec)
    out_full, _ = run(ref)
    return cost[0], cost[1], cost[2], out_paged == out_full


def shared_prefix_sweep(cfg, params, shared_lens, *, smoke: bool) -> None:
    """The paged-KV tentpole claim: consumer admission cost and fresh KV
    bytes are flat in the shared-prefix length, at exact tokens."""
    print(f"\n== paged KV: shared-prefix admission cost (B={ROWS} consumers "
          "joining a resident prefix, churn mid-run) ==")
    print(f"{'shared S':>9} {'prefill toks':>13} {'fresh pages':>12} "
          f"{'admit ms':>10} {'exact':>6}")
    toks, pages = {}, {}
    for S in shared_lens:
        t, p, ms, exact = _measure_shared(cfg, params, S)
        toks[S], pages[S] = t, p
        print(f"{S:>9} {t:>13} {p:>12} {ms:>10.2f} {str(exact):>6}")
        if smoke:
            assert exact, \
                f"paged decode diverged from full-forward at shared S={S}"
    lo, hi = min(shared_lens), max(shared_lens)
    print(f"admission cost {lo}→{hi}: prefill tokens "
          f"{toks[lo]}→{toks[hi]}, fresh pages {pages[lo]}→{pages[hi]}")
    if smoke:
        # deterministic counters, no timer noise: tails are identical
        # across the sweep, so any growth means the prefix was re-paid
        assert toks[hi] <= toks[lo], \
            f"consumer admission cost grew with shared-prefix length " \
            f"({toks[lo]} → {toks[hi]} prefill tokens): prefix not reused"
        assert pages[hi] <= pages[lo], \
            f"consumer KV bytes grew with shared-prefix length " \
            f"({pages[lo]} → {pages[hi]} fresh pages): prefix not reused"
        print("smoke OK: shared-prefix admission cost and KV bytes flat")


def main(smoke: bool = False, lengths=None, steps: int = STEPS) -> int:
    from repro.configs import get_smoke_config
    from repro.models import model as M

    lengths = lengths or ([32, 160] if smoke else [32, 64, 128, 256])
    max_len = max(lengths) + DECODE_BUDGET
    cfg = get_smoke_config("smollm2-1.7b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    print("== live decode: per-step latency vs prefix length "
          f"(B={ROWS}, pool T={max_len}, churn mid-run) ==")
    print(f"{'S':>6} {'slot step ms':>14} {'full step ms':>14} "
          f"{'slot admit ms':>14}")
    slot_ms = {}
    full_ms = {}
    for S in lengths:
        s_ms, a_ms = _measure(cfg, params, S, slot_cached=True,
                              max_len=max_len, steps=steps)
        f_ms, _ = _measure(cfg, params, S, slot_cached=False,
                           max_len=max_len, steps=steps)
        slot_ms[S], full_ms[S] = s_ms, f_ms
        print(f"{S:>6} {s_ms:>14.2f} {f_ms:>14.2f} {a_ms:>14.2f}")

    lo, hi = min(lengths), max(lengths)
    grow_slot = slot_ms[hi] / slot_ms[lo]
    grow_full = full_ms[hi] / full_ms[lo]
    print(f"step-time growth {lo}→{hi}: slot-cached {grow_slot:.2f}x, "
          f"full-forward {grow_full:.2f}x")
    if smoke:
        # the tentpole claim: cached step time is FLAT in prefix length
        # (2.5x allows CI timer noise; a genuinely O(S) step would grow
        # ~hi/lo = 5x here)
        assert grow_slot < 2.5, \
            f"slot-cached step time grew {grow_slot:.2f}x from S={lo} " \
            f"to S={hi} — the cached decode path is not O(1) in S"
        print("smoke OK: slot-cached per-step time flat in prefix length")

    shared_lens = [16, 96] if smoke else [32, 64, 128, 256]
    shared_prefix_sweep(cfg, params, shared_lens, smoke=smoke)
    return 0


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI guard: fail if cached step time grows with S")
    args = ap.parse_args()
    sys.exit(main(smoke=args.smoke))
