"""Crash-safe decode: checkpointed resume vs restart-fresh under crash storms.

Scenario (ROADMAP: failure-domain hardening): a decode-heavy request
stream on a small opportunistic pool with replacement supply, hit by a
seeded train of SILENT crash faults — no advance notice; only the
:class:`~repro.cluster.FailureDetector`'s heartbeat-lease expiry
converts each dead worker into an eviction (detection latency bounded by
the lease interval).  Two runs differ in exactly one knob:

* ``ckpt``    — ``ckpt_every_steps=CKPT_EVERY``: every settled batch
  member exports a bit-exact KV snapshot to a host in a different
  failure zone as a budget-checked ``KV_CKPT`` plane op; a crash victim
  with a landed checkpoint resumes from it, losing only the steps since;
* ``restart`` — ``ckpt_every_steps=None``: today's baseline, every
  crash victim restarts its decode from scratch.

Claims asserted in ``--smoke`` (and full) mode:

* equal completed work, strictly higher goodput (lower makespan) AND
  strictly fewer wasted decode tokens for the checkpointed run;
* every crash is detected within one lease interval of the fault;
* zero slot/page/byte leaks in both runs: nothing queued/running at the
  end, no plane op in flight, and the planned/moved byte meters agree
  exactly — including the KV_CKPT bytes (a drained run's in-flight
  checkpoints are refunded, so parity covers the checkpoint plane too);
* LIVE (this container's device): a decode stream checkpointed
  mid-flight and adopted by a fresh decoder continues TOKEN-EXACTLY vs
  an uninterrupted reference — on both the contiguous and the paged KV
  layout.

Usage: python -m benchmarks.run [--smoke] | python -m benchmarks.bench_faults [--smoke] [--seed N]
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import Optional

from repro.cluster import (Application, FailureDetector, FaultInjector,
                           GPU_CATALOG, fault_schedule, format_zone_bytes,
                           make_sim)
from repro.core import WarmPoolPolicy

from .common import ACTIVE_PARAMS, RECIPE

A10 = GPU_CATALOG["NVIDIA A10"]
POOL_N = 6               # workers (3 zones x 2)
CKPT_EVERY = 8           # decode steps between KV checkpoint exports
LEASE_S = 20.0           # heartbeat lease: crash-detection bound
FIRST_FAULT_S = 40.0
FAULT_EVERY_S = 60.0
_EPS = 1e-6


def _assert_drained(sched, ex, label: str) -> None:
    """End-of-run accounting: nothing queued/running/in flight, no slot
    residue, and the plane's planned/moved byte meters agree exactly
    (KV_CKPT ops included — in-flight checkpoints of finished requests
    are refunded, so a drained run meters to parity)."""
    assert sched.done, f"[{label}] run did not drain"
    assert not sched.running, f"[{label}] requests stuck in running"
    assert all(not lane for lane in sched.lanes.values()), \
        f"[{label}] non-empty lane after drain"
    assert ex.pending_arrivals == 0, f"[{label}] arrivals never fired"
    for w in sched.workers.values():
        for lib in w.libraries.values():
            assert not lib.batch, \
                f"[{label}] slot leak on {w.worker_id}: {set(lib.batch)}"
    plane = sched.plane
    assert plane.inflight_ops == 0, \
        f"[{label}] {plane.inflight_ops} plane op(s) still in flight"
    assert plane.planned.as_dict() == plane.moved.as_dict(), \
        f"[{label}] byte leak: planned {plane.planned.as_dict()} != " \
        f"moved {plane.moved.as_dict()}"


def run_sim(ckpt_every: Optional[int], *, n_requests: int, decode_steps: int,
            n_faults: int, fault_workers: int, seed: int) -> dict:
    """One crash-storm run; returns its scorecard."""
    # replacement supply: the trace re-offers the pool ceiling every
    # 30 s, so crashed capacity comes back (as FRESH workers) while the
    # backlog drains — the opportunistic steady state
    horizon = FIRST_FAULT_S + n_faults * FAULT_EVERY_S + 3600.0
    trace = [(30.0 * i, POOL_N) for i in range(int(horizon / 30.0))]
    sched, ex, fac = make_sim(devices=[A10] * 4, trace=trace,
                              workers_per_zone=2,
                              warm_pool=WarmPoolPolicy(),
                              ckpt_every_steps=ckpt_every,
                              retry_seed=seed)
    app = Application(sched)
    key = app.register(RECIPE, active_params=ACTIVE_PARAMS)
    app.submit_stream(ex, [dict(recipe_key=key, decode_steps=decode_steps,
                                arrival_s=i * 0.1)
                           for i in range(n_requests)])
    det = FailureDetector(ex, lease_s=LEASE_S)
    faults = fault_schedule(FIRST_FAULT_S, FAULT_EVERY_S, n_faults,
                            "crash", fault_workers)
    inj = FaultInjector(ex, faults, detector=det, seed=seed)
    inj.arm()
    t0 = time.time()
    makespan = ex.run()
    label = f"ckpt={ckpt_every}"
    _assert_drained(sched, ex, label)
    for wid, cause, t_fault, t_detect in det.detection_log:
        assert cause != "crash" or t_detect - t_fault <= LEASE_S + _EPS, \
            f"[{label}] crash on {wid} detected {t_detect - t_fault:.1f}s " \
            f"after the fault (> lease {LEASE_S}s)"
    return {
        "label": label, "makespan": makespan,
        "completed": sched.completed_inferences,
        "wasted": sched.evicted_inferences,
        "ckpts": sched.kv_ckpts, "ckpt_resumes": sched.ckpt_resumes,
        "ckpts_deferred": sched.kv_ckpts_deferred,
        "crashes": sched.evictions_by_cause.get("crash", 0),
        "detections": len(det.detection_log),
        "kv": sched.plane.kv_summary(), "sched": sched,
        "wall_s": time.time() - t0,
    }


def run_live(paged: bool, *, n_steps: int = 24, crash_at: int = 10) -> None:
    """LIVE bit-exactness: checkpoint a decode mid-flight, adopt the
    snapshot into a FRESH decoder (the checkpoint host), and verify the
    resumed stream's tokens equal an uninterrupted reference's."""
    import jax
    import numpy as np
    from repro.configs import get_smoke_config
    from repro.inference.streaming import StreamingDecoder
    from repro.models import model as M

    cfg = get_smoke_config("smollm2-1.7b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    prompt = list(rng.integers(4, cfg.vocab_size, 12))

    def mk():
        return StreamingDecoder(cfg, params, None, None, prompt_len=32,
                                max_len=64, paged=paged, page_size=8)

    layout = "paged" if paged else "contiguous"
    ref = mk()
    ref.ensure_tokens(0, prompt)
    want = [ref.step([0])[0] for _ in range(n_steps)]

    src = mk()                       # the worker that will "crash"
    src.ensure_tokens(0, prompt)
    got = [src.step([0])[0] for _ in range(crash_at)]
    snap = src.checkpoint(0)         # non-destructive: src keeps decoding
    assert snap is not None, f"[{layout}] no snapshot for a bound slot"
    assert src.pool.slot_of.get(0) is not None, \
        f"[{layout}] checkpoint released the source slot"
    got += [src.step([0])[0] for _ in range(2)]   # steps LOST to the crash

    dst = mk()                       # the checkpoint host takes over
    dst.adopt(0, snap)
    dst.resume(0)
    resumed = [dst.step([0])[0] for _ in range(n_steps - crash_at)]
    assert got[:crash_at] + resumed == want, \
        f"[{layout}] resumed stream diverged from the reference"
    assert dst.finish(0) == want, \
        f"[{layout}] finished token buffer diverged"
    if paged:
        assert dst.pages.in_use == 0 and src.pages is not None, \
            f"[{layout}] page leak after finish"
    print(f"  [live {layout}] {crash_at} steps + crash + resume on fresh "
          f"decoder == {n_steps}-step reference (token-exact)")


def main(smoke: bool = False, seed: int = 3) -> None:
    sizes = dict(n_requests=48, decode_steps=256, n_faults=4,
                 fault_workers=3) if smoke else \
        dict(n_requests=160, decode_steps=384, n_faults=8, fault_workers=3)
    ckpt = run_sim(CKPT_EVERY, seed=seed, **sizes)
    base = run_sim(None, seed=seed, **sizes)

    print(f"\n[bench_faults] crash storms: {sizes['n_faults']} x "
          f"{sizes['fault_workers']} workers, lease {LEASE_S:.0f}s, "
          f"seed {seed}")
    for r in (ckpt, base):
        goodput = r["completed"] / r["makespan"]
        print(f"  {r['label']:>10}: makespan {r['makespan']:8.1f}s | "
              f"goodput {goodput:6.1f} inf/s | wasted decode "
              f"{r['wasted']:6d} | crashes {r['crashes']} "
              f"(detected {r['detections']}) | ckpts {r['ckpts']} "
              f"({r['ckpt_resumes']} resume(s), "
              f"{r['ckpts_deferred']} deferred)")
    print(format_zone_bytes(ckpt["sched"].plane, label="ckpt"))

    assert ckpt["completed"] == base["completed"], \
        "runs completed different work"
    assert ckpt["crashes"] > 0 and base["crashes"] > 0, \
        "no crash ever hit the pool — the scenario is vacuous"
    assert ckpt["ckpt_resumes"] > 0, \
        "no crash victim ever resumed from a checkpoint"
    assert ckpt["makespan"] < base["makespan"], \
        f"checkpointed resume did not beat restart-fresh on goodput " \
        f"({ckpt['makespan']:.1f}s vs {base['makespan']:.1f}s)"
    assert ckpt["wasted"] < base["wasted"], \
        f"checkpointed resume did not waste fewer decode tokens " \
        f"({ckpt['wasted']} vs {base['wasted']})"
    print(f"  claims hold: equal work ({ckpt['completed']} inf), goodput "
          f"{ckpt['makespan']:.1f}s < {base['makespan']:.1f}s, waste "
          f"{ckpt['wasted']} < {base['wasted']}, detection <= lease")

    run_live(paged=False)
    run_live(paged=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--seed", type=int, default=3,
                    help="fault-schedule + retry-jitter seed")
    args = ap.parse_args()
    main(smoke=args.smoke, seed=args.seed)
    sys.exit(0)
