"""Benchmark driver: one module per paper table/figure + roofline.

  PYTHONPATH=src python -m benchmarks.run [--quick | --smoke]

--quick runs the sims at 15k inferences instead of the paper's 150k
(identical code paths, ~10x faster; claim tolerances unchanged).
--smoke is the CI job: tiny sizes, only the benchmarks whose claims are
scale-free (hardware table, continuous batching, mixed backfill).
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny sizes, scale-free claims only")
    args = ap.parse_args(argv)
    n_total = 15_000 if args.quick else 150_000

    from . import (bench_table1_hardware, bench_fig4_scaling_efforts,
                   bench_fig5_table2_task_times, bench_fig6_busy_cluster,
                   bench_fig7_resilience, bench_claims, bench_roofline,
                   bench_batch_policy, bench_context_plane,
                   bench_continuous_batching, bench_disagg, bench_elastic,
                   bench_faults, bench_gateway, bench_live_decode)

    t0 = time.time()
    if args.smoke:
        bench_table1_hardware.main()
        bench_continuous_batching.main(n_requests=120, n_workers=8)
        # asserts plan/executed byte-accounting equality and the
        # budgeted-vs-idle staging-makespan criterion
        bench_context_plane.main(smoke=True)
        # asserts slot-cached per-step decode time flat in prefix length
        # AND paged shared-prefix admission cost / KV bytes flat in the
        # shared-prefix length, at exact tokens vs full-forward
        bench_live_decode.main(smoke=True)
        # asserts interactive p95 <= 1.2x unloaded under 10x batch
        # overload at equal batch work, token-exact suspend/resume, and
        # zero slot/page accounting leaks
        bench_gateway.main(smoke=True)
        # asserts disaggregated routing >= colocated throughput at equal
        # completed work, shipped-KV decode token-exact on both layouts,
        # and zero KV byte leaks (planned == moved incl KV_SHIP)
        bench_disagg.main(smoke=True)
        # asserts forecast-driven elastic supply strictly beats the
        # reactive EWMA baseline on goodput under burst-then-storm at
        # equal completed work, with zero slot/byte leaks after storms
        bench_elastic.main(smoke=True)
        # asserts checkpointed resume strictly beats restart-fresh on
        # goodput AND wasted decode tokens under a seeded crash storm at
        # equal completed work, crash detection within one lease, zero
        # slot/page/byte leaks, and token-exact checkpoint/adopt resume
        # on both KV layouts
        bench_faults.main(smoke=True)
        bench_roofline.main()
        print(f"\nsmoke benchmarks done in {time.time()-t0:.1f}s")
        return 0
    bench_table1_hardware.main()
    res4 = bench_fig4_scaling_efforts.run_all(150_000)   # claims need paper scale
    bench_fig4_scaling_efforts.main(res=res4)
    bench_fig5_table2_task_times.main(n_total)
    res6 = bench_fig6_busy_cluster.run_pair(150_000)
    bench_fig6_busy_cluster.main(res=res6)
    bench_fig6_busy_cluster.main_mixed()
    bench_fig7_resilience.main(n_total)
    bench_fig7_resilience.main_storms(n_total)
    bench_claims.main(res=res4, drain=res6)
    bench_batch_policy.main(n_total)
    bench_batch_policy.main_mixed()
    bench_continuous_batching.main()
    bench_context_plane.main()
    bench_gateway.main()
    bench_disagg.main()
    bench_elastic.main()
    bench_faults.main()
    bench_live_decode.main()
    bench_roofline.main()
    print(f"\nall benchmarks done in {time.time()-t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
