"""Continuous admission vs run-to-completion batching (the API-redesign
claim).

A mixed short/long request stream (the companion-paper workload: many
8-step classifications interleaved with 256-step generations) arrives at
a heterogeneous pool.  Two systems execute the SAME stream at equal
completed work:

* ``batched``   — every request is a run-to-completion exclusive task
  (the pre-redesign ``Task`` semantics): a worker decodes one request at
  a time, shorts wait behind longs;
* ``continuous``— the request-stream API: resident libraries admit
  arrivals into their in-flight dynamic batch between decode steps, with
  per-device slot budgets from the hardware catalog.

Claims asserted:
  * both systems complete identical work;
  * continuous throughput >= 1.1x batched (it lands ~2-3x: decode is
    memory-bound, so co-decoding B requests costs far less than B
    sequential decodes);
  * per-request records expose queue-wait and time-to-first-step
    distributions for both systems (impossible under the old per-task
    records).
"""
from __future__ import annotations

from typing import Dict, List

from repro.cluster import GPU_CATALOG, latency_summary

from .common import Report, run_stream_experiment

SHORT_STEPS = 8
LONG_STEPS = 256
LONG_EVERY = 5                 # every 5th request is a long generation


def build_mixed_stream(n_requests: int, *, gap_s: float = 0.5
                       ) -> List[Dict[str, float]]:
    """Deterministic open-loop arrival schedule, shorts + longs mixed."""
    return [dict(decode_steps=(LONG_STEPS if i % LONG_EVERY == 0
                               else SHORT_STEPS),
                 arrival_s=round(i * gap_s, 6))
            for i in range(n_requests)]


def run_pair(n_requests: int = 480, n_workers: int = 12):
    devices = ([GPU_CATALOG["NVIDIA A10"]] * (n_workers // 2)
               + [GPU_CATALOG["NVIDIA TITAN X (Pascal)"]]
               * (n_workers - n_workers // 2))
    specs = build_mixed_stream(n_requests)
    cont = run_stream_experiment("continuous", specs, n_workers=n_workers,
                                 devices=devices)
    batched = run_stream_experiment("batched", specs, n_workers=n_workers,
                                    devices=devices, exclusive=True)
    return cont, batched


def _split(records):
    shorts = [r for r in records if r.n_units == SHORT_STEPS]
    longs = [r for r in records if r.n_units == LONG_STEPS]
    return shorts, longs


def main(n_requests: int = 480, n_workers: int = 12):
    (cont, app_c), (batched, app_b) = run_pair(n_requests, n_workers)
    assert cont.completed == batched.completed, \
        "systems must complete identical work"
    tput_c = cont.completed / cont.makespan_s
    tput_b = batched.completed / batched.makespan_s
    ratio = tput_c / tput_b

    rep = Report("Continuous admission vs run-to-completion "
                 f"({n_requests} requests, {n_workers} workers)",
                 ["exp", "makespan_s", "completed", "units_per_s",
                  "admissions", "cold_starts"])
    for res in (batched, cont):
        s = res.sched
        rep.add(res.exp_id, f"{res.makespan_s:.0f}", res.completed,
                f"{res.completed / res.makespan_s:.1f}", s.admissions,
                sum(1 for r in res.records if not r.warm))
    rep.print()

    lat = Report("Per-request latency (sim records)",
                 ["exp", "class", "queue_p50_s", "queue_p95_s",
                  "ttfs_p50_s", "ttfs_p95_s", "e2e_p50_s", "e2e_p95_s"])
    for res, app in ((batched, app_b), (cont, app_c)):
        for name, recs in zip(("short", "long"), _split(app.records())):
            s = latency_summary(recs)
            lat.add(res.exp_id, name, f"{s['queue_wait_p50_s']:.1f}",
                    f"{s['queue_wait_p95_s']:.1f}",
                    f"{s['ttfs_p50_s']:.1f}", f"{s['ttfs_p95_s']:.1f}",
                    f"{s['e2e_p50_s']:.1f}", f"{s['e2e_p95_s']:.1f}")
    lat.print()

    print(f"\ncontinuous/batched throughput: {ratio:.2f}x")
    assert ratio >= 1.1, \
        f"continuous admission must beat run-to-completion: {ratio:.2f}x"
    short_c = latency_summary(_split(app_c.records())[0])
    short_b = latency_summary(_split(app_b.records())[0])
    assert short_c["e2e_p95_s"] < short_b["e2e_p95_s"], \
        "short requests must stop waiting behind long ones"
    print("continuous batching claims: OK")
    return ratio


if __name__ == "__main__":
    main()
