"""Shared benchmark harness utilities."""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.configs import get_config
from repro.core import (ContextElement, ContextMode, ContextRecipe, MODES,
                        NAIVE, PARTIAL, PERVASIVE, WorkerShape,
                        model_context_recipe)
from repro.cluster import (Application, make_sim, opportunistic_supply,
                           GPU_CATALOG)

CFG = get_config("smollm2-1.7b")
RECIPE = model_context_recipe(CFG, include_compile=False)
ACTIVE_PARAMS = CFG.n_active_params()
N_INFERENCES = 150_000        # the paper's 150k FEVER claims

# -- mixed-recipe scenario assets (backfill/spill benchmarks) ---------------
# An 8B-class recipe: its 16 GB device copy fits the 24 GB A10s but not the
# 12 GB TITAN Xs, so a queue headed by a big task head-of-line-blocks a
# FIFO scheduler while half the pool idles.
BIG_RECIPE = ContextRecipe("infer::big-8b", (
    ContextElement("deps", nbytes_disk=3_700_000_000,
                   nbytes_host=512_000_000, version="conda-308pkg"),
    ContextElement("code", nbytes_disk=65_536, version="big-8b"),
    ContextElement("weights", nbytes_disk=16_000_000_000,
                   nbytes_host=32_000_000_000,
                   nbytes_device=16_000_000_000, version="big-8b"),
), activation_s=2.0)
BIG_AP = 8.0e9
# Fits either recipe alone, not both host-resident — switching spills.
MIXED_SHAPE = WorkerShape(cores=2, memory_gb=36, disk_gb=70, gpus=1)
MIXED_RECIPES: Dict[str, Tuple[ContextRecipe, float]] = {
    "small": (RECIPE, ACTIVE_PARAMS),
    "big": (BIG_RECIPE, BIG_AP),
}


@dataclass
class ExpResult:
    exp_id: str
    makespan_s: float
    avg_workers: float
    completed: int
    evicted_inferences: int
    records: list = field(repr=False, default_factory=list)
    sched: object = field(repr=False, default=None)


def run_experiment(exp_id: str, *, mode: ContextMode, batch: int,
                   n_workers: int = 20, n_total: int = N_INFERENCES,
                   devices=None, trace=None, evict_priority=None,
                   until: Optional[float] = None) -> ExpResult:
    sched, ex, fac = make_sim(devices=devices, trace=trace,
                              evict_priority=evict_priority)
    key = sched.register_context(RECIPE)
    sched.submit_sweep(key, n_total, batch, mode,
                       active_params=ACTIVE_PARAMS)
    if trace is None:
        fac.reconcile(n_workers)
    ex.pump()
    ex.loop.run(until=until, stop=lambda: sched.done)
    return ExpResult(exp_id, sched.makespan(), sched.avg_connected_workers(),
                     sched.completed_inferences, sched.evicted_inferences,
                     sched.records, sched)


def run_mixed_experiment(exp_id: str, *,
                         sweeps: Sequence[Tuple[str, int, int]],
                         n_workers: int = 20, backfill: bool = True,
                         warm_pool=None, devices=None, trace=None,
                         until: Optional[float] = None) -> ExpResult:
    """Multi-recipe sweep on one pool.  ``sweeps`` is a list of
    (recipe name from MIXED_RECIPES, n_inferences, batch)."""
    sched, ex, fac = make_sim(devices=devices, trace=trace,
                              worker_shape=MIXED_SHAPE, backfill=backfill,
                              warm_pool=warm_pool)
    for name, n_total, batch in sweeps:
        recipe, ap = MIXED_RECIPES[name]
        key = sched.register_context(recipe)
        sched.submit_sweep(key, n_total, batch, PERVASIVE, active_params=ap)
    if trace is None:
        fac.reconcile(n_workers)
    ex.pump()
    ex.loop.run(until=until, stop=lambda: sched.done)
    return ExpResult(exp_id, sched.makespan(), sched.avg_connected_workers(),
                     sched.completed_inferences, sched.evicted_inferences,
                     sched.records, sched)


def run_stream_experiment(exp_id: str, specs: Sequence[Dict[str, Any]], *,
                          n_workers: int = 12, exclusive: bool = False,
                          devices=None, warm_pool=None, backfill: bool = True,
                          until: Optional[float] = None
                          ) -> Tuple[ExpResult, Application]:
    """Replay a request-arrival schedule through the sim.

    ``specs`` are :meth:`Application.make_request` kwargs (decode_steps,
    arrival_s, ...); ``exclusive=True`` runs the SAME stream as
    run-to-completion batch requests — the pre-redesign baseline
    continuous admission is measured against."""
    sched, ex, fac = make_sim(devices=devices, warm_pool=warm_pool,
                              backfill=backfill)
    app = Application(sched)
    key = app.register(RECIPE, active_params=ACTIVE_PARAMS)
    app.submit_stream(ex, [dict(s, recipe_key=key, exclusive=exclusive)
                           for s in specs])
    fac.reconcile(n_workers)
    ex.run(until=until)
    res = ExpResult(exp_id, sched.makespan(), sched.avg_connected_workers(),
                    sched.completed_inferences, sched.evicted_inferences,
                    sched.records, sched)
    return res, app


class Report:
    """Collects rows; prints an aligned table + a machine-readable CSV."""

    def __init__(self, title: str, columns: List[str]):
        self.title = title
        self.columns = columns
        self.rows: List[List[str]] = []

    def add(self, *values) -> None:
        self.rows.append([str(v) for v in values])

    def print(self) -> None:
        widths = [max(len(c), *(len(r[i]) for r in self.rows)) if self.rows
                  else len(c) for i, c in enumerate(self.columns)]
        print(f"\n== {self.title} ==")
        print("  ".join(c.ljust(w) for c, w in zip(self.columns, widths)))
        for r in self.rows:
            print("  ".join(v.ljust(w) for v, w in zip(r, widths)))
        print("-- csv --")
        print(",".join(self.columns))
        for r in self.rows:
            print(",".join(r))


def fmt_s(x: float) -> str:
    return f"{x:,.0f}s"
