"""Forecast-driven elastic supply vs the reactive EWMA baseline.

Scenario (ROADMAP: forecast-aware elastic pool, storms first-class): a
repeated-burst request schedule — a steady base rate with several short
high-rate bursts, ending ON a burst so the final drain is
capacity-bound — plus correlated eviction storms (zone-correlated, one
revoking mid-staging workers) fired through the
:class:`~repro.cluster.ChurnInjector` while the backlog drains.

Both runs use the SAME demand-driven factory machinery
(``Factory(policy=ElasticPolicy(...))`` under the same availability
ceiling); the only difference is the demand signal:

* ``ewma``     — the decayed arrival EWMA (the reactive
                 ``arrival_horizon_s``-style signal PR 3 introduced);
* ``forecast`` — the :class:`~repro.cluster.DemandForecaster`'s
                 windowed trend + burst-pinned forecast.

The EWMA pool rides each rate edge ~an EWMA time-constant late and
releases between bursts once the decayed rate falls; the forecast
detects each burst within a window, pins capacity through the
burst-hold period, and so meets the next burst (and the post-storm
re-acquire) with the pool already warm.  The smoke claims:

* equal completed work, strictly higher goodput for the forecast run
  (>= 10x bench_fig7's request count, all on the cheap DES executor);
* the forecast crosses the burst threshold strictly ahead of the EWMA
  (positive forecast lead time);
* zero slot/byte leaks after every storm window: live batch membership
  matches the running table at each post-storm checkpoint, and the
  plane's planned/moved meters agree exactly at the end of both runs.

Usage: python -m benchmarks.bench_elastic [--smoke | --quick]
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import Dict, List, Optional, Tuple

from repro.cluster import (Application, ChurnInjector, DemandForecaster,
                           ElasticPolicy, Storm, format_pool, make_sim,
                           opportunistic_supply, pool_summary)
from repro.core import WarmPoolPolicy

from .common import ACTIVE_PARAMS, RECIPE

BASE_RATE = 8.0          # req/s between bursts
BURST_RATE = 35.0        # req/s during a burst
DECODE_STEPS = 6         # work units per request
CEILING = 48             # availability ceiling (supply has 64)
SUPPLY_N = 64
STORM_N = 12             # workers lost per storm
SETTLE_S = 25.0          # post-storm leak-checkpoint delay


def burst_schedule(n_min: int, cycles: int
                   ) -> Tuple[List[float], List[Tuple[float, float]]]:
    """Arrival times: base rate throughout, ``cycles`` bursts layered on
    top, the LAST burst extended and closing the schedule (no tail —
    the final drain stays capacity-bound).  Returns (arrivals, list of
    (burst_start, burst_end)).  Extends the base span until at least
    ``n_min`` requests exist."""
    bursts = []
    t0, gap, dur = 260.0, 200.0, 40.0
    for i in range(cycles):
        start = t0 + i * (gap + dur)
        end = start + (dur * 2 if i == cycles - 1 else dur)
        bursts.append((start, end))
    horizon = bursts[-1][1]
    arrivals: List[float] = []
    t = 0.0
    while t < horizon:
        arrivals.append(t)
        t += 1.0 / BASE_RATE
    for start, end in bursts:
        t = start
        while t < end:
            arrivals.append(t)
            t += 1.0 / BURST_RATE
    # top up with extra base-rate arrivals BEFORE the last burst if the
    # target count is not met (keeps the no-tail property)
    i = 0
    while len(arrivals) < n_min:
        arrivals.append((i % int(bursts[-1][0])) + 0.5 + (i * 1e-3))
        i += 1
    arrivals.sort()
    return arrivals, bursts


def _check_no_storm_leaks(sched, label: str) -> None:
    """Mid-run integrity after a storm settled: every live batch slot
    belongs to a running request routed to that worker, and every
    in-flight plane op references a live worker (dead workers' ops were
    refunded by drop_worker)."""
    for w in sched.workers.values():
        for lib in w.libraries.values():
            for rid in lib.batch:
                assert rid in sched.running, \
                    f"[{label}] slot leak: {w.worker_id} holds request " \
                    f"{rid} which is not running"
    for (key, wid) in sched.plane._inflight:
        assert wid in sched.workers, \
            f"[{label}] in-flight op on dead worker {wid} (not refunded)"


def _assert_drained(sched, ex, label: str) -> None:
    """End-of-run accounting: nothing queued/running/in flight, no slot
    residue, and the plane's planned/moved byte meters agree exactly."""
    assert not sched.running, f"[{label}] requests stuck in running"
    assert all(not lane for lane in sched.lanes.values()), \
        f"[{label}] non-empty lane after drain"
    assert ex.pending_arrivals == 0, f"[{label}] arrivals never fired"
    for w in sched.workers.values():
        for lib in w.libraries.values():
            assert not lib.batch, \
                f"[{label}] slot leak on {w.worker_id}: {set(lib.batch)}"
    plane = sched.plane
    assert plane.inflight_ops == 0, \
        f"[{label}] {plane.inflight_ops} plane op(s) still in flight"
    assert plane.planned.as_dict() == plane.moved.as_dict(), \
        f"[{label}] byte leak: planned {plane.planned.as_dict()} != " \
        f"moved {plane.moved.as_dict()}"


def run_one(signal: str, arrivals: List[float],
            bursts: List[Tuple[float, float]], *,
            sample: bool = False) -> Dict[str, object]:
    policy = ElasticPolicy(signal=signal, active_params=ACTIVE_PARAMS)
    sched, ex, fac = make_sim(
        devices=opportunistic_supply(SUPPLY_N, seed=3),
        trace=[(0.0, CEILING)],
        warm_pool=WarmPoolPolicy(arrival_horizon_s=30.0),
        policy=policy)
    # tune the burst hold to this trace's cadence: bursts recur every
    # ~240s, so the pin must survive a full inter-burst gap or the
    # forecast pool releases mid-gap and re-ramps late like the EWMA
    sched.forecaster = DemandForecaster(burst_hold_s=240.0)
    app = Application(sched)
    key = app.register(RECIPE, active_params=ACTIVE_PARAMS)
    app.submit_stream(ex, [dict(recipe_key=key, decode_steps=DECODE_STEPS,
                                arrival_s=t) for t in arrivals])
    # storm 1 lands on a mid-train burst ramp (acquisitions in flight —
    # exercises revoke-during-staging); storm 2 lands in the gap BEFORE
    # the final burst: the forecast's burst pin refills the pool ahead
    # of the heaviest burst, the reactive signal not until it hits
    storms = [Storm(bursts[2][0] + 15.0, STORM_N, revoke_staging=True),
              Storm(bursts[-1][0] - 45.0, STORM_N)]
    inj = ChurnInjector(ex, storms, factory=fac, seed=1, suppress_s=30.0)
    inj.arm()
    for s in storms:
        ex.loop.at(s.t_s + SETTLE_S,
                   lambda s=s: _check_no_storm_leaks(
                       sched, f"{signal} storm@{s.t_s:.0f}"))
    samples: List[Tuple[float, float, float, int]] = []
    if sample:
        def probe():
            v = sched.view(ex.loop.now)
            samples.append((ex.loop.now, v.forecast_rate.get(key, 0.0),
                            v.arrival_rate.get(key, 0.0),
                            len(sched.workers)))
            if not (sched.done and not ex.pending_arrivals):
                ex.loop.after(2.0, probe)
        ex.loop.after(2.0, probe)
    makespan = ex.run()
    _assert_drained(sched, ex, signal)
    units = sched.completed_inferences
    return {"signal": signal, "makespan": makespan, "units": units,
            "goodput": units / makespan, "killed": inj.killed,
            "sched": sched, "fac": fac, "samples": samples,
            "n_storms": len(inj.storm_log)}


def forecast_lead_s(samples, bursts,
                    thresh: float) -> Optional[float]:
    """Mean (EWMA crossing - forecast crossing) over burst onsets: how
    far ahead of the reactive signal the forecast saw each burst."""
    leads = []
    for start, end in bursts:
        t_f = t_e = None
        for t, f, e, _ in samples:
            if t < start:
                continue
            if t_f is None and f >= thresh:
                t_f = t
            if t_e is None and e >= thresh:
                t_e = t
            if t_f is not None and t_e is not None:
                break
        if t_f is not None and t_e is not None:
            leads.append(t_e - t_f)
    return sum(leads) / len(leads) if leads else None


def main(smoke: bool = False, n_requests: Optional[int] = None) -> None:
    from .common import Report
    if n_requests is None:
        # smoke: >= 10x bench_fig7's request count (150k units / batch
        # 100 = 1500 requests); full: ~30x on a longer burst train
        n_requests = 15_000 if smoke else 45_000
    cycles = 4 if n_requests <= 20_000 else 10
    arrivals, bursts = burst_schedule(n_requests, cycles)
    t0 = time.time()
    res = {s: run_one(s, arrivals, bursts, sample=(s == "forecast"))
           for s in ("ewma", "forecast")}
    rep = Report(
        f"elastic supply under burst-then-storm ({len(arrivals):,} "
        f"requests x {DECODE_STEPS} units, {cycles} bursts "
        f"{BASE_RATE:.0f}->{BURST_RATE:.0f} req/s, ceiling {CEILING}, "
        f"2 storms x {STORM_N} workers)",
        ["signal", "units", "makespan s", "goodput u/s", "killed",
         "scale events"])
    for name, r in res.items():
        rep.add(name, f"{r['units']:,}", f"{r['makespan']:.1f}",
                f"{r['goodput']:.2f}", r["killed"],
                len(r["fac"].scale_log))
    rep.print()
    lead = forecast_lead_s(res["forecast"]["samples"], bursts,
                           thresh=(BASE_RATE + BURST_RATE) / 2.0)
    if lead is not None:
        print(f"forecast lead over EWMA at burst onsets: {lead:.1f}s "
              f"(threshold {(BASE_RATE + BURST_RATE) / 2:.0f} req/s)")
    print(format_pool(pool_summary(res["forecast"]["sched"],
                                   res["forecast"]["fac"]),
                      label="forecast"))
    print(f"[bench_elastic] done in {time.time() - t0:.1f}s")

    ew, fc = res["ewma"], res["forecast"]
    assert ew["units"] == fc["units"], \
        f"unequal completed work: {ew['units']} vs {fc['units']}"
    assert ew["n_storms"] == fc["n_storms"] == 2, "a storm never fired"
    if smoke:
        assert len(arrivals) >= 15_000, \
            f"scenario too small: {len(arrivals)} requests < 10x " \
            "bench_fig7's 1500"
        assert fc["goodput"] > ew["goodput"], \
            f"forecast goodput {fc['goodput']:.2f} u/s does not beat " \
            f"reactive EWMA {ew['goodput']:.2f} u/s"
        assert lead is not None and lead > 0, \
            f"forecast did not lead the EWMA at burst onsets ({lead})"
        # with a hold spanning the inter-burst gap, later bursts extend
        # the first pin rather than count as fresh detections
        assert fc["sched"].forecaster.bursts_detected >= 1, \
            "burst detection never fired"


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="same as --smoke sizing, without the asserts")
    args = ap.parse_args()
    main(smoke=args.smoke,
         n_requests=15_000 if args.quick else None)
    sys.exit(0)
