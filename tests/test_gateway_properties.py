"""Hypothesis property tests on the serving gateway's invariants.

Per-class queue bounds must hold at EVERY DES event, terminal outcomes
(done / REJECTED / TIMED_OUT) are mutually exclusive and recorded
exactly once, and preemption conserves work whatever the schedule.
"""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.cluster import (Application, ClassPolicy, Gateway, REJECTED,
                           TIMED_OUT, make_sim)

from test_gateway import A10, AP, RECIPE2, run_preemption_scenario

arrivals = st.lists(
    st.tuples(st.sampled_from(["interactive", "batch"]),
              st.integers(0, 40),               # arrival second
              st.integers(1, 6)),               # decode steps
    min_size=1, max_size=25)


@given(arrivals, st.integers(1, 3), st.integers(1, 3))
@settings(max_examples=25, deadline=None)
def test_queue_bounds_hold_at_every_des_event(schedule, ibound, bbound):
    """At no point in the run may the fresh queued population of a
    bounded class exceed its bound — checked after EVERY loop event."""
    sched, ex, fac = make_sim(devices=[A10])
    app = Application(sched)
    key = app.register(RECIPE2, active_params=AP)
    gw = Gateway(sched,
                 interactive=ClassPolicy(max_queue=ibound, overflow="reject",
                                         deadline_s=25.0),
                 batch=ClassPolicy(max_queue=bbound, overflow="queue"))
    app.submit_stream(ex, [dict(recipe_key=key, decode_steps=steps,
                                arrival_s=float(t), slo=slo)
                           for slo, t, steps in schedule])
    fac.reconcile(1)
    ex.pump()
    while ex.loop.step():
        for slo, pol in gw.policies.items():
            if pol.max_queue is not None:
                depth = gw.queued_fresh(key, slo)
                assert depth <= pol.max_queue, \
                    f"{slo} fresh depth {depth} > bound {pol.max_queue} " \
                    f"at t={ex.loop.now:.2f}"

    # terminal exclusivity: one record per request, disjoint outcomes
    ids = [r.request_id for r in sched.records]
    assert len(ids) == len(set(ids)), "request finalized twice"
    assert len(ids) == len(app.requests), "request lost"
    for r in sched.records:
        assert r.outcome in ("done", REJECTED, TIMED_OUT)
    done_units = sum(r.n_units for r in sched.records
                     if r.outcome == "done")
    assert done_units == sched.completed_inferences


@given(st.integers(20, 60), st.integers(1, 6), st.integers(26, 50))
@settings(max_examples=15, deadline=None)
def test_preemption_conserves_victim_work(batch_steps, int_steps,
                                          int_arrival):
    """Whatever the preemption schedule, a suspended victim eventually
    completes exactly its submitted decode steps — never fewer (lost
    work) and never more (double credit)."""
    sched, gw, app = run_preemption_scenario(
        batch_steps=batch_steps, int_steps=int_steps,
        int_arrival=float(int_arrival))
    assert sched.done
    total = 2 * batch_steps + int_steps
    done_units = sum(r.n_units for r in sched.records
                     if r.outcome == "done")
    timed_out = [r for r in sched.records if r.outcome == TIMED_OUT]
    assert done_units + sum(r.n_units for r in timed_out) == total
    assert sched.completed_inferences == done_units
    kv = sched.plane.kv_summary()
    assert kv["resume_events"] == kv["spill_events"] == sched.preemptions
