"""Paged KV cache with refcounted shared-prefix reuse: lifecycle + exactness.

The invariants the paged layout must hold under live churn:

* refcounts never go negative; every page is freed exactly once and the
  prefix index is purged with it;
* a shared page is NEVER freed (or recycled) while any tenant still maps
  it — one holder finishing must not disturb the others' tokens;
* a tenant whose ring wraps into a shared page COPIES it first
  (copy-on-write) instead of corrupting the other holders;
* admission maps an indexed prefix by reference: zero prefill tokens and
  zero new pages for the shared span;
* the decode step still compiles ONCE per pool capacity with paging on;
* paged greedy tokens equal the contiguous slot pool's and the
  full-forward reference's under membership churn.

Plus the three bugfix regressions riding along: over-length prompts
raise or set the ``truncated`` flag (never a silent clip), cache growth
carries UNKNOWN cache keys (a layout the grower doesn't know about must
survive ``_grow``), and ``make_pff_step_fn`` frees decoder state for
requests the scheduler pulled out of the batch mid-flight.
"""
import numpy as np
import pytest

import jax

from repro.configs import get_smoke_config
from repro.inference.streaming import (PagePool, PrefixIndex,
                                       StreamingDecoder, make_pff_step_fn)
from repro.models import model as M


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("smollm2-1.7b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _mk(cfg, params, **kw):
    kw.setdefault("max_len", 48)
    kw.setdefault("page_size", 8)
    return StreamingDecoder(cfg, params, None, None, prompt_len=48, **kw)


def _prompts(rng, cfg, n, shared_len=24):
    """n prompts, even rids share a ``shared_len`` prefix, odd are
    private; tails/lengths all distinct."""
    shared = list(rng.integers(4, cfg.vocab_size, shared_len))
    out = {}
    for rid in range(n):
        tail = list(rng.integers(4, cfg.vocab_size, 3 + rid))
        out[rid] = shared + tail if rid % 2 == 0 else \
            list(rng.integers(4, cfg.vocab_size, 10 + rid))
    return out


def _run(dec, prompts, script):
    """Drive ``dec`` through (rids, finish_after) steps; returns tokens."""
    out = {}
    for rids, fins in script:
        for r in rids:
            if r not in dec._tokens:
                dec.ensure_tokens(r, prompts[r])
        for r, t in dec.step(rids).items():
            out.setdefault(r, []).append(t)
        for r in fins:
            dec.finish(r)
    return out


CHURN = [
    ([0, 1], []), ([0, 1, 2], []), ([0, 1, 2, 4], [1]),
    ([0, 2, 4], [0]), ([2, 4, 6], []), ([2, 4, 6, 3], [2]),
    ([4, 6, 3, 5], [4]), ([6, 3, 5], [6, 3]), ([5, 7], []),
    ([5, 7], [5, 7]),
]


class TestPagePool:
    def test_refcount_lifecycle(self):
        pool = PagePool(4)
        assert pool.free == 3                      # page 0 is trash
        a = pool.alloc()
        assert a != PagePool.TRASH and pool.refcount(a) == 1
        pool.incref(a)
        assert pool.refcount(a) == 2
        assert pool.decref(a) is False             # still held
        assert pool.refcount(a) == 1
        assert pool.decref(a) is True              # freed now
        assert pool.refcount(a) == 0 and pool.free == 3

    def test_refcounts_never_negative(self):
        pool = PagePool(3)
        a = pool.alloc()
        pool.decref(a)
        with pytest.raises(AssertionError):
            pool.decref(a)                         # double free asserts

    def test_trash_page_never_allocated(self):
        pool = PagePool(3)
        got = {pool.alloc(), pool.alloc()}
        assert PagePool.TRASH not in got
        with pytest.raises(IndexError):
            pool.alloc()                           # exhausted, trash stays

    def test_grow_adds_free_pages(self):
        pool = PagePool(2)
        pool.alloc()
        pool.grow(5)
        assert pool.free == 3 and pool.n_pages == 5


class TestPrefixIndex:
    def test_longest_whole_page_match(self):
        idx = PrefixIndex()
        toks = list(range(20))
        idx.insert(toks, 8, [5, 6])                # two full pages of 8
        assert idx.lookup(toks, 8, 2) == [5, 6]
        assert idx.lookup(toks, 8, 1) == [5]       # caller's tail cap
        assert idx.lookup(toks[:12], 8, 1) == [5]  # shorter prompt, 1 page
        assert idx.lookup(list(range(1, 21)), 8, 2) == []

    def test_forget_page_purges_chains(self):
        idx = PrefixIndex()
        toks = list(range(24))
        idx.insert(toks, 8, [5, 6, 7])
        idx.forget_page(6)                         # middle page dies
        assert idx.lookup(toks, 8, 3) == [5]       # 1-page chain survives
        idx.forget_page(5)
        assert idx.lookup(toks, 8, 3) == []
        assert len(idx) == 0

    def test_first_insert_wins(self):
        idx = PrefixIndex()
        toks = list(range(8))
        idx.insert(toks, 8, [3])
        idx.insert(toks, 8, [9])                   # duplicate content
        assert idx.lookup(toks, 8, 1) == [3]


class TestPagedDecoder:
    def test_churn_token_exact_vs_slot_and_full(self, setup):
        cfg, params = setup
        rng = np.random.default_rng(3)
        prompts = _prompts(rng, cfg, 8)
        paged = _run(_mk(cfg, params, paged=True), prompts, CHURN)
        slot = _run(_mk(cfg, params, paged=False), prompts, CHURN)
        full = _run(_mk(cfg, params, slot_cached=False), prompts, CHURN)
        assert paged == slot == full

    def test_all_pages_freed_and_index_purged_after_churn(self, setup):
        cfg, params = setup
        dec = _mk(cfg, params, paged=True)
        _run(dec, _prompts(np.random.default_rng(4), cfg, 8), CHURN)
        assert dec.pages.in_use == 0
        assert dec.pages.free == dec.pages.n_pages - 1
        assert len(dec.prefix) == 0
        assert len(dec.pool) == 0

    def test_admission_maps_shared_prefix_by_reference(self, setup):
        """Second tenant of a 24-token (3 full pages of 8) prefix pays
        only its tail: no prefix prefill tokens, no new prefix pages."""
        cfg, params = setup
        rng = np.random.default_rng(5)
        prompts = _prompts(rng, cfg, 4)
        dec = _mk(cfg, params, paged=True)
        dec.ensure_tokens(0, prompts[0])
        dec.step([0])
        t0, p0 = dec.prefill_tokens_total, dec.pages.in_use
        dec.ensure_tokens(2, prompts[2])
        dec.step([0, 2])
        tail = len(prompts[2]) - 24
        assert dec.shared_tokens_total == 24
        assert dec.prefill_tokens_total - t0 <= tail + 7   # bucket pad only
        assert dec.pages.in_use - p0 == -(-tail // 8)      # tail pages only
        shared = [p for p in range(1, dec.pages.n_pages)
                  if dec.pages.refcount(p) > 1]
        assert len(shared) == 3

    def test_shared_page_survives_one_holders_finish(self, setup):
        """Producer finishes; the consumer still maps the prefix pages —
        they must stay allocated and its tokens must stay exact."""
        cfg, params = setup
        rng = np.random.default_rng(6)
        prompts = _prompts(rng, cfg, 4)
        dec = _mk(cfg, params, paged=True)
        ref = _mk(cfg, params, slot_cached=False)
        script = [([0], []), ([0, 2], []), ([0, 2], [0]),
                  ([2], []), ([2], []), ([2], [2])]
        got = _run(dec, prompts, script)
        # after rid 0 finished, rid 2 still held the 3 prefix pages alone
        assert got == _run(ref, prompts, script)
        assert dec.pages.in_use == 0               # and all freed at the end

    def test_copy_on_write_on_ring_wrap(self, setup):
        """Two tenants share a prefix; both generate past the ring length
        so their writes WRAP into the shared pages.  Each must copy first
        — tokens stay equal to the contiguous slot pool's (same ring T),
        and while both are live the shared pages get un-shared."""
        cfg, params = setup
        rng = np.random.default_rng(7)
        shared = list(rng.integers(4, cfg.vocab_size, 16))
        prompts = {0: shared + list(rng.integers(4, cfg.vocab_size, 5)),
                   1: shared + list(rng.integers(4, cfg.vocab_size, 3))}
        # T = 24 for both layouts: wrap after ~8 generated tokens
        paged = _mk(cfg, params, paged=True, max_len=24)
        slot = _mk(cfg, params, paged=False, max_len=24)
        script = [([0], []), ([0, 1], [])] + [([0, 1], [])] * 12
        got = _run(paged, prompts, script)
        n_shared_mid = len([p for p in range(1, paged.pages.n_pages)
                            if paged.pages.refcount(p) > 1])
        assert n_shared_mid == 0, "wrap must have COW'd the shared pages"
        assert got == _run(slot, prompts, script)
        for r in (0, 1):
            paged.finish(r)
        assert paged.pages.in_use == 0

    def test_decode_compiles_once_per_capacity(self, setup):
        """Recompile audit with paging ON: whatever the admissions, COWs
        and table rewrites, decode has ONE compiled shape per capacity."""
        cfg, params = setup
        dec = _mk(cfg, params, paged=True, b_max=4)
        _run(dec, _prompts(np.random.default_rng(8), cfg, 8), CHURN)
        decode_shapes = [s for s in dec._shapes if s[0] == "decode"]
        assert decode_shapes == [("decode", 4)]

    def test_measured_slot_bytes_is_page_budget(self, setup):
        cfg, params = setup
        dec = _mk(cfg, params, paged=True)
        dec.ensure_tokens(0, list(range(4, 24)))
        dec.step([0])
        assert dec.page_bytes > 0
        assert dec.measured_slot_bytes == dec.max_pages * dec.page_bytes
        assert dec.kv_bytes_in_use == dec.pages.in_use * dec.page_bytes


class TestBugfixRegressions:
    def test_overlong_prompt_strict_raises(self, setup):
        cfg, params = setup
        dec = _mk(cfg, params, paged=True, strict_prompts=True)
        with pytest.raises(ValueError, match="caps prompts"):
            dec.ensure_tokens(0, list(range(4, 4 + 80)))
        assert 0 not in dec._tokens                # nothing half-admitted

    @pytest.mark.parametrize("paged", [False, True])
    def test_overlong_prompt_sets_truncated_flag(self, setup, paged):
        cfg, params = setup
        dec = _mk(cfg, params, paged=paged)
        dec.ensure_tokens(0, list(range(4, 4 + 80)))
        dec.ensure_tokens(1, list(range(4, 24)))
        assert dec.truncated[0] is True
        assert dec.truncated[1] is False
        assert len(dec._tokens[0]) == dec.max_len  # clipped, not dropped
        dec.step([0, 1])
        dec.finish(0)
        assert 0 not in dec.truncated              # state fully released

    @pytest.mark.parametrize("paged", [False, True])
    def test_grow_preserves_unknown_cache_keys(self, setup, paged):
        """_grow must rebuild the cache GENERICALLY: keys the initialiser
        does not produce (here a fake sampling-state leaf) ride across
        growth with their prefix contents intact — and live requests
        keep decoding exactly."""
        cfg, params = setup
        rng = np.random.default_rng(9)
        prompts = _prompts(rng, cfg, 6)
        dec = _mk(cfg, params, paged=paged, b_max=2)
        ref = _mk(cfg, params, slot_cached=False)
        script = [([0, 1], [])] * 2
        got = _run(dec, prompts, script)
        marker = jax.numpy.arange(7, dtype=jax.numpy.float32)
        dec._cache["rng_state"] = marker           # a key _grow doesn't know
        script2 = [([0, 1, 2, 3], [])] * 2 + [([0, 1, 2, 3], [0, 1, 2, 3])]
        got2 = _run(dec, prompts, script2)
        assert dec.pool.capacity == 4              # growth happened
        assert "rng_state" in dec._cache
        np.testing.assert_array_equal(np.asarray(dec._cache["rng_state"]),
                                      np.asarray(marker))
        full = _run(ref, prompts, script + script2)
        merged = {r: got.get(r, []) + got2.get(r, []) for r in full}
        assert merged == full

    def test_step_fn_frees_state_for_requeued_members(self, setup):
        """A rid that was stepping here and then VANISHES from members
        (requeued/migrated by the scheduler) must have its slot, pages
        and token buffers freed — not leak until teardown."""
        from repro.cluster.scheduler import Request

        cfg, params = setup

        class _Tok:                                # identity tokenizer
            def encode(self, text):
                return list(text)

        class _Tpl:
            def render(self, claim):
                return claim

        class _Eng:
            def __init__(self):
                self.cfg, self.params = cfg, params

        payloads = {"xla_executable": _Eng(),
                    "context_inputs": {"tokenizer": _Tok(),
                                       "template": _Tpl()}}
        step_fn = make_pff_step_fn(prompt_len=16, max_len=32)
        reqs = {i: Request(recipe_key="k", decode_steps=8,
                           payload=[4 + i] * (10 + i)) for i in range(3)}
        def run(members):                          # the executor's loop
            step_fn(payloads, members)
            for r in members:
                r.steps_done += 1

        members = [reqs[0], reqs[1]]
        run(members)
        dec = payloads["_stream_decoder"]
        assert set(dec.active_rids()) == {r.request_id for r in members}
        # rid 0 requeued away; rid 2 joins
        run([reqs[1], reqs[2]])
        live = {reqs[1].request_id, reqs[2].request_id}
        assert set(dec.active_rids()) == live
        assert set(dec.pool.slot_of) == live
        if dec.paged:                              # rid 0's pages came back
            held = {int(p) for row in dec._table for p in row if p}
            assert dec.pages.in_use == len(held)
        # drain everyone: step_fn's own finish path frees the rest
        for _ in range(8):
            run([reqs[1], reqs[2]])
        assert dec.active_rids() == []
        if dec.paged:
            assert dec.pages.in_use == 0

    def test_truncated_flag_reaches_request(self, setup):
        """make_pff_step_fn surfaces the decoder's clip onto the Request,
        which the scheduler copies into its RequestRecord."""
        from repro.cluster.scheduler import Request

        cfg, params = setup

        class _Tok:
            def encode(self, text):
                return list(text)

        class _Tpl:
            def render(self, claim):
                return claim

        class _Eng:
            def __init__(self):
                self.cfg, self.params = cfg, params

        payloads = {"xla_executable": _Eng(),
                    "context_inputs": {"tokenizer": _Tok(),
                                       "template": _Tpl()}}
        step_fn = make_pff_step_fn(prompt_len=8, max_len=32)
        long_req = Request(recipe_key="k", decode_steps=4,
                           payload=[4] * 50)       # 50 > prompt_len=8
        short_req = Request(recipe_key="k", decode_steps=4,
                            payload=[4] * 6)
        step_fn(payloads, [long_req, short_req])
        assert long_req.truncated is True
        assert short_req.truncated is False
