"""Per-architecture smoke tests: reduced variant, one forward/train step on
CPU, output shapes + no NaNs; prefill+decode consistency with the full
forward (the strongest cheap correctness check a serving stack has)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (ARCH_REGISTRY, ASSIGNED_ARCHS, get_config,
                           get_smoke_config)
from repro.models import model as M
from repro.optim import adamw_init
from repro.launch.steps import make_train_step

ALL_ARCHS = ASSIGNED_ARCHS + ["smollm2-1.7b"]


def _batch(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(
        rng.integers(4, cfg.vocab_size, (B, S)), jnp.int32)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_vision_patches, 1024)), cfg.dtype)
    if cfg.is_encdec:
        batch["audio_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_audio_frames, cfg.d_model)), cfg.dtype)
    return batch


@pytest.fixture(scope="module")
def smoke(request):
    return None


@pytest.mark.parametrize("arch", ALL_ARCHS)
class TestArchSmoke:
    def test_forward_shapes_no_nans(self, arch):
        cfg = get_smoke_config(arch)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        batch = _batch(cfg)
        logits = M.forward(cfg, params, batch)
        B, S = batch["tokens"].shape
        n_prefix = cfg.n_vision_patches if cfg.family == "vlm" else 0
        assert logits.shape == (B, S + n_prefix, cfg.vocab_size)
        assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())

    def test_train_step(self, arch):
        cfg = get_smoke_config(arch)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw_init(params)
        step = make_train_step(cfg)
        p2, opt2, metrics = step(params, opt, _batch(cfg))
        assert np.isfinite(float(metrics["loss"]))
        assert int(opt2.step) == 1
        # params actually moved
        moved = jax.tree_util.tree_map(
            lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                       - b.astype(jnp.float32)).max()),
            params, p2)
        assert max(jax.tree_util.tree_leaves(moved)) > 0

    def test_prefill_decode_consistency(self, arch):
        """prefill(S)+decode(t) logits == forward(S+t) last-token logits."""
        cfg = get_smoke_config(arch).with_(dtype="float32")
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        B, S, EXTRA = 2, 12, 3
        batch = _batch(cfg, B=B, S=S + EXTRA, seed=1)
        full_logits = M.forward(cfg, params, batch)

        n_prefix = cfg.n_vision_patches if cfg.family == "vlm" else 0
        pre = {k: (v[:, :S] if k == "tokens" else v)
               for k, v in batch.items()}
        logits_p, cache = M.prefill(cfg, params, pre,
                                    max_len=n_prefix + S + EXTRA + 4)
        np.testing.assert_allclose(
            np.asarray(logits_p[:, -1]),
            np.asarray(full_logits[:, -1 - EXTRA]), rtol=2e-3, atol=2e-3)
        logits_d = logits_p
        for t in range(EXTRA):
            logits_d, cache = M.decode_step(
                cfg, params, cache, batch["tokens"][:, S + t:S + t + 1])
            np.testing.assert_allclose(
                np.asarray(logits_d[:, -1]),
                np.asarray(full_logits[:, S + t
                                       + (cfg.n_vision_patches
                                          if cfg.family == "vlm" else 0)]),
                rtol=2e-3, atol=2e-3,
                err_msg=f"{arch}: decode step {t} diverges from forward")


def test_registry_complete():
    assert set(ASSIGNED_ARCHS) <= set(ARCH_REGISTRY)
    assert len(ASSIGNED_ARCHS) == 10


@pytest.mark.parametrize("arch,nl,dm,nh,nkv,dff,vocab", [
    ("llava-next-34b", 60, 7168, 56, 8, 20480, 64000),
    ("granite-3-8b", 40, 4096, 32, 8, 12800, 49155),
    ("llama3-405b", 126, 16384, 128, 8, 53248, 128256),
    ("qwen3-1.7b", 28, 2048, 16, 8, 6144, 151936),
    ("hymba-1.5b", 32, 1600, 25, 5, 5504, 32001),
    ("xlstm-350m", 24, 1024, 4, 4, 0, 50304),
    ("whisper-small", 12, 768, 12, 12, 3072, 51865),
    ("phi3.5-moe-42b-a6.6b", 32, 4096, 32, 8, 6400, 32064),
    # deepseek: the assigned d_ff=2048 is the EXPERT hidden dim (checked in
    # test_arch_specific_features); cfg.d_ff=18432 is the dense-head dim
    ("deepseek-v3-671b", 61, 7168, 128, 128, 18432, 129280),
    ("olmo-1b", 16, 2048, 16, 16, 8192, 50304),
])
def test_assigned_dims_exact(arch, nl, dm, nh, nkv, dff, vocab):
    cfg = get_config(arch)
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab_size) == (nl, dm, nh, nkv, dff, vocab)
    assert cfg.source, f"{arch} must cite its source"


def test_arch_specific_features():
    assert get_config("qwen3-1.7b").qk_norm
    assert get_config("olmo-1b").nonparametric_norm
    assert get_config("deepseek-v3-671b").mla is not None
    ds = get_config("deepseek-v3-671b").moe
    assert ds.n_experts == 256 and ds.top_k == 8 and ds.n_shared_experts == 1
    assert ds.d_ff_expert == 2048          # the assigned d_ff
    phi = get_config("phi3.5-moe-42b-a6.6b").moe
    assert phi.n_experts == 16 and phi.top_k == 2
    assert get_config("hymba-1.5b").hybrid_parallel_heads
    assert get_config("xlstm-350m").block_pattern
    assert get_config("whisper-small").is_encdec
    assert get_config("llava-next-34b").n_vision_patches > 0


def test_smoke_variant_bounds():
    for arch in ALL_ARCHS:
        s = get_smoke_config(arch)
        assert s.n_layers <= 2 or s.block_pattern
        assert s.d_model <= 512
        if s.moe:
            assert s.moe.n_experts <= 4


def test_param_counts_plausible():
    """n_params() within 20% of the published totals."""
    expect = {"llama3-405b": 405e9, "deepseek-v3-671b": 671e9,
              "granite-3-8b": 8e9, "qwen3-1.7b": 1.7e9, "olmo-1b": 1.1e9,
              "phi3.5-moe-42b-a6.6b": 42e9}
    for arch, n in expect.items():
        got = get_config(arch).n_params()
        assert abs(got - n) / n < 0.25, f"{arch}: {got/1e9:.1f}B vs {n/1e9}B"
    active = get_config("phi3.5-moe-42b-a6.6b").n_active_params()
    assert abs(active - 6.6e9) / 6.6e9 < 0.3


def test_int8_kv_cache_decode_close():
    """§Perf G5: int8 cache halves decode memory; logits stay argmax-true."""
    import jax
    import jax.numpy as jnp

    cfg = get_smoke_config("granite-3-8b").with_(dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(4, cfg.vocab_size, (2, 20)), jnp.int32)
    pre = {"tokens": toks[:, :16]}
    _, cache = M.prefill(cfg, params, pre, max_len=24)
    cfg8 = cfg.with_(kv_cache_dtype="int8")
    _, cache8 = M.prefill(cfg8, params, pre, max_len=24)
    assert cache8["stages"][0]["k"].dtype == jnp.int8
    for t in range(3):
        ld, cache = M.decode_step(cfg, params, cache, toks[:, 16 + t:17 + t])
        ld8, cache8 = M.decode_step(cfg8, params, cache8,
                                    toks[:, 16 + t:17 + t])
        assert float(jnp.abs(ld - ld8).max()) < 0.05
        assert bool((jnp.argmax(ld, -1) == jnp.argmax(ld8, -1)).all())
