"""Property test: work is conserved under random fault interleavings.

Whatever sequence of crash / revoke / hang / transfer faults hits the
pool — at any times, any sizes, under any checkpoint cadence — the run
must drain to the same completed work with exact accounting:

* every submitted request completes exactly once (no loss, no dupes);
* no plane op is left in flight and the planned/moved byte meters agree
  (checkpoints, retries and refunds included);
* no batch-slot residue on any surviving worker.

Requires ``hypothesis`` (requirements-dev.txt); skipped when absent so
the tier-1 suite stays runnable on the bare image.
"""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import WarmPoolPolicy                     # noqa: E402
from repro.cluster import (Application, FailureDetector,  # noqa: E402
                           FaultInjector, make_sim)
from repro.cluster.traces import FAULT_KINDS, Fault       # noqa: E402

from test_forecast import A10, AP, RECIPE                 # noqa: E402

N_REQUESTS = 12
LEASE_S = 10.0

fault_events = st.lists(
    st.tuples(st.floats(min_value=1.0, max_value=120.0,
                        allow_nan=False, allow_infinity=False),
              st.sampled_from(FAULT_KINDS),
              st.integers(min_value=1, max_value=3)),
    min_size=0, max_size=6)


@given(spec=fault_events,
       ckpt_every=st.sampled_from([None, 4, 16]),
       seed=st.integers(min_value=0, max_value=7))
@settings(max_examples=20, deadline=None)
def test_work_conserved_under_random_faults(spec, ckpt_every, seed):
    # replacement supply every 15 s so even a full-pool wipe recovers
    trace = [(15.0 * i, 6) for i in range(200)]
    sched, ex, fac = make_sim(devices=[A10] * 4, trace=trace,
                              workers_per_zone=2,
                              warm_pool=WarmPoolPolicy(),
                              ckpt_every_steps=ckpt_every,
                              retry_seed=seed)
    app = Application(sched)
    key = app.register(RECIPE, active_params=AP)
    app.submit_stream(ex, [dict(recipe_key=key, decode_steps=16,
                                arrival_s=i * 0.5)
                           for i in range(N_REQUESTS)])
    det = FailureDetector(ex, lease_s=LEASE_S)
    inj = FaultInjector(ex, [Fault(t, kind, n) for t, kind, n in spec],
                        detector=det, seed=seed)
    inj.arm()
    ex.run()
    # ex.run() stops the instant the last request completes; a warm-pool
    # replication (or its post-fault retry) may legitimately still be in
    # flight at that instant.  The zero-leak invariant is a property of
    # the DRAINED loop, so run the remaining events to exhaustion first.
    ex.loop.run()

    # conservation: every request exactly one completion record
    assert sched.done, "run failed to drain after the fault sequence"
    rids = [rec.request_id for rec in sched.records]
    assert len(rids) == len(set(rids)), "a request completed twice"
    assert len(rids) == N_REQUESTS, \
        f"lost work: {N_REQUESTS - len(rids)} request(s) never completed"
    assert not sched.running
    assert all(not lane for lane in sched.lanes.values())

    # exact accounting: no leaked ops, planned == moved (ckpts included)
    plane = sched.plane
    assert plane.inflight_ops == 0, \
        f"{plane.inflight_ops} plane op(s) leaked"
    assert plane.planned.as_dict() == plane.moved.as_dict(), \
        "planned/moved byte meters diverged under faults"

    # no slot residue on any surviving worker
    for w in sched.workers.values():
        for lib in w.libraries.values():
            assert not lib.batch, f"slot leak on {w.worker_id}"

    # every detected failure was attributed and bounded
    for wid, cause, t_fault, t_detect in det.detection_log:
        bound = LEASE_S if cause == "crash" else det.watchdog_s
        assert t_detect - t_fault <= bound + 1e-9, \
            f"{cause} on {wid} detected too late"


@given(seed=st.integers(min_value=0, max_value=31))
@settings(max_examples=10, deadline=None)
def test_injector_replay_is_deterministic(seed):
    """Same seed + same schedule => identical victim sequence."""
    logs = []
    for _ in range(2):
        sched, ex, fac = make_sim(devices=[A10] * 4,
                                  trace=[(15.0 * i, 6) for i in range(40)],
                                  workers_per_zone=2,
                                  warm_pool=WarmPoolPolicy(),
                                  ckpt_every_steps=8, retry_seed=seed)
        app = Application(sched)
        key = app.register(RECIPE, active_params=AP)
        app.submit_stream(ex, [dict(recipe_key=key, decode_steps=16,
                                    arrival_s=i * 0.5)
                               for i in range(N_REQUESTS)])
        det = FailureDetector(ex, lease_s=LEASE_S)
        inj = FaultInjector(ex, [Fault(20.0, "crash", 2),
                                 Fault(45.0, "revoke", 1)],
                            detector=det, seed=seed)
        inj.arm()
        ex.run()
        # worker ids come from a process-global counter, so two sims
        # name the "same" worker differently: normalize by order of
        # first appearance before comparing the kill sequences
        order = {}
        norm = [(t, order.setdefault(wid, len(order)), cause)
                for t, wid, cause in sched.failure_log]
        logs.append((inj.fault_log, norm, sched.completed_inferences))
    assert logs[0] == logs[1], "seeded fault replay diverged"
