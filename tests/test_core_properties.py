"""Hypothesis property tests on the system's invariants."""
import math

from hypothesis import given, settings, strategies as st

from repro.core import (ContextCache, ContextElement, Peer, Tier,
                        CacheFullError, plan_spanning_tree,
                        expected_task_time, eviction_loss, PERVASIVE,
                        PARTIAL, NAIVE)


# ---------------------------------------------------------------------------
# Cache invariants
# ---------------------------------------------------------------------------

ops = st.lists(
    st.tuples(st.integers(0, 9),                     # element id
              st.sampled_from(list(Tier)),           # target tier
              st.booleans()),                        # pinned
    min_size=1, max_size=40)


@given(ops)
@settings(max_examples=200, deadline=None)
def test_cache_capacity_never_exceeded(op_list):
    cap = dict(disk_bytes=5_000, host_bytes=3_000, device_bytes=1_500)
    c = ContextCache(**cap)
    elements = {i: ContextElement(f"e{i}", nbytes_disk=(i + 1) * 100,
                                  nbytes_host=(i + 1) * 150,
                                  nbytes_device=(i + 1) * 50 if i % 2 else 0)
                for i in range(10)}
    for i, tier, pinned in op_list:
        try:
            c.put(elements[i], tier, pinned=pinned)
        except CacheFullError:
            pass
        for t, limit in zip(Tier, (cap["disk_bytes"], cap["host_bytes"],
                                   cap["device_bytes"])):
            assert c.used(t) <= limit, f"{t} over capacity"


@given(ops)
@settings(max_examples=100, deadline=None)
def test_cache_used_equals_sum_of_entries(op_list):
    c = ContextCache(disk_bytes=10_000, host_bytes=8_000, device_bytes=4_000)
    elements = {i: ContextElement(f"e{i}", nbytes_disk=(i + 1) * 100,
                                  nbytes_host=(i + 1) * 120,
                                  nbytes_device=(i + 1) * 60 if i % 2 else 0)
                for i in range(10)}
    resident = {}
    for i, tier, pinned in op_list:
        try:
            c.put(elements[i], tier, pinned=pinned)
        except CacheFullError:
            continue
        resident[elements[i].key] = (elements[i], tier)
        # drop anything the cache evicted
        resident = {k: v for k, v in resident.items() if k in c.keys()}
        # entries may have been demoted — re-read tiers from the cache
        for t in Tier:
            expect = sum(e.nbytes(t) for k, (e, _) in resident.items()
                         if t.order <= c.tier_of(k).order)
            assert c.used(t) == expect


# ---------------------------------------------------------------------------
# Spanning-tree transfer invariants
# ---------------------------------------------------------------------------

peers = st.lists(st.tuples(st.integers(0, 3)), min_size=1, max_size=24)


@given(n_targets=st.integers(1, 24), n_zones=st.integers(1, 4),
       fanout=st.integers(1, 5), nbytes=st.integers(1, 10**9))
@settings(max_examples=150, deadline=None)
def test_spanning_tree_properties(n_targets, n_zones, fanout, nbytes):
    src = Peer("src", zone="z0")
    targets = [Peer(f"t{i}", zone=f"z{i % n_zones}")
               for i in range(n_targets)]
    plan = plan_spanning_tree(nbytes, [src], targets, fanout_cap=fanout)
    # every target receives exactly once
    dsts = [e.dst for e in plan.edges]
    assert sorted(dsts) == sorted(t.worker_id for t in targets)
    # a node only sends after it has received
    recv_time = {"src": 0.0}
    for e in sorted(plan.edges, key=lambda e: e.start_s):
        assert e.src in recv_time, "sender had not received the context"
        assert e.start_s >= recv_time[e.src] - 1e-9
        recv_time[e.dst] = e.end_s
    # topology-aware: at most one cross-zone edge per zone needing seeding
    zones_without_source = {t.zone for t in targets} - {"z0"}
    assert plan.cross_zone_edges <= max(len(zones_without_source), 0) + 1
    # makespan grows at most logarithmically-ish: bounded by serial chain
    per_edge = nbytes / Peer("x").bw_cross
    assert plan.makespan_s <= (n_targets + n_zones) * per_edge + 1e-6


@given(st.integers(1, 40), st.integers(1, 4))
@settings(max_examples=50, deadline=None)
def test_spanning_tree_beats_star_for_many_targets(n, fanout):
    """Tree makespan ≤ single-source star topology (the scheduler-push
    baseline the paper's peer transfer replaces)."""
    src = Peer("src", zone="z0")
    targets = [Peer(f"t{i}", zone="z0") for i in range(n)]
    nbytes = 10**9
    tree = plan_spanning_tree(nbytes, [src], targets, fanout_cap=fanout)
    star_makespan = n * nbytes / src.bw_local / fanout
    assert tree.makespan_s <= star_makespan + nbytes / src.bw_local + 1e-6


# ---------------------------------------------------------------------------
# Policy model properties
# ---------------------------------------------------------------------------

@given(batch=st.integers(1, 10_000), infer=st.floats(0.01, 2.0),
       init=st.floats(1.0, 300.0))
@settings(max_examples=100, deadline=None)
def test_mode_ordering(batch, infer, init):
    """pervasive ≤ partial ≤ naive for any warm task."""
    t_perv = expected_task_time(batch, infer_s=infer, init_s=init,
                                mode=PERVASIVE, warm=True)
    t_part = expected_task_time(batch, infer_s=infer, init_s=init,
                                mode=PARTIAL, warm=True)
    t_naive = expected_task_time(batch, infer_s=infer, init_s=init,
                                 mode=NAIVE, warm=True)
    assert t_perv <= t_part <= t_naive
    # cold start is identical-ish across modes (everyone stages once)
    c_perv = expected_task_time(batch, infer_s=infer, init_s=init,
                                mode=PERVASIVE, warm=False)
    assert c_perv >= t_perv


@given(b1=st.integers(1, 5_000), b2=st.integers(1, 5_000),
       rate=st.floats(1e-5, 1e-2))
@settings(max_examples=100, deadline=None)
def test_eviction_loss_monotone_in_batch(b1, b2, rate):
    lo, hi = sorted((b1, b2))
    l_lo = eviction_loss(lo, infer_s=0.3, evict_rate_per_s=rate)
    l_hi = eviction_loss(hi, infer_s=0.3, evict_rate_per_s=rate)
    assert l_lo <= l_hi + 1e-9
    assert 0 <= l_lo <= lo and 0 <= l_hi <= hi
