"""Hypothesis property tests on the system's invariants."""
import math

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import (ContextCache, ContextElement, Peer, Tier,
                        CacheFullError, plan_spanning_tree,
                        expected_task_time, eviction_loss, PERVASIVE,
                        PARTIAL, NAIVE)


# ---------------------------------------------------------------------------
# Cache invariants
# ---------------------------------------------------------------------------

ops = st.lists(
    st.tuples(st.integers(0, 9),                     # element id
              st.sampled_from(list(Tier)),           # target tier
              st.booleans()),                        # pinned
    min_size=1, max_size=40)


@given(ops)
@settings(max_examples=200, deadline=None)
def test_cache_capacity_never_exceeded(op_list):
    cap = dict(disk_bytes=5_000, host_bytes=3_000, device_bytes=1_500)
    c = ContextCache(**cap)
    elements = {i: ContextElement(f"e{i}", nbytes_disk=(i + 1) * 100,
                                  nbytes_host=(i + 1) * 150,
                                  nbytes_device=(i + 1) * 50 if i % 2 else 0)
                for i in range(10)}
    for i, tier, pinned in op_list:
        try:
            c.put(elements[i], tier, pinned=pinned)
        except CacheFullError:
            pass
        for t, limit in zip(Tier, (cap["disk_bytes"], cap["host_bytes"],
                                   cap["device_bytes"])):
            assert c.used(t) <= limit, f"{t} over capacity"


@given(ops)
@settings(max_examples=100, deadline=None)
def test_cache_used_equals_sum_of_entries(op_list):
    c = ContextCache(disk_bytes=10_000, host_bytes=8_000, device_bytes=4_000)
    elements = {i: ContextElement(f"e{i}", nbytes_disk=(i + 1) * 100,
                                  nbytes_host=(i + 1) * 120,
                                  nbytes_device=(i + 1) * 60 if i % 2 else 0)
                for i in range(10)}
    resident = {}
    for i, tier, pinned in op_list:
        try:
            c.put(elements[i], tier, pinned=pinned)
        except CacheFullError:
            continue
        resident[elements[i].key] = (elements[i], tier)
        # drop anything the cache evicted
        resident = {k: v for k, v in resident.items() if k in c.keys()}
        # entries may have been demoted — re-read tiers from the cache
        for t in Tier:
            expect = sum(e.nbytes(t) for k, (e, _) in resident.items()
                         if t.order <= c.tier_of(k).order)
            assert c.used(t) == expect


# ---------------------------------------------------------------------------
# Demotion (spill) invariants: byte accounting and pins under tier moves
# ---------------------------------------------------------------------------

spill_ops = st.lists(
    st.tuples(st.integers(0, 9),
              st.sampled_from(["put_disk", "put_host", "put_dev",
                               "put_pinned", "pin", "unpin",
                               "demote", "demote_disk"])),
    min_size=1, max_size=60)


def _manual_used(cache, elements, tier):
    total = 0
    for e in elements.values():
        t = cache.tier_of(e.key)
        if t is not None and tier.order <= t.order:
            total += e.nbytes(tier)
    return total


@given(spill_ops)
@settings(max_examples=200, deadline=None)
def test_demotion_accounting_and_pin_invariants(op_list):
    cap = dict(disk_bytes=50_000, host_bytes=40_000, device_bytes=20_000)
    c = ContextCache(**cap)
    elements = {i: ContextElement(f"e{i}", nbytes_disk=(i + 1) * 100,
                                  nbytes_host=(i + 1) * 150,
                                  nbytes_device=(i + 1) * 50 if i % 2 else 0)
                for i in range(10)}
    pins = {e.key: 0 for e in elements.values()}     # shadow pin ledger
    for i, op in op_list:
        e = elements[i]
        resident = c.tier_of(e.key) is not None
        if op.startswith("put"):
            tier = {"put_disk": Tier.DISK, "put_host": Tier.HOST,
                    "put_dev": Tier.DEVICE, "put_pinned": Tier.HOST}[op]
            try:
                c.put(e, tier, pinned=(op == "put_pinned"))
                if op == "put_pinned":
                    pins[e.key] += 1
            except CacheFullError:
                pass
        elif op == "pin" and resident:
            c.pin(e.key, True)
            pins[e.key] += 1
        elif op == "unpin" and resident:
            c.pin(e.key, False)
            pins[e.key] = max(0, pins[e.key] - 1)
        elif op.startswith("demote") and resident:
            before = c.tier_of(e.key)
            target = Tier.DISK if op == "demote_disk" else None
            if c.pins(e.key) > 0:
                # pinned entries must refuse to move
                try:
                    c.demote(e.key, target)
                    assert False, "demote must raise on a pinned entry"
                except ValueError:
                    assert c.tier_of(e.key) is before
            else:
                after = c.demote(e.key, target)
                assert after.order <= before.order
                assert c.tier_of(e.key) is after
        # resync the shadow ledger with cache-side evictions
        pins = {k: (v if k in c.keys() else 0) for k, v in pins.items()}
        # invariants after EVERY op
        for t, limit in zip(Tier, (cap["disk_bytes"], cap["host_bytes"],
                                   cap["device_bytes"])):
            assert c.used(t) <= limit, f"{t} over capacity"
            assert c.used(t) == _manual_used(c, elements, t), \
                f"{t} accounting drifted"
        for k, v in pins.items():
            assert c.pins(k) == v
            if v > 0:
                assert k in c.keys(), "pinned entry was evicted"


def test_spilled_bytes_freed_above_target_tier():
    """After demoting an unpinned DEVICE-resident entry, its DEVICE (and
    HOST, for a disk spill) bytes are released but the DISK copy stays."""
    c = ContextCache(disk_bytes=10**6, host_bytes=10**6, device_bytes=10**6)
    e = ContextElement("w", nbytes_disk=1_000, nbytes_host=2_000,
                       nbytes_device=1_500)
    c.put(e, Tier.DEVICE)
    assert (c.used(Tier.DEVICE), c.used(Tier.HOST), c.used(Tier.DISK)) == \
        (1_500, 2_000, 1_000)
    c.demote(e.key)                  # one level: DEVICE -> HOST
    assert (c.used(Tier.DEVICE), c.used(Tier.HOST), c.used(Tier.DISK)) == \
        (0, 2_000, 1_000)
    c.demote(e.key, Tier.DISK)
    assert (c.used(Tier.DEVICE), c.used(Tier.HOST), c.used(Tier.DISK)) == \
        (0, 0, 1_000)
    assert c.tier_of(e.key) is Tier.DISK


# ---------------------------------------------------------------------------
# Spanning-tree transfer invariants
# ---------------------------------------------------------------------------

peers = st.lists(st.tuples(st.integers(0, 3)), min_size=1, max_size=24)


@given(n_targets=st.integers(1, 24), n_zones=st.integers(1, 4),
       fanout=st.integers(1, 5), nbytes=st.integers(1, 10**9))
@settings(max_examples=150, deadline=None)
def test_spanning_tree_properties(n_targets, n_zones, fanout, nbytes):
    src = Peer("src", zone="z0")
    targets = [Peer(f"t{i}", zone=f"z{i % n_zones}")
               for i in range(n_targets)]
    plan = plan_spanning_tree(nbytes, [src], targets, fanout_cap=fanout)
    # every target receives exactly once
    dsts = [e.dst for e in plan.edges]
    assert sorted(dsts) == sorted(t.worker_id for t in targets)
    # a node only sends after it has received
    recv_time = {"src": 0.0}
    for e in sorted(plan.edges, key=lambda e: e.start_s):
        assert e.src in recv_time, "sender had not received the context"
        assert e.start_s >= recv_time[e.src] - 1e-9
        recv_time[e.dst] = e.end_s
    # topology-aware: at most one cross-zone edge per zone needing seeding
    zones_without_source = {t.zone for t in targets} - {"z0"}
    assert plan.cross_zone_edges <= max(len(zones_without_source), 0) + 1
    # makespan grows at most logarithmically-ish: bounded by serial chain
    per_edge = nbytes / Peer("x").bw_cross
    assert plan.makespan_s <= (n_targets + n_zones) * per_edge + 1e-6


@given(st.integers(1, 40), st.integers(1, 4))
@settings(max_examples=50, deadline=None)
def test_spanning_tree_beats_star_for_many_targets(n, fanout):
    """Tree makespan ≤ single-source star topology (the scheduler-push
    baseline the paper's peer transfer replaces)."""
    src = Peer("src", zone="z0")
    targets = [Peer(f"t{i}", zone="z0") for i in range(n)]
    nbytes = 10**9
    tree = plan_spanning_tree(nbytes, [src], targets, fanout_cap=fanout)
    star_makespan = n * nbytes / src.bw_local / fanout
    assert tree.makespan_s <= star_makespan + nbytes / src.bw_local + 1e-6


# ---------------------------------------------------------------------------
# Policy model properties
# ---------------------------------------------------------------------------

@given(batch=st.integers(1, 10_000), infer=st.floats(0.01, 2.0),
       init=st.floats(1.0, 300.0))
@settings(max_examples=100, deadline=None)
def test_mode_ordering(batch, infer, init):
    """pervasive ≤ partial ≤ naive for any warm task."""
    t_perv = expected_task_time(batch, infer_s=infer, init_s=init,
                                mode=PERVASIVE, warm=True)
    t_part = expected_task_time(batch, infer_s=infer, init_s=init,
                                mode=PARTIAL, warm=True)
    t_naive = expected_task_time(batch, infer_s=infer, init_s=init,
                                 mode=NAIVE, warm=True)
    assert t_perv <= t_part <= t_naive
    # cold start is identical-ish across modes (everyone stages once)
    c_perv = expected_task_time(batch, infer_s=infer, init_s=init,
                                mode=PERVASIVE, warm=False)
    assert c_perv >= t_perv


@given(b1=st.integers(1, 5_000), b2=st.integers(1, 5_000),
       rate=st.floats(1e-5, 1e-2))
@settings(max_examples=100, deadline=None)
def test_eviction_loss_monotone_in_batch(b1, b2, rate):
    lo, hi = sorted((b1, b2))
    l_lo = eviction_loss(lo, infer_s=0.3, evict_rate_per_s=rate)
    l_hi = eviction_loss(hi, infer_s=0.3, evict_rate_per_s=rate)
    assert l_lo <= l_hi + 1e-9
    assert 0 <= l_lo <= lo and 0 <= l_hi <= hi
