"""Request-stream API + continuous batching: invariants and satellites.

Covers the redesign's contract: slot budgets are never exceeded, no
request starves past the aging bound, eviction mid-batch requeues only
unfinished requests, sim and live executors agree on completed-work
accounting, aging_bound="auto" derives from observed service times, and
the factory's default eviction priority is spill-aware.
"""
import dataclasses

import pytest

from repro.core import (AGING_BOUND_DEFAULT, ContextElement, ContextRecipe,
                        PERVASIVE, Tier, derive_aging_bound)
from repro.cluster import (Application, GPU_CATALOG, LiveExecutor, Request,
                           Scheduler, SimExecutor, Worker, latency_summary,
                           make_sim)
from repro.configs import get_config

from benchmarks.common import BIG_AP, BIG_RECIPE, MIXED_SHAPE

CFG = get_config("smollm2-1.7b")
AP = CFG.n_active_params()
from repro.core import model_context_recipe
RECIPE = model_context_recipe(CFG, include_compile=False)

A10 = GPU_CATALOG["NVIDIA A10"]


def tiny_live_recipe(name="stream::tiny"):
    """A context whose loaders really run but cost nothing (live tests)."""
    return ContextRecipe(name, (
        ContextElement("deps", nbytes_disk=1000, nbytes_host=100,
                       version="t", loader=lambda: {"ok": True}),
        ContextElement("weights", nbytes_disk=1000, nbytes_host=100,
                       version="t", loader=lambda: object()),
    ))


class TestRequestModel:
    def test_task_shim_is_exclusive_request(self):
        from repro.cluster.scheduler import Task
        with pytest.warns(DeprecationWarning):
            t = Task("k", 25, PERVASIVE, payload="p")
        assert isinstance(t, Request)
        assert t.exclusive and t.n_inferences == 25 and t.n_units == 25
        assert t.task_id == t.request_id

    def test_submit_sweep_expands_to_exclusive_requests(self):
        sched = Scheduler()
        key = sched.register_context(RECIPE)
        with pytest.warns(DeprecationWarning):
            n = sched.submit_sweep(key, 1_000, 300, PERVASIVE)
        assert n == 4
        q = sched.queue
        assert [r.n_units for r in q] == [300, 300, 300, 100]
        assert all(r.exclusive for r in q)

    def test_prompt_units_count_as_work(self):
        r = Request("k", decode_steps=8, prompt_units=2)
        assert r.n_units == 10

    def test_bad_aging_bound_rejected(self):
        with pytest.raises(ValueError):
            Scheduler(aging_bound=3.5)

    def test_stream_requests_must_be_state_resident(self):
        from repro.core import PARTIAL
        sched = Scheduler()
        key = sched.register_context(RECIPE)
        with pytest.raises(ValueError, match="state-resident"):
            sched.submit(Request(key, decode_steps=4, mode=PARTIAL))
        # the run-to-completion baseline path still accepts any mode
        sched.submit(Request(key, decode_steps=4, mode=PARTIAL,
                             exclusive=True))

    def test_joiner_never_activates_before_admission(self):
        """Regression: a request admitted at time t must not be credited
        with decode steps at lazily settled boundaries before t."""
        sched, ex, fac = make_sim(devices=[A10])
        app = Application(sched)
        key = app.register(RECIPE, active_params=AP)
        app.submit_stream(ex, [
            dict(recipe_key=key, decode_steps=400, arrival_s=0.0),
            dict(recipe_key=key, decode_steps=50, arrival_s=60.0),
        ])
        fac.reconcile(1)
        ex.run()
        recs = sorted(app.records(), key=lambda r: r.request_id)
        late = recs[1]
        assert late.joined
        assert late.ttfs_s >= 0, "first step cannot predate arrival"
        # 50 steps at the 2-member rate cannot finish faster than the
        # batch-2 step time allows
        step2 = A10.step_time(AP, 2)
        assert late.t_end - late.t_arrival >= 50 * step2 - 1.0
        assert sched.completed_inferences == 450


class TestSlotBudget:
    def test_budget_from_hardware_catalog(self):
        w = Worker(A10)
        lib = w.library_for(RECIPE)
        budget = lib.slot_budget(w.device_bytes, AP)
        titan = Worker(GPU_CATALOG["NVIDIA TITAN X (Pascal)"])
        budget_titan = titan.library_for(RECIPE).slot_budget(
            titan.device_bytes, AP)
        assert budget > budget_titan > 0, \
            "slot budgets must track device memory"

    def test_explicit_slot_bytes_override(self):
        r = dataclasses.replace(RECIPE, fn_name="infer::fat-kv",
                                slot_bytes=5_000_000_000)
        w = Worker(A10)
        lib = w.library_for(r)
        assert lib.slot_budget(w.device_bytes, AP) == 4

    def test_budget_derated_by_co_resident_libraries(self):
        """A multi-context worker must not hand a stream the device
        bytes its co-resident libraries occupy."""
        w = Worker(A10, shape=MIXED_SHAPE)       # 24 GB device
        # big recipe resident on device: 16 GB of the 24 are taken
        lib_big = w.library_for(BIG_RECIPE)
        lib_big.materialize_cost(w.device, fetch_bw=float("inf"))
        lib_small = w.library_for(RECIPE)
        alone = lib_small.slot_budget(w.device_bytes, AP)
        shared = w.slot_budget(RECIPE.key, AP)
        assert shared < alone, \
            "co-resident device bytes must shrink the slot budget"

    def test_slot_budget_never_exceeded_during_run(self):
        """Invariant: at EVERY event, every dynamic batch fits its
        budget (checked stepwise through the DES)."""
        fat = dataclasses.replace(RECIPE, fn_name="infer::fat-kv",
                                  slot_bytes=5_000_000_000)   # 4 slots/A10
        sched, ex, fac = make_sim(devices=[A10] * 2)
        app = Application(sched)
        key = app.register(fat, active_params=AP)
        specs = [dict(recipe_key=key, decode_steps=3 + (i % 7),
                      arrival_s=0.05 * i) for i in range(60)]
        app.submit_stream(ex, specs)
        fac.reconcile(2)
        ex.pump()
        while ex.loop.step():
            for w in sched.workers.values():
                for lib in w.libraries.values():
                    assert len(lib.batch) <= lib.slot_budget(
                        w.device_bytes, AP)
        assert sched.completed_inferences == sum(
            s["decode_steps"] for s in specs)
        assert sched.admissions > 0

    def test_membership_changes_between_steps(self):
        """A request admitted mid-flight joins the SAME batch (no new
        cold start) and both finish."""
        sched, ex, fac = make_sim(devices=[A10])
        app = Application(sched)
        key = app.register(RECIPE, active_params=AP)
        app.submit_stream(ex, [
            dict(recipe_key=key, decode_steps=200, arrival_s=0.0),
            dict(recipe_key=key, decode_steps=10, arrival_s=40.0),
        ])
        fac.reconcile(1)
        ex.run()
        recs = sorted(app.records(), key=lambda r: r.request_id)
        assert len(recs) == 2
        assert recs[1].joined and recs[1].warm, \
            "the late request must be admitted, not cold-started"
        assert sched.completed_inferences == 210
        # joining mid-batch: its first step lands shortly after arrival,
        # not after the long request's 200 steps
        assert recs[1].ttfs_s < 30.0


class TestConcurrentWorker:
    def test_never_founds_second_batch_for_same_recipe(self):
        """A concurrency-2 worker stays idle-capable while its stream
        batch runs; later requests must JOIN that batch, not found a
        second one on the same library."""
        from repro.core import WorkerShape
        shape = WorkerShape(cores=4, memory_gb=10, disk_gb=70, gpus=2,
                            concurrency=2)
        sched, ex, fac = make_sim(devices=[A10], worker_shape=shape)
        app = Application(sched)
        key = app.register(RECIPE, active_params=AP)
        for _ in range(6):
            app.submit(key, decode_steps=12)
        fac.reconcile(1)
        ex.run()
        assert sched.completed_inferences == 72
        assert len(sched.records) == 6
        assert sum(1 for r in sched.records if not r.joined) == 1, \
            "exactly one founding member"


class TestNoStarvation:
    def _stream_world(self, aging_bound=2):
        sched = Scheduler(aging_bound=aging_bound)
        k_small = sched.register_context(RECIPE)
        k_big = sched.register_context(BIG_RECIPE)
        w = Worker(A10, shape=MIXED_SHAPE)
        sched.add_worker(w)
        return sched, k_small, k_big, w

    def test_aged_head_blocks_further_admissions(self):
        """A starved exclusive head reserves even a NEVER-IDLE stream
        worker: younger stream requests stop being admitted once the
        head ages out, so the batch drains and the head lands."""
        sched, k_small, k_big, w = self._stream_world(aging_bound=2)
        # founding stream member, materialised and decoding
        r0 = Request(k_small, decode_steps=100, active_params=AP)
        sched.submit(r0)
        a0 = sched.route()
        assert a0 is not None and not a0.join
        sched.on_start(a0)
        w.libraries[k_small].materialize_cost(w.device,
                                              fetch_bw=float("inf"))
        sched.on_staged(a0)
        # an exclusive big request that cannot place (worker busy)
        big = Request(k_big, decode_steps=10, active_params=BIG_AP,
                      exclusive=True)
        sched.submit(big)
        # younger stream requests keep arriving and joining...
        joined = 0
        for i in range(5):
            sched.submit(Request(k_small, decode_steps=10,
                                 active_params=AP))
            a = sched.route()
            if a is None:
                break
            assert a.join
            sched.on_start(a)
            joined += 1
        # ...until the big head hit its bound and reserved the worker
        assert joined == sched.aging_bound == big.skipped
        assert sched.route() is None, \
            "reserved worker must admit no younger request"

    def test_starved_head_lands_once_batch_drains(self):
        sched, k_small, k_big, w = self._stream_world(aging_bound=1)
        r0 = Request(k_small, decode_steps=5, active_params=AP)
        sched.submit(r0)
        a0 = sched.route()
        sched.on_start(a0)
        w.libraries[k_small].materialize_cost(w.device,
                                              fetch_bw=float("inf"))
        sched.on_staged(a0)
        big = Request(k_big, decode_steps=10, active_params=BIG_AP,
                      exclusive=True)
        sched.submit(big)
        sched.submit(Request(k_small, decode_steps=5, active_params=AP))
        a1 = sched.route()                  # ages the big head to bound
        sched.on_start(a1)
        assert big.skipped == 1
        assert sched.route() is None
        # batch drains: members complete, stream closes, worker idles
        lib = w.libraries[k_small]
        lib.activate()
        for _ in range(5):
            done = lib.step()
        for r in done:
            pass
        for rid, a in ((r0.request_id, a0), (a1.request.request_id, a1)):
            sched.on_complete(a, 0.0, 1.0)
        sched.close_stream(w.worker_id, k_small)
        a_big = sched.route()
        assert a_big is not None and a_big.request is big


class TestEvictionMidBatch:
    def test_requeues_only_unfinished(self):
        sched, ex, fac = make_sim(devices=[A10])
        app = Application(sched)
        key = app.register(RECIPE, active_params=AP)
        for steps in (4, 40, 40):
            app.submit(key, decode_steps=steps)
        fac.reconcile(1)
        ex.pump()
        ex.loop.run(stop=lambda: sched.completed_inferences > 0)
        assert sched.completed_inferences == 4, "short member finished"
        assert len(sched.records) == 1
        wid = next(iter(sched.workers))
        requeued = sched.on_evict(wid, now=ex.loop.now)
        assert len(requeued) == 2, "only unfinished members requeue"
        assert all(r.steps_done == 0 and r.t_first_step is None
                   for r in requeued)
        assert sched.evicted_tasks == 2
        assert len(sched.records) == 1, "finished member keeps its record"
        fac.reconcile(1)                    # replacement worker joins
        ex.run()
        assert sched.completed_inferences == 84
        assert len(sched.records) == 3
        late = [r for r in sched.records if r.attempts > 0]
        assert len(late) == 2


class TestSimLiveAgreement:
    def test_completed_work_accounting_matches(self):
        """Same request multiset through both executors: identical
        completed-work totals, and both report per-request latency."""
        steps = [3, 5, 7, 2, 6]
        # -- sim --------------------------------------------------------
        sim_recipe = tiny_live_recipe("agree::sim")
        sched_s, ex_s, fac_s = make_sim(devices=[A10] * 2)
        app_s = Application(sched_s)
        key_s = app_s.register(sim_recipe, active_params=AP)
        for d in steps:
            app_s.submit(key_s, decode_steps=d)
        fac_s.reconcile(2)
        ex_s.run()
        # -- live -------------------------------------------------------
        live_recipe = tiny_live_recipe("agree::live")
        sched_l = Scheduler()
        app_l = Application(sched_l)
        key_l = app_l.register(live_recipe, active_params=AP)
        for _ in range(2):
            sched_l.add_worker(Worker(A10))
        for d in steps:
            app_l.submit(key_l, decode_steps=d)
        ex_l = LiveExecutor(sched_l, step_fns={
            key_l: lambda payloads, members: {m.request_id: 1
                                              for m in members}})
        ex_l.run()
        # -- agreement --------------------------------------------------
        total = sum(steps)
        assert sched_s.completed_inferences == total
        assert sched_l.completed_inferences == total
        for app in (app_s, app_l):
            recs = app.records()
            assert len(recs) == len(steps)
            assert sorted(r.n_units for r in recs) == sorted(steps)
            assert all(r.queue_wait_s >= 0 for r in recs)
            assert all(r.ttfs_s >= r.queue_wait_s for r in recs)
            summary = latency_summary(recs)
            assert summary["n"] == len(steps)
            assert summary["ttfs_p95_s"] >= 0
        # live step outputs: one fragment per decode step
        for r in app_l.requests:
            assert len(ex_l.results[r.request_id]) == r.n_units


class TestAgingAuto:
    def test_auto_falls_back_without_data(self):
        sched = Scheduler(aging_bound="auto")
        key = sched.register_context(RECIPE)
        assert sched.aging_bound_for(key) == AGING_BOUND_DEFAULT

    def test_auto_tracks_observed_ratio(self):
        sched = Scheduler(aging_bound="auto")
        key = sched.register_context(RECIPE)
        # observed: warm requests ~1s, cold starts ~55s
        sched._service[key] = [10.0, 10, 550.0, 10]
        assert sched.aging_bound_for(key) == 55
        # pathological ratios stay clamped
        sched._service[key] = [1.0, 1, 1000.0, 1]
        assert sched.aging_bound_for(key) == 64
        sched._service[key] = [10.0, 1, 1.0, 1]
        assert sched.aging_bound_for(key) == 2

    def test_derive_aging_bound_helper(self):
        assert derive_aging_bound(1.0, 8.0) == 8
        assert derive_aging_bound(0.0, 8.0) == AGING_BOUND_DEFAULT
        assert derive_aging_bound(1.0, 1e9, hi=64) == 64

    def test_service_stats_populated_by_completions(self):
        sched, ex, fac = make_sim(devices=[A10] * 2,
                                  aging_bound="auto")
        app = Application(sched)
        key = app.register(RECIPE, active_params=AP)
        for i in range(6):
            app.submit(key, decode_steps=20)
        fac.reconcile(2)
        ex.run()
        assert sched.completed_inferences == 120
        st = sched._service[key]
        assert st[1] > 0 and st[3] > 0, "warm AND cold observed"
        bound = sched.aging_bound_for(key)
        assert 2 <= bound <= 64

    def test_auto_sweep_completes(self):
        sched, ex, fac = make_sim(aging_bound="auto")
        key = sched.register_context(RECIPE)
        with pytest.warns(DeprecationWarning):
            sched.submit_sweep(key, 2_000, 100, PERVASIVE,
                               active_params=AP)
        fac.reconcile(4)
        ex.run()
        assert sched.completed_inferences == 2_000


class TestSpillAwareEviction:
    def _warm(self, sched, w, recipe, key):
        lib = w.library_for(recipe)
        lib.materialize_cost(w.device, fetch_bw=float("inf"))
        sched.registry.mark_ready(key, w.worker_id)

    def test_default_priority_prefers_replicated_hosts(self):
        other = dataclasses.replace(RECIPE, fn_name="infer::other")
        sched, ex, fac = make_sim(devices=[A10] * 3)
        k_sole = sched.register_context(RECIPE)
        k_repl = sched.register_context(other)
        fac.reconcile(3)
        w0, w1, w2 = sched.workers.values()
        self._warm(sched, w0, RECIPE, k_sole)      # the ONLY copy
        self._warm(sched, w1, other, k_repl)       # replicated on w1+w2
        self._warm(sched, w2, other, k_repl)
        fac.reconcile(2)
        assert w0.worker_id in sched.workers, \
            "the sole warm copy must be reclaimed last"
        assert sched.registry.replication(k_repl) == 1, \
            "the replicated recipe lost exactly one of its copies"

    def test_workers_hosting_nothing_evicted_first(self):
        sched, ex, fac = make_sim(devices=[A10] * 2)
        key = sched.register_context(RECIPE)
        fac.reconcile(2)
        w0, w1 = sched.workers.values()
        self._warm(sched, w0, RECIPE, key)
        fac.reconcile(1)
        assert list(sched.workers.values()) == [w0]
