"""Serving gateway: SLO classes, bounded queues, deadline preemption.

Covers the gateway contract end to end: admission bounds (reject vs
overflow-queue), re-admission bypass, deadline stamping and DES-event
expiry, terminal-outcome mutual exclusion, deadline-driven batch
preemption with KV suspend/resume on both executors, and the
outcome-aware latency summaries.

The Hypothesis section property-checks the two DES-wide invariants
(per-class queue bounds hold at EVERY event; terminal outcomes are
recorded exactly once and are mutually exclusive) plus work conservation
under preemption.  Token bit-exactness across a suspend/resume cycle is
checked against the real :class:`StreamingDecoder` (deterministically
parametrized — a real model per Hypothesis example would be
prohibitive; the DES property covers the schedule space instead).
"""
import dataclasses

import pytest

from repro.core import model_context_recipe
from repro.cluster import (Application, ClassPolicy, GPU_CATALOG, Gateway,
                           REJECTED, Request, Scheduler, TIMED_OUT, Worker,
                           class_latency_summary, latency_summary, make_sim)
from repro.cluster.scheduler import RequestRecord
from repro.configs import get_config

CFG = get_config("smollm2-1.7b")
AP = CFG.n_active_params()
A10 = GPU_CATALOG["NVIDIA A10"]

# ~2 decode slots per 24 GB A10 (deterministic slot budget)
RECIPE2 = dataclasses.replace(model_context_recipe(CFG, include_compile=False),
                              slot_bytes=10_000_000_000)


def mk_sim(n_workers=1, *, interactive=None, batch=None, with_gateway=True):
    sched, ex, fac = make_sim(devices=[A10] * max(n_workers, 1),
                              workers_per_zone=max(n_workers, 1))
    app = Application(sched)
    key = app.register(RECIPE2, active_params=AP)
    gw = Gateway(sched, interactive=interactive, batch=batch) \
        if with_gateway else None
    if n_workers:
        fac.reconcile(n_workers)
    return sched, ex, fac, app, key, gw


class TestAdmission:
    def test_reject_overflow_is_terminal(self):
        sched, ex, _, app, key, gw = mk_sim(0, interactive=ClassPolicy(
            max_queue=2, overflow="reject", deadline_s=60.0))
        reqs = [app.submit(key, decode_steps=1, slo="interactive")
                for _ in range(3)]
        assert gw.queued_fresh(key, "interactive") == 2
        assert gw.rejected["interactive"] == 1
        rec = [r for r in sched.records
               if r.request_id == reqs[2].request_id]
        assert len(rec) == 1 and rec[0].outcome == REJECTED
        assert rec[0].slo == "interactive"
        # a rejected request is terminal: never in a lane, never runs
        assert all(r is not reqs[2] for lane in sched.lanes.values()
                   for r in lane)

    def test_queue_overflow_parks_and_never_exceeds_bound(self):
        sched, ex, fac, app, key, gw = mk_sim(1, batch=ClassPolicy(
            max_queue=2, overflow="queue"))
        for _ in range(5):
            app.submit(key, decode_steps=2, slo="batch")
        assert gw.queued_fresh(key, "batch") == 2
        assert gw.pending_overflow == 3
        assert not sched.done, "parked requests must hold the run open"
        ex.run()
        assert sched.done and gw.pending_overflow == 0
        assert sched.completed_inferences == 10
        assert all(r.outcome == "done" for r in sched.records)

    def test_readmission_bypasses_bound(self):
        sched, _, _, app, key, gw = mk_sim(0, batch=ClassPolicy(
            max_queue=1, overflow="queue"))
        app.submit(key, decode_steps=2, slo="batch")
        veteran = app.make_request(key, decode_steps=2, slo="batch")
        veteran.attempts = 1                   # evicted elsewhere, requeued
        sched.ingress(veteran)
        assert gw.pending_overflow == 0, "re-admission must not park"
        assert sum(len(l) for l in sched.lanes.values()) == 2

    def test_deadline_stamped_relative_to_arrival(self):
        sched, _, _, app, key, _ = mk_sim(0, interactive=ClassPolicy(
            max_queue=8, overflow="reject", deadline_s=30.0))
        r = app.submit(key, decode_steps=1, slo="interactive", arrival_s=5.0)
        assert r.deadline_s == 35.0
        explicit = app.submit(key, decode_steps=1, slo="interactive",
                              deadline_s=12.0)
        assert explicit.deadline_s == 12.0, "explicit deadline kept"

    def test_unknown_slo_rejected(self):
        sched, _, _, app, key, _ = mk_sim(0)
        with pytest.raises(ValueError, match="SLO class"):
            app.submit(key, decode_steps=1, slo="bulk")

    def test_interactive_lane_prefix_invariant(self):
        sched, _, _, app, key, _ = mk_sim(0)
        for slo in ("batch", "interactive", "batch", "interactive"):
            app.submit(key, decode_steps=1, slo=slo)
        lane = list(sched.lanes[key])
        slos = [r.slo for r in lane]
        assert slos == ["interactive", "interactive", "batch", "batch"]
        # FIFO within each class
        assert [r.request_id for r in lane if r.slo == "interactive"] == \
            sorted(r.request_id for r in lane if r.slo == "interactive")


class TestDeadline:
    def test_expiry_fires_as_des_event_on_idle_pool(self):
        """A queued deadline must fire even when nothing else happens —
        the sim arms a timer for it (no busy-wait, no hang)."""
        sched, ex, _, app, key, gw = mk_sim(0, interactive=ClassPolicy(
            max_queue=8, overflow="reject", deadline_s=5.0))
        app.submit_stream(ex, [dict(recipe_key=key, decode_steps=1,
                                    arrival_s=1.0, slo="interactive")])
        ex.run(until=100.0)
        assert sched.done
        assert ex.loop.now < 10.0, "loop ran to the safety net, not the " \
            "deadline event"
        assert gw.timed_out["interactive"] == 1
        (rec,) = sched.records
        assert rec.outcome == TIMED_OUT and rec.t_end == pytest.approx(
            6.0, abs=0.1)

    def test_overflowed_requests_also_expire(self):
        sched, ex, _, app, key, gw = mk_sim(0, interactive=ClassPolicy(
            max_queue=1, overflow="queue", deadline_s=4.0))
        for _ in range(3):
            app.submit(key, decode_steps=1, slo="interactive")
        assert gw.pending_overflow == 2
        gw.expire(10.0)
        assert gw.pending_overflow == 0
        assert gw.timed_out["interactive"] == 3
        assert {r.outcome for r in sched.records} == {TIMED_OUT}

    def test_terminal_outcome_recorded_exactly_once(self):
        sched = Scheduler()
        key = sched.register_context(RECIPE2)
        req = Request(key, decode_steps=1)
        sched.record_terminal(req, REJECTED, 0.0)
        with pytest.raises(AssertionError):
            sched.record_terminal(req, TIMED_OUT, 1.0)
        assert [r.outcome for r in sched.records] == [REJECTED]


def run_preemption_scenario(*, n_workers=1, batch_steps=60, int_steps=4,
                            int_arrival=30.0, deadline=8.0):
    """Fill the pool with long batch decodes, then land one deadline'd
    interactive request that can only be served by preempting."""
    sched, ex, fac, app, key, gw = mk_sim(
        n_workers, interactive=ClassPolicy(
            max_queue=8, overflow="reject", deadline_s=deadline,
            preempt_slack_s=deadline))
    n_batch = 2 * n_workers                     # 2 slots per worker
    app.submit_stream(ex, [dict(recipe_key=key, decode_steps=batch_steps,
                                arrival_s=0.0, slo="batch")
                           for _ in range(n_batch)])
    app.submit_stream(ex, [dict(recipe_key=key, decode_steps=int_steps,
                                arrival_s=int_arrival, slo="interactive")])
    fac.reconcile(n_workers)
    ex.run(until=2_000.0)
    return sched, gw, app


class TestPreemption:
    def test_deadline_preempts_batch_and_victim_resumes(self):
        sched, gw, app = run_preemption_scenario()
        assert sched.done
        assert sched.preemptions == 1
        by_slo = {}
        for r in sched.records:
            by_slo.setdefault(r.slo, []).append(r)
        (irec,) = by_slo["interactive"]
        assert irec.outcome == "done"
        # deadlines bound QUEUE time: the interactive request started
        # decoding before its (absolute) deadline
        assert irec.t_first_step <= 30.0 + 8.0
        victims = [r for r in by_slo["batch"] if r.preemptions > 0]
        assert len(victims) == 1 and victims[0].outcome == "done"
        # work conservation: nothing lost across the suspend/resume cycle
        assert sched.completed_inferences == 2 * 60 + 4
        kv = sched.plane.kv_summary()
        assert kv["spill_events"] == 1 and kv["resume_events"] == 1
        assert kv["spilled_bytes"] == kv["resumed_bytes"] > 0
        # no slot leaks
        for w in sched.workers.values():
            for lib in w.libraries.values():
                assert not lib.batch

    def test_no_preemption_without_gateway(self):
        sched, ex, fac, app, key, _ = mk_sim(1, with_gateway=False)
        app.submit_stream(ex, [dict(recipe_key=key, decode_steps=60,
                                    arrival_s=0.0, slo="batch")
                               for _ in range(2)])
        app.submit_stream(ex, [dict(recipe_key=key, decode_steps=4,
                                    arrival_s=30.0, slo="interactive",
                                    deadline_s=38.0)])
        fac.reconcile(1)
        ex.run(until=2_000.0)
        assert sched.done and sched.preemptions == 0

    def test_victim_redispatches_fresh_when_worker_lost(self):
        """Eviction of the suspended-on worker voids the KV snapshot:
        the victim must restart from step 0 elsewhere, not resume."""
        sched, ex, fac, app, key, gw = mk_sim(2, interactive=ClassPolicy(
            max_queue=8, overflow="reject", deadline_s=8.0,
            preempt_slack_s=8.0))
        app.submit_stream(ex, [dict(recipe_key=key, decode_steps=400,
                                    arrival_s=0.0, slo="batch")
                               for _ in range(4)])
        app.submit_stream(ex, [dict(recipe_key=key, decode_steps=4,
                                    arrival_s=30.0, slo="interactive")])
        fac.reconcile(2)
        ex.pump()
        # pause right after the preemption, before the victim can resume
        ex.loop.run(until=30.5)
        assert sched.preemptions == 1
        victim = next(r for lane in sched.lanes.values() for r in lane
                      if r.suspended)
        wid = victim.suspended_on
        assert wid is not None
        sched.on_evict(wid, now=ex.loop.now)
        fac.reconcile(2)                       # replacement joins
        ex.run(until=5_000.0)
        assert sched.done
        assert not victim.suspended and victim.suspended_on is None, \
            "stale suspension survived the worker loss"
        vrec = [r for r in sched.records
                if r.request_id == victim.request_id]
        assert len(vrec) == 1 and vrec[0].outcome == "done"


class TestOutcomeAwareSummaries:
    @staticmethod
    def _rec(rid, outcome="done", preemptions=0, slo="batch", t_end=10.0):
        return RequestRecord(
            request_id=rid, worker_id="w", device="d", t_arrival=0.0,
            t_start=1.0, t_first_step=2.0, t_end=t_end, n_units=4,
            warm=True, attempts=0, outcome=outcome, slo=slo,
            preemptions=preemptions)

    def test_terminal_and_preempted_records_do_not_pollute_percentiles(self):
        recs = [self._rec(1, t_end=10.0),
                self._rec(2, outcome=REJECTED, t_end=0.01),
                self._rec(3, outcome=TIMED_OUT, t_end=0.5),
                self._rec(4, preemptions=2, t_end=500.0)]
        s = latency_summary(recs)
        assert s["n"] == 4 and s["n_done"] == 2
        assert s["n_rejected"] == 1 and s["n_timed_out"] == 1
        assert s["n_preempted"] == 1
        # only the cleanly served record feeds the distribution: neither
        # the instant refusals nor the suspension-smeared e2e leak in
        assert s["e2e_p50_s"] == s["e2e_p95_s"] == 10.0

    def test_class_split(self):
        recs = [self._rec(1, slo="interactive", t_end=2.0),
                self._rec(2, slo="batch", t_end=90.0)]
        s = class_latency_summary(recs)
        assert set(s) == {"interactive", "batch"}
        assert s["interactive"]["e2e_p50_s"] == 2.0
        assert s["batch"]["e2e_p50_s"] == 90.0


class TestPagePoolRetention:
    def test_park_revive_and_pressure_reclaim(self):
        from repro.inference.streaming import PagePool
        pool = PagePool(5, retained_cap=2)
        dropped = []
        pool.on_evict_retained = dropped.append
        a, b, c = pool.alloc(), pool.alloc(), pool.alloc()
        assert pool.decref(a) is False, "parked, not freed"
        assert pool.retained_count == 1 and not dropped
        pool.incref(a)                          # prefix hit revives
        assert pool.retained_count == 0 and pool.refcount(a) == 1
        for p in (a, b, c):
            assert pool.decref(p) is False
        # the park overflowed its cap: oldest page actually freed
        assert pool.retained_count == 2 and dropped == [a]
        pool.alloc()                            # free list still preferred
        assert pool.retained_count == 2
        pool.alloc()
        got = pool.alloc()                      # pressure: LRU reclaim
        assert got == b and dropped == [a, b]

    def test_cap_zero_frees_immediately(self):
        from repro.inference.streaming import PagePool
        pool = PagePool(3)
        p = pool.alloc()
        assert pool.decref(p) is True
        assert pool.retained_count == 0 and pool.free == 2


# ---------------------------------------------------------------------------
# Live suspend/resume: token bit-exactness (real decoder, deterministic)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def live_setup():
    import jax
    from repro.configs import get_smoke_config
    from repro.models import model as M
    cfg = get_smoke_config("smollm2-1.7b")
    return cfg, M.init_params(cfg, jax.random.PRNGKey(0))


@pytest.mark.parametrize("paged", [False, True])
def test_suspend_resume_tokens_bit_exact(live_setup, paged):
    import numpy as np
    from repro.inference import StreamingDecoder
    cfg, params = live_setup
    rng = np.random.default_rng(3)
    prompts = {r: list(rng.integers(4, cfg.vocab_size, 10 + 2 * r))
               for r in range(3)}
    kw = dict(max_len=48, paged=paged)
    if paged:
        kw["page_size"] = 8

    def decode(suspend_at):
        dec = StreamingDecoder(cfg, params, None, None, **kw)
        for r, p in prompts.items():
            dec.ensure_tokens(r, list(p))
        outs = {}
        done = 0
        while done < 8:
            if suspend_at is not None and done == suspend_at:
                assert dec.suspend(0) > 0
                for _ in range(2):              # others decode meanwhile
                    dec.step([1, 2])
                dec.resume(0)
            for r, t in dec.step([0, 1, 2]).items():
                outs.setdefault(r, []).append(t)
            done += 1
        for r in prompts:
            dec.finish(r)
        assert dec.pool.free == dec.pool.capacity, "slot leak"
        if paged:
            assert dec.pages.in_use == 0, "page leak"
        assert not dec._suspended
        return outs[0]

    reference = decode(None)
    for point in (1, 5):
        assert decode(point) == reference, \
            f"tokens diverged after suspend at step {point} (paged={paged})"
