"""Hypothesis property tests on the elastic-supply contract.

The policy's two guarantees (see ElasticPolicy's docstring) must hold
for ANY arrival schedule and ceiling, not just the benchmark scenarios:
pool targets stay within [0, ceiling] at every DES event, and the
hysteresis/cooldown contract forbids acquire->release flip-flop on a
boundary-oscillating demand signal.
"""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.cluster import (Application, ChurnInjector, ElasticPolicy,
                           Storm, make_sim)

from test_forecast import A10, AP, RECIPE, _FakeView

schedules = st.lists(
    st.tuples(st.integers(0, 60),               # arrival second
              st.integers(1, 6)),               # decode steps
    min_size=1, max_size=30)


@given(schedules, st.integers(2, 8), st.booleans())
@settings(max_examples=20, deadline=None)
def test_target_and_pool_bounded_at_every_des_event(schedule, ceiling,
                                                    with_storm):
    """The decided target and the actual pool never leave
    [0, availability ceiling], at any point in the run — including
    through a mid-run eviction storm and its re-acquire suppression."""
    policy = ElasticPolicy(signal="forecast", active_params=AP)
    sched, ex, fac = make_sim(devices=[A10] * 4,
                              trace=[(0.0, ceiling)],
                              policy=policy, tick_s=5.0)
    app = Application(sched)
    key = app.register(RECIPE, active_params=AP)
    app.submit_stream(ex, [dict(recipe_key=key, decode_steps=steps,
                                arrival_s=float(t))
                           for t, steps in schedule])
    if with_storm:
        inj = ChurnInjector(ex, [Storm(10.0, 2)], factory=fac,
                            seed=0, suppress_s=15.0)
        inj.arm()
    ex.pump()
    while ex.loop.step():
        assert 0 <= fac.target <= ceiling, \
            f"target {fac.target} outside [0, {ceiling}] " \
            f"at t={ex.loop.now:.2f}"
        assert len(sched.workers) <= ceiling, \
            f"pool {len(sched.workers)} above ceiling {ceiling} " \
            f"at t={ex.loop.now:.2f}"
    assert sched.done, "run never drained"


@given(st.lists(st.floats(0.1, 60.0), min_size=4, max_size=40),
       st.floats(0.05, 0.5))
@settings(max_examples=50, deadline=None)
def test_no_flip_flop_within_cooldowns(rates, hysteresis):
    """Whatever the demand oscillation, consecutive voluntary scale
    actions respect the shared cooldown clock: an action following an
    acquire waits at least acquire_cooldown_s, a release at least
    release_cooldown_s — so a rate bouncing across a hysteresis
    boundary cannot acquire-then-release in quick succession."""
    pol = ElasticPolicy(supply=[A10], active_params=AP,
                        hysteresis=hysteresis)
    cur, t = 1, 0.0
    events = []
    for r in rates:
        t += 5.0
        new = pol.decide(_FakeView(r), current=cur, ceiling=1000, now=t)
        assert new >= 0
        if new != cur:
            events.append((t, "up" if new > cur else "down"))
            cur = new
    for (t1, _), (t2, d2) in zip(events, events[1:]):
        gap = t2 - t1
        if d2 == "down":
            assert gap >= pol.release_cooldown_s, \
                f"release {gap:.0f}s after the previous scale action"
        else:
            assert gap >= pol.acquire_cooldown_s, \
                f"acquire {gap:.0f}s after the previous scale action"
