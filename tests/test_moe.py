"""MoE dispatch-mode equivalence: dense_onehot == sort_scatter == a2a.

The three dispatch modes are different *distribution* strategies for the
same mathematical operator; with a dropless capacity factor they must
agree to float tolerance.  a2a needs a multi-device mesh — tested in a
subprocess with 8 placeholder devices (same mechanism as the dry-run).
"""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import moe as moe_mod

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _setup(arch="phi3.5-moe-42b-a6.6b", cf=8.0, dtype=jnp.float32):
    cfg = get_smoke_config(arch)
    cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, capacity_factor=cf))
    p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, dtype)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model), dtype)
    return cfg, p, x


class TestDispatchEquivalence:
    def test_dense_onehot_equals_sort_scatter(self):
        cfg, p, x = _setup()
        y1, aux1 = moe_mod.moe_apply_dense_onehot(p, cfg, x)
        y2, aux2 = moe_mod.moe_apply_sort_scatter(p, cfg, x)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-5, atol=1e-5)
        assert float(aux1) == pytest.approx(float(aux2), rel=1e-5)

    def test_shared_experts_added(self):
        cfg, p, x = _setup("deepseek-v3-671b")
        assert cfg.moe.n_shared_experts >= 1
        y, _ = moe_mod.moe_apply_sort_scatter(p, cfg, x)
        y_shared = moe_mod._shared_ffn(p, x)
        assert float(jnp.abs(y_shared).max()) > 0
        # shared expert contributes: zeroing it changes the output
        p2 = dict(p, ws1=jnp.zeros_like(p["ws1"]))
        y2, _ = moe_mod.moe_apply_sort_scatter(p2, cfg, x)
        assert float(jnp.abs(y - y2).max()) > 0

    def test_capacity_drops_tokens(self):
        """Tiny capacity: outputs differ from dropless (tokens dropped)."""
        cfg, p, x = _setup(cf=8.0)
        cfg_tight = cfg.with_(moe=dataclasses.replace(cfg.moe,
                                                      capacity_factor=0.25))
        y_free, _ = moe_mod.moe_apply_sort_scatter(p, cfg, x)
        y_tight, _ = moe_mod.moe_apply_sort_scatter(p, cfg_tight, x)
        assert float(jnp.abs(y_free - y_tight).max()) > 1e-3

    def test_a2a_equals_sort_scatter_multidevice(self):
        code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp
from repro.configs import get_smoke_config
from repro.models import moe as moe_mod
from repro.sharding import sharding_ctx
cfg = get_smoke_config('deepseek-v3-671b')
cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0,
                                        dispatch='a2a'))
mesh = jax.make_mesh((2, 4), ("data", "model"))
p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                      jnp.float32)
y_ref, _ = moe_mod.moe_apply_sort_scatter(p, cfg, x)
for seq in (True, False):
    c = cfg.with_(parallel=dataclasses.replace(cfg.parallel,
                                               seq_parallel=seq))
    with sharding_ctx(mesh, c):
        y, _ = jax.jit(lambda p, x: moe_mod.moe_apply(p, c, x))(p, x)
    d = float(jnp.abs(y_ref - y).max())
    assert d < 1e-5, (seq, d)
    # grads flow through the a2a path
    with sharding_ctx(mesh, c):
        g = jax.jit(jax.grad(lambda p, x: moe_mod.moe_apply(
            p, c, x)[0].sum()))(p, x)
    assert all(float(jnp.abs(v).max()) > 0 for k, v in g.items()
               if k.startswith("we"))
print("A2A_OK")
"""
        env = dict(os.environ, PYTHONPATH=SRC)
        out = subprocess.run([sys.executable, "-c", code],
                             capture_output=True, text=True, env=env,
                             timeout=560)
        assert out.returncode == 0, out.stderr[-3000:]
        assert "A2A_OK" in out.stdout
