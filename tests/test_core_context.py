"""Unit tests for the context-management core (recipes, cache, library)."""
import pytest

from repro.configs import get_config
from repro.core import (CacheFullError, ContextCache, ContextElement,
                        ContextRecipe, ContextRegistry, HostState, Library,
                        Tier, model_context_recipe, partial_context_recipe)


def small_recipe(weights=1000, deps=500):
    return ContextRecipe("f", (
        ContextElement("deps", nbytes_disk=deps, nbytes_host=50),
        ContextElement("weights", nbytes_disk=weights,
                       nbytes_host=2 * weights, nbytes_device=weights),
    ), activation_s=1.0)


class HW:
    disk_bw = 100.0
    h2d_bw = 1000.0

    def compile_s(self, recipe):
        return 5.0


class TestRecipe:
    def test_key_stable_and_content_addressed(self):
        r1, r2 = small_recipe(), small_recipe()
        assert r1.key == r2.key
        assert small_recipe(weights=2000).key != r1.key

    def test_model_recipe_sizes(self):
        cfg = get_config("smollm2-1.7b")
        r = model_context_recipe(cfg)
        w = r.element("weights")
        # 1.7B bf16 ≈ 3.4-3.7 GB on disk, ~2x in host (paper: 3.7/7.4 GB)
        assert 3.0e9 < w.nbytes_disk < 4.2e9
        assert w.nbytes_host == 2 * w.nbytes_disk
        assert r.element("xla_executable").nbytes_device > 0

    def test_partial_recipe_subset(self):
        cfg = get_config("smollm2-1.7b")
        p = partial_context_recipe(cfg)
        assert {e.name for e in p.elements} == {"deps", "weights"}


class TestCache:
    def test_byte_accounting(self):
        c = ContextCache(disk_bytes=10_000, host_bytes=5_000,
                         device_bytes=2_000)
        r = small_recipe()
        c.put(r.element("deps"), Tier.HOST)
        c.put(r.element("weights"), Tier.DEVICE)
        assert c.used(Tier.DISK) == 1500
        assert c.used(Tier.HOST) == 50 + 2000
        assert c.used(Tier.DEVICE) == 1000

    def test_lru_eviction_frees_space(self):
        c = ContextCache(disk_bytes=2_500, host_bytes=10_000,
                         device_bytes=10_000)
        a = ContextElement("a", nbytes_disk=1000)
        b = ContextElement("b", nbytes_disk=1000)
        d = ContextElement("d", nbytes_disk=1000)
        c.put(a, Tier.DISK)
        c.put(b, Tier.DISK)
        c.lookup(a.key)              # a now MRU
        c.put(d, Tier.DISK)          # evicts b (LRU)
        assert c.tier_of(b.key) is None
        assert c.tier_of(a.key) is Tier.DISK
        assert c.evictions == 1

    def test_pinned_never_evicted(self):
        c = ContextCache(disk_bytes=2_000, host_bytes=10_000,
                         device_bytes=10_000)
        a = ContextElement("a", nbytes_disk=1500)
        c.put(a, Tier.DISK, pinned=True)
        with pytest.raises(CacheFullError):
            c.put(ContextElement("b", nbytes_disk=1000), Tier.DISK)
        assert c.tier_of(a.key) is Tier.DISK

    def test_oversized_element_rejected(self):
        c = ContextCache(disk_bytes=100, host_bytes=100, device_bytes=100)
        with pytest.raises(CacheFullError):
            c.put(ContextElement("x", nbytes_disk=500), Tier.DISK)


class TestLibrary:
    def test_cold_then_warm_cost(self):
        c = ContextCache(disk_bytes=10**6, host_bytes=10**6,
                         device_bytes=10**6)
        lib = Library(small_recipe(), c)
        cold = lib.materialize_cost(HW(), fetch_bw=50.0)
        assert cold.fetch_s == pytest.approx(1500 / 50.0)
        assert cold.load_s == pytest.approx((50 + 2000) / 100.0)
        assert cold.device_s == pytest.approx(1000 / 1000.0)
        assert cold.activation_s == 1.0
        warm = lib.materialize_cost(HW(), already_local=True)
        assert warm.fetch_s == warm.load_s == warm.device_s == 0.0
        assert lib.ready

    def test_teardown_then_restage_pays_load_not_fetch(self):
        c = ContextCache(disk_bytes=10**6, host_bytes=10**6,
                         device_bytes=10**6)
        lib = Library(small_recipe(), c)
        lib.materialize_cost(HW(), fetch_bw=50.0)
        lib.teardown()
        # partial-mode teardown: demote to disk
        for e in lib.recipe.elements:
            c.put(e, Tier.DISK)
        relib = Library(lib.recipe, c)
        cost = relib.materialize_cost(HW())
        assert cost.fetch_s == 0.0
        assert cost.load_s > 0.0

    def test_compile_cost_used_for_executable(self):
        r = small_recipe().with_elements(
            ContextElement("xla_executable", nbytes_disk=10,
                           nbytes_device=10))
        c = ContextCache(disk_bytes=10**6, host_bytes=10**6,
                         device_bytes=10**6)
        cost = Library(r, c).materialize_cost(HW(), already_local=True)
        assert cost.device_s >= 5.0      # HW.compile_s


class TestRegistry:
    def test_lifecycle(self):
        reg = ContextRegistry()
        r = small_recipe()
        key = reg.register(r)
        reg.mark_staging(key, "w0")
        assert reg.staging_workers(key) == {"w0"}
        assert reg.ready_workers(key) == set()
        reg.mark_ready(key, "w0")
        assert reg.ready_workers(key) == {"w0"}
        lost = reg.drop_worker("w0")
        assert key in lost
        assert reg.replication(key) == 0

    def test_unregistered_recipe_rejected(self):
        reg = ContextRegistry()
        with pytest.raises(AssertionError):
            reg.mark_staging("nope", "w0")
