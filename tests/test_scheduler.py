"""Scheduler + sim-executor behaviour: the paper's management layer."""
import pytest

from repro.core import (NAIVE, PARTIAL, PERVASIVE, ContextElement,
                        ContextRecipe, Tier, WarmPoolPolicy,
                        model_context_recipe)
from repro.cluster import (GPU_CATALOG, LiveExecutor, Scheduler, SimExecutor,
                           Task, Worker, make_sim, paper_20gpu_pool, traces)
from repro.configs import get_config

# the mixed-recipe scenario assets the benchmarks run — tested here so the
# regression tests exercise exactly the configuration the benchmarks claim
from benchmarks.common import BIG_AP, BIG_RECIPE, MIXED_SHAPE

CFG = get_config("smollm2-1.7b")
RECIPE = model_context_recipe(CFG, include_compile=False)
AP = CFG.n_active_params()


def run_sweep(mode, batch, n_total=5_000, n_workers=8, devices=None,
              trace=None, **kw):
    sched, ex, fac = make_sim(devices=devices, trace=trace, **kw)
    key = sched.register_context(RECIPE)
    sched.submit_sweep(key, n_total, batch, mode, active_params=AP)
    if trace is None:
        fac.reconcile(n_workers)
    t = ex.run()
    return t, sched


class TestWorkConservation:
    def test_all_inferences_complete(self):
        t, s = run_sweep(PERVASIVE, 100)
        assert s.completed_inferences == 5_000
        assert s.done
        assert sum(r.n_inferences for r in s.records) == 5_000

    def test_uneven_batch_remainder(self):
        t, s = run_sweep(PERVASIVE, 333, n_total=1_000)
        assert s.completed_inferences == 1_000
        assert [r.n_inferences for r in s.records].count(1) == 1


class TestContextModes:
    def test_mode_ordering_end_to_end(self):
        t_naive, _ = run_sweep(NAIVE, 100)
        t_partial, _ = run_sweep(PARTIAL, 100)
        t_perv, _ = run_sweep(PERVASIVE, 100)
        assert t_perv < t_partial < t_naive

    def test_pervasive_pays_staging_once_per_worker(self):
        _, s = run_sweep(PERVASIVE, 100, n_workers=4)
        cold = [r for r in s.records if not r.warm]
        warm = [r for r in s.records if r.warm]
        assert len(cold) == 4                    # one per worker
        assert warm, "subsequent tasks must route warm"
        assert max(r.exec_s for r in warm) < min(r.exec_s for r in cold)

    def test_partial_never_routes_warm_library(self):
        _, s = run_sweep(PARTIAL, 500, n_workers=4)
        # partial tears the library down: no assignment is 'warm'
        assert all(not r.warm for r in s.records)

    def test_batch_size_insensitivity_pervasive_vs_partial(self):
        """The paper's headline mechanism (pv3 vs pv4)."""
        t_p1, _ = run_sweep(PARTIAL, 10)
        t_p100, _ = run_sweep(PARTIAL, 500)
        t_v1, _ = run_sweep(PERVASIVE, 10)
        t_v100, _ = run_sweep(PERVASIVE, 500)
        sens_partial = t_p1 / t_p100
        sens_perv = t_v1 / t_v100
        assert sens_partial > 3.0
        assert sens_perv < 1.5


class TestHeterogeneity:
    def test_work_stealing_favours_fast_devices(self):
        _, s = run_sweep(PERVASIVE, 50, n_workers=20)
        by_dev = {}
        for wid, w in list(s.workers.items()):
            by_dev.setdefault(w.device.name, []).append(w.inferences_done)
        a10 = sum(by_dev["NVIDIA A10"])
        titan = sum(by_dev["NVIDIA TITAN X (Pascal)"])
        # A10 is 2.5x faster; it must complete ~2.5x the work
        assert a10 / titan == pytest.approx(2.5, rel=0.25)


class TestEviction:
    def test_evicted_tasks_requeued_and_finish(self):
        trace = [(0.0, 8), (50.0, 2), (200.0, 8)]
        sched, ex, fac = make_sim(trace=trace)
        key = sched.register_context(RECIPE)
        sched.submit_sweep(key, 3_000, 100, PERVASIVE, active_params=AP)
        ex.run()
        assert sched.completed_inferences == 3_000
        assert sched.evicted_tasks > 0
        assert any(r.attempts > 0 for r in sched.records)

    def test_eviction_drops_registry_residency(self):
        sched, ex, fac = make_sim()
        key = sched.register_context(RECIPE)
        sched.submit_sweep(key, 500, 100, PERVASIVE, active_params=AP)
        fac.reconcile(2)
        ex.run()
        wids = list(sched.workers)
        assert sched.registry.ready_workers(key) == set(wids)
        sched.on_evict(wids[0], now=ex.loop.now)
        assert wids[0] not in sched.registry.ready_workers(key)

    def test_no_grace_period_loses_whole_batch(self):
        sched, ex, fac = make_sim(trace=[(0.0, 1), (10.0, 0), (11.0, 1)])
        key = sched.register_context(RECIPE)
        sched.submit_sweep(key, 1_000, 1_000, PERVASIVE, active_params=AP)
        ex.run()
        assert sched.evicted_inferences >= 1_000
        assert sched.completed_inferences == 1_000


class TestPeerTransfer:
    def test_cold_worker_fetches_from_ready_peer(self):
        sched, ex, fac = make_sim()
        key = sched.register_context(RECIPE)
        sched.submit_sweep(key, 20_000, 100, PERVASIVE, active_params=AP)
        fac.reconcile(1)
        ex.loop.run(until=200.0, stop=lambda: sched.done)  # w0 warm
        assert sched.registry.replication(key) == 1
        fac.reconcile(6)
        ex.run()
        # peer-staged workers must come up much faster than the shared-fs
        # cold start (their fetch uses the 12.5 GB/s local links)
        cold = sorted((r for r in sched.records if not r.warm),
                      key=lambda r: r.t_start)
        first, rest = cold[0], cold[1:]
        assert rest, "expected additional cold starts on joiners"
        assert max(r.exec_s for r in rest) < first.exec_s

    def test_avg_connected_workers_timeweighted(self):
        sched, ex, fac = make_sim(trace=[(0.0, 4)])
        key = sched.register_context(RECIPE)
        sched.submit_sweep(key, 1_000, 100, PERVASIVE, active_params=AP)
        ex.run()
        assert sched.avg_connected_workers() == pytest.approx(4.0, abs=0.3)


class TestSchedulerUnit:
    def test_warm_routing_prefers_fastest_warm_device(self):
        sched = Scheduler()
        key = sched.register_context(RECIPE)
        slow = Worker(GPU_CATALOG["NVIDIA TITAN X (Pascal)"])
        fast = Worker(GPU_CATALOG["NVIDIA A10"])
        sched.add_worker(slow)
        sched.add_worker(fast)
        for w in (slow, fast):
            lib = w.library_for(RECIPE)
            lib.ready = True
            sched.registry.mark_ready(key, w.worker_id)
        sched.submit(Task(key, 10, PERVASIVE))
        a = sched.route()
        assert a.warm and a.worker is fast

    def test_route_returns_none_when_no_idle(self):
        sched = Scheduler()
        key = sched.register_context(RECIPE)
        sched.submit(Task(key, 10, PERVASIVE))
        assert sched.route() is None


class TestPrestage:
    def test_burst_join_prestage_beats_on_demand(self):
        """Beyond-paper: proactive spanning-tree distribution at bulk join
        (the planner from core/transfer.py driving the executor)."""
        from repro.cluster import Factory, SimExecutor, opportunistic_supply

        def run(prestage):
            sched = Scheduler()
            ex = SimExecutor(sched, prestage=prestage)
            fac = Factory(sched, ex, opportunistic_supply(32))
            key = sched.register_context(RECIPE)
            sched.submit_sweep(key, 30_000, 100, PERVASIVE,
                               active_params=AP)
            fac.reconcile(1)
            ex.pump()
            ex.loop.run(until=120.0, stop=lambda: sched.done)
            fac.apply_trace([(130.0, 32)])
            t = ex.run()
            cold_after = [r for r in sched.records
                          if not r.warm and r.t_start > 125]
            return t, cold_after

        t_lazy, cold_lazy = run(False)
        t_pre, cold_pre = run(True)
        assert t_pre < t_lazy
        assert len(cold_pre) < len(cold_lazy)

    def test_prestage_without_ready_host_is_noop(self):
        from repro.cluster import SimExecutor
        sched = Scheduler()
        ex = SimExecutor(sched, prestage=True)
        key = sched.register_context(RECIPE)
        sched.add_worker(Worker(GPU_CATALOG["NVIDIA A10"]))
        assert ex.prestage(key) == 0


class TestObservability:
    def test_progress_monitor_over_a_run(self):
        """Challenge #2: rate/ETA/progress reporting from scheduler state."""
        from repro.cluster import ProgressMonitor, SimExecutor, Factory
        from repro.cluster import opportunistic_supply, format_snapshot
        sched = Scheduler()
        ex = SimExecutor(sched)
        fac = Factory(sched, ex, opportunistic_supply(8))
        key = sched.register_context(RECIPE)
        sched.submit_sweep(key, 8_000, 100, PERVASIVE, active_params=AP)
        mon = ProgressMonitor(sched)
        lines = []
        mon.attach(ex.loop, every_s=30.0, printer=lines.append)
        fac.reconcile(8)
        ex.run()
        assert len(mon.snapshots) >= 2
        mid = mon.snapshots[len(mon.snapshots) // 2]
        assert 0 < mid.completed < 8_000
        assert mid.rate_inf_s > 0
        assert mid.eta_s is not None and mid.eta_s > 0
        final = mon.snapshot(ex.loop.now)
        assert final.completed == 8_000
        assert final.warm_fraction > 0.5
        assert "inf/s" in format_snapshot(final)


class TestBackfill:
    """The tentpole: per-recipe lanes + context-aware backfill routing."""

    def _pool(self, **sched_kw):
        sched = Scheduler(**sched_kw)
        k_big = sched.register_context(BIG_RECIPE)
        k_small = sched.register_context(RECIPE)
        a10 = Worker(GPU_CATALOG["NVIDIA A10"], shape=MIXED_SHAPE)
        titan = Worker(GPU_CATALOG["NVIDIA TITAN X (Pascal)"],
                       shape=MIXED_SHAPE)
        sched.add_worker(a10)
        sched.add_worker(titan)
        return sched, k_big, k_small, a10, titan

    def test_blocked_head_does_not_starve_deeper_task(self):
        sched, k_big, k_small, a10, titan = self._pool()
        # occupy the only big-capable worker
        sched.submit(Task(k_big, 10, PERVASIVE, active_params=BIG_AP))
        a1 = sched.route()
        assert a1.worker is a10
        sched.on_start(a1)
        # head: another big task (unplaceable — only the TITAN is idle and
        # it cannot host 16 GB device bytes); deeper: a small task
        blocked = Task(k_big, 10, PERVASIVE, active_params=BIG_AP)
        deep = Task(k_small, 10, PERVASIVE, active_params=AP)
        sched.submit(blocked)
        sched.submit(deep)
        a2 = sched.route()
        assert a2 is not None, "backfill must route past the blocked head"
        assert a2.task is deep and a2.worker is titan
        assert blocked.skipped == 1
        assert sched.backfills == 1

    def test_seed_fifo_mode_stalls_on_blocked_head(self):
        sched, k_big, k_small, a10, titan = self._pool(backfill=False)
        sched.submit(Task(k_big, 10, PERVASIVE, active_params=BIG_AP))
        sched.on_start(sched.route())
        sched.submit(Task(k_big, 10, PERVASIVE, active_params=BIG_AP))
        sched.submit(Task(k_small, 10, PERVASIVE, active_params=AP))
        # seed policy examines only the queue head → whole pool stalls
        assert sched.route() is None

    def test_aging_bound_reserves_capable_worker(self):
        """A starved head eventually beats warm-routed younger tasks."""
        sched = Scheduler(aging_bound=2)
        k_big = sched.register_context(BIG_RECIPE)
        k_small = sched.register_context(RECIPE)
        a10 = Worker(GPU_CATALOG["NVIDIA A10"], shape=MIXED_SHAPE)
        sched.add_worker(a10)
        # warm the worker for the small recipe
        lib = a10.library_for(RECIPE)
        lib.materialize_cost(a10.device, fetch_bw=float("inf"))
        sched.registry.mark_ready(k_small, a10.worker_id)
        # oldest task: big (cold); younger: a stream of small (warm)
        big = Task(k_big, 10, PERVASIVE, active_params=BIG_AP)
        sched.submit(big)
        for _ in range(5):
            sched.submit(Task(k_small, 10, PERVASIVE, active_params=AP))
        dispatched = []
        for _ in range(3):
            a = sched.route()
            dispatched.append(a.task.recipe_key)
            sched.on_start(a)
            if not a.warm:
                sched.on_staged(a)
            sched.on_complete(a, 0.0, 1.0)
        # warm-first wins twice; at skipped == aging_bound the worker is
        # reserved and the big head finally lands
        assert dispatched == [k_small, k_small, k_big]
        assert big.skipped == sched.aging_bound

    def test_eviction_mid_staging_requeues_and_finishes(self):
        """Worker reclaimed while its context is still materialising."""
        sched, ex, fac = make_sim(worker_shape=MIXED_SHAPE)
        key = sched.register_context(BIG_RECIPE)
        sched.submit(Task(key, 50, PERVASIVE, active_params=BIG_AP))
        fac.reconcile(1)
        ex.pump()
        ex.loop.run(until=5.0, stop=lambda: sched.done)
        assert sched.running, "task must be in flight (staging)"
        wid = next(iter(sched.workers))
        sched.on_evict(wid, now=ex.loop.now)
        assert sched.evicted_tasks == 1
        assert not sched.registry.workers_with(key), \
            "lost residencies must vanish from the registry"
        fac.reconcile(1)            # replacement joins
        ex.run()
        assert sched.completed_inferences == 50
        assert all(r.attempts > 0 for r in sched.records)


class TestSpill:
    """Multi-context workers: tier spill instead of drop_library."""

    def test_recipe_switch_spills_and_repromotes_locally(self):
        sched = Scheduler()
        k_big = sched.register_context(BIG_RECIPE)
        k_small = sched.register_context(RECIPE)
        w = Worker(GPU_CATALOG["NVIDIA A10"], shape=MIXED_SHAPE)
        sched.add_worker(w)
        # host the small recipe
        lib_s = w.library_for(RECIPE)
        lib_s.materialize_cost(w.device, fetch_bw=float("inf"))
        sched.registry.mark_ready(k_small, w.worker_id)
        # big task arrives: both cannot be host-resident together
        sched.submit(Task(k_big, 10, PERVASIVE, active_params=BIG_AP))
        a = sched.route()
        assert a.worker is w and not a.warm
        sched.on_start(a)
        # the small library was spilled, not dropped
        assert not lib_s.ready and lib_s.spills == 1
        assert sched.registry.spilled_workers(k_small) == {w.worker_id}
        weights = RECIPE.element("weights")
        assert w.cache.tier_of(weights.key) is Tier.DISK
        assert w.cache.pins(weights.key) == 0
        # the shared deps element is still pinned by the big library's
        # materialisation and must not lose residency
        lib_b = w.library_for(BIG_RECIPE)
        cost_b = lib_b.materialize_cost(w.device, fetch_bw=1e9)
        sched.on_staged(a)
        deps = RECIPE.element("deps")
        assert w.cache.pins(deps.key) >= 1
        sched.on_complete(a, 0.0, 1.0)
        # switching back: cold but LOCAL — promotion from disk, no fetch
        small2 = Task(k_small, 10, PERVASIVE, active_params=AP)
        sched.submit(small2)
        a2 = sched.route()
        assert a2.task is small2 and a2.worker is w
        assert not a2.warm and a2.local_restage
        assert a2.peer_source is None
        sched.on_start(a2)
        cost = w.library_for(RECIPE).materialize_cost(w.device)
        assert cost.fetch_s == 0.0, "re-promotion must not re-fetch"
        assert cost.load_s > 0.0

    def test_mixed_sweep_completes_with_spills(self):
        """End-to-end: one worker alternating two recipes via spill."""
        sched, ex, fac = make_sim(devices=[GPU_CATALOG["NVIDIA A10"]],
                                  worker_shape=MIXED_SHAPE)
        k_big = sched.register_context(BIG_RECIPE)
        k_small = sched.register_context(RECIPE)
        for _ in range(3):
            sched.submit(Task(k_big, 20, PERVASIVE, active_params=BIG_AP))
            sched.submit(Task(k_small, 20, PERVASIVE, active_params=AP))
        fac.reconcile(1)
        ex.run()
        assert sched.completed_inferences == 120
        assert sched.spilled_libraries > 0
        w = next(iter(sched.workers.values()))
        assert w.cache.stats()["demotions"] > 0


class TestWarmPool:
    def test_hot_recipe_replicated_ahead_of_demand(self):
        policy = WarmPoolPolicy(min_replicas=4, tasks_per_replica=1000,
                                max_fraction=1.0)
        sched, ex, fac = make_sim(devices=[GPU_CATALOG["NVIDIA A10"]] * 4,
                                  warm_pool=policy)
        key = sched.register_context(RECIPE)
        for _ in range(2):
            sched.submit(Task(key, 50, PERVASIVE, active_params=AP))
        fac.reconcile(4)
        ex.loop.run()               # drain everything incl. staging events
        assert sched.completed_inferences == 100
        # only 2 tasks ran, but the policy staged the other 2 idle workers
        assert sched.registry.replication(key) == 4
        # the next wave routes warm everywhere — no new cold starts
        for _ in range(4):
            sched.submit(Task(key, 50, PERVASIVE, active_params=AP))
        ex.run()
        assert sched.completed_inferences == 300
        assert sum(1 for r in sched.records if not r.warm) == 2

    def test_live_executor_exercises_warm_pool(self):
        loads = []
        tiny = ContextRecipe("live::tiny", (
            ContextElement("deps", nbytes_disk=1000, nbytes_host=100,
                           version="t", loader=lambda: loads.append(1)),
            ContextElement("weights", nbytes_disk=1000, nbytes_host=100,
                           version="t", loader=lambda: object()),
        ))
        policy = WarmPoolPolicy(min_replicas=2, tasks_per_replica=1000,
                                max_fraction=1.0)
        sched = Scheduler()
        key = sched.register_context(tiny)
        for _ in range(2):
            sched.add_worker(Worker(GPU_CATALOG["NVIDIA A10"]))
        for i in range(3):
            sched.submit(Task(key, 1, PERVASIVE, payload=i))
        ex = LiveExecutor(sched, {key: lambda payloads, p: p},
                          warm_pool=policy)
        ex.run()
        assert sorted(ex.results.values()) == [0, 1, 2]
        # the second worker was warmed by the policy, not by a task
        assert sched.registry.replication(key) == 2
        assert all(w.has_ready(key) for w in sched.workers.values())


class TestMultiContext:
    def test_two_contexts_share_the_pool(self):
        """Two (LLM, template) pairs — PfF's real workload — interleave on
        the same workers; each routes warm to its OWN context."""
        import dataclasses
        r1 = RECIPE
        r2 = dataclasses.replace(RECIPE, fn_name="infer::other-template")
        assert r1.key != r2.key
        sched, ex, fac = make_sim()
        k1 = sched.register_context(r1)
        k2 = sched.register_context(r2)
        sched.submit_sweep(k1, 2_000, 100, PERVASIVE, active_params=AP)
        sched.submit_sweep(k2, 2_000, 100, PERVASIVE, active_params=AP)
        fac.reconcile(4)
        ex.run()
        assert sched.completed_inferences == 4_000
        # both contexts became resident somewhere
        assert sched.registry.replication(k1) > 0
        assert sched.registry.replication(k2) > 0
