"""Live-executor integration: the context lifecycle runs for REAL (imports,
weight init, jit compile, reuse) through the same scheduler as the sim."""
import numpy as np
import pytest

from repro.cluster import LiveExecutor, Scheduler, Worker
from repro.cluster.hardware import GPU_CATALOG
from repro.cluster.scheduler import Task
from repro.configs import get_smoke_config
from repro.core import MODES, PERVASIVE, PARTIAL
from repro.data import accuracy, claim_batches, generate_claims
from repro.inference import build_context_recipe, infer_claims


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("smollm2-1.7b")
    claims = generate_claims(24, seed=1)
    recipe = build_context_recipe(cfg, "with_evidence")
    return cfg, claims, recipe


def run_live(recipe, claims, mode, workers=2, batch=8):
    sched = Scheduler()
    key = sched.register_context(recipe)
    for _ in range(workers):
        sched.add_worker(Worker(GPU_CATALOG["NVIDIA A10"]))
    for b in claim_batches(claims, batch):
        sched.submit(Task(key, len(b), mode, payload=b))
    ex = LiveExecutor(sched, {key: infer_claims})
    ex.run()
    return sched, ex


class TestLivePfF:
    def test_all_results_returned_in_order(self, setup):
        _, claims, recipe = setup
        sched, ex = run_live(recipe, claims, PERVASIVE)
        preds = [p for tid in sorted(ex.results) for p in ex.results[tid]]
        assert len(preds) == len(claims)
        assert all(p in ("SUPPORTED", "REFUTED", "NOT ENOUGH INFO")
                   for p in preds)

    def test_warm_invocations_much_faster_than_cold(self, setup):
        """The live measurement of the paper's central effect."""
        _, claims, recipe = setup
        sched, _ = run_live(recipe, claims, PERVASIVE, workers=1)
        recs = sorted(sched.records, key=lambda r: r.t_start)
        cold, warm = recs[0], recs[1:]
        assert warm
        assert cold.exec_s > 5 * max(r.exec_s for r in warm)

    def test_pervasive_beats_partial_live(self, setup):
        _, claims, recipe = setup
        s_perv, _ = run_live(recipe, claims, PERVASIVE, workers=1)
        s_part, _ = run_live(recipe, claims, PARTIAL, workers=1)
        assert s_perv.makespan() < s_part.makespan()

    def test_deterministic_predictions_across_modes(self, setup):
        """Context mode must not change RESULTS, only performance."""
        _, claims, recipe = setup
        _, ex1 = run_live(recipe, claims, PERVASIVE, workers=1)
        _, ex2 = run_live(recipe, claims, PARTIAL, workers=1)
        p1 = [p for tid in sorted(ex1.results) for p in ex1.results[tid]]
        p2 = [p for tid in sorted(ex2.results) for p in ex2.results[tid]]
        assert p1 == p2
