"""Sharding-rule resolution + a real (subprocess) dry-run compile.

The in-process tests exercise rule logic against synthetic meshes via the
resolver directly (this host has one device, so mesh axes of size 1 are
dropped — we construct multi-device meshes in a subprocess with
xla_force_host_platform_device_count, exactly like the dry-run)."""
import json
import os
import subprocess
import sys

import pytest

from jax.sharding import PartitionSpec as P

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str) -> str:
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


class TestResolverRules:
    def test_resolution_on_8dev_mesh(self):
        out = run_sub("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, json
from repro.sharding import ShardingCtx
from repro.configs import get_config
mesh = jax.make_mesh((2, 4), ("data", "model"))
ctx = ShardingCtx(mesh, get_config("granite-3-8b"))
print(json.dumps({
  # batch -> data axis
  "batch": str(ctx.resolve(("batch", None), (16, 7))),
  # 8 kv heads divide 4-way model axis
  "kv": str(ctx.resolve((None, "kv_heads", None), (1, 8, 128))),
  # 3 kv heads do NOT divide 4 -> replicated
  "kv3": str(ctx.resolve((None, "kv_heads", None), (1, 3, 128))),
  # two logical axes cannot claim the same mesh axis
  "dup": str(ctx.resolve(("heads", "ff"), (32, 12800))),
}))
""")
        got = json.loads(out)
        assert got["batch"] == "PartitionSpec('data', None)"
        assert got["kv"] == "PartitionSpec(None, 'model', None)"
        assert got["kv3"] == "PartitionSpec(None, None, None)"
        assert got["dup"] == "PartitionSpec('model', None)"

    def test_param_rules_cover_all_leaves(self):
        out = run_sub("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.sharding import ShardingCtx, param_specs
from repro.configs import get_smoke_config
from repro.models import model as M
mesh = jax.make_mesh((2, 4), ("data", "model"))
n_sharded = 0
for arch in ("granite-3-8b", "deepseek-v3-671b", "xlstm-350m",
             "hymba-1.5b", "whisper-small"):
    cfg = get_smoke_config(arch)
    ctx = ShardingCtx(mesh, cfg)
    params = jax.eval_shape(lambda k: M.init_params(cfg, k),
                            jax.random.PRNGKey(0))
    specs = param_specs(params, ctx)
    leaves = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec))
    n_sharded += sum(1 for s in leaves if any(a is not None for a in s))
print("sharded:", n_sharded)
""")
        assert int(out.split(":")[1]) > 20

    def test_hint_noop_without_ctx(self):
        import jax.numpy as jnp
        from repro.sharding import hint
        x = jnp.ones((4, 4))
        assert hint(x, "batch", "embed") is x


@pytest.mark.slow
class TestDryrunSubprocess:
    def test_single_combo_compiles_on_production_mesh(self):
        out = run_sub("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.dryrun import dryrun_one
rec = dryrun_one("olmo-1b", "decode_32k", verbose=False)
import json
print(json.dumps({k: rec[k] for k in
                  ("chips", "bottleneck", "hlo_flops", "collective_bytes")}))
""")
        got = json.loads(out.strip().splitlines()[-1])
        assert got["chips"] == 256
        assert got["hlo_flops"] > 0

    def test_multipod_mesh_has_pod_axis(self):
        out = run_sub("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
from repro.launch.mesh import make_production_mesh
m = make_production_mesh(multi_pod=True)
print(m.axis_names, m.devices.shape)
""")
        assert "('pod', 'data', 'model') (2, 16, 16)" in out
