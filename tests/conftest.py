"""Shared fixtures. NOTE: no XLA_FLAGS here — tests see the host's real
single device; only the dry-run subprocess uses placeholder devices."""
import os
import sys

_ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)         # for the `benchmarks` namespace package

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
