"""The context plane: single-writer discipline, priced/budgeted plans,
LOST tombstones, arrival-aware warm pool, and plan/executed byte parity.
"""
import pathlib
import re

import pytest

from repro.core import (Acquire, ClusterView, HostState, LinkBudget, OpKind,
                        PERVASIVE, Peer, Release, Replicate, Tier,
                        WarmPoolPolicy, model_context_recipe, pick_sources,
                        plan_spanning_tree)
from repro.cluster import (GPU_CATALOG, LiveExecutor, Request, Scheduler,
                           SimExecutor, Worker, make_sim, traces,
                           zone_byte_summary)
from repro.cluster.scheduler import Task
from repro.configs import get_config

from benchmarks.common import BIG_AP, BIG_RECIPE, MIXED_SHAPE

CFG = get_config("smollm2-1.7b")
RECIPE = model_context_recipe(CFG, include_compile=False)
AP = CFG.n_active_params()
A10 = GPU_CATALOG["NVIDIA A10"]
SRC = pathlib.Path(__file__).resolve().parent.parent / "src" / "repro"


def warm(sched, w, recipe, key):
    w.library_for(recipe).materialize_cost(w.device, fetch_bw=float("inf"))
    sched.plane.note_ready(key, w.worker_id)


# ---------------------------------------------------------------------------
# Single-writer discipline (grep-enforced)
# ---------------------------------------------------------------------------

REGISTRY_WRITE = re.compile(
    r"\b(?:registry|reg)\s*\.\s*"
    r"(register|mark_staging|mark_ready|mark_spilled|drop_worker|forget)"
    r"\s*\(")
ALLOWED = {("core", "plane.py"), ("core", "registry.py")}


def test_all_registry_writes_live_in_the_plane():
    """Every ContextRegistry mutation in src/repro flows through
    core/plane.py — the tentpole's architectural invariant."""
    offenders = []
    for path in SRC.rglob("*.py"):
        if tuple(path.parts[-2:]) in ALLOWED:
            continue
        for i, line in enumerate(path.read_text().splitlines(), 1):
            if "``" in line or line.lstrip().startswith("#"):
                continue                # docs (migration tables), comments
            if REGISTRY_WRITE.search(line):
                offenders.append(f"{path.relative_to(SRC)}:{i}: "
                                 f"{line.strip()}")
    assert not offenders, (
        "registry mutations outside core/plane.py:\n" + "\n".join(offenders))


# ---------------------------------------------------------------------------
# Acquire compilation: the priced op per placement situation
# ---------------------------------------------------------------------------

class TestAcquireCompile:
    def test_fetch_when_no_ready_peer(self):
        sched = Scheduler()
        key = sched.register_context(RECIPE)
        w = Worker(A10, zone="z1")
        sched.add_worker(w)
        plan = sched.plane.compile([Acquire(key, w.worker_id)],
                                   sched.view())
        op = plan.acquire_op()
        assert op.kind is OpKind.FETCH
        assert op.nbytes == RECIPE.transfer_bytes
        assert op.dst_zone == "z1"

    def test_peer_copy_prefers_in_zone_source(self):
        sched = Scheduler()
        key = sched.register_context(RECIPE)
        near = Worker(A10, zone="z1")
        far = Worker(A10, zone="z0")
        dst = Worker(A10, zone="z1")
        for w in (near, far, dst):
            sched.add_worker(w)
        warm(sched, near, RECIPE, key)
        warm(sched, far, RECIPE, key)
        plan = sched.plane.compile([Acquire(key, dst.worker_id)],
                                   sched.view())
        op = plan.acquire_op()
        assert op.kind is OpKind.PEER_COPY
        assert op.src_worker == near.worker_id and not op.cross_zone

    def test_promote_for_spilled_local_copy_and_spill_preview(self):
        sched = Scheduler()
        k_small = sched.register_context(RECIPE)
        k_big = sched.register_context(BIG_RECIPE)
        w = Worker(A10, shape=MIXED_SHAPE)
        sched.add_worker(w)
        warm(sched, w, RECIPE, k_small)
        # acquiring the big recipe must preview the small library's spill
        plan = sched.plane.compile([Acquire(k_big, w.worker_id)],
                                   sched.view())
        kinds = [op.kind for op in plan.ops]
        assert kinds == [OpKind.SPILL, OpKind.FETCH]
        assert plan.ops[0].recipe_key == k_small
        # spill it for real: re-acquiring the small recipe is a PROMOTE
        w.libraries[k_small].spill()
        sched.plane.note_spilled(k_small, w.worker_id)
        plan2 = sched.plane.compile([Acquire(k_small, w.worker_id)],
                                    sched.view())
        op = plan2.acquire_op()
        assert op.kind is OpKind.PROMOTE and op.nbytes == 0

    def test_same_key_intents_share_one_plan_budget(self):
        """Recovery and policy can both emit Replicate for one recipe in
        the same round; the plan must not place a full set for each."""
        sched = Scheduler()
        key = sched.register_context(RECIPE)
        seed = Worker(A10, zone="z0")
        sched.add_worker(seed)
        warm(sched, seed, RECIPE, key)
        for _ in range(6):
            sched.add_worker(Worker(A10, zone="z1"))
        plan = sched.plane.compile([Replicate(key, 1), Replicate(key, 3)],
                                   sched.view())
        assert len(plan.acquire_ops()) == 2      # 3 wanted, 1 ready seed

    def test_release_spill_op_really_executes(self):
        sched = Scheduler()
        ex = SimExecutor(sched)
        key = sched.register_context(RECIPE)
        w = Worker(A10)
        sched.add_worker(w)
        warm(sched, w, RECIPE, key)
        plan = sched.plane.compile([Release(key, w.worker_id)],
                                   sched.view())
        ex.execute_plan(plan)
        assert not w.libraries[key].ready and w.libraries[key].spills == 1
        assert sched.registry.spilled_workers(key) == {w.worker_id}
        weights = RECIPE.element("weights")
        assert w.cache.tier_of(weights.key) is Tier.DISK

    def test_release_spills_then_evicts(self):
        sched = Scheduler()
        key = sched.register_context(RECIPE)
        w = Worker(A10)
        sched.add_worker(w)
        warm(sched, w, RECIPE, key)
        plan = sched.plane.compile([Release(key, w.worker_id)],
                                   sched.view())
        assert [op.kind for op in plan.ops] == [OpKind.SPILL]
        sched.plane.note_spilled(key, w.worker_id)
        plan2 = sched.plane.compile([Release(key, w.worker_id)],
                                    sched.view())
        assert [op.kind for op in plan2.ops] == [OpKind.EVICT]


# ---------------------------------------------------------------------------
# LOST tombstones + recovery (satellite: drop_worker fix)
# ---------------------------------------------------------------------------

class TestLostTombstones:
    def test_drop_worker_marks_lost_not_delete(self):
        sched = Scheduler()
        key = sched.register_context(RECIPE)
        w = Worker(A10)
        sched.add_worker(w)
        warm(sched, w, RECIPE, key)
        lost = sched.plane.drop_worker(w.worker_id)
        reg = sched.registry
        assert lost == [key]
        assert reg.state(key, w.worker_id) is HostState.LOST
        assert reg.lost_workers(key) == {w.worker_id}
        # tombstones are bookkeeping, not copies
        assert reg.workers_with(key) == set()
        assert reg.replication(key) == 0

    def test_recovery_intent_emitted_while_demand_exists(self):
        sched = Scheduler()
        key = sched.register_context(RECIPE)
        w = Worker(A10)
        sched.add_worker(w)
        warm(sched, w, RECIPE, key)
        sched.submit(Request(key, decode_steps=8, exclusive=True))
        sched.on_evict(w.worker_id)
        intents = sched.plane.recovery_intents(sched.view())
        assert intents == [Replicate(key, 1)]
        # the tombstone survives until the loss is recovered
        assert sched.plane.recovery_intents(sched.view()) == [
            Replicate(key, 1)]
        # a copy comes back: tombstone + LOST records are consumed
        w2 = Worker(A10)
        sched.add_worker(w2)
        warm(sched, w2, RECIPE, key)
        assert sched.plane.recovery_intents(sched.view()) == []
        assert sched.registry.lost_workers(key) == set()

    def test_sim_rereplicates_after_losing_last_warm_copy(self):
        policy = WarmPoolPolicy(min_replicas=1, tasks_per_replica=1000)
        sched, ex, fac = make_sim(devices=[A10] * 3, warm_pool=policy)
        key = sched.register_context(RECIPE)
        sched.submit(Task(key, 400, PERVASIVE, active_params=AP))
        sched.submit(Task(key, 400, PERVASIVE, active_params=AP))
        fac.reconcile(2)
        ex.pump()
        ex.loop.run(until=120.0, stop=lambda: False)
        wid = next(iter(sched.registry.ready_workers(key)))
        sched.on_evict(wid, now=ex.loop.now)
        fac.reconcile(2)                # replacement joins cold
        ex.run()
        assert sched.completed_inferences == 800
        assert sched.registry.replication(key) >= 1


# ---------------------------------------------------------------------------
# LinkBudget: zone at budget DEFERS, never drops (satellite regression)
# ---------------------------------------------------------------------------

class TestLinkBudget:
    def _pool(self, budget):
        sched = Scheduler(link_budget=budget)
        key = sched.register_context(RECIPE)
        seed = Worker(A10, zone="z0")
        sched.add_worker(seed)
        warm(sched, seed, RECIPE, key)
        joiners = [Worker(A10, zone="z1") for _ in range(3)]
        for w in joiners:
            sched.add_worker(w)
        return sched, key

    def test_zone_at_budget_defers_not_drops(self):
        nb = RECIPE.transfer_bytes
        sched, key = self._pool(LinkBudget(cross_bytes_per_window=1.5 * nb,
                                           window_s=60.0))
        plane = sched.plane
        plan = plane.compile([Replicate(key, 4)], sched.view(now=0.0))
        # one cross copy fits the window; the other two are DEFERRED,
        # recorded on the plan — not silently dropped
        assert len(plan.acquire_ops()) == 1
        assert len(plan.deferred) == 1
        assert plan.deferred[0].intent == Replicate(key, 4)
        assert plan.deferred[0].short == 2
        plane.commit(plan, now=0.0)
        plane.op_started(plan.acquire_op())
        # inside the window the zone stays saturated: everything defers
        plan2 = plane.compile([Replicate(key, 4)], sched.view(now=10.0))
        assert not plan2.acquire_ops() and plan2.deferred
        # the window slides: the deferred replica is admitted again
        plan3 = plane.compile([Replicate(key, 4)], sched.view(now=70.0))
        assert len(plan3.acquire_ops()) == 1
        assert plane.deferred_intents >= 2

    def test_unbounded_budget_never_defers(self):
        sched, key = self._pool(None)
        plan = sched.plane.compile([Replicate(key, 4)], sched.view())
        assert len(plan.acquire_ops()) == 3 and not plan.deferred

    def test_acquire_is_never_deferred(self):
        nb = RECIPE.transfer_bytes
        sched, key = self._pool(LinkBudget(cross_bytes_per_window=0.5 * nb,
                                           window_s=60.0))
        wid = [w for w in sched.workers.values() if w.zone == "z1"][0]
        plan = sched.plane.compile([Acquire(key, wid.worker_id)],
                                   sched.view())
        assert plan.acquire_op().kind is OpKind.PEER_COPY
        assert not plan.deferred


# ---------------------------------------------------------------------------
# Arrival-aware warm pool (satellite: EWMA sizing)
# ---------------------------------------------------------------------------

class TestArrivalAwareWarmPool:
    def test_scheduler_tracks_arrival_ewma(self):
        sched = Scheduler()
        key = sched.register_context(RECIPE)
        for i in range(150):
            sched.submit(Request(key, decode_steps=4,
                                 arrival_s=float(i)))
        rate = sched.view().arrival_rate[key]
        assert rate == pytest.approx(1.0, rel=0.1)

    def test_horizon_emits_replicate_before_backlog(self):
        sched = Scheduler()
        key = sched.register_context(RECIPE)
        for _ in range(4):
            sched.add_worker(Worker(A10))
        # steady 2 req/s arrivals, but the queue itself is still short
        for i in range(40):
            sched.submit(Request(key, decode_steps=4,
                                 arrival_s=i * 0.5))
        for lane in sched.lanes.values():
            kept = [lane.popleft() for _ in range(1)]
            lane.clear()
            lane.extend(kept)
        reactive = WarmPoolPolicy(tasks_per_replica=4, max_fraction=1.0)
        proactive = WarmPoolPolicy(tasks_per_replica=4, max_fraction=1.0,
                                   arrival_horizon_s=8.0)
        view = sched.view()
        n_reactive = {r.recipe_key: r.n for r in reactive.intents(view)}
        n_proactive = {r.recipe_key: r.n for r in proactive.intents(view)}
        assert n_proactive[key] > n_reactive.get(key, 0), \
            "the EWMA term must size the pool ahead of the backlog"


# ---------------------------------------------------------------------------
# Transfer satellites: dst-indexed arrival, bw tie-break
# ---------------------------------------------------------------------------

class TestTransferSatellites:
    def test_arrival_is_dst_indexed_and_correct(self):
        srcs = [Peer("s0", "z0")]
        tgts = [Peer(f"t{i}", f"z{i % 3}") for i in range(12)]
        plan = plan_spanning_tree(10**9, srcs, tgts, fanout_cap=2)
        for e in plan.edges:
            assert plan.arrival(e.dst) == e.end_s
        assert plan.arrival("not-a-worker") is None
        # direct edge appends (legacy callers) still resolve
        plan.edges.append(type(plan.edges[0])("s0", "tX", 10**9,
                                              0.0, 1.0, False))
        assert plan.arrival("tX") == 1.0

    def test_pick_sources_prefers_higher_local_bandwidth_on_ties(self):
        slow = Peer("slow", "z1", bw_local=5e9)
        fast = Peer("fast", "z1", bw_local=20e9)
        other = Peer("other", "z0", bw_local=50e9)
        assert pick_sources([slow, fast, other], "z1")[0] is fast
        # zone preference still dominates raw bandwidth
        assert pick_sources([slow, other], "z1")[0] is slow


# ---------------------------------------------------------------------------
# Plan/executed byte parity (satellite: property + deterministic)
# ---------------------------------------------------------------------------

def assert_bytes_balanced(sched):
    plane = sched.plane
    assert plane.inflight_ops == 0
    assert plane.planned.as_dict() == plane.moved.as_dict(), \
        zone_byte_summary(plane)


class TestByteParity:
    def test_sim_moves_exactly_the_priced_bytes(self):
        """Cold dispatches, warm-pool replication, spills and an eviction
        mid-run: per zone and link class the executor moves exactly what
        the committed plans priced."""
        policy = WarmPoolPolicy(tasks_per_replica=2, max_fraction=1.0)
        sched, ex, fac = make_sim(devices=[A10] * 9, warm_pool=policy,
                                  workers_per_zone=3,
                                  trace=[(0.0, 9), (40.0, 5), (80.0, 9)])
        key = sched.register_context(RECIPE)
        sched.submit_sweep(key, 6_000, 250, PERVASIVE, active_params=AP)
        ex.run()
        ex.loop.run()                   # drain trailing staging events
        assert sched.completed_inferences == 6_000
        assert_bytes_balanced(sched)
        assert sched.plane.moved.total() > 0

    def test_budgeted_run_still_completes_all_work(self):
        budget = LinkBudget(cross_bytes_per_window=RECIPE.transfer_bytes,
                            window_s=30.0)
        policy = WarmPoolPolicy(tasks_per_replica=2, max_fraction=1.0)
        sched, ex, fac = make_sim(devices=[A10] * 6, warm_pool=policy,
                                  workers_per_zone=2, link_budget=budget)
        key = sched.register_context(RECIPE)
        sched.submit_sweep(key, 4_000, 250, PERVASIVE, active_params=AP)
        fac.reconcile(6)
        ex.run()
        ex.loop.run()
        assert sched.completed_inferences == 4_000
        assert_bytes_balanced(sched)

    def test_live_executor_runs_the_same_plan_ops(self):
        from repro.core import ContextElement, ContextRecipe
        tiny = ContextRecipe("plane::tiny", (
            ContextElement("deps", nbytes_disk=1000, nbytes_host=100,
                           version="t", loader=lambda: {"ok": True}),
            ContextElement("weights", nbytes_disk=1000, nbytes_host=100,
                           version="t", loader=lambda: object()),
        ))
        policy = WarmPoolPolicy(min_replicas=3, tasks_per_replica=1000,
                                max_fraction=1.0)
        sched = Scheduler()
        key = sched.register_context(tiny)
        for _ in range(3):
            sched.add_worker(Worker(A10))
        for i in range(2):
            sched.submit(Task(key, 1, PERVASIVE, payload=i))
        ex = LiveExecutor(sched, {key: lambda payloads, p: p},
                          warm_pool=policy)
        ex.run()
        assert sched.registry.replication(key) == 3
        assert_bytes_balanced(sched)


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                     # optional, like the other property
    HAVE_HYPOTHESIS = False             # tests (requirements-dev.txt)

if HAVE_HYPOTHESIS:
    @given(st.integers(3, 8),           # workers
           st.integers(1, 4),           # workers per zone
           st.integers(2, 6),           # tasks
           st.booleans(),               # budgeted?
           st.integers(0, 1))           # eviction dip?
    @settings(max_examples=20, deadline=None)
    def test_priced_bytes_match_moved_bytes_property(
            n_workers, per_zone, n_tasks, budgeted, dip):
        budget = LinkBudget(
            cross_bytes_per_window=1.2 * RECIPE.transfer_bytes,
            window_s=45.0) if budgeted else None
        policy = WarmPoolPolicy(tasks_per_replica=1, max_fraction=1.0)
        trace = [(0.0, n_workers)]
        if dip:
            trace += [(35.0, max(1, n_workers // 2)), (70.0, n_workers)]
        sched, ex, fac = make_sim(devices=[A10] * n_workers,
                                  warm_pool=policy, link_budget=budget,
                                  workers_per_zone=per_zone, trace=trace)
        key = sched.register_context(RECIPE)
        sched.submit_sweep(key, n_tasks * 200, 200, PERVASIVE,
                           active_params=AP)
        ex.run()
        ex.loop.run()
        assert sched.completed_inferences == n_tasks * 200
        assert_bytes_balanced(sched)

    @given(st.integers(0, 2),           # compute-rich Adas
           st.integers(2, 5),           # memory-side A10s
           st.integers(2, 4),           # workers per zone
           st.integers(4, 16),          # phase-split requests
           st.booleans(),               # budgeted?
           st.integers(0, 1))           # eviction dip?
    @settings(max_examples=15, deadline=None)
    def test_kv_ship_bytes_balance_property(
            n_ada, n_a10, per_zone, n_reqs, budgeted, dip):
        """Disaggregated request streams: every KV_SHIP the router
        commits either lands (moved == planned, metered per landing
        zone) or is refunded by churn — the parity invariant holds with
        ships in the mix, under budget pressure and worker loss alike."""
        from repro.cluster import Application
        ada = GPU_CATALOG["NVIDIA RTX 6000 Ada Generation"]
        pool = [ada] * n_ada + [A10] * n_a10
        budget = LinkBudget(
            cross_bytes_per_window=1.2 * RECIPE.transfer_bytes,
            window_s=45.0) if budgeted else None
        trace = [(0.0, len(pool))]
        if dip:
            trace += [(40.0, max(1, len(pool) // 2)), (80.0, len(pool))]
        sched, ex, fac = make_sim(devices=pool, link_budget=budget,
                                  workers_per_zone=per_zone, trace=trace,
                                  disaggregate=True)
        app = Application(sched)
        key = app.register(RECIPE, active_params=AP)
        app.submit_stream(ex, [dict(recipe_key=key, prompt_units=3,
                                    decode_steps=16, arrival_s=0.5 * i)
                               for i in range(n_reqs)])
        ex.run(until=20_000.0)
        ex.loop.run()
        assert sched.done
        assert sched.prefills_done >= n_reqs     # churn may re-prefill
        assert_bytes_balanced(sched)
        kv = sched.plane.kv_summary()
        assert sum(sched.plane.kv_shipped.values()) == kv["shipped_bytes"]
        assert kv["ship_events"] == sched.kv_ships
