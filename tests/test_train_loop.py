"""End-to-end training loop: loss decreases; resume from checkpoint works;
microbatched gradient accumulation matches the single-batch step."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data import ByteTokenizer, TokenStream
from repro.launch.steps import make_train_step
from repro.launch.train import main as train_main, reduced
from repro.configs import get_config
from repro.models import model as M
from repro.optim import adamw_init


def test_train_driver_improves(tmp_path):
    rc = train_main(["--arch", "olmo-1b", "--steps", "25",
                     "--d-model", "128", "--layers", "2",
                     "--batch", "4", "--seq", "128",
                     "--ckpt", str(tmp_path / "ck")])
    assert rc == 0
    from repro.checkpointing import checkpoint_step
    assert checkpoint_step(str(tmp_path / "ck")) == 25


def test_microbatch_equals_full_batch():
    """grad-accum (k=2) step ≈ one full-batch step (same data)."""
    cfg = get_smoke_config("olmo-1b").with_(dtype="float32")
    cfg_mb = cfg.with_(parallel=cfg.parallel.__class__(remat="none",
                                                       microbatch=2))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    tok = ByteTokenizer(cfg.vocab_size)
    batch = {"tokens": jnp.asarray(
        next(iter(TokenStream(tok, batch=4, seq_len=64)))["tokens"])}
    p1, _, m1 = make_train_step(cfg)(params, opt, batch)
    p2, _, m2 = make_train_step(cfg_mb)(params, opt, batch)
    # losses agree; params agree to fp tolerance
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=2e-3)
    a = jax.tree_util.tree_leaves(p1)[3]
    b = jax.tree_util.tree_leaves(p2)[3]
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=5e-3, atol=5e-4)


def test_reduced_keeps_family_features():
    r = reduced(get_config("deepseek-v3-671b"), 128, 2)
    assert r.moe is not None and r.mla is not None
    r = reduced(get_config("xlstm-350m"), 128, 4)
    assert len(r.block_pattern) == 4
    r = reduced(get_config("whisper-small"), 128, 2)
    assert r.is_encdec
