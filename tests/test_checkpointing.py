"""Checkpoint save/restore: exactness, dtypes, resume metadata."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpointing import (checkpoint_step, restore_checkpoint,
                                 save_checkpoint)
from repro.configs import get_smoke_config
from repro.models import model as M


@pytest.fixture()
def params():
    cfg = get_smoke_config("olmo-1b")
    return cfg, M.init_params(cfg, jax.random.PRNGKey(0))


def test_roundtrip_exact(tmp_path, params):
    cfg, p = params
    nbytes = save_checkpoint(str(tmp_path), p, step=7)
    assert nbytes > 0
    assert checkpoint_step(str(tmp_path)) == 7
    restored = restore_checkpoint(str(tmp_path), p)
    flat_a = jax.tree_util.tree_leaves(p)
    flat_b = jax.tree_util.tree_leaves(restored)
    assert len(flat_a) == len(flat_b)
    for a, b in zip(flat_a, flat_b):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_shape_mismatch_rejected(tmp_path, params):
    cfg, p = params
    save_checkpoint(str(tmp_path), p)
    wrong = jax.tree_util.tree_map(
        lambda a: jnp.zeros(a.shape + (1,), a.dtype), p)
    with pytest.raises(ValueError, match="shape mismatch"):
        restore_checkpoint(str(tmp_path), wrong)


def test_missing_checkpoint_none(tmp_path):
    assert checkpoint_step(str(tmp_path)) is None
