"""Data substrate tests: tokenizer round-trip, claims determinism, prompts."""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.data import (ByteTokenizer, LABELS, TokenStream, claim_batches,
                        generate_claims, parse_verdict, TEMPLATES)


class TestTokenizer:
    @given(st.text(alphabet=st.characters(codec="utf-8",
                                          exclude_characters="\x00"),
                   max_size=200))
    @settings(max_examples=200, deadline=None)
    def test_roundtrip(self, text):
        tok = ByteTokenizer(512)
        normalized = " ".join(text.split())
        assert tok.decode(tok.encode(text)) == normalized

    def test_word_merges_used(self):
        tok = ByteTokenizer(512)
        ids = tok.encode("the claim is true", bos=False)
        # 4 common words + 3 spaces = 7 ids, far fewer than bytes
        assert len(ids) == 7

    def test_ids_in_vocab(self):
        tok = ByteTokenizer(300)
        ids = tok.encode("hello δοκιμή world")
        assert all(0 <= i < 300 for i in ids)

    def test_encode_batch_pads(self):
        tok = ByteTokenizer(512)
        out = tok.encode_batch(["a", "much longer text here"], 16)
        assert out.shape == (2, 16) and out.dtype == np.int32


class TestClaims:
    def test_deterministic(self):
        a = generate_claims(100, seed=3)
        b = generate_claims(100, seed=3)
        assert [c.text for c in a] == [c.text for c in b]
        assert [c.text for c in generate_claims(100, seed=4)] != \
            [c.text for c in a]

    def test_label_mix(self):
        claims = generate_claims(3000, seed=0)
        counts = {lbl: sum(c.label == lbl for c in claims)
                  for lbl in LABELS}
        for lbl, n in counts.items():
            assert n > 500, f"{lbl} underrepresented: {counts}"

    def test_supported_claims_match_evidence(self):
        for c in generate_claims(500, seed=1):
            if c.label == "SUPPORTED" and c.text:
                assert c.text == c.evidence
            if c.label == "REFUTED":
                assert c.text != c.evidence

    def test_empty_control_group(self):
        claims = generate_claims(5000, seed=0, empty_fraction=0.01)
        empties = [c for c in claims if not c.text]
        assert empties and all(c.label == "NOT ENOUGH INFO" for c in empties)

    def test_batching_covers_all(self):
        claims = generate_claims(103, seed=0)
        batches = claim_batches(claims, 10)
        assert sum(len(b) for b in batches) == 103
        assert len(batches) == 11


class TestPrompts:
    def test_all_templates_render(self):
        c = generate_claims(1, seed=0)[0]
        for t in TEMPLATES.values():
            s = t.render(c)
            assert isinstance(s, str) and "answer" in s

    def test_parse_verdict_first_match(self):
        assert parse_verdict("supported yes") == "SUPPORTED"
        assert parse_verdict("it is refuted clearly") == "REFUTED"
        assert parse_verdict("not enough info to tell") == "NOT ENOUGH INFO"
        assert parse_verdict("gibberish") == "NOT ENOUGH INFO"
        assert parse_verdict("refuted but maybe supported") == "REFUTED"


class TestTokenStream:
    def test_shapes_and_determinism(self):
        tok = ByteTokenizer(512)
        s1 = iter(TokenStream(tok, batch=4, seq_len=64, seed=5))
        s2 = iter(TokenStream(tok, batch=4, seq_len=64, seed=5))
        b1, b2 = next(s1), next(s2)
        assert b1["tokens"].shape == (4, 64)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        assert not np.array_equal(next(s1)["tokens"], b1["tokens"])
