"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.decode_attention.decode_attention import (
    decode_attention_paged_pallas, decode_attention_pallas)
from repro.kernels.decode_attention.ref import (decode_attention_paged_ref,
                                                decode_attention_ref,
                                                gather_pages_ref)
from repro.kernels.ssm_scan.ssm_scan import ssm_scan_pallas
from repro.kernels.ssm_scan.ref import ssm_scan_ref, ssm_step_ref


def _qkv(key, B, S, T, H, K, hd, dtype):
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, S, H, hd), dtype)
    k = jax.random.normal(kk, (B, T, K, hd), dtype)
    v = jax.random.normal(kv, (B, T, K, hd), dtype)
    return q, k, v


TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("B,S,T,H,K,hd", [
        (1, 128, 128, 4, 4, 64),       # MHA square
        (2, 128, 128, 8, 2, 64),       # GQA 4:1
        (1, 256, 256, 4, 1, 128),      # MQA, MXU-aligned head
        (1, 128, 256, 4, 2, 64),       # cross-length (cache longer)
    ])
    def test_sweep_vs_ref(self, dtype, B, S, T, H, K, hd):
        q, k, v = _qkv(jax.random.PRNGKey(0), B, S, T, H, K, hd, dtype)
        out = flash_attention_pallas(q, k, v, causal=True, interpret=True)
        ref = flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   **TOL[dtype])

    @pytest.mark.parametrize("window", [128, 256])
    def test_sliding_window(self, window):
        q, k, v = _qkv(jax.random.PRNGKey(1), 1, 384, 384, 4, 4, 64,
                       jnp.float32)
        out = flash_attention_pallas(q, k, v, causal=True, window=window,
                                     interpret=True)
        ref = flash_attention_ref(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_softcap(self):
        q, k, v = _qkv(jax.random.PRNGKey(2), 1, 128, 128, 2, 2, 64,
                       jnp.float32)
        out = flash_attention_pallas(q, k, v, causal=True, softcap=30.0,
                                     interpret=True)
        ref = flash_attention_ref(q, k, v, causal=True, softcap=30.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_non_causal(self):
        q, k, v = _qkv(jax.random.PRNGKey(3), 1, 128, 128, 2, 2, 64,
                       jnp.float32)
        out = flash_attention_pallas(q, k, v, causal=False, interpret=True)
        ref = flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_dispatch_unaligned_falls_back(self):
        # odd lengths route to the reference path and still agree with it
        q, k, v = _qkv(jax.random.PRNGKey(4), 1, 100, 100, 2, 2, 64,
                       jnp.float32)
        out = flash_attention(q, k, v, causal=True)
        ref = flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-6, atol=1e-6)


class TestDecodeAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("B,T,H,K,hd", [
        (1, 256, 4, 4, 64),
        (2, 256, 8, 2, 64),
        (1, 512, 16, 2, 128),
    ])
    def test_sweep_vs_ref(self, dtype, B, T, H, K, hd):
        q, k, v = _qkv(jax.random.PRNGKey(5), B, 1, T, H, K, hd, dtype)
        for n_valid in (T // 4, T):
            nv = jnp.asarray(n_valid, jnp.int32)
            out = decode_attention_pallas(q, k, v, nv, interpret=True)
            ref = decode_attention_ref(q, k, v, nv)
            np.testing.assert_allclose(np.asarray(out, np.float32),
                                       np.asarray(ref, np.float32),
                                       **TOL[dtype])

    def test_vector_n_valid_ragged_rows(self):
        """(B,) n_valid — each slot-pool row masked at its OWN length:
        pallas-interpret vs ref parity, and each row must equal a scalar
        single-row call at that row's length."""
        B, T, H, K, hd = 4, 256, 4, 2, 64
        q, k, v = _qkv(jax.random.PRNGKey(7), B, 1, T, H, K, hd, jnp.float32)
        nv = jnp.asarray([17, 256, 64, 1], jnp.int32)
        out = decode_attention_pallas(q, k, v, nv, interpret=True)
        ref = decode_attention_ref(q, k, v, nv)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
        for i in range(B):
            solo = decode_attention_ref(q[i:i + 1], k[i:i + 1], v[i:i + 1],
                                        nv[i])
            np.testing.assert_allclose(
                np.asarray(ref[i]), np.asarray(solo[0]), rtol=2e-5,
                atol=2e-5, err_msg=f"row {i} != scalar call at its length")

    def test_vector_n_valid_softcap(self):
        q, k, v = _qkv(jax.random.PRNGKey(8), 2, 1, 256, 4, 2, 64,
                       jnp.float32)
        nv = jnp.asarray([40, 200], jnp.int32)
        out = decode_attention_pallas(q, k, v, nv, softcap=30.0,
                                      interpret=True)
        ref = decode_attention_ref(q, k, v, nv, softcap=30.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)

    def test_matches_flash_on_full_prefix(self):
        """decode(q_last) == flash(q_full)[:, -1] when the cache holds the
        same prefix — the consistency the serving path relies on."""
        B, S, H, K, hd = 1, 128, 4, 2, 64
        q, k, v = _qkv(jax.random.PRNGKey(6), B, S, S, H, K, hd, jnp.float32)
        full = flash_attention_ref(q, k, v, causal=True)
        one = decode_attention_ref(q[:, -1:], k, v,
                                   jnp.asarray(S, jnp.int32))
        np.testing.assert_allclose(np.asarray(one[:, 0]),
                                   np.asarray(full[:, -1]),
                                   rtol=1e-5, atol=1e-5)


def _paged(key, B, n_pages, P, max_pages, H, K, hd, dtype,
           share_first=0):
    """Random page pools + a page table mapping each row to distinct
    pages (optionally aliasing the first ``share_first`` pages across
    every row, the shared-prefix shape).  Page 0 stays trash."""
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, 1, H, hd), dtype)
    kp = jax.random.normal(kk, (n_pages, P, K, hd), dtype)
    vp = jax.random.normal(kv, (n_pages, P, K, hd), dtype)
    table = np.zeros((B, max_pages), np.int32)
    nxt = 1 + share_first
    for b in range(B):
        table[b, :share_first] = range(1, share_first + 1)
        for j in range(share_first, max_pages):
            table[b, j] = nxt
            nxt += 1
    assert nxt <= n_pages
    return q, kp, vp, jnp.asarray(table)


class TestPagedDecodeAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("B,P,max_pages,H,K,hd", [
        (1, 128, 2, 4, 4, 64),
        (2, 128, 4, 8, 2, 64),
        (2, 256, 2, 4, 2, 128),
    ])
    def test_sweep_vs_ref_and_dense(self, dtype, B, P, max_pages, H, K, hd):
        """Pallas-interpret == paged ref == dense ref over the gathered
        ring, for scalar n_valid at several fills."""
        T = P * max_pages
        q, kp, vp, table = _paged(jax.random.PRNGKey(11), B,
                                  1 + B * max_pages, P, max_pages, H, K, hd,
                                  dtype)
        for n_valid in (P // 2, T // 2, T):
            nv = jnp.asarray(n_valid, jnp.int32)
            out = decode_attention_paged_pallas(q, kp, vp, table, nv,
                                                interpret=True)
            ref = decode_attention_paged_ref(q, kp, vp, table, nv)
            dense = decode_attention_ref(q, gather_pages_ref(kp, table),
                                         gather_pages_ref(vp, table), nv)
            np.testing.assert_allclose(np.asarray(out, np.float32),
                                       np.asarray(ref, np.float32),
                                       **TOL[dtype])
            np.testing.assert_allclose(np.asarray(ref, np.float32),
                                       np.asarray(dense, np.float32),
                                       **TOL[dtype])

    def test_vector_n_valid_shared_pages(self):
        """(B,) per-row lengths over a table whose first page is ALIASED
        across rows (shared prefix): parity, and each row must equal a
        single-row dense call over its own gathered ring."""
        B, P, max_pages, H, K, hd = 4, 128, 3, 4, 2, 64
        q, kp, vp, table = _paged(jax.random.PRNGKey(12), B,
                                  1 + 1 + B * max_pages, P, max_pages, H, K,
                                  hd, jnp.float32, share_first=1)
        nv = jnp.asarray([P - 7, P * max_pages, P + 1, 1], jnp.int32)
        out = decode_attention_paged_pallas(q, kp, vp, table, nv,
                                            interpret=True)
        ref = decode_attention_paged_ref(q, kp, vp, table, nv)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
        dense_k = gather_pages_ref(kp, table)
        dense_v = gather_pages_ref(vp, table)
        for i in range(B):
            solo = decode_attention_ref(q[i:i + 1], dense_k[i:i + 1],
                                        dense_v[i:i + 1], nv[i])
            np.testing.assert_allclose(
                np.asarray(ref[i]), np.asarray(solo[0]), rtol=2e-5,
                atol=2e-5, err_msg=f"row {i} != its own gathered ring")

    def test_unmapped_pages_inert(self):
        """Entries past the valid length (0 = trash sentinel) must not
        leak into the output: scribbling on the trash page and on the
        unmapped tail pages changes nothing."""
        B, P, max_pages, H, K, hd = 2, 128, 3, 4, 2, 64
        q, kp, vp, table = _paged(jax.random.PRNGKey(13), B,
                                  1 + B * max_pages, P, max_pages, H, K, hd,
                                  jnp.float32)
        tbl = np.asarray(table).copy()
        tbl[:, -1] = 0                          # last logical page unmapped
        nv = jnp.asarray([P, 2 * P], jnp.int32)   # valid stops before it
        base = decode_attention_paged_ref(q, kp, vp, jnp.asarray(tbl), nv)
        unmapped = np.unique(np.asarray(table)[:, -1])
        kp2 = kp.at[0].set(999.0).at[unmapped].set(-999.0)
        out = decode_attention_paged_ref(q, kp2, vp, jnp.asarray(tbl), nv)
        np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                                   rtol=1e-6, atol=1e-6)

    def test_softcap(self):
        B, P, max_pages, H, K, hd = 2, 128, 2, 4, 2, 64
        q, kp, vp, table = _paged(jax.random.PRNGKey(14), B,
                                  1 + B * max_pages, P, max_pages, H, K, hd,
                                  jnp.float32)
        nv = jnp.asarray([40, 200], jnp.int32)
        out = decode_attention_paged_pallas(q, kp, vp, table, nv,
                                            softcap=30.0, interpret=True)
        ref = decode_attention_paged_ref(q, kp, vp, table, nv, softcap=30.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


class TestSSMScan:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("Bt,L,DI,N,chunk", [
        (1, 128, 64, 8, 32),
        (2, 256, 128, 16, 64),
        (1, 64, 256, 16, 64),
    ])
    def test_sweep_vs_ref(self, dtype, Bt, L, DI, N, chunk):
        key = jax.random.PRNGKey(7)
        ks = jax.random.split(key, 5)
        x = jax.random.normal(ks[0], (Bt, L, DI), dtype)
        dt = jax.random.normal(ks[1], (Bt, L, DI), dtype) * 0.1
        A = -jnp.abs(jax.random.normal(ks[2], (DI, N), jnp.float32)) - 0.1
        B = jax.random.normal(ks[3], (Bt, L, N), dtype)
        C = jax.random.normal(ks[4], (Bt, L, N), dtype)
        D = jnp.ones((DI,), jnp.float32) * 0.5
        y, h = ssm_scan_pallas(x, dt, A, B, C, D, chunk=chunk,
                               interpret=True)
        y_ref, h_ref = ssm_scan_ref(x, dt, A, B, C, D)
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(y_ref, np.float32),
                                   **TOL[dtype])
        np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                                   rtol=1e-3, atol=1e-3)

    def test_scan_equals_stepwise(self):
        """Chunked scan == token-by-token recurrence (decode consistency)."""
        Bt, L, DI, N = 1, 32, 16, 8
        ks = jax.random.split(jax.random.PRNGKey(8), 5)
        x = jax.random.normal(ks[0], (Bt, L, DI), jnp.float32)
        dt = jax.random.normal(ks[1], (Bt, L, DI), jnp.float32) * 0.1
        A = -jnp.abs(jax.random.normal(ks[2], (DI, N), jnp.float32)) - 0.1
        B = jax.random.normal(ks[3], (Bt, L, N), jnp.float32)
        C = jax.random.normal(ks[4], (Bt, L, N), jnp.float32)
        D = jnp.ones((DI,), jnp.float32)
        y_scan, h_scan = ssm_scan_ref(x, dt, A, B, C, D)
        h = jnp.zeros((Bt, DI, N), jnp.float32)
        ys = []
        for t in range(L):
            y_t, h = ssm_step_ref(x[:, t], dt[:, t], A, B[:, t], C[:, t],
                                  D, h)
            ys.append(y_t)
        y_step = jnp.stack(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_step),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(h_scan), np.asarray(h),
                                   rtol=1e-5, atol=1e-5)
