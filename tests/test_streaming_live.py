"""Live continuous batching: the persistent slot-pool decode is REAL.

The slot-cached streamed greedy decode must be token-exact against the
full-forward reference (both against a per-request full-forward loop and
against each other under membership churn), slot reuse must never leak a
freed tenant's K/V into the next one, the compiled-shape audit must stay
O(1) in decode steps, and the whole request-stream path must serve PfF
end-to-end through the LiveExecutor with per-request latency records —
feeding the measured per-slot cache bytes back into the recipe's slot
budget.
"""
import numpy as np
import pytest

from repro.cluster import Application, LiveExecutor, Scheduler, Worker
from repro.cluster.hardware import GPU_CATALOG
from repro.configs import get_smoke_config
from repro.data import accuracy, generate_claims
from repro.data.tokenizer import ByteTokenizer
from repro.inference import (MAX_NEW, StreamingDecoder, build_context_recipe,
                             make_pff_step_fn, stream_verdict)
from repro.models import model as M


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("smollm2-1.7b")
    claims = generate_claims(10, seed=2)
    recipe = build_context_recipe(cfg, "with_evidence")
    payloads = {e.name: e.loader() for e in recipe.elements}
    return cfg, claims, recipe, payloads


class TestStreamingDecoder:
    def test_streamed_greedy_matches_reference(self, setup):
        """Batched-and-padded stepping == isolated full-forward greedy."""
        cfg, claims, _, payloads = setup
        eng = payloads["xla_executable"]
        ci = payloads["context_inputs"]
        dec = StreamingDecoder(eng.cfg, eng.params, ci["tokenizer"],
                               ci["template"])
        rids = list(range(4))
        for i in rids:
            dec.ensure(i, claims[i])
        streamed = {i: [] for i in rids}
        for _ in range(MAX_NEW):
            for i, t in dec.step(rids).items():
                streamed[i].append(t)
        for i in rids:
            toks = list(ci["tokenizer"].encode(
                ci["template"].render(claims[i]))[:96])
            ref = []
            for _ in range(MAX_NEW):
                logits = np.asarray(M.forward(
                    cfg, eng.params,
                    {"tokens": np.asarray([toks], np.int32)}))
                nxt = int(np.argmax(logits[0, len(toks) - 1]))
                toks.append(nxt)
                ref.append(nxt)
            assert streamed[i] == ref, f"request {i} diverged"

    def test_membership_churn_keeps_requests_exact(self, setup):
        """Requests leaving/joining between steps must not change the
        tokens of the ones that stay."""
        cfg, claims, _, payloads = setup
        eng = payloads["xla_executable"]
        ci = payloads["context_inputs"]
        mk = lambda: StreamingDecoder(eng.cfg, eng.params,
                                      ci["tokenizer"], ci["template"])
        solo, churn = mk(), mk()
        solo.ensure(0, claims[0])
        alone = []
        for _ in range(MAX_NEW):
            alone.append(solo.step([0])[0])
        churn.ensure(0, claims[0])
        churn.ensure(1, claims[1])
        churn.ensure(2, claims[2])
        got = []
        got.append(churn.step([0, 1, 2])[0])     # B=3 (padded to 4)
        got.append(churn.step([0, 1])[0])        # member 2 left
        churn.ensure(3, claims[3])
        for _ in range(MAX_NEW - 2):
            got.append(churn.step([0, 3])[0])    # member 3 joined
        assert got == alone

    def test_shape_buckets_bounded(self, setup):
        cfg, claims, _, payloads = setup
        eng = payloads["xla_executable"]
        ci = payloads["context_inputs"]
        dec = StreamingDecoder(eng.cfg, eng.params, ci["tokenizer"],
                               ci["template"])
        for i in range(6):
            dec.ensure(i, claims[i])
        for step in range(MAX_NEW):
            dec.step(list(range(6 if step < 4 else 3)))
        # 6→pad 8 and 3→pad 4 batches, sequence growth inside one
        # 8-multiple: at most a handful of compiled shapes
        assert dec.shape_buckets <= 4


class TestSlotPoolDecoding:
    """The slot-cached path vs the full-forward reference path."""

    def _mk(self, payloads, **kw):
        eng = payloads["xla_executable"]
        ci = payloads["context_inputs"]
        return StreamingDecoder(eng.cfg, eng.params, ci["tokenizer"],
                                ci["template"], **kw)

    def _churn(self, dec, claims, budget, concurrent=3):
        """Admissions/finishes interleaved at every step: one admission per
        step while a slot is free, finish as soon as a request hits its
        budget.  Returns {rid: [tokens]}."""
        toks = {rid: [] for rid in budget}
        pending = sorted(budget, reverse=True)
        live = []
        while live or pending:
            if pending and len(live) < concurrent:
                rid = pending.pop()
                dec.ensure(rid, claims[rid])
                live.append(rid)
            for rid, t in dec.step(live).items():
                toks[rid].append(t)
            for rid in list(live):
                if len(toks[rid]) >= budget[rid]:
                    dec.finish(rid)
                    live.remove(rid)
        return toks

    @pytest.mark.parametrize("paged", [False, True])
    def test_churn_token_exact_and_slot_reuse_no_leak(self, setup, paged):
        """10 requests through a ≤4-slot pool, membership changing at
        every step: every slot is re-tenanted at least once, and the
        slot-cached tokens must equal the full-forward reference's —
        a freed slot's stale K/V leaking into its next tenant would
        diverge immediately.  Runs both KV layouts: contiguous per-slot
        rings and refcounted pages (the rendered claim prompts share the
        template preamble, so the paged run also exercises prefix reuse
        under churn)."""
        cfg, claims, _, payloads = setup
        slot = self._mk(payloads, paged=paged)
        full = self._mk(payloads, slot_cached=False)
        budget = {rid: 3 + (rid % 4) for rid in range(10)}
        got = self._churn(slot, claims, budget)
        ref = self._churn(full, claims, budget)
        assert got == ref
        assert slot.pool.capacity <= 4 < len(budget), \
            "pool must have re-tenanted freed slots"
        assert len(slot.pool) == 0 and slot.pool.free == slot.pool.capacity

    def test_recompile_audit_constant_in_steps(self, setup):
        """Stable membership: after the admission prefill and the first
        decode, EVERY further step reuses the same compiled shapes."""
        cfg, claims, _, payloads = setup
        dec = self._mk(payloads)
        for rid in range(3):
            dec.ensure(rid, claims[rid])
        rids = list(range(3))
        dec.step(rids)                                  # admission prefill
        dec.step(rids)                                  # first cached step
        buckets_after_two = dec.shape_buckets
        for _ in range(24):
            dec.step(rids)
        assert dec.shape_buckets == buckets_after_two
        assert dec.shape_buckets <= 3

    def test_b_max_presized_pool(self, setup):
        """A pool pre-sized to the library's slot budget never grows."""
        cfg, claims, _, payloads = setup
        dec = self._mk(payloads, b_max=4)
        for rid in range(4):
            dec.ensure(rid, claims[rid])
        dec.step(list(range(4)))
        assert dec.pool.capacity == 4
        assert dec.measured_slot_bytes > 0


class TestLiveStreamServing:
    def test_pff_request_stream_end_to_end(self, setup):
        cfg, claims, recipe, _ = setup
        sched = Scheduler()
        app = Application(sched)
        key = app.register(recipe)
        for _ in range(2):
            sched.add_worker(Worker(GPU_CATALOG["NVIDIA A10"]))
        for c in claims:
            app.submit(key, decode_steps=MAX_NEW, payload=c)
        ex = LiveExecutor(sched, step_fns={key: make_pff_step_fn()})
        ex.run()
        tok = ByteTokenizer(cfg.vocab_size)
        preds = [stream_verdict(tok, ex.results[r.request_id])
                 for r in app.requests]
        assert len(preds) == len(claims)
        assert all(p in ("SUPPORTED", "REFUTED", "NOT ENOUGH INFO")
                   for p in preds)
        assert 0.0 <= accuracy(preds, claims) <= 1.0
        assert sched.completed_inferences == len(claims) * MAX_NEW
        recs = app.records()
        assert len(recs) == len(claims)
        assert all(not r.exclusive for r in recs)
        assert all(r.ttfs_s >= 0 and r.queue_wait_s >= 0 for r in recs)
        assert sched.admissions > 0, \
            "later claims must be admitted into the live batch"
        # slot budgets from measured memory: the live run must have fed the
        # REAL per-slot cache footprint back into the recipe, displacing
        # the KV_BYTES_PER_PARAM analytic estimate
        assert recipe.measured_slot_bytes > 0
        assert recipe.decode_slot_bytes(1.71e9) == recipe.measured_slot_bytes

    def test_stream_predictions_deterministic(self, setup):
        """Two runs with different worker counts give identical verdicts
        (continuous batching must not change RESULTS, only timing)."""
        cfg, claims, recipe, _ = setup

        def run(workers):
            sched = Scheduler()
            app = Application(sched)
            key = app.register(recipe)
            for _ in range(workers):
                sched.add_worker(Worker(GPU_CATALOG["NVIDIA A10"]))
            for c in claims:
                app.submit(key, decode_steps=MAX_NEW, payload=c)
            ex = LiveExecutor(sched, step_fns={key: make_pff_step_fn()})
            ex.run()
            tok = ByteTokenizer(cfg.vocab_size)
            return [stream_verdict(tok, ex.results[r.request_id])
                    for r in app.requests]

        assert run(1) == run(2)
