"""Prefill/decode disaggregation: per-phase device model, phase-tagged
routing with the ship-vs-local rule, the KV_SHIP lifecycle on the
context plane, phase-split latency records, the preemption-rate warm
pool signal, and shipped-KV token exactness on the live decoder.
"""
import dataclasses

import pytest

from repro.core import (ClusterView, LinkBudget, OpKind, WarmPoolPolicy,
                        model_context_recipe)
from repro.cluster import (Application, DECODE, GPU_CATALOG, Gateway,
                           LiveExecutor, PREFILL, Scheduler, Worker,
                           format_latency, latency_summary, make_sim,
                           pool_rate)
from repro.cluster.hardware import (DeviceModel, PREFILL_MFU,
                                    PREFILL_TOKENS_PER_UNIT)
from repro.configs import get_config

CFG = get_config("smollm2-1.7b")
RECIPE = model_context_recipe(CFG, include_compile=False)
AP = CFG.n_active_params()
A10 = GPU_CATALOG["NVIDIA A10"]
ADA = GPU_CATALOG["NVIDIA RTX 6000 Ada Generation"]
H100 = GPU_CATALOG["NVIDIA H100 80GB HBM3"]
TITAN = GPU_CATALOG["NVIDIA TITAN X (Pascal)"]

# compute-rich but HBM-poor vs the reverse: a rig where shipping the KV
# after prefill strictly beats decoding in place
PREFILL_RIG = DeviceModel("prefill-rig", 2024, 1, 1.0, 24, 500e6, 8e9,
                          tflops=500.0)
DECODE_RIG = DeviceModel("decode-rig", 2024, 1, 0.08, 80, 500e6, 8e9,
                         tflops=5.0)


def _run_disagg_sim(devices, n_reqs, *, disaggregate=True, prompt_units=4,
                    decode_steps=32, workers_per_zone=4, arrival_every=0.25):
    sched, ex, fac = make_sim(devices=devices,
                              workers_per_zone=workers_per_zone,
                              disaggregate=disaggregate)
    app = Application(sched)
    key = app.register(RECIPE, active_params=AP)
    app.submit_stream(ex, [dict(recipe_key=key, prompt_units=prompt_units,
                                decode_steps=decode_steps,
                                arrival_s=i * arrival_every)
                           for i in range(n_reqs)])
    fac.reconcile(len(devices))
    ex.run(until=20_000.0)
    assert sched.done
    return sched


def assert_kv_balanced(sched):
    assert sched.plane.planned.as_dict() == sched.plane.moved.as_dict()
    assert sched.plane.inflight_ops == 0
    kv = sched.plane.kv_summary()
    assert sum(sched.plane.kv_shipped.values()) == kv["shipped_bytes"]


# ---------------------------------------------------------------------------
# DeviceModel: the two phases rank devices differently
# ---------------------------------------------------------------------------

class TestPhaseModel:
    def test_prefill_is_flop_bound(self):
        flops = 2.0 * AP * PREFILL_TOKENS_PER_UNIT
        assert H100.prefill_time(AP, 1) == pytest.approx(
            flops / (H100.tflops * 1e12 * PREFILL_MFU))
        assert H100.prefill_time(AP, 3) == pytest.approx(
            3 * H100.prefill_time(AP, 1))

    def test_phase_spreads_diverge(self):
        """The disaggregation opportunity: matmul throughput spreads far
        wider across the catalog than HBM-bound decode speed."""
        decode_spread = TITAN.infer_time(AP) / H100.infer_time(AP)
        prefill_spread = TITAN.prefill_time(AP, 1) / H100.prefill_time(AP, 1)
        assert prefill_spread > 5 * decode_spread

    def test_uncatalogued_tflops_falls_back_to_balanced(self):
        legacy = dataclasses.replace(A10, tflops=0.0)
        assert legacy.prefill_time(AP, 5) == pytest.approx(
            5 * legacy.infer_time(AP))

    def test_pool_rate_phases(self):
        pool = [ADA, A10, TITAN]
        legacy = pool_rate(pool, AP)
        assert legacy == pytest.approx(
            sum(1.0 / d.infer_time(AP) for d in pool))
        prefill = pool_rate(pool, AP, phase="prefill")
        decode = pool_rate(pool, AP, phase="decode")
        assert prefill == pytest.approx(
            sum(1.0 / d.prefill_time(AP, 1) for d in pool))
        # every device counts toward BOTH phase capacities
        assert decode == pytest.approx(
            sum(1.0 / d.step_time(AP, 1) for d in pool))
        with pytest.raises(ValueError):
            pool_rate(pool, AP, phase="training")


# ---------------------------------------------------------------------------
# Phase tagging at submit
# ---------------------------------------------------------------------------

class TestPhaseTagging:
    def _mk(self, disaggregate):
        sched = Scheduler(disaggregate=disaggregate)
        app = Application(sched)
        key = app.register(RECIPE, active_params=AP)
        return sched, app, key

    def test_split_candidate_is_tagged_prefill(self):
        sched, app, key = self._mk(True)
        r = app.submit(key, prompt_units=3, decode_steps=8, payload=0)
        assert r.phase == PREFILL

    def test_untagged_without_opt_in_or_prompt(self):
        sched, app, key = self._mk(False)
        assert app.submit(key, prompt_units=3, decode_steps=8,
                          payload=0).phase is None
        sched, app, key = self._mk(True)
        assert app.submit(key, decode_steps=8, payload=0).phase is None


# ---------------------------------------------------------------------------
# KV_SHIP lifecycle on the plane
# ---------------------------------------------------------------------------

class TestShipLifecycle:
    def _plane(self):
        sched = Scheduler()
        sched.register_context(RECIPE)
        return sched.plane

    def _op(self, plane, nbytes=1000, dst_zone="z1"):
        return plane.kv_ship_op(RECIPE.key, "w0", "w1", nbytes,
                                src_zone="z0", dst_zone=dst_zone)

    def test_commit_then_complete_balances(self):
        plane = self._plane()
        op = self._op(plane)
        plane.commit_kv_ship(7, op)
        assert plane.inflight_ops == 1
        plane.kv_ship_completed(7)
        assert plane.planned.as_dict() == plane.moved.as_dict()
        assert plane.kv_shipped == {"z1": 1000}
        assert plane.kv_summary()["ship_events"] == 1
        assert plane.inflight_ops == 0

    def test_complete_is_stale_safe(self):
        plane = self._plane()
        plane.commit_kv_ship(7, self._op(plane))
        plane.kv_ship_completed(7)
        plane.kv_ship_completed(7)          # late DES timer: no-op
        assert plane.kv_summary()["ship_events"] == 1
        assert plane.planned.as_dict() == plane.moved.as_dict()

    def test_abort_refunds_and_is_idempotent(self):
        plane = self._plane()
        plane.commit_kv_ship(7, self._op(plane))
        plane.kv_ship_aborted(7)
        plane.kv_ship_aborted(7)
        assert plane.inflight_ops == 0
        assert plane.kv_summary()["ship_events"] == 0
        # full refund: the planned meter nets back to zero everywhere
        assert all(v == 0 for row in plane.planned.as_dict().values()
                   for v in row.values())

    def test_drop_worker_aborts_touching_ships(self):
        plane = self._plane()
        plane.commit_kv_ship(1, self._op(plane))                # src dies
        plane.commit_kv_ship(2, plane.kv_ship_op(
            RECIPE.key, "w2", "w0", 500, src_zone="z1", dst_zone="z0"))
        plane.commit_kv_ship(3, plane.kv_ship_op(
            RECIPE.key, "w2", "w3", 500, src_zone="z1", dst_zone="z1"))
        plane.drop_worker("w0")
        assert sorted(plane._inflight_ships) == [3]
        plane.kv_ship_completed(3)
        assert plane.planned.as_dict() == plane.moved.as_dict()

    def test_ship_admission_respects_link_budget(self):
        sched = Scheduler(link_budget=LinkBudget(
            cross_bytes_per_window=100, window_s=10.0))
        sched.register_context(RECIPE)
        plane = sched.plane
        small = self._op(plane, nbytes=80)
        big = self._op(plane, nbytes=200)
        assert plane.ship_admits(small, 0.0)
        assert not plane.ship_admits(big, 0.0)
        plane.commit_kv_ship(1, small, 0.0)
        assert not plane.ship_admits(small, 1.0)    # window now full
        assert plane.ship_admits(small, 60.0)       # window slid past


# ---------------------------------------------------------------------------
# Routing: ship-vs-local in the DES
# ---------------------------------------------------------------------------

class TestShipVsLocal:
    def test_homogeneous_pool_takes_the_fast_path(self):
        """Identical devices: shipping only adds cost, so every decode
        stays on its prefill worker."""
        sched = _run_disagg_sim([A10] * 2, 8, workers_per_zone=2,
                                decode_steps=8)
        assert sched.kv_ships == 0
        assert sched.local_decodes == 8
        assert sched.prefills_done == 8
        assert_kv_balanced(sched)

    def test_heterogeneous_pool_ships(self):
        """Mixed pool under load: once the compute-rich workers' decode
        slots fill, freshly prefilled KV ships to the memory-side pool
        instead of queueing behind the fast prefill engines."""
        sched = _run_disagg_sim([ADA] * 2 + [A10] * 6, 40)
        assert sched.kv_ships > 0
        assert sched.prefills_done == 40
        assert sched.plane.kv_summary()["shipped_bytes"] > 0
        shipped = [r for r in sched.records
                   if r.outcome == "done" and r.ship_s > 0]
        assert len(shipped) == sched.kv_ships
        assert_kv_balanced(sched)

    def test_disaggregation_completes_equal_work_no_slower(self):
        pool = [ADA] * 2 + [A10] * 6
        col = _run_disagg_sim(pool, 40, disaggregate=False)
        dis = _run_disagg_sim(pool, 40, disaggregate=True)

        def units(s):
            return sum(r.n_units for r in s.records if r.outcome == "done")
        assert units(dis) == units(col) > 0
        assert dis.kv_ships > 0
        assert dis.makespan() <= col.makespan() * 1.01
        assert_kv_balanced(dis)
        assert_kv_balanced(col)

    def test_legacy_run_is_untouched(self):
        """disaggregate=False never phase-splits, ships, or prefills."""
        sched = _run_disagg_sim([A10] * 4, 12, disaggregate=False)
        assert sched.kv_ships == sched.local_decodes == 0
        assert sched.prefills_done == 0
        assert all(r.prefill_s == 0.0 for r in sched.records)
        assert_kv_balanced(sched)


# ---------------------------------------------------------------------------
# Per-phase latency records
# ---------------------------------------------------------------------------

class TestPhaseLatency:
    def test_records_split_by_phase(self):
        sched = _run_disagg_sim([PREFILL_RIG, DECODE_RIG], 8,
                                workers_per_zone=2, decode_steps=8,
                                arrival_every=0.0)
        done = [r for r in sched.records if r.outcome == "done"]
        assert all(r.prefill_s > 0 for r in done)
        shipped = [r for r in done if r.ship_s > 0]
        assert len(shipped) == sched.kv_ships
        for r in done:
            assert r.decode_s == pytest.approx(
                max(0.0, (r.t_end - r.t_start) - r.ship_s))

    def test_latency_summary_reports_phases(self):
        sched = _run_disagg_sim([PREFILL_RIG, DECODE_RIG], 8,
                                workers_per_zone=2, decode_steps=8,
                                arrival_every=0.0)
        summ = latency_summary(sched.records)
        assert summ["n_phased"] == 8
        assert summ["n_shipped"] == sched.kv_ships
        for name in ("prefill", "ship", "decode"):
            assert f"{name}_p50_s" in summ
        assert "[phases]" in format_latency(summ)

    def test_phase_keys_absent_without_disaggregation(self):
        sched = _run_disagg_sim([A10] * 2, 6, disaggregate=False,
                                workers_per_zone=2, decode_steps=8)
        summ = latency_summary(sched.records)
        assert "n_phased" not in summ and "prefill_p50_s" not in summ
        assert "[phases]" not in format_latency(summ)


# ---------------------------------------------------------------------------
# Preemption-rate warm-pool signal (satellite)
# ---------------------------------------------------------------------------

class TestPreemptHorizon:
    def _view(self, sched, key, rate):
        return ClusterView(workers=sched.workers, registry=sched.registry,
                           demand={key: 1}, preempt_rate={key: rate})

    def test_preempt_rate_inflates_replica_demand(self):
        sched = Scheduler()
        key = sched.register_context(RECIPE)
        for _ in range(8):
            sched.add_worker(Worker(A10))
        reactive = WarmPoolPolicy(tasks_per_replica=1, max_fraction=1.0)
        stormy = dataclasses.replace(reactive, preempt_horizon_s=10.0)
        view = self._view(sched, key, rate=0.5)
        # 1 queued + 0.5/s * 10s horizon = 6 tasks of demand
        assert reactive.intents(view)[0].n == 1
        assert stormy.intents(view)[0].n == 6

    def test_scheduler_tracks_preemption_ewma(self):
        sched = Scheduler()
        key = sched.register_context(RECIPE)
        for t in (10.0, 11.0, 12.0):
            sched._note_event(sched._preempts, key, t)
        assert sched.view(12.0).preempt_rate[key] > 0
        assert sched.view(12.0).preempt_rate.get("other") is None


# ---------------------------------------------------------------------------
# Gateway: banked progress never times out at the edge (satellite)
# ---------------------------------------------------------------------------

class TestExpirableProgress:
    def test_decode_phase_requeue_keeps_its_slot(self):
        sched = Scheduler()
        gw = Gateway(sched)
        app = Application(sched)
        key = app.register(RECIPE, active_params=AP)
        fresh = app.make_request(key, decode_steps=4, payload=0,
                                 slo="interactive", deadline_s=5.0)
        banked = app.make_request(key, decode_steps=4, payload=1,
                                  slo="interactive", deadline_s=5.0)
        banked.steps_done = 2           # mid-service: prefill KV is banked
        sched.submit(fresh)
        sched.submit(banked)
        assert gw.next_deadline() == 5.0
        expired = gw.expire(10.0)
        assert [r.request_id for r in expired] == [fresh.request_id]
        assert banked in sched.lanes[key]
        # the deadline timer must never re-arm on the unexpirable request
        assert gw.next_deadline() is None


# ---------------------------------------------------------------------------
# Live: shipped KV decodes token-exact (both layouts)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def live_setup():
    from repro.configs import get_smoke_config
    from repro.data import generate_claims
    from repro.inference import build_context_recipe
    cfg = get_smoke_config("smollm2-1.7b")
    return (cfg, generate_claims(4, seed=2),
            build_context_recipe(cfg, "with_evidence"))


class TestLiveShippedKV:
    def _run(self, claims, recipe, *, disaggregate, paged):
        from repro.inference import make_pff_step_fn
        sched = Scheduler(disaggregate=disaggregate)
        app = Application(sched)
        key = app.register(recipe)
        sched.add_worker(Worker(PREFILL_RIG))
        sched.add_worker(Worker(DECODE_RIG))
        for c in claims:
            app.submit(key, prompt_units=2, decode_steps=5, payload=c)
        ex = LiveExecutor(sched,
                          step_fns={key: make_pff_step_fn(paged=paged)})
        ex.run()
        return [ex.results[r.request_id] for r in app.requests], sched

    @pytest.mark.parametrize("paged", [False, True],
                             ids=["contiguous", "paged"])
    def test_shipped_decode_matches_colocated(self, live_setup, paged):
        cfg, claims, recipe = live_setup
        base, _ = self._run(claims, recipe, disaggregate=False, paged=paged)
        dis, sched = self._run(claims, recipe, disaggregate=True,
                               paged=paged)
        assert base == dis
        assert sched.kv_ships > 0
        assert sched.prefills_done == len(claims)
        assert all(len(t) == 7 for t in dis)     # 2 prefill + 5 decode
        assert sched.plane.kv_summary()["shipped_bytes"] > 0
        assert_kv_balanced(sched)

    def test_adopted_bytes_metered_apart_from_resume(self, live_setup):
        """A shipped snapshot adopts into the destination decoder's pool
        under its own counter, so preemption resume accounting stays
        exact."""
        cfg, claims, recipe = live_setup
        _, sched = self._run(claims, recipe, disaggregate=True, paged=False)
        decs = [lib.context.payloads.get("_stream_decoder")
                for w in sched.workers.values()
                for lib in w.libraries.values()]
        decs = [d for d in decs if d is not None]
        assert sum(d.kv_adopt_bytes_total for d in decs) > 0
        # the same-worker fast path RESUMES its own suspended snapshot
        # (kv_resume_bytes_total); only shipped snapshots adopt
        resumed = sum(d.kv_resume_bytes_total for d in decs)
        assert (resumed > 0) == (sched.local_decodes > 0)
